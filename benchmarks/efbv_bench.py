"""EF-BV benchmark (the ``efbv`` comm mode): bits-to-target vs the two
mechanisms it unifies.

EF-BV (Condat, Li & Richtárik, 2022) is the shift recursion
``h += eta * C(g - h)`` with estimator ``g_bar = h_bar + nu * m_bar``:
``eta = nu = 1`` is EF21 (error feedback for BIASED contractive
operators), and for UNBIASED operators the damped ``eta = 1/(1+omega)``
is DIANA at its optimal alpha.  This bench measures both regimes on the
theorem-test ridge instance:

  * biased Top-K: EF-BV at its recommended (eta, nu) vs EF21 — same
    operator, same tuned-gamma protocol, bits/iters to rel_err <= 1e-6;
  * unbiased Rand-K: EF-BV (damped) vs DIANA — the variance-reduction
    side of the unification.

Writes the machine-readable ``BENCH_efbv.json`` next to the repo root
(uploaded as a CI artifact alongside ``BENCH_overlap.json``) so the
algorithm-quality trajectory is tracked run over run.
"""

from __future__ import annotations

from benchmarks.common import (
    finite_or_none as _finite,
    fmt_bits,
    print_table,
    tuned_run,
    write_bench_json,
)
from repro.core import (
    DCGDShift,
    DianaShift,
    EF21Shift,
    EFBVShift,
    RandK,
    TopK,
    efbv_params,
    stepsize_diana,
    stepsize_ef21,
    stepsize_efbv,
)
from repro.core.simulate import run_dcgd_shift
from repro.data.problems import make_ridge

TOL = 1e-6
STEPS = 20_000
OUT_JSON = "BENCH_efbv.json"


def main(steps: int = STEPS):
    # noise=10: the non-interpolating regime (same fixture as the
    # theorem tests) — shift quality decides the reachable tolerance
    prob = make_ridge(m=100, d=80, n_workers=10, seed=0, noise=10.0)
    results = {}
    rows = []

    # -- biased route: Top-K, EF-BV vs EF21 -------------------------------
    for qf in (0.1, 0.25):
        c = TopK(qf)
        delta = c.delta(prob.d)
        g_ef = stepsize_ef21(prob.L, prob.L_max, delta)
        bits_e, it_e, _ = tuned_run(
            lambda m: run_dcgd_shift(
                prob, DCGDShift(q=c, rule=EF21Shift()), g_ef * m, steps,
                name="ef21"),
            multipliers=(1, 4, 16, 64), tol=TOL,
        )
        eta, nu = efbv_params(delta=delta)
        g_bv = stepsize_efbv(prob.L, prob.L_max, delta=delta, eta=eta, nu=nu)
        bits_b, it_b, _ = tuned_run(
            lambda m: run_dcgd_shift(
                prob, DCGDShift(q=c, rule=EFBVShift(eta=eta, nu=nu)),
                g_bv * m, steps, name="efbv"),
            multipliers=(1, 4, 16, 64), tol=TOL,
        )
        key = f"topk_q{qf}"
        results[key] = {
            "efbv": {"bits": _finite(bits_b), "iters": _finite(it_b),
                     "eta": eta, "nu": nu},
            "ef21": {"bits": _finite(bits_e), "iters": _finite(it_e)},
        }
        rows.append((f"top-k q={qf} (biased)",
                     f"{it_b:.0f}", fmt_bits(bits_b),
                     f"{it_e:.0f}", fmt_bits(bits_e), "ef21"))

    # -- unbiased route: Rand-K, damped EF-BV vs DIANA --------------------
    for qf in (0.1, 0.25):
        u = RandK(qf)
        omega = u.omega(prob.d)
        eta, nu = efbv_params(omega=omega)
        g_bv = stepsize_efbv(prob.L, prob.L_max, omega=omega, eta=eta, nu=nu)
        bits_b, it_b, _ = tuned_run(
            lambda m: run_dcgd_shift(
                prob, DCGDShift(q=u, rule=EFBVShift(eta=eta, nu=nu)),
                g_bv * m, steps, name="efbv"),
            multipliers=(1, 4, 16, 64), tol=TOL,
        )
        alpha, g_di = stepsize_diana(prob.L_max, omega, 0.0, prob.n_workers)
        # same tuning grid as the EF-BV side — the comparison must
        # measure the algorithm, not the protocol
        bits_d, it_d, _ = tuned_run(
            lambda m: run_dcgd_shift(
                prob, DCGDShift(q=u, rule=DianaShift(alpha=alpha)),
                g_di * m, steps, name="diana"),
            multipliers=(1, 4, 16, 64), tol=TOL,
        )
        key = f"randk_q{qf}"
        results[key] = {
            "efbv": {"bits": _finite(bits_b), "iters": _finite(it_b),
                     "eta": eta, "nu": nu},
            "diana": {"bits": _finite(bits_d), "iters": _finite(it_d)},
        }
        rows.append((f"rand-k q={qf} (unbiased)",
                     f"{it_b:.0f}", fmt_bits(bits_b),
                     f"{it_d:.0f}", fmt_bits(bits_d), "diana"))

    print_table(
        "EF-BV vs the mechanisms it unifies (bits/iters to rel_err <= 1e-6)",
        ["compressor", "EF-BV iters", "EF-BV bits",
         "baseline iters", "baseline bits", "baseline"],
        rows,
    )
    write_bench_json(OUT_JSON, results)
    return results


if __name__ == "__main__":
    main()
