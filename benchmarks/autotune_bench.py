"""Autotuner benchmark: predicted vs measured step time per comm mode.

Runs the ``repro.tune`` pipeline on a synthetic worker-stacked gradient
tree over 8 fake devices (subprocess, like the dist tests — the parent
process must keep its single device): calibrate the alpha-beta link
model by timed micro-reduces, predict each candidate mode's step time
from the structural wire model, then MEASURE every candidate through
its real channel and mark the plan the tuner picks.  The artifact is
the tuner's trust record: if predicted ranking and measured ranking
drift apart run over run, the cost model is rotting.

Writes the machine-readable ``BENCH_autotune.json`` next to the repo
root (uploaded as a CI artifact alongside ``BENCH_overlap.json`` /
``BENCH_efbv.json``).

NOTE on CPU numbers: fake-device collectives share one memory bus, so
alpha dominates and the measured ranking mostly reflects launch/dispatch
structure, not TPU link speed — predicted-vs-measured AGREEMENT per
mode is the portable signal, and the fused overlap mode runs
interpret-mode Pallas (keep the tree tiny in smoke).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import REPO_ROOT as REPO, print_table, write_bench_json

ITERS = 5
OUT_JSON = "BENCH_autotune.json"

_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.tune import (
    Candidate, calibrate_link, compose_step_s, measure_candidate,
    predict_step,
)

iters = {iters}
smoke = {smoke}
mesh = jax.make_mesh((8, 1), ("data", "model"))
key = jax.random.PRNGKey(0)
w = 8

# synthetic reverse-layer gradient stack (kept modest so the fused
# overlap candidate's interpret-mode Pallas stays benchmarkable on CPU)
dims = [(256, 256), (256, 512), (512,), (256, 256), (64, 256), (333,)]
if smoke:
    dims = dims[:4]
tree = {{
    f"layer{{i:02d}}": jax.random.normal(jax.random.fold_in(key, i), (w, *d))
    for i, d in enumerate(dims)
}}
tree = jax.device_put(tree, NamedSharding(mesh, P("data")))

bucket = 256 << 10   # tiny bucket: the synthetic tree is ~1 MB/worker
candidates = [
    Candidate("dense"),
    Candidate("randk_shared", randk_q=0.05),
    Candidate("q8_ring"),
    Candidate("q8_ring_overlap", bucket_bytes=bucket),
]

link = calibrate_link(mesh, tree, iters=iters)
rows = {{}}
best, best_t = None, float("inf")
for c in candidates:
    pred = predict_step(c, tree, link, w)
    comm_s = measure_candidate(c, mesh, tree, key, iters=iters)
    step_s = compose_step_s(pred.compute_s, comm_s, c.overlap)
    rows[c.label] = {{
        "comm_mode": c.comm_mode,
        "predicted_step_s": pred.step_s,
        "measured_step_s": step_s,
        "wire_bytes": pred.wire_bytes,
        "n_buckets": pred.n_buckets,
        "chosen": False,
    }}
    if step_s < best_t:
        best, best_t = c.label, step_s
rows[best]["chosen"] = True
rows["_link"] = {{"alpha_s": link.alpha_s,
                  "beta_s_per_byte": link.beta_s_per_byte}}
print("BENCH_JSON " + json.dumps(rows))
"""


def main(iters: int = ITERS, smoke: bool = False):
    iters = max(2, iters)
    r = subprocess.run(
        [sys.executable, "-c", _CHILD.format(iters=iters, smoke=smoke)],
        capture_output=True, text=True, timeout=1800,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=REPO,
    )
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("BENCH_JSON ")),
        None,
    )
    if line is None:
        raise RuntimeError(
            f"autotune bench child failed:\n{r.stdout}\n{r.stderr[-3000:]}"
        )
    results = json.loads(line[len("BENCH_JSON "):])
    write_bench_json(OUT_JSON, results)
    rows = [
        (
            label,
            f"{m['predicted_step_s'] * 1e3:.2f}ms",
            f"{m['measured_step_s'] * 1e3:.2f}ms",
            f"{m['wire_bytes'] / 1e6:.3f}MB",
            m["n_buckets"],
            "<- chosen" if m["chosen"] else "",
        )
        for label, m in results.items() if not label.startswith("_")
    ]
    print_table(
        "Autotuner: predicted vs measured step time over 8 fake devices "
        "(CPU: alpha-dominated; agreement per mode is the signal)",
        ["candidate", "predicted", "measured", "wire/worker", "buckets", ""],
        rows,
    )
    return results


if __name__ == "__main__":
    main()
