"""Serve-delta benchmark: N serving replicas kept fresh off the shifted
model-delta stream while a REAL smoke trainer runs.

Runs ``repro.serving.run_fleet_demo`` in a subprocess (process
isolation, like the other benches) for a ladder of model-wire codecs —
the lossless ``dense`` bit-pattern delta stream, ``q8`` and ``natural``
— and records per variant the delta bytes per publish/step against the
dense-broadcast baseline (``bytes_fraction``), the per-publish
``err_rel`` series (the shrinking-delta effect: error falls as training
converges), the max staleness seen against the bound K, resync count,
and the tokens the fleet actually served.  The artifact is the serving
layer's cost record: every variant must sustain the decode traffic at
staleness <= K, with the compressed rows moving a small fraction of the
dense broadcast bytes.

Staleness/resync bookkeeping is event-sourced: the bridge's ``stats()``
reads the structured obs events the fleet emits (``publish``,
``fleet_resync``, ``fleet_staleness``...), and the ``obs events``
column prints the raw counts so the table provably agrees with the
JSONL a ``--metrics_out`` run would persist.

Writes the machine-readable ``BENCH_serve_delta.json`` next to the repo
root (uploaded as a CI artifact alongside the other BENCH files).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import REPO_ROOT as REPO, print_table, write_bench_json

STEPS = 8
OUT_JSON = "BENCH_serve_delta.json"

_CHILD = """
import json

from repro.serving import run_fleet_demo

rows = {{}}
for flag in ("dense", "q8", "natural"):
    rows[flag] = run_fleet_demo(
        "qwen3-0.6b", n_replicas=2, model_wire=flag, publish_every=2,
        stale_k=4, steps={steps}, n_requests=4, gen_len=8,
    )
print("BENCH_JSON " + json.dumps(rows))
"""


def main(steps: int = STEPS, smoke: bool = False):
    steps = max(4, 4 if smoke else steps)
    r = subprocess.run(
        [sys.executable, "-c", _CHILD.format(steps=steps)],
        capture_output=True, text=True, timeout=1800,
        env={**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"},
        cwd=REPO,
    )
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("BENCH_JSON ")),
        None,
    )
    if line is None:
        raise RuntimeError(
            f"serve_delta bench child failed:\n{r.stdout}\n{r.stderr[-3000:]}"
        )
    results = json.loads(line[len("BENCH_JSON "):])
    write_bench_json(OUT_JSON, results)
    rows = [
        (
            flag,
            f"{m['delta_bytes_per_publish'] / 1e6:.3f}MB",
            f"{m['dense_bytes_per_publish'] / 1e6:.3f}MB",
            f"{m['bytes_fraction']:.3f}",
            f"{m['err_rel'][0]:.1e}->{m['err_rel'][-1]:.1e}"
            if m["err_rel"] else "n/a",
            f"{m['max_staleness']}/{m['stale_k']}",
            str(m["resyncs"]),
            str(m["tokens_served"]),
            " ".join(f"{k}:{v}"
                     for k, v in sorted(m.get("obs_events", {}).items())),
        )
        for flag, m in results.items()
    ]
    print_table(
        "model-delta downlink: 2 replicas off one shifted stream "
        "(publish_every=2; err column is first->last publish — the "
        "shrinking-delta effect; obs events = the event-sourced ledger)",
        ["wire", "delta B/pub", "dense B/pub", "fraction", "err_rel",
         "stale/K", "resyncs", "tokens", "obs events"],
        rows,
    )
    return results


if __name__ == "__main__":
    main()
