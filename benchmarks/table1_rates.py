"""Table 1: iteration complexities of the DCGD-SHIFT instances.

For each method we measure empirical iterations to rel_err <= 1e-6 on
ridge regression and report them against the theoretical complexity
kappa(1 + omega/n)-style expressions (up to log 1/eps and constants —
we validate the ORDERING and the omega-scaling, which is what the table
claims)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import print_table
from repro.core import (
    DCGDShift,
    DianaShift,
    FixedShift,
    GDCI,
    RandDianaShift,
    RandK,
    StarShift,
    VRGDCI,
    rand_diana_default_p,
    stepsize_dcgd_fixed,
    stepsize_dcgd_star,
    stepsize_diana,
    stepsize_gdci,
    stepsize_rand_diana,
    stepsize_vr_gdci,
)
from repro.core.simulate import run_dcgd_shift, run_gdci
from repro.data.problems import make_ridge

TOL = 1e-6
STEPS = 30_000


def main(steps: int = STEPS):
    prob = make_ridge(m=100, d=80, n_workers=10, seed=0)
    q = RandK(0.25)
    omega = q.omega(prob.d)
    n = prob.n_workers
    kappa = prob.kappa

    runs = {}
    g = stepsize_dcgd_fixed(prob.L, prob.L_max, omega, n)
    runs["DCGD-FIXED(h=0)"] = (
        run_dcgd_shift(prob, DCGDShift(q=q, rule=FixedShift()), g, steps),
        f"neighborhood (Thm 1)",
    )
    g = stepsize_dcgd_star(prob.L, prob.L_max, omega, 0.0, n)
    runs["DCGD-STAR"] = (
        run_dcgd_shift(prob, DCGDShift(q=q, rule=StarShift()), g, steps,
                       use_star=True),
        f"~kappa(1+w/n) = {kappa * (1 + omega / n):.0f} (Thm 2)",
    )
    alpha, g = stepsize_diana(prob.L_max, omega, 0.0, n)
    runs["DIANA"] = (
        run_dcgd_shift(prob, DCGDShift(q=q, rule=DianaShift(alpha=alpha)),
                       g, steps),
        f"max{{kappa(1+w/n), w}} (Thm 3)",
    )
    p = rand_diana_default_p(omega)
    _, g = stepsize_rand_diana(prob.L_max, omega, n, p)
    runs["RAND-DIANA"] = (
        run_dcgd_shift(prob, DCGDShift(q=q, rule=RandDianaShift(p=p)),
                       g, steps),
        f"max{{kappa(1+w/n), 1/p={1/p:.0f}}} (Thm 4)",
    )
    eta, gamma = stepsize_gdci(prob.L, prob.L_max, prob.mu, omega, n)
    runs["GDCI"] = (
        run_gdci(prob, GDCI(q=q, gamma=gamma, eta=eta), steps),
        "neighborhood; kappa(1+w/n) (Thm 5, improved over kappa^2)",
    )
    a2, e2, g2 = stepsize_vr_gdci(prob.L, prob.L_max, prob.mu, omega, n)
    runs["VR-GDCI"] = (
        run_gdci(prob, VRGDCI(q=q, gamma=g2, eta=e2, alpha=a2), steps),
        "max{2(w+1), (1+6w/n)kappa} (Thm 6)",
    )

    rows = []
    for name, (tr, theory) in runs.items():
        it = tr.steps_to_tol(TOL)
        final = float(tr.rel_err[-1])
        rows.append((
            name,
            f"{it:.0f}" if np.isfinite(it) else f"plateau@{final:.1e}",
            theory,
        ))
    print_table(
        f"Table 1: iterations to rel_err<=1e-6 (ridge, Rand-K q=0.25, "
        f"kappa={kappa:.0f}, omega={omega:.1f}, n={n})",
        ["method", "iters (empirical)", "theoretical rate"], rows,
    )
    return rows


if __name__ == "__main__":
    main()
