"""Pallas kernel microbenchmarks (interpret=True on CPU).

Wall times here are the INTERPRETER's, not TPU times — the deliverable
on CPU is correctness parity + the VMEM-tiling structure; real speed
comes from the fused single-pass design on TPU (see kernel docstrings).
We report us/call for kernel vs pure-jnp reference at several sizes so
regressions in either path are visible."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import print_table
from repro.kernels.natural.ops import shifted_natural
from repro.kernels.natural.ref import shifted_natural_ref
from repro.kernels.topk.ops import block_topk
from repro.kernels.topk.ref import block_topk_ref
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref


def _time(fn, *args, n=5):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def main(smoke: bool = False):
    """``smoke=True`` shrinks every size to a CI-scale config — same
    code paths (pallas interpret + jnp reference), seconds not minutes."""
    rows = []
    key = jax.random.PRNGKey(0)

    for n in (4_096,) if smoke else (32_768, 1_048_576):
        g = jax.random.normal(key, (n,))
        h = jnp.zeros((n,))
        t_k = _time(lambda: shifted_natural(key, g, h))
        u = jax.random.uniform(key, (n,))
        t_r = _time(jax.jit(shifted_natural_ref), g, h, u)
        rows.append((f"shifted_natural n={n}", f"{t_k:.0f}us", f"{t_r:.0f}us"))

    for n in (8_192,) if smoke else (65_536, 1_048_576):
        x = jax.random.normal(key, (n,))
        t_k = _time(lambda: block_topk(x, q=0.1))
        x2 = x.reshape(-1, 128)
        # k is PER 8192-element (64x128) block of the reference, not per n
        t_r = _time(jax.jit(
            lambda a: block_topk_ref(a, k=819, block=64)), x2)
        rows.append((f"block_topk n={n}", f"{t_k:.0f}us", f"{t_r:.0f}us"))

    b, t, hh, d = (1, 64, 2, 64) if smoke else (2, 256, 4, 64)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, t, hh, d))
    k2 = jax.random.normal(ks[1], (b, t, hh, d))
    v = jax.random.normal(ks[2], (b, t, hh, d))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, t, hh, d))))
    u2 = jax.random.normal(ks[4], (hh, d))
    t_k = _time(lambda: wkv6(r, k2, v, w, u2))
    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * hh, t, x.shape[-1])
    ub = jnp.broadcast_to(u2[None], (b, hh, d)).reshape(b * hh, d)
    t_r = _time(jax.jit(wkv6_ref), to_bh(r), to_bh(k2), to_bh(v), to_bh(w), ub)
    rows.append((f"wkv6 B{b}xT{t}xH{hh}x{d}", f"{t_k:.0f}us", f"{t_r:.0f}us"))

    print_table("Pallas kernels (interpret=True) vs jnp reference",
                ["kernel", "pallas us/call", "ref us/call"], rows)
    return rows


if __name__ == "__main__":
    main()
