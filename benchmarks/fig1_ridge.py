"""Figure 1: DIANA vs Rand-DIANA on ridge regression (m=100, d=80,
n=10 workers — the paper's exact setup).

Protocols reported:
  * TUNED gamma (best over power-of-2 multiples of the theoretical step
    size, among converging runs) — the implicit protocol behind the
    paper's figures; metric = ITERATIONS to rel_err <= 1e-6 and bits.
  * theory gamma (exact Theorem 3/4 step sizes) for reference.

Paper's claims reproduced / checked:
  * Fig1-left: Rand-DIANA beats DIANA for every Rand-K q (we observe
    this in ITERATIONS for most q under tuned gamma; under our FULL bit
    accounting — which charges Rand-DIANA's rare full-vector refresh
    p*32d bits/step — DIANA leads on wire bits; see EXPERIMENTS.md
    discussion of the accounting difference).
  * Fig1-right: DIANA with tuned Natural-Dithering s* can beat
    Rand-DIANA; Rand-DIANA preferable at s=2 (aggressive compression).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (
    diana_run,
    fmt_bits,
    print_table,
    rand_diana_run,
    tuned_run,
)
from repro.core import (
    DCGDShift,
    DianaShift,
    NaturalDithering,
    RandDianaShift,
    RandK,
    rand_diana_default_p,
    stepsize_diana,
    stepsize_rand_diana,
)
from repro.core.simulate import run_dcgd_shift
from repro.data.problems import make_ridge

TOL = 1e-6
STEPS = 20_000


def _pair(prob, q, steps):
    omega = q.omega(prob.d)
    alpha, g_d = stepsize_diana(prob.L_max, omega, 0.0, prob.n_workers)
    p = rand_diana_default_p(omega)
    _, g_r = stepsize_rand_diana(prob.L_max, omega, prob.n_workers, p)

    bits_d, it_d, _ = tuned_run(
        lambda m: run_dcgd_shift(
            prob, DCGDShift(q=q, rule=DianaShift(alpha=alpha)),
            g_d * m, steps),
        tol=TOL,
    )
    bits_r, it_r, _ = tuned_run(
        lambda m: run_dcgd_shift(
            prob, DCGDShift(q=q, rule=RandDianaShift(p=p)),
            g_r * m, steps),
        tol=TOL,
    )
    return (bits_d, it_d), (bits_r, it_r)


def main(steps: int = STEPS):
    prob = make_ridge(m=100, d=80, n_workers=10, seed=0)
    rows, iter_wins = [], 0
    qs = (0.1, 0.25, 0.5, 0.75, 0.9)
    for qf in qs:
        (bd, id_), (br, ir) = _pair(prob, RandK(qf), steps)
        iter_wins += ir < id_
        rows.append((f"rand-k q={qf}", f"{id_:.0f}", f"{ir:.0f}",
                     fmt_bits(bd), fmt_bits(br),
                     "rand-diana" if ir < id_ else "diana"))
    print_table(
        "Fig1-left (tuned gamma): DIANA vs Rand-DIANA, Rand-K",
        ["compressor", "DIANA iters", "RD iters", "DIANA bits", "RD bits",
         "iter winner"], rows,
    )
    print(f"rand-diana wins {iter_wins}/{len(qs)} q values on iterations "
          f"(paper Fig1: wins on its bits metric for all q)")

    rows = []
    best = {}
    for s in (2, 4, 8, 16):
        (bd, id_), (br, ir) = _pair(prob, NaturalDithering(s), steps)
        best[s] = (id_, ir)
        rows.append((f"nat-dith s={s}", f"{id_:.0f}", f"{ir:.0f}",
                     fmt_bits(bd), fmt_bits(br),
                     "rand-diana" if ir < id_ else "diana"))
    print_table(
        "Fig1-right (tuned gamma): DIANA vs Rand-DIANA, Natural Dithering",
        ["compressor", "DIANA iters", "RD iters", "DIANA bits", "RD bits",
         "iter winner"], rows,
    )
    return rows


if __name__ == "__main__":
    main()
