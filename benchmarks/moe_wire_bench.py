"""MoE-wire benchmark: step time + per-wire bytes with the expert
dispatch/combine all-to-all (and optionally the pipeline-boundary
activations) routed through the codec transport.

Runs the REAL train step (``launch/train.build_train_step``) on the
qwen2-moe smoke config in a subprocess (process isolation, like the
autotune bench) for a ladder of wire configurations — grad wire only,
``moe_wire`` at identity width, ``moe_wire`` q8, and q8 on both the moe
and act wires — and records the median step time, the final loss, and
the structural per-wire bytes from the same ``Transport.per_wire_bits``
accounting the dry-run table prints.  The artifact is the wire layer's
cost record: the q8 rows should show ~4x fewer moe-wire bytes than the
dense row at a loss within noise of the grad-only row.

Writes the machine-readable ``BENCH_moe_wire.json`` next to the repo
root (uploaded as a CI artifact alongside ``BENCH_autotune.json``).

NOTE on CPU numbers: with one host device the all-to-all never leaves
the chip, so step TIME differences mostly reflect codec encode/decode
compute — the bytes table is the portable signal.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import REPO_ROOT as REPO, print_table, write_bench_json

STEPS = 5
OUT_JSON = "BENCH_moe_wire.json"

_CHILD = """
import json
import time

import jax
import jax.numpy as jnp

from repro.comm import build_transport
from repro.configs import get_smoke_config
from repro.configs.base import CompressionConfig, TrainConfig
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_host_mesh, n_workers
from repro.launch.train import build_train_step, init_state
from repro.models import model as M

steps = {steps}
batch, seq = 8, 64
cfg = get_smoke_config("qwen2-moe-a2.7b").with_(dtype="float32")
mesh = make_host_mesh()
w = n_workers(mesh)
params_shapes = jax.eval_shape(
    lambda k: M.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
)

variants = [
    ("grad-only", "none", "none"),
    ("moe-dense", "dense", "none"),
    ("moe-q8", "q8", "none"),
    ("moe-q8+act-q8", "q8", "q8"),
]
rows = {{}}
for label, mw, aw in variants:
    comp = CompressionConfig(comm_mode="dense", shift_rule="diana",
                             moe_wire=mw, act_wire=aw)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=steps,
                      compression=comp)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
    step_fn = jax.jit(build_train_step(cfg, tcfg, mesh, w))
    stream = TokenStream(cfg, seq, batch)
    state, m = step_fn(state, stream.batch(0))  # compile + warm
    jax.block_until_ready(m["loss"])
    times = []
    for i in range(1, steps + 1):
        t0 = time.perf_counter()
        state, m = step_fn(state, stream.batch(i))
        jax.block_until_ready(m["loss"])
        times.append(time.perf_counter() - t0)
    times.sort()
    transport = build_transport(
        comp, cfg, None, w=w, params_like=params_shapes,
        tokens_per_worker=batch * seq // max(w, 1),
    )
    rows[label] = {{
        "moe_wire": mw,
        "act_wire": aw,
        "step_s": times[len(times) // 2],
        "final_loss": float(m["loss"]),
        "wire_bytes": {{n: b / 8.0
                        for n, b in transport.per_wire_bits().items()}},
    }}
print("BENCH_JSON " + json.dumps(rows))
"""


def main(steps: int = STEPS, smoke: bool = False):
    steps = max(2, 2 if smoke else steps)
    r = subprocess.run(
        [sys.executable, "-c", _CHILD.format(steps=steps)],
        capture_output=True, text=True, timeout=1800,
        env={**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"},
        cwd=REPO,
    )
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("BENCH_JSON ")),
        None,
    )
    if line is None:
        raise RuntimeError(
            f"moe_wire bench child failed:\n{r.stdout}\n{r.stderr[-3000:]}"
        )
    results = json.loads(line[len("BENCH_JSON "):])
    write_bench_json(OUT_JSON, results)
    rows = [
        (
            label,
            m["moe_wire"],
            m["act_wire"],
            f"{m['step_s'] * 1e3:.1f}ms",
            f"{m['final_loss']:.4f}",
            f"{m['wire_bytes'].get('moe', 0.0) / 1e6:.3f}MB",
            f"{m['wire_bytes'].get('act', 0.0) / 1e6:.3f}MB",
        )
        for label, m in results.items()
    ]
    print_table(
        "MoE/activation wires through the codec transport (CPU: bytes "
        "are the portable signal; times reflect codec compute)",
        ["variant", "moe", "act", "step", "loss", "moe B/step",
         "act B/step"],
        rows,
    )
    return results


if __name__ == "__main__":
    main()
