"""Fused-backward-encode benchmark: step time + peak-HBM proxy.

Runs the REAL train step (``launch/train.build_train_step``) on the
qwen3-0.6b smoke config over 8 fake devices in a subprocess (process
isolation, like the overlap bench) for three comm modes:

  dense              no compression (the baseline the paper beats)
  q8_ring_overlap    post-hoc encode: dense backward, then the bucketed
                     AsyncChannel encodes + reduces each bucket
  q8_ring_fused_vjp  backward-fused encode: each layer's message is
                     emitted AS its cotangent (``repro.comm.fused_vjp``),
                     per-leaf buckets, no standalone encode stage

For each mode it records the median wall-clock step time, the final
loss, the per-round uplink bits the trainer accounted, and a peak-HBM
proxy from the compiled step's ``memory_analysis()`` (temp + argument
bytes — the quantity the fused path shrinks by never materialising the
dense message tree between backward and encode).  Writes the
machine-readable ``BENCH_fused_vjp.json`` next to the repo root.

NOTE on CPU numbers: interpret-mode Pallas makes absolute times
unrepresentative; the portable signals are the memory proxy, the bits
accounting, and fused-vs-overlap step-time RATIO (both run the same
kernels — the delta is the deleted standalone encode stage).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import REPO_ROOT as REPO, print_table, write_bench_json

STEPS = 5
OUT_JSON = "BENCH_fused_vjp.json"

_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import time

import jax

from repro.configs import get_smoke_config
from repro.configs.base import CompressionConfig, TrainConfig
from repro.data.tokens import TokenStream
from repro.launch.train import build_train_step, init_state

steps = {steps}
batch, seq = 8, {seq}
cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
mesh = jax.make_mesh((8, 1), ("data", "model"))
w = 8

results = {{}}
for mode in ("dense", "q8_ring_overlap", "q8_ring_fused_vjp"):
    comp = CompressionConfig(comm_mode=mode, shift_rule="diana",
                             compressor="natural",
                             overlap_bucket_bytes=256 << 10)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=steps,
                       compression=comp)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
    step_fn = jax.jit(build_train_step(cfg, tcfg, mesh, w))
    stream = TokenStream(cfg, seq, batch)
    compiled = step_fn.lower(state, stream.batch(0)).compile()
    mem = {{}}
    try:
        ma = compiled.memory_analysis()
        for f in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes"):
            v = getattr(ma, f, None)
            if v is not None:
                mem[f] = int(v)
    except Exception:
        pass
    state, m = step_fn(state, stream.batch(0))  # warm
    jax.block_until_ready(m["loss"])
    bits0 = float(state.bits)
    times = []
    for i in range(1, steps + 1):
        t0 = time.perf_counter()
        state, m = step_fn(state, stream.batch(i))
        jax.block_until_ready(m["loss"])
        times.append(time.perf_counter() - t0)
    times.sort()
    results[mode] = {{
        "step_time_s": times[len(times) // 2],
        "final_loss": float(m["loss"]),
        "uplink_bits_per_round": (float(state.bits) - bits0) / steps,
        "peak_hbm_proxy_bytes": sum(mem.values()) if mem else None,
        "memory_analysis": mem,
    }}
print("BENCH_JSON " + json.dumps(results))
"""


def main(steps: int = STEPS, smoke: bool = False):
    steps = max(2, 2 if smoke else steps)
    seq = 32 if smoke else 64
    r = subprocess.run(
        [sys.executable, "-c", _CHILD.format(steps=steps, seq=seq)],
        capture_output=True, text=True, timeout=1800,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=REPO,
    )
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("BENCH_JSON ")),
        None,
    )
    if line is None:
        raise RuntimeError(
            f"fused_vjp bench child failed:\n{r.stdout}\n{r.stderr[-3000:]}"
        )
    results = json.loads(line[len("BENCH_JSON "):])
    write_bench_json(OUT_JSON, results)
    rows = [
        (
            mode,
            f"{m['step_time_s'] * 1e3:.1f}ms",
            f"{m['final_loss']:.4f}",
            f"{m['uplink_bits_per_round'] / 8e6:.3f}MB",
            (f"{m['peak_hbm_proxy_bytes'] / 1e6:.1f}MB"
             if m.get("peak_hbm_proxy_bytes") else "n/a"),
        )
        for mode, m in results.items()
    ]
    print_table(
        "Fused backward encode: real train step over 8 fake devices "
        "(interpret-mode kernels on CPU; memory proxy + bits are the "
        "portable signals)",
        ["mode", "step", "loss", "uplink/round", "HBM proxy"],
        rows,
    )
    return results


if __name__ == "__main__":
    main()
