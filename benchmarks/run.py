"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast | --smoke] [--only ...]

--fast shrinks step counts ~4x for CI-style runs; --smoke shrinks them
~50x AND runs the kernel microbench at tiny sizes — the CI job that
keeps every bench entrypoint importable and runnable.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time


def _smoke_summary(elapsed_s: float, suites_run) -> None:
    """Fold every ``BENCH_*.json`` artifact into obs summary records,
    persist them as schema-valid JSONL (the CI artifact), and print ONE
    aggregate table — the single place the smoke run reports itself."""
    from benchmarks.common import REPO_ROOT
    from repro import obs

    sink = obs.JsonlSink(
        os.path.join(REPO_ROOT, "experiments", "obs", "bench_smoke.jsonl")
    )
    records = [obs.summary_record(
        "bench_smoke", suites=sorted(suites_run), elapsed_s=elapsed_s,
    )]
    for path in sorted(glob.glob(os.path.join(REPO_ROOT, "BENCH_*.json"))):
        with open(path) as f:
            payload = json.load(f)
        records.append(obs.summary_record(
            os.path.basename(path),
            entries=len(payload) if isinstance(payload, (dict, list)) else 1,
            bytes=os.path.getsize(path),
        ))
    for rec in records:
        sink.emit(rec)
    sink.close()

    rows = [(r["name"], r["data"].get("entries", len(suites_run)),
             r["data"].get("bytes", ""))
            for r in records]
    print(obs.format_table("bench smoke aggregate (obs records)",
                           ["artifact", "entries", "bytes"], rows))
    print(f"obs records: {sink.path}")

    # every smoke run also extends the bench trajectory: one flattened,
    # sha+fingerprint-keyed ledger entry per artifact, the input to the
    # repro.obs.regress CI gate
    from repro.obs import history

    paths = sorted(glob.glob(os.path.join(REPO_ROOT, "BENCH_*.json")))
    if paths:
        ledger = os.path.join(REPO_ROOT, history.DEFAULT_HISTORY_PATH)
        recs = history.ingest(paths, ledger)
        print(f"history: ingested {len(recs)} artifacts -> {ledger}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config run of every suite (CI smoke)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig2,fig4,table1,"
                         "gdci,ef21,efbv,kernels,overlap,fused_vjp,"
                         "autotune,moe_wire,serve_delta,roofline")
    args = ap.parse_args(argv)
    scale = 50 if args.smoke else (4 if args.fast else 1)

    from benchmarks import (
        autotune_bench,
        ef21_bench,
        efbv_bench,
        fig1_ridge,
        fig2_stability,
        fig4_logreg,
        fused_vjp_bench,
        gdci_bench,
        kernels_bench,
        moe_wire_bench,
        overlap_bench,
        roofline_report,
        serve_delta_bench,
        table1_rates,
    )

    suites = {
        "fig1": lambda: fig1_ridge.main(steps=fig1_ridge.STEPS // scale),
        "fig2": lambda: fig2_stability.main(steps=fig2_stability.STEPS // scale),
        "fig4": lambda: fig4_logreg.main(steps=fig4_logreg.STEPS // scale),
        "table1": lambda: table1_rates.main(steps=table1_rates.STEPS // scale),
        "gdci": lambda: gdci_bench.main(steps=gdci_bench.STEPS // scale),
        "ef21": lambda: ef21_bench.main(steps=ef21_bench.STEPS // scale),
        "efbv": lambda: efbv_bench.main(steps=efbv_bench.STEPS // scale),
        "kernels": lambda: kernels_bench.main(smoke=args.smoke),
        "overlap": lambda: overlap_bench.main(
            steps=overlap_bench.STEPS // scale, smoke=args.smoke),
        "fused_vjp": lambda: fused_vjp_bench.main(
            steps=max(2, fused_vjp_bench.STEPS // (2 if scale > 1 else 1)),
            smoke=args.smoke),
        "autotune": lambda: autotune_bench.main(
            iters=max(2, autotune_bench.ITERS // (2 if scale > 1 else 1)),
            smoke=args.smoke),
        "moe_wire": lambda: moe_wire_bench.main(
            steps=max(2, moe_wire_bench.STEPS // (2 if scale > 1 else 1)),
            smoke=args.smoke),
        "serve_delta": lambda: serve_delta_bench.main(
            steps=max(4, serve_delta_bench.STEPS // (2 if scale > 1 else 1)),
            smoke=args.smoke),
        "roofline": roofline_report.main,
    }
    only = set(args.only.split(",")) if args.only else set(suites)

    t0 = time.time()
    ran = []
    for name, fn in suites.items():
        if name not in only:
            continue
        print(f"\n{'='*72}\n[{name}]  ({time.time()-t0:.0f}s elapsed)")
        fn()
        ran.append(name)
    if args.smoke:
        _smoke_summary(time.time() - t0, ran)
    print(f"\nall benchmarks done in {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
