"""EF21 error-feedback benchmark (the ``ef21`` comm mode).

Biased contractive compressors (Top-K) plugged straight into DCGD stall
at a bias floor; EF21 (Richtárik, Sokolov & Fatkhullin, 2021) integrates
every compressed residual into the shifts and converges exactly with the
SAME operator and the same per-step wire budget.  This reports, per
keep-fraction q:

  * EF21 iterations/bits to rel_err <= 1e-6 under the tuned-gamma
    protocol (multiples of the EF21 theory step, as in fig1),
  * the bias floor plain DCGD+TopK plateaus at (median tail rel_err),
  * DIANA with the induced-unbiased TopK wrap for reference — the
    unbiased-route alternative at ~2x the wire cost per step.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_bits, print_table, tuned_run
from repro.core import (
    DCGDShift,
    DianaShift,
    EF21Shift,
    FixedShift,
    Induced,
    RandK,
    TopK,
    stepsize_diana,
    stepsize_ef21,
)
from repro.core.simulate import run_dcgd_shift
from repro.data.problems import make_ridge

TOL = 1e-6
STEPS = 20_000


def main(steps: int = STEPS):
    # noise=10: the non-interpolating regime where the DCGD bias floor
    # is far above float32 (same fixture as the theorem tests)
    prob = make_ridge(m=100, d=80, n_workers=10, seed=0, noise=10.0)
    rows = []
    for qf in (0.05, 0.1, 0.25, 0.5):
        c = TopK(qf)
        g_ef = stepsize_ef21(prob.L, prob.L_max, c.delta(prob.d))
        bits_e, it_e, _ = tuned_run(
            lambda m: run_dcgd_shift(
                prob, DCGDShift(q=c, rule=EF21Shift()), g_ef * m, steps,
                name="ef21"),
            multipliers=(1, 4, 16, 64), tol=TOL,
        )
        # the no-feedback baseline: same operator, same tuned gamma range
        tr_d = run_dcgd_shift(
            prob, DCGDShift(q=c, rule=FixedShift()), g_ef * 16, steps)
        floor = float(np.median(tr_d.rel_err[-max(1, steps // 40):]))
        # unbiased route: DIANA with the induced TopK wrap (Lemma 3)
        ind = Induced(c=c, q=RandK(qf))
        alpha, g_di = stepsize_diana(
            prob.L_max, ind.omega(prob.d), 0.0, prob.n_workers)
        bits_i, it_i, _ = tuned_run(
            lambda m: run_dcgd_shift(
                prob, DCGDShift(q=ind, rule=DianaShift(alpha=alpha)),
                g_di * m, steps, name="diana-induced"),
            tol=TOL,
        )
        rows.append((
            f"top-k q={qf}",
            f"{it_e:.0f}", fmt_bits(bits_e),
            f"{floor:.1e}",
            f"{it_i:.0f}", fmt_bits(bits_i),
        ))
    print_table(
        "EF21 (error feedback) vs plain DCGD and induced-DIANA, biased Top-K",
        ["compressor", "EF21 iters", "EF21 bits", "DCGD floor",
         "DIANA-ind iters", "DIANA-ind bits"],
        rows,
    )
    return rows


if __name__ == "__main__":
    main()
