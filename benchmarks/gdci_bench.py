"""GDCI / VR-GDCI (compressed ITERATES — the model-broadcast direction):
neighborhood vs exact convergence, and the kappa-vs-kappa^2 improvement
claim (Thm 5 improves Chraibi et al.'s kappa^2 omega/n rate)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import print_table
from repro.core import (
    GDCI,
    RandK,
    VRGDCI,
    stepsize_gdci,
    stepsize_vr_gdci,
)
from repro.core.simulate import run_gdci
from repro.data.problems import make_ridge

STEPS = 30_000


def main(steps: int = STEPS):
    prob = make_ridge(m=100, d=80, n_workers=10, seed=0)
    rows = []
    for qf in (0.25, 0.5):
        q = RandK(qf)
        omega = q.omega(prob.d)
        eta, gamma = stepsize_gdci(prob.L, prob.L_max, prob.mu, omega,
                                   prob.n_workers)
        t_g = run_gdci(prob, GDCI(q=q, gamma=gamma, eta=eta), steps)
        a, e, g = stepsize_vr_gdci(prob.L, prob.L_max, prob.mu, omega,
                                   prob.n_workers)
        t_v = run_gdci(prob, VRGDCI(q=q, gamma=g, eta=e, alpha=a), steps)
        rows.append((
            f"rand-k q={qf}",
            f"{float(t_g.rel_err[-1]):.2e}",
            f"{float(t_v.rel_err[-1]):.2e}",
            "VR eliminates neighborhood"
            if t_v.rel_err[-1] < 1e-2 * t_g.rel_err[-1] else "check",
        ))
    print_table("GDCI vs VR-GDCI final rel_err (model compression)",
                ["compressor", "GDCI", "VR-GDCI", "verdict"], rows)
    return rows


if __name__ == "__main__":
    main()
