"""Overlap-runtime benchmark: step time + HLO collective bytes + buckets.

Compares the three aggregation paths on a synthetic worker-stacked
gradient tree over 8 fake devices (subprocess, like the dist tests —
the parent process must keep its single device):

  dense             plain psum mean (the no-compression baseline)
  q8_ring           MeshChannel over the generic Int8Stochastic ring
  q8_ring_overlap   AsyncChannel: reverse-layer buckets over the
                    Pallas-fused blockwise-int8 ring

For each mode it reports median wall-clock per reduce step, the
HLO-counted collective bytes of the jitted step (structural: the q8
payloads really appear as s8 on the wire), and the bucket count, and
writes the machine-readable ``BENCH_overlap.json`` next to the repo
root so the perf trajectory is tracked run over run.

NOTE on CPU numbers: the fused kernels run in Pallas interpret mode on
CPU, so *step time* here tracks scheduling structure, not TPU kernel
speed — bytes-on-wire and bucket structure are the portable signals.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import REPO_ROOT as REPO, print_table, write_bench_json

STEPS = 20
OUT_JSON = "BENCH_overlap.json"

_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.comm import make_channel, plan_buckets
from repro.launch.hlo_stats import collective_bytes

steps = {steps}
smoke = {smoke}
mesh = jax.make_mesh((8, 1), ("data", "model"))
key = jax.random.PRNGKey(0)
w = 8

# synthetic reverse-layer gradient stack: a few transformer-ish leaves
# (kept modest so interpret-mode Pallas stays benchmarkable on CPU)
dims = [(256, 256), (256, 512), (512,), (256, 256), (64, 256), (333,)]
if smoke:
    dims = dims[:4]
tree = {{
    f"layer{{i:02d}}": jax.random.normal(jax.random.fold_in(key, i), (w, *d))
    for i, d in enumerate(dims)
}}
tree = jax.device_put(tree, NamedSharding(mesh, P("data")))
n_elem = sum(x.size // w for x in tree.values())

results = {{}}
for mode in ("dense", "q8_ring", "q8_ring_overlap"):
    kw = {{"bucket_bytes": 256 << 10}} if mode == "q8_ring_overlap" else {{}}
    ch = make_channel(mode, mesh, **kw)
    fn = jax.jit(ch.reduce_mean)
    lowered = fn.lower(key, tree)
    coll = collective_bytes(lowered.compile().as_text())
    wire = sum(v for k, v in coll.items() if k != "_counts")
    out = fn(key, tree)
    jax.block_until_ready(out)
    times = []
    for i in range(steps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(jax.random.fold_in(key, 1000 + i), tree))
        times.append(time.perf_counter() - t0)
    times.sort()
    nb = len(plan_buckets(tree, ch.bucket_bytes)) if hasattr(
        ch, "bucket_bytes") else 1
    results[mode] = {{
        "step_time_s": times[len(times) // 2],
        "collective_bytes": int(wire),
        "bucket_count": nb,
        "dense_bytes": int(4 * n_elem),
    }}
print("BENCH_JSON " + json.dumps(results))
"""


def main(steps: int = STEPS, smoke: bool = False):
    steps = max(2, steps)
    r = subprocess.run(
        [sys.executable, "-c", _CHILD.format(steps=steps, smoke=smoke)],
        capture_output=True, text=True, timeout=1800,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=REPO,
    )
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("BENCH_JSON ")),
        None,
    )
    if line is None:
        raise RuntimeError(
            f"overlap bench child failed:\n{r.stdout}\n{r.stderr[-3000:]}"
        )
    results = json.loads(line[len("BENCH_JSON "):])
    write_bench_json(OUT_JSON, results)
    rows = [
        (
            mode,
            f"{m['step_time_s'] * 1e3:.1f}ms",
            f"{m['collective_bytes'] / 1e6:.3f}MB",
            f"{m['collective_bytes'] / m['dense_bytes']:.3f}",
            m["bucket_count"],
        )
        for mode, m in results.items()
    ]
    print_table(
        "Overlap runtime: reduce step over 8 fake devices "
        "(interpret-mode kernels on CPU; bytes are the HLO truth)",
        ["mode", "step", "collective bytes", "vs dense msg", "buckets"],
        rows,
    )
    return results


if __name__ == "__main__":
    main()
