"""Figure 4 (Appendix C): DIANA vs Rand-DIANA on l2-regularized logistic
regression with condition number ~100 (synthetic stand-in for w2a).

Paper's claim: same conclusions as ridge, though DIANA does slightly
better with Rand-K at q = 0.9.  Protocol as fig1 (tuned gamma).
"""

from __future__ import annotations

from benchmarks.common import fmt_bits, print_table, tuned_run
from repro.core import (
    DCGDShift,
    DianaShift,
    NaturalDithering,
    RandDianaShift,
    RandK,
    rand_diana_default_p,
    stepsize_diana,
    stepsize_rand_diana,
)
from repro.core.simulate import run_dcgd_shift
from repro.data.problems import make_logreg

TOL = 1e-5
STEPS = 20_000


def main(steps: int = STEPS):
    prob = make_logreg(m=300, d=60, n_workers=10, kappa_target=100.0)
    rows = []
    for q in (RandK(0.1), RandK(0.5), RandK(0.9),
              NaturalDithering(2), NaturalDithering(8)):
        omega = q.omega(prob.d)
        alpha, g_d = stepsize_diana(prob.L_max, omega, 0.0, prob.n_workers)
        p = rand_diana_default_p(omega)
        _, g_r = stepsize_rand_diana(prob.L_max, omega, prob.n_workers, p)
        bd, id_, _ = tuned_run(
            lambda m: run_dcgd_shift(
                prob, DCGDShift(q=q, rule=DianaShift(alpha=alpha)),
                g_d * m, steps), tol=TOL,
        )
        br, ir, _ = tuned_run(
            lambda m: run_dcgd_shift(
                prob, DCGDShift(q=q, rule=RandDianaShift(p=p)),
                g_r * m, steps), tol=TOL,
        )
        name = (f"rand-k q={q.q}" if isinstance(q, RandK)
                else f"nat-dith s={q.s}")
        rows.append((name, f"{id_:.0f}", f"{ir:.0f}", fmt_bits(bd),
                     fmt_bits(br), "rand-diana" if ir < id_ else "diana"))
    print_table("Fig4 (tuned gamma): logistic regression kappa~100",
                ["compressor", "DIANA iters", "RD iters", "DIANA bits",
                 "RD bits", "iter winner"], rows)
    return rows


if __name__ == "__main__":
    main()
