"""Figure 2 (+ Fig 3): Rand-DIANA stability in (M, p), q = 0.1 regime.

Left: gamma is set from M = b * M' (M' = 2 omega/(n p)); the theory
needs M > M', i.e. b > 1.  Small b inflates gamma beyond the guarantee.
Paper's claim: small b destabilizes/diverges; b = 1.5 is stable but
slower.

Right: (M, gamma) FIXED from the theory at p0 = 0.02, then the actual
refresh probability p varies.  The step-size condition
gamma <= 1/((1+2w/n)L + M max p_i L_i) is violated once p grows past a
threshold -> divergence; below it, smaller p = cheaper steps (bits).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_bits, print_table
from repro.core import (
    DCGDShift,
    RandDianaShift,
    RandK,
    rand_diana_default_p,
    stepsize_rand_diana,
)
from repro.core.simulate import run_dcgd_shift
from repro.data.problems import make_ridge

STEPS = 20_000
TOL = 1e-5


def _status(tr):
    final = float(tr.rel_err[-1])
    if not np.isfinite(final) or final > 10.0:
        return "DIVERGED", final
    return f"{final:.2e}", final


def main(steps: int = STEPS):
    prob = make_ridge(m=100, d=80, n_workers=10, seed=0)
    q = RandK(0.1)
    omega = q.omega(prob.d)
    p_def = rand_diana_default_p(omega)

    # gamma_boost: the theoretical gamma has a large safety margin on this
    # problem (the (1+2w/n)L term caps it); the paper's observed divergence
    # requires operating at the aggressive end, so we scale the base gamma
    # by 8x — then the M > M' margin becomes the live stability constraint.
    BOOST = 8.0
    rows = []
    for b in (0.02, 0.1, 0.5, 1.0, 1.5):
        _, gamma = stepsize_rand_diana(prob.L_max, omega, prob.n_workers,
                                       p_def, M_mult=b)
        tr = run_dcgd_shift(
            prob, DCGDShift(q=q, rule=RandDianaShift(p=p_def)),
            gamma * BOOST, steps,
        )
        s, _ = _status(tr)
        rows.append((f"M = {b} * M'  (gamma={gamma*BOOST:.2e})", s))
    print_table(
        "Fig2-left: final rel_err vs M multiplier at 8x-aggressive gamma "
        "(theory needs M > M'; small M inflates gamma -> divergence)",
        ["setting", "final rel_err"], rows,
    )

    # right: fix (M, gamma) at p0, vary the actual refresh probability
    p0 = 0.02
    _, gamma0 = stepsize_rand_diana(prob.L_max, omega, prob.n_workers, p0)
    gamma0 *= 8.0
    rows = []
    for p in (0.005, 0.02, 0.1, 0.3, 0.8):
        tr = run_dcgd_shift(
            prob, DCGDShift(q=q, rule=RandDianaShift(p=p)), gamma0, steps,
        )
        s, final = _status(tr)
        bits = tr.bits_to_tol(TOL)
        rows.append((f"p = {p:.3f}", s, fmt_bits(bits)))
    print_table(
        f"Fig2-right: (M, gamma) fixed at p0={p0}; actual p varies "
        f"(q=0.1 high compression)",
        ["setting", "final rel_err", f"bits to {TOL}"], rows,
    )
    return rows


if __name__ == "__main__":
    main()
