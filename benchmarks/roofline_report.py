"""Roofline report: aggregates the dry-run JSON artifacts under
experiments/dryrun/ into the §Roofline table (one row per arch x shape
x mesh): three terms, dominant bottleneck, useful-flops fraction."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import print_table


def load_records(path: str = "experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def main(path: str = "experiments/dryrun", mesh: str = "pod256",
         comm: str = "dense"):
    recs = [r for r in load_records(path) if r.get("mesh") == mesh]
    rows = []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            rows.append((r["arch"], r["shape"], "SKIP", "-", "-", "-", "-"))
            continue
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], "ERROR", "-", "-", "-", "-"))
            continue
        rf = r["roofline"]
        rows.append((
            r["arch"], r["shape"],
            rf["dominant"].replace("_s", ""),
            f"{rf['compute_s']:.3f}",
            f"{rf['memory_s']:.3f}",
            f"{rf['collective_s']:.3f}",
            f"{rf['useful_flops_frac']:.2f}",
        ))
    if not rows:
        print(f"(no dry-run artifacts under {path} for mesh={mesh}; run "
              f"PYTHONPATH=src python -m repro.launch.dryrun --all first)")
        return []
    print_table(
        f"Roofline terms per (arch x shape), mesh={mesh} "
        f"(seconds/step/chip; TPU v5e: 197TF bf16, 819GB/s HBM, 50GB/s ICI)",
        ["arch", "shape", "bottleneck", "compute_s", "memory_s",
         "collective_s", "6ND/HLO"],
        rows,
    )
    return rows


if __name__ == "__main__":
    main()
