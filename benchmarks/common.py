"""Shared helpers for the paper-fidelity benchmarks."""

from __future__ import annotations

import os

import numpy as np

from repro.core import (
    DCGDShift,
    DianaShift,
    NaturalDithering,
    RandDianaShift,
    RandK,
    rand_diana_default_p,
    stepsize_diana,
    stepsize_rand_diana,
)
from repro.core.simulate import Trace, run_dcgd_shift
from repro.data.problems import Problem
from repro.obs import finite_or_none, format_table, write_strict_json

__all__ = [
    "REPO_ROOT", "diana_run", "finite_or_none", "fmt_bits", "print_table",
    "rand_diana_run", "tuned_run", "write_bench_json",
]


def diana_run(problem: Problem, q, steps: int, seed: int = 0,
              name: str = "diana") -> Trace:
    omega = q.omega(problem.d)
    alpha, gamma = stepsize_diana(problem.L_max, omega, 0.0,
                                  problem.n_workers)
    return run_dcgd_shift(
        problem, DCGDShift(q=q, rule=DianaShift(alpha=alpha)), gamma, steps,
        seed=seed, name=name,
    )


def rand_diana_run(problem: Problem, q, steps: int, seed: int = 0,
                   p: float | None = None, m_mult: float = 2.0,
                   name: str = "rand-diana") -> Trace:
    omega = q.omega(problem.d)
    p = rand_diana_default_p(omega) if p is None else p
    _, gamma = stepsize_rand_diana(problem.L_max, omega, problem.n_workers,
                                   p, M_mult=m_mult)
    return run_dcgd_shift(
        problem, DCGDShift(q=q, rule=RandDianaShift(p=p)), gamma, steps,
        seed=seed, name=name,
    )


def tuned_run(run_fn, multipliers=(1, 2, 4, 8, 16), tol=1e-6):
    """Paper-style step-size protocol: best bits/iters over gamma
    multipliers of the theoretical step size, among converging runs.
    (The paper's Fig. 1/4 comparisons are only reproducible under a
    tuned-gamma protocol; pure theory-gamma is also reported.)"""
    best_bits, best_iters, best_trace = np.inf, np.inf, None
    for m in multipliers:
        tr = run_fn(m)
        final = float(tr.rel_err[-1])
        if not np.isfinite(final) or final > 1.0:
            continue
        b = tr.bits_to_tol(tol)
        it = tr.steps_to_tol(tol)
        if it < best_iters:
            best_bits, best_iters, best_trace = b, it, tr
    return best_bits, best_iters, best_trace


REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# strict-JSON discipline is shared with the obs sinks — one
# ``finite_or_none``, one sanitize pass, one ``allow_nan=False`` writer
# (``repro.obs``), so bench artifacts and obs JSONL cannot drift apart.


def write_bench_json(name: str, results) -> str:
    """Write one machine-readable ``BENCH_*.json`` next to the repo root
    (the CI-artifact convention every bench shares)."""
    path = write_strict_json(os.path.join(REPO_ROOT, name), results)
    print(f"wrote {path}")
    return path


def fmt_bits(b: float) -> str:
    if not np.isfinite(b):
        return "inf"
    if b > 1e9:
        return f"{b/1e9:.2f}Gb"
    if b > 1e6:
        return f"{b/1e6:.2f}Mb"
    return f"{b/1e3:.1f}Kb"


def print_table(title: str, header: list, rows: list) -> None:
    print(format_table(title, header, rows))
