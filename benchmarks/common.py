"""Shared helpers for the paper-fidelity benchmarks."""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core import (
    DCGDShift,
    DianaShift,
    NaturalDithering,
    RandDianaShift,
    RandK,
    rand_diana_default_p,
    stepsize_diana,
    stepsize_rand_diana,
)
from repro.core.simulate import Trace, run_dcgd_shift
from repro.data.problems import Problem


def diana_run(problem: Problem, q, steps: int, seed: int = 0,
              name: str = "diana") -> Trace:
    omega = q.omega(problem.d)
    alpha, gamma = stepsize_diana(problem.L_max, omega, 0.0,
                                  problem.n_workers)
    return run_dcgd_shift(
        problem, DCGDShift(q=q, rule=DianaShift(alpha=alpha)), gamma, steps,
        seed=seed, name=name,
    )


def rand_diana_run(problem: Problem, q, steps: int, seed: int = 0,
                   p: float | None = None, m_mult: float = 2.0,
                   name: str = "rand-diana") -> Trace:
    omega = q.omega(problem.d)
    p = rand_diana_default_p(omega) if p is None else p
    _, gamma = stepsize_rand_diana(problem.L_max, omega, problem.n_workers,
                                   p, M_mult=m_mult)
    return run_dcgd_shift(
        problem, DCGDShift(q=q, rule=RandDianaShift(p=p)), gamma, steps,
        seed=seed, name=name,
    )


def tuned_run(run_fn, multipliers=(1, 2, 4, 8, 16), tol=1e-6):
    """Paper-style step-size protocol: best bits/iters over gamma
    multipliers of the theoretical step size, among converging runs.
    (The paper's Fig. 1/4 comparisons are only reproducible under a
    tuned-gamma protocol; pure theory-gamma is also reported.)"""
    best_bits, best_iters, best_trace = np.inf, np.inf, None
    for m in multipliers:
        tr = run_fn(m)
        final = float(tr.rel_err[-1])
        if not np.isfinite(final) or final > 1.0:
            continue
        b = tr.bits_to_tol(tol)
        it = tr.steps_to_tol(tol)
        if it < best_iters:
            best_bits, best_iters, best_trace = b, it, tr
    return best_bits, best_iters, best_trace


REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def finite_or_none(x):
    """inf/nan -> None so bench artifacts stay STRICT JSON (json.dump
    would happily emit a bare ``Infinity`` token, which RFC 8259
    parsers — jq, JSON.parse — reject); None means 'no finite value'."""
    x = float(x)
    return x if x == x and abs(x) != float("inf") else None


def write_bench_json(name: str, results) -> str:
    """Write one machine-readable ``BENCH_*.json`` next to the repo root
    (the CI-artifact convention every bench shares).  ``allow_nan=False``:
    fail loudly HERE rather than shipping a non-JSON artifact if a
    non-finite value ever slips past ``finite_or_none``."""
    path = os.path.join(REPO_ROOT, name)
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True, allow_nan=False)
    print(f"wrote {path}")
    return path


def fmt_bits(b: float) -> str:
    if not np.isfinite(b):
        return "inf"
    if b > 1e9:
        return f"{b/1e9:.2f}Gb"
    if b > 1e6:
        return f"{b/1e6:.2f}Mb"
    return f"{b/1e3:.1f}Kb"


def print_table(title: str, header: list, rows: list) -> None:
    print(f"\n## {title}")
    widths = [max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
              for i, h in enumerate(header)]
    print("  ".join(str(h).ljust(w) for h, w in zip(header, widths)))
    for r in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
