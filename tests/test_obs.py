"""The observability layer's contracts (PR 8):

  * schema: records round-trip strict JSON, the version is PINNED
    (wrong ``v`` / unknown keys / non-finite floats all fail loudly),
    and ``sanitize_tree`` is the one nan/inf -> null pass.
  * sinks: JSONL rotation keeps generations; MemorySink/TeeSink feed
    the serving bridge's event-sourced stats; the ``--check`` CLI gate
    exits non-zero on an invalid line.
  * obs OFF is bit-exact: ``build_train_step(diag=True)`` returns the
    IDENTICAL TrainState as ``diag=False`` for every shift rule x
    channel — diagnostics live in the metrics dict only.
  * obs is near-zero-cost on the jit path: ``span`` adds no ops and no
    extra compilations (trace-count pinned).
  * measured-vs-predicted: ``measure_overlap_hide`` yields a hide
    fraction in [0, 1] from the real AsyncChannel handles, and the
    fraction lands in the ``TunePlan`` (``hide_fraction``/
    ``hide_source``) and shifts ``compose_step_s``.
  * per-wire telemetry: ``Transport.obs_snapshot`` reports structural
    wire_bits AND concrete payload bytes (+ finite codec timings).
  * dedupe: ``benchmarks.common`` shares the obs strict-JSON helpers.
"""

import dataclasses
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, tune
from repro.comm import SimChannel, build_transport
from repro.configs import get_smoke_config
from repro.configs.base import CompressionConfig, TrainConfig
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_host_mesh, n_workers
from repro.launch.train import build_train_step, init_state
from repro.models import model as M

tmap = jax.tree_util.tree_map

RULE_CONFIGS = {
    "fixed": CompressionConfig(enabled=True, compressor="natural",
                               shift_rule="fixed"),
    "diana": CompressionConfig(enabled=True, compressor="natural",
                               shift_rule="diana", shift_alpha=0.25),
    "rand_diana": CompressionConfig(enabled=True, compressor="natural",
                                    shift_rule="rand_diana", shift_p=0.5),
    "ef21": CompressionConfig(enabled=True, compressor="topk",
                              compressor_kwargs=(("q", 0.25),),
                              shift_rule="ef21"),
    "efbv": CompressionConfig(enabled=True, compressor="natural",
                              shift_rule="efbv", efbv_eta=0.5, efbv_nu=0.9),
}


def _wtree(key, w=4):
    return {
        "a": jax.random.normal(key, (w, 40)),
        "b": {
            "c": jax.random.normal(jax.random.fold_in(key, 1), (w, 3, 5)),
            "d": jax.random.normal(jax.random.fold_in(key, 2), (w,)),
        },
    }


# ---------------------------------------------------------------------------
# Schema: round-trip, version pinning, strictness
# ---------------------------------------------------------------------------


def test_record_constructors_round_trip_strict_json():
    recs = [
        obs.run_record("train", arch="qwen3", workers=4),
        obs.step_record(3, run="train", loss=1.5, step_s=0.01),
        obs.event_record("resync_requested", 7, replica=0, reason="staleness"),
        obs.summary_record("train", n_steps=8),
    ]
    for rec in recs:
        line = json.dumps(rec, allow_nan=False)      # strict-serializable
        assert obs.validate_record(json.loads(line)) == rec
        assert rec["v"] == obs.SCHEMA_VERSION
        assert rec["kind"] in obs.RECORD_KINDS


def test_schema_version_is_pinned():
    rec = obs.step_record(0, loss=1.0)
    stale = {**rec, "v": obs.SCHEMA_VERSION + 1}
    with pytest.raises(ValueError, match="version"):
        obs.validate_record(stale)
    with pytest.raises(ValueError, match="version"):
        obs.validate_record({**rec, "v": None})


def test_schema_rejects_malformed_records():
    with pytest.raises(ValueError, match="kind"):
        obs.validate_record({"v": obs.SCHEMA_VERSION, "kind": "bogus",
                             "data": {}})
    with pytest.raises(ValueError, match="unknown record keys"):
        obs.validate_record({**obs.step_record(0), "loss": 1.0})
    with pytest.raises(ValueError, match="missing required"):
        obs.validate_record({"v": obs.SCHEMA_VERSION, "kind": "event",
                             "step": 0, "data": {}})
    with pytest.raises(ValueError, match="step"):
        obs.validate_record({"v": obs.SCHEMA_VERSION, "kind": "step",
                             "step": -1, "data": {}})
    with pytest.raises(ValueError, match="non-finite"):
        obs.validate_record({"v": obs.SCHEMA_VERSION, "kind": "step",
                             "step": 0, "data": {"loss": float("nan")}})


def test_sanitize_tree_and_finite_or_none():
    assert obs.finite_or_none(float("inf")) is None
    assert obs.finite_or_none(float("nan")) is None
    assert obs.finite_or_none(2) == 2.0
    out = obs.sanitize_tree({
        "nan": float("nan"),
        "jax": jnp.float32(1.5),
        "np": np.float64(2.5),
        "tup": (1, float("inf")),
        "keep": {"s": "x", "b": True, "n": None, "i": 7},
    })
    assert out["nan"] is None
    assert out["jax"] == 1.5 and isinstance(out["jax"], float)
    assert out["np"] == 2.5
    assert out["tup"] == [1, None]
    assert out["keep"] == {"s": "x", "b": True, "n": None, "i": 7}
    # the record constructors sanitize: device scalars are writable
    rec = obs.step_record(0, loss=jnp.float32(3.0), bad=float("inf"))
    assert rec["data"] == {"loss": 3.0, "bad": None}


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------


def test_jsonl_sink_rotation_and_read_back(tmp_path):
    path = str(tmp_path / "run.jsonl")
    sink = obs.JsonlSink(path, rotate_bytes=512, keep=2)
    for i in range(64):
        sink.emit(obs.step_record(i, loss=float(i)))
    sink.close()
    assert os.path.exists(path)
    assert os.path.exists(path + ".1")          # rotated generation
    assert not os.path.exists(path + ".3")      # keep=2 bounds the set
    live = obs.read_jsonl(path)                 # every line schema-valid
    assert all(r["kind"] == "step" for r in live)
    n, errors = obs.check_jsonl(path + ".1")
    assert n > 0 and errors == []


def test_check_jsonl_collects_all_failures(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    good = json.dumps(obs.step_record(0, loss=1.0))
    with open(path, "w") as f:
        f.write(good + "\n")
        f.write("not json\n")
        f.write(json.dumps({"v": 999, "kind": "step", "step": 1,
                            "data": {}}) + "\n")
    n, errors = obs.check_jsonl(path)
    assert n == 1 and len(errors) == 2
    with pytest.raises(ValueError):
        obs.read_jsonl(path)


def test_export_cli_check_gate(tmp_path):
    from repro.obs import export

    good = str(tmp_path / "good.jsonl")
    sink = obs.JsonlSink(good)
    sink.emit(obs.run_record("r", workers=1))
    sink.emit(obs.step_record(0, run="r", loss=0.5, step_s=0.01,
                              predicted_step_s=0.02))
    sink.close()
    assert export.main(["--check", good]) == 0

    bad = str(tmp_path / "bad.jsonl")
    with open(bad, "w") as f:
        f.write('{"v": 0, "kind": "step", "step": 0, "data": {}}\n')
    assert export.main(["--check", bad]) == 1


def test_memory_and_tee_sinks():
    mem, mirror = obs.MemorySink(), obs.MemorySink()
    tee = obs.TeeSink(mem, None, mirror)        # None sinks are dropped
    tee.emit(obs.event_record("publish", 1, bytes=10.0))
    tee.emit(obs.event_record("fleet_resync", 2, replica=0))
    tee.emit(obs.step_record(3, loss=1.0))
    assert [r["name"] for r in mem.events()] == ["publish", "fleet_resync"]
    assert len(mem.events("publish")) == 1
    assert len(mem.by_kind("step")) == 1
    assert mirror.records == mem.records


def test_typed_metrics():
    m = obs.Metrics()
    m.counter("resyncs").inc()
    m.counter("resyncs").inc(2)
    m.gauge("staleness").set(3.0)
    for x in (0.1, 0.2, 0.3):
        m.histogram("step_s").observe(x)
    m.histogram("step_s").observe(float("nan"))  # ignored, not poisoned
    snap = m.snapshot()
    assert snap["resyncs"] == 3.0
    assert snap["staleness"] == 3.0
    assert snap["step_s"]["count"] == 3
    assert snap["step_s"]["mean"] == pytest.approx(0.2)
    assert snap["step_s"]["min"] == 0.1 and snap["step_s"]["max"] == 0.3
    with pytest.raises(ValueError, match="negative"):
        m.counter("resyncs").inc(-1)
    with pytest.raises(ValueError, match="already registered"):
        m.gauge("resyncs")
    # the snapshot is record-ready
    obs.validate_record(obs.summary_record("metrics", **snap))


# ---------------------------------------------------------------------------
# Obs OFF is bit-exact; spans are free on the jit path
# ---------------------------------------------------------------------------


def _train_setup(comp):
    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    tcfg = TrainConfig(learning_rate=1e-2, total_steps=10, warmup_steps=2,
                       compression=comp)
    mesh = make_host_mesh()
    return cfg, tcfg, mesh, n_workers(mesh)


@pytest.mark.parametrize("comm_mode", ["sim", "dense"])
@pytest.mark.parametrize("name", sorted(RULE_CONFIGS))
def test_diag_metrics_leave_state_bit_exact(name, comm_mode):
    """THE obs-off contract: ``diag=True`` (what ``--metrics_out`` jits)
    returns a TrainState IDENTICAL to ``diag=False`` for every rule x
    channel — h_bar drift / EF error norms are read-only taps."""
    comp = dataclasses.replace(RULE_CONFIGS[name], comm_mode=comm_mode)
    cfg, tcfg, mesh, w = _train_setup(comp)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
    stream = TokenStream(cfg, 16, 4)

    step_off = jax.jit(build_train_step(cfg, tcfg, mesh, w, diag=False))
    step_on = jax.jit(build_train_step(cfg, tcfg, mesh, w, diag=True))
    s_off, m_off = step_off(state, stream.batch(0))
    s_on, m_on = step_on(state, stream.batch(0))

    for a, b in zip(jax.tree_util.tree_leaves(s_off),
                    jax.tree_util.tree_leaves(s_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # diagnostics ride the METRICS dict only, as a superset
    assert set(m_off) <= set(m_on)
    assert np.isfinite(float(m_on["ef_err_norm"]))
    if s_on.h_bar is not None:
        assert np.isfinite(float(m_on["h_bar_drift"]))


def test_span_adds_no_ops_and_no_recompilation():
    """``span`` inside jit is pure trace metadata: same lowering as the
    bare function, ONE trace across repeated calls, recording on/off."""
    traces = []

    def g(x):
        traces.append(1)
        with obs.span("test/phase"):
            return x * 2.0 + 1.0

    f = jax.jit(g)
    x = jnp.arange(4, dtype=jnp.float32)
    y0 = f(x)
    y1 = f(x + 1)
    with obs.recording(obs.SpanRecorder()):
        y2 = f(x + 2)
    assert sum(traces) == 1                     # no extra compilations
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(x) * 2 + 1)
    np.testing.assert_array_equal(np.asarray(y2),
                                  (np.asarray(x) + 2) * 2 + 1)
    # and the math is the bare function's math
    bare = jax.jit(lambda x: x * 2.0 + 1.0)(x + 1)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(bare))


def test_span_times_host_work_only_when_recording():
    rec = obs.SpanRecorder()
    with obs.span("host/untimed"):              # no recorder active
        pass
    assert rec.spans == {}
    with obs.recording(rec):
        for _ in range(3):
            with obs.span("host/timed"):
                pass
    assert obs.active_recorder() is None        # restored on exit
    snap = rec.snapshot()
    assert snap["host/timed"]["count"] == 3
    assert snap["host/timed"]["total_s"] >= 0.0


def test_stamp_recorder_windows():
    rec = obs.StampRecorder()
    with rec.stamp("reduce_start"):
        pass
    with rec.stamp("finish"):
        pass
    assert len(rec.windows("reduce_start")) == 1
    assert len(rec.windows("finish")) == 1
    assert rec.total("finish") >= 0.0
    rec.clear()
    assert rec.events == []


# ---------------------------------------------------------------------------
# Measured hide fraction -> cost model -> TunePlan
# ---------------------------------------------------------------------------


def test_measure_overlap_hide_in_unit_interval():
    mesh = make_host_mesh()
    wtree = _wtree(jax.random.PRNGKey(0), w=2)
    m = tune.measure_overlap_hide(mesh, wtree, cap_bytes=1 << 14, iters=1,
                                  n_compute=64)
    assert 0.0 <= m.hide_fraction <= 1.0
    assert m.source == "measured"
    assert m.compute_s > 0.0 and m.comm_s > 0.0 and m.overlapped_s > 0.0


def test_compose_step_s_uses_measured_hide():
    full = tune.compose_step_s(1.0, 1.0, True, hide=1.0)
    none = tune.compose_step_s(1.0, 1.0, True, hide=0.0)
    nominal = tune.compose_step_s(1.0, 1.0, True)
    assert full < nominal < none
    assert nominal == tune.compose_step_s(1.0, 1.0, True,
                                          hide=tune.OVERLAP_HIDE)
    # without overlap the hide fraction must not matter
    assert tune.compose_step_s(1.0, 1.0, False, hide=1.0) == \
        tune.compose_step_s(1.0, 1.0, False, hide=0.0)


def test_measured_hide_lands_in_tune_plan(tmp_path):
    """Satellite: a measured hide fraction is plumbed through
    ``search_plan`` into the produced ``TunePlan`` and survives the
    strict-JSON round trip (what ``repro.tune`` consumes in place of
    the nominal constant)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wtree = _wtree(jax.random.PRNGKey(0), w=4)
    kw = dict(modes=("dense", "q8_ring_overlap"), bucket_grid=(1 << 20,),
              link=tune.LinkModel.nominal(), verify_top=0,
              # a nonzero compute half so the hide fraction has comm to
              # tuck under it (None analysis contributes zero compute);
              # small enough that no hide value clamps the comm to zero
              analysis={"flops": 2e8, "bytes": 0.0})
    plan = tune.search_plan(CompressionConfig(), wtree, mesh, 4,
                            hide=0.42, hide_source="measured", **kw)
    assert plan.hide_fraction == pytest.approx(0.42)
    assert plan.hide_source == "measured"

    nominal = tune.search_plan(CompressionConfig(), wtree, mesh, 4, **kw)
    assert nominal.hide_fraction is None
    assert nominal.hide_source == "nominal"
    # the fraction changes the overlap candidates' predictions
    t = {r["comm_mode"]: r["predicted_step_s"] for r in plan.candidates}
    t0 = {r["comm_mode"]: r["predicted_step_s"] for r in nominal.candidates}
    assert t["q8_ring_overlap"] != t0["q8_ring_overlap"]
    assert t["dense"] == t0["dense"]            # no overlap -> no effect

    rt = tune.load_plan(tune.save_plan(plan, str(tmp_path / "p.json")))
    assert rt.hide_fraction == pytest.approx(0.42)
    assert rt.hide_source == "measured"


# ---------------------------------------------------------------------------
# Per-wire telemetry
# ---------------------------------------------------------------------------


def test_transport_obs_snapshot_bits_payload_timings():
    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    shapes = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    comp = CompressionConfig(enabled=False, model_wire="q8", publish_every=2)
    transport = build_transport(comp, cfg, SimChannel(), params_like=shapes)
    snap = transport.obs_snapshot()
    rec = snap["model"]
    assert rec["topology"] == "broadcast"
    assert rec["wire_bits"] > 0.0
    assert rec["payload_bytes"] > 0.0
    # the container is at least as wide as the protocol bits it carries
    assert rec["payload_bytes"] >= rec["wire_bits"] / 8.0
    assert rec["encode_s"] is None              # untimed snapshot is AOT

    timed = transport.obs_snapshot(timed=True)["model"]
    assert timed["encode_s"] > 0.0 and np.isfinite(timed["encode_s"])
    assert timed["decode_s"] >= 0.0 and np.isfinite(timed["decode_s"])
    # the snapshot is record-ready for the run header
    obs.validate_record(obs.run_record("t", wires=snap))


def test_grad_wire_payload_and_codec_timings():
    comp = RULE_CONFIGS["diana"]
    q, rule = comp.make()
    params_like = {"a": jax.ShapeDtypeStruct((40,), jnp.float32),
                   "b": jax.ShapeDtypeStruct((3, 5), jnp.float32)}
    transport = build_transport(comp, None, SimChannel(), rule=rule,
                                msg_codec=q, w=4, params_like=params_like)
    wire = transport["grad"]
    assert wire.payload_nbytes() > 0.0
    t = wire.codec_timings(jax.random.PRNGKey(0))
    assert t["encode_s"] > 0.0 and t["decode_s"] >= 0.0
    # a traffic-free wire reports Nones, not zeros
    bare = build_transport(comp, None, SimChannel(), rule=rule,
                           msg_codec=q, w=4)["grad"]
    assert bare.codec_timings() == {"encode_s": None, "decode_s": None}


def test_fused_grad_wire_snapshot_encode_stage_gone():
    """Schema pin for the fused-backward mode: the grad wire reports
    ``fused: True`` and EXACT ZERO standalone encode/decode seconds —
    the deleted stage — while payload accounting is unchanged vs the
    post-hoc overlap mode; non-fused wires report ``fused: False``."""
    import dataclasses

    from repro.comm import make_channel

    params_like = {"a": jax.ShapeDtypeStruct((40,), jnp.float32),
                   "b": jax.ShapeDtypeStruct((3, 5), jnp.float32)}
    snaps = {}
    for mode in ("q8_ring_overlap", "q8_ring_fused_vjp"):
        comp = dataclasses.replace(RULE_CONFIGS["diana"], comm_mode=mode)
        q, rule = comp.make()
        transport = build_transport(comp, None, make_channel(comp),
                                    rule=rule, msg_codec=q, w=4,
                                    params_like=params_like)
        snaps[mode] = transport.obs_snapshot(timed=True)["grad"]

    fused = snaps["q8_ring_fused_vjp"]
    posthoc = snaps["q8_ring_overlap"]
    assert fused["fused"] is True
    assert posthoc["fused"] is False
    assert fused["encode_s"] == 0.0 and fused["decode_s"] == 0.0
    assert posthoc["encode_s"] > 0.0
    # the wire payload itself is unchanged — only the launch is deleted
    assert fused["wire_bits"] == posthoc["wire_bits"] > 0.0
    assert fused["payload_bytes"] == posthoc["payload_bytes"] > 0.0
    assert fused["codec"] == posthoc["codec"]
    # record-ready for the run header, strict schema
    obs.validate_record(obs.run_record("t", wires=snaps))


# ---------------------------------------------------------------------------
# Serving fleet: event-sourced accounting
# ---------------------------------------------------------------------------


def test_fleet_bridge_event_sourced_stats():
    from repro.serving import TrainerFleetBridge
    from repro.comm import Wire, wire_flag_codec

    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    wire = Wire(name="model", topology="broadcast",
                codec=wire_flag_codec("q8"), channel=SimChannel())
    mirror = obs.MemorySink()
    bridge = TrainerFleetBridge(cfg, params, wire, n_replicas=2,
                                publish_every=2, stale_k=4, obs=mirror)

    leaves, treedef = jax.tree_util.tree_flatten(params)
    for i in range(1, 7):
        leaves = [x + 1e-3 for x in leaves]
        bridge.on_step(jax.tree_util.tree_unflatten(treedef, leaves), i)
    stats = bridge.stats()

    # stats IS the event stream: counts match the records verbatim
    assert stats["publishes"] == len(bridge.events.events("publish")) == 3
    assert stats["resyncs"] == len(bridge.events.events("fleet_resync"))
    assert len(bridge.events.events("fleet_bootstrap")) == 1
    assert stats["obs_events"]["publish"] == 3
    assert len(stats["err_rel"]) == 3
    assert stats["delta_bytes_per_publish"] > 0.0
    # the caller's sink saw the SAME stream (tee) and it is schema-valid
    assert mirror.records == bridge.events.records
    for rec in mirror.records:
        obs.validate_record(rec)


# ---------------------------------------------------------------------------
# Exports
# ---------------------------------------------------------------------------


def _fake_run_records():
    recs = [obs.run_record(
        "train", workers=4,
        wires={"grad": {"topology": "allreduce", "codec": "Natural",
                        "wire_bits": 1000.0, "payload_bytes": 500.0,
                        "encode_s": 1e-4, "decode_s": 2e-4,
                        "omega_hat": 0.11, "nmse": 0.09}},
        hide_fraction=0.8, hide_source="measured",
        omega=0.13, omega_source="measured",
    )]
    for i in range(4):
        recs.append(obs.step_record(i, run="train", loss=2.0 - 0.1 * i,
                                    bits=100.0 * (i + 1), step_s=0.01,
                                    predicted_step_s=0.012,
                                    grad_sq=4.0,
                                    shift_residual_sq=1.0 / (i + 1)))
    recs.append(obs.event_record("drift_resync", 3, every=4))
    recs.append(obs.event_record("publish", 2, bytes=10.0, err_rel=0.01))
    return recs


def test_summarize_measured_vs_predicted():
    s = obs.summarize(_fake_run_records(), name="train")["data"]
    assert s["n_steps"] == 4
    assert s["step_s"]["mean"] == pytest.approx(0.01)
    assert s["predicted_step_s"]["mean"] == pytest.approx(0.012)
    assert s["predicted_over_actual"]["mean"] == pytest.approx(1.2)
    assert s["final_loss"] == pytest.approx(1.7)
    assert s["final_bits"] == pytest.approx(400.0)
    assert s["hide_fraction"] == pytest.approx(0.8)
    assert s["hide_source"] == "measured"
    assert s["wires"]["grad"]["payload_bytes"] == 500.0
    assert s["wires"]["grad"]["omega_hat"] == pytest.approx(0.11)
    assert s["events"] == {"drift_resync": 1, "publish": 1}
    # the quality aggregate: measured omega from the run header, the
    # shift-residual trajectory from the step stream
    assert s["omega"] == pytest.approx(0.13)
    assert s["omega_source"] == "measured"
    assert s["shift_residual_first"] == pytest.approx(1.0)
    assert s["shift_residual_last"] == pytest.approx(0.25)
    assert s["shift_residual_sq"]["count"] == 4
    assert s["shift_residual_over_grad"]["mean"] == pytest.approx(
        (1.0 + 0.5 + 1.0 / 3.0 + 0.25) / 4.0 / 4.0)


def test_summary_table_and_prometheus_text():
    recs = _fake_run_records()
    table = obs.summary_table(recs, name="train")
    for needle in ("wire grad", "predicted/actual", "event drift_resync",
                   "overlap hide fraction", "omega", "shift resid/grad",
                   "omega_hat 0.11"):
        assert needle in table
    prom = obs.prometheus_text(recs, name="train")
    assert '# TYPE repro_overlap_hide_fraction gauge' in prom
    assert 'repro_overlap_hide_fraction{run="train"} 0.8' in prom
    assert 'repro_wire_payload_bytes_per_step{run="train",wire="grad"}' in prom
    assert 'repro_events_total{run="train",event="publish"} 1' in prom
    # schema pins for the quality gauges (dashboards key on these names)
    assert 'repro_omega{run="train"} 0.13' in prom
    assert 'repro_wire_omega_hat{run="train",wire="grad"} 0.11' in prom
    assert 'repro_wire_nmse{run="train",wire="grad"} 0.09' in prom
    assert '# TYPE repro_shift_residual_sq gauge' in prom
    assert '# TYPE repro_shift_residual_over_grad gauge' in prom
    # exposition format: every non-comment line is `name{labels} value`
    for line in prom.strip().splitlines():
        if not line.startswith("#"):
            assert "{" in line and line.rsplit(" ", 1)[1]


# ---------------------------------------------------------------------------
# Dedupe: benchmarks share the obs strict-JSON helpers
# ---------------------------------------------------------------------------


def test_bench_common_shares_obs_helpers(tmp_path):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks import common

    assert common.finite_or_none is obs.finite_or_none
    # print_table renders through the same formatter as the obs summary
    assert common.format_table is obs.format_table
    assert common.write_strict_json is obs.write_strict_json
    # tune plans sanitize through the same pass
    from repro.tune import plan as tune_plan
    assert tune_plan._finite_tree({"x": float("inf")}) == {"x": None}
