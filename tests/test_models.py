"""Model-core correctness beyond smoke: MLA absorbed-decode parity, MoE
routing invariants, rolling-window cache equivalence, RoPE properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import model as M


# ---------------------------------------------------------------------------
# MLA: the absorbed decode must equal the expanded train-time math
# ---------------------------------------------------------------------------


def test_mla_absorbed_decode_matches_expanded():
    """Decode attends in LATENT space (W_uk folded into q, W_uv into the
    output).  Token-by-token decode must reproduce the expanded
    full-sequence forward — the strongest MLA correctness check."""
    cfg = get_smoke_config("deepseek-v2-lite-16b").with_(dtype="float32")
    p = MLA.init_mla(jax.random.PRNGKey(0), cfg)
    b, s = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.1

    full = MLA.mla_apply(p, x, cfg)

    cache = MLA.make_mla_cache(cfg, b, 16, jnp.float32)
    outs = []
    for t in range(s):
        y, cache = MLA.mla_decode(p, x[:, t:t+1], cfg, cache, jnp.int32(t))
        outs.append(y[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               rtol=2e-3, atol=2e-3)


def test_mla_cache_is_latent_sized():
    """The MLA memory win: cache stores (kv_lora_rank + qk_rope_dim) per
    token, NOT n_heads * (k + v) like GQA."""
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    cache = MLA.make_mla_cache(cfg, 1, 64, jnp.float32)
    per_tok = cache["ckv"].shape[-1] + cache["kr"].shape[-1]
    gqa_equiv = cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim
                               + cfg.v_head_dim)
    assert per_tok == cfg.kv_lora_rank + cfg.qk_rope_dim
    assert per_tok < gqa_equiv / 3


# ---------------------------------------------------------------------------
# MoE routing invariants
# ---------------------------------------------------------------------------


@pytest.fixture
def moe_setup():
    cfg = get_smoke_config("qwen2-moe-a2.7b").with_(dtype="float32")
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    return cfg, p, x


def test_moe_dispatch_capacity(moe_setup):
    cfg, p, x = moe_setup
    dispatch, combine, aux = MOE.route(p, x, cfg)
    n, e, c = dispatch.shape
    # each (expert, slot) holds at most one token
    assert float(jnp.max(jnp.sum(dispatch, axis=0))) <= 1.0 + 1e-6
    # each token occupies at most experts_per_token slots
    assert float(jnp.max(jnp.sum(dispatch, axis=(1, 2)))) <= (
        cfg.experts_per_token + 1e-6
    )
    # combine weights of a routed token sum to <= 1 (normalized gates,
    # possibly reduced by capacity drops)
    sums = jnp.sum(combine, axis=(1, 2))
    assert float(jnp.max(sums)) <= 1.0 + 1e-5
    assert jnp.isfinite(aux)


def test_moe_combine_matches_dispatch_support(moe_setup):
    cfg, p, x = moe_setup
    dispatch, combine, _ = MOE.route(p, x, cfg)
    # combine nonzero only where dispatch nonzero
    assert float(jnp.max(jnp.abs(combine * (1 - dispatch)))) < 1e-6


def test_moe_grouping_invariance():
    """moe_apply output must not depend on the group size (GShard groups
    are an implementation detail) up to capacity-drop differences at the
    group boundary — with generous capacity, results match exactly."""
    cfg = get_smoke_config("qwen2-moe-a2.7b").with_(
        dtype="float32", capacity_factor=8.0
    )
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model)) * 0.3
    y1, _ = MOE.moe_apply(p, x, cfg.with_(moe_group_size=64))
    y2, _ = MOE.moe_apply(p, x, cfg.with_(moe_group_size=16))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


def test_moe_aux_loss_penalizes_imbalance():
    """A router collapsed onto one expert must have a higher aux loss
    than the near-uniform random-init router."""
    # E=16 so maximal imbalance is clearly separable (at E=4/top-2 the
    # best possible ratio is only 2x)
    cfg = get_smoke_config("qwen2-moe-a2.7b").with_(
        dtype="float32", n_experts=16, experts_per_token=2
    )
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (128, cfg.d_model))
    _, _, aux_balanced = MOE.route(p, x, cfg)
    # the aux loss is the me.ce correlation (Shazeer): it penalizes only
    # when routed FRACTIONS and router PROBS skew together — so collapse
    # both: identical tokens (ce concentrates) + a sharpened router
    # (me concentrates on the same experts)
    x_same = jnp.broadcast_to(x[:1], x.shape)
    p_sharp = {**p, "router": p["router"] * 50.0}
    _, _, aux_collapsed = MOE.route(p_sharp, x_same, cfg)
    assert float(aux_collapsed) > 2.0 * float(aux_balanced), (
        float(aux_collapsed), float(aux_balanced))


# ---------------------------------------------------------------------------
# Sliding-window / rolling cache
# ---------------------------------------------------------------------------


def test_sliding_window_decode_matches_full_for_short_seq():
    """Within the window, a windowed model must equal the full-attention
    model exactly (window only masks beyond its reach)."""
    base = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    win = base.with_(sliding_window=32)
    params = M.init_params(jax.random.PRNGKey(1), base)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                              base.vocab_size)
    lg_full, _ = M.forward_train(params, base, {"tokens": toks})
    lg_win, _ = M.forward_train(params, win, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg_full), np.asarray(lg_win),
                               rtol=1e-5, atol=1e-5)


def test_rolling_cache_window_decode():
    """Decode far past the window with a ring cache of window size: the
    cache must keep exactly the last `window` keys and stay finite."""
    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32",
                                               sliding_window=8)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    state = M.make_decode_state(cfg, 1, 8)  # cache_len == window
    tok = jnp.zeros((1, 1), jnp.int32)
    for t in range(20):
        logits, state = M.decode_step(params, cfg, tok, state, jnp.int32(t))
        assert bool(jnp.all(jnp.isfinite(logits))), t
    kpos = np.asarray(state["kv"]["kpos"])   # (L, B, C) per-slot validity
    # every layer's cache holds positions 12..19 (the last 8)
    assert kpos.min() == 12 and kpos.max() == 19


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def test_rope_relative_position_property():
    """<rope(q,m), rope(k,n)> depends only on m-n (the defining RoPE
    property)."""
    dh = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, dh))

    def score(m, n):
        qm = L.apply_rope(q, jnp.asarray([[m]]), 10_000.0)
        kn = L.apply_rope(k, jnp.asarray([[n]]), 10_000.0)
        return float(jnp.sum(qm * kn))

    assert abs(score(5, 3) - score(105, 103)) < 1e-3
    assert abs(score(0, 0) - score(77, 77)) < 1e-3
    assert abs(score(5, 3) - score(3, 5)) > 1e-4 or True  # not symmetric


def test_rope_norm_preserving():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 3, 64))
    pos = jnp.broadcast_to(jnp.arange(4), (2, 4))
    y = L.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5,
    )
