"""Wire-codec layer tests: encode/decode round-trip identity against the
derived ``__call__``, structural bits accounting (runtime ``wire_bits``
vs the AOT ``aot_wire_bits`` eval_shape path), SimChannel vs MeshChannel
agreement, and payload-size pins for the codec-driven collectives."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    MeshChannel,
    SimChannel,
    aggregation_mode_of,
    collective_payload_scale,
    make_channel,
)
from repro.configs.base import CompressionConfig
from repro.core.compressors import (
    BernoulliP,
    Identity,
    Induced,
    Int8Stochastic,
    NaturalCompression,
    NaturalDithering,
    PackedBits,
    RandK,
    ScaledSign,
    TernGrad,
    TopK,
    Zero,
    aot_wire_bits,
    make_compressor,
    wire_bits,
)

# one representative instance per registry entry
REGISTERED = [
    ("identity", Identity()),
    ("zero", Zero()),
    ("randk", RandK(0.25)),
    ("randk/shared", RandK(0.25, shared_pattern=True)),
    ("bernoulli", BernoulliP(0.3)),
    ("natural_dithering", NaturalDithering(4)),
    ("natural", NaturalCompression()),
    ("terngrad", TernGrad()),
    ("int8", Int8Stochastic()),
    ("topk", TopK(0.25)),
    ("sign", ScaledSign()),
    ("induced", Induced(TopK(0.25), RandK(0.25))),
]
IDS = [n for n, _ in REGISTERED]
OPS = [op for _, op in REGISTERED]


@pytest.fixture(scope="module")
def xvec():
    return jax.random.normal(jax.random.PRNGKey(7), (48,)) * 2.0 + 0.5


@pytest.mark.parametrize("op", OPS, ids=IDS)
def test_roundtrip_matches_derived_call(op, xvec):
    """decode(encode(key, x)) IS __call__(key, x) — for every registered
    codec, on 1-D and 2-D inputs (shape/dtype preserved exactly)."""
    for x in (xvec, xvec.reshape(12, 4)):
        key = jax.random.PRNGKey(3)
        payload, meta = op.encode(key, x)
        dec = op.decode(payload, meta, jax.ShapeDtypeStruct(x.shape, x.dtype))
        out = op(key, x)
        assert dec.shape == x.shape and dec.dtype == x.dtype
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(out))


def test_payload_dtypes_honest(xvec):
    """Payloads carry honest wire dtypes: int8 quantized values, packed
    sub-byte index/sign/code fields, f32 scales."""
    key = jax.random.PRNGKey(0)
    p, _ = Int8Stochastic().encode(key, xvec)
    assert p["q"].dtype == jnp.int8 and p["scale"].dtype == jnp.float32

    d = xvec.size
    p, _ = TopK(0.25).encode(key, xvec)
    assert isinstance(p["indices"], PackedBits)
    assert p["indices"].width == math.ceil(math.log2(d))
    assert p["indices"].data.dtype == jnp.int32

    p, _ = RandK(0.25).encode(key, xvec)
    assert isinstance(p["indices"], PackedBits)
    p, meta = RandK(0.25, shared_pattern=True).encode(key, xvec)
    assert "indices" not in p  # pattern implied by the shared seed
    assert meta["indices"].shape == (12,)

    p, _ = TernGrad().encode(key, xvec)
    assert p["tern"].width == 2 and p["tern"].data.dtype == jnp.int8
    p, _ = ScaledSign().encode(key, xvec)
    assert p["sign"].width == 1
    p, _ = NaturalCompression().encode(key, xvec)
    assert p["exp"].width == 8 and p["sign"].width == 1


@pytest.mark.parametrize("op", OPS, ids=IDS)
def test_wire_bits_agrees_with_aot(op, xvec):
    """The AOT ``aot_wire_bits`` (eval_shape of the codec's own encode)
    must equal the structural ``wire_bits`` of a real payload
    (BernoulliP's payload is a random variable; its AOT size is the
    expectation)."""
    d = int(xvec.size)
    payload, _ = op.encode(jax.random.PRNGKey(1), xvec)
    wb = op.wire_bits(payload)
    aot = aot_wire_bits(op, d)
    if isinstance(op, BernoulliP):
        # concrete count: either just the flag, or flag + full vector
        assert float(wb) in (1.0, 1.0 + 32 * d)
        assert aot == op.p * 32 * d + 1.0
    else:
        assert float(wb) == aot, (float(wb), aot)


def test_wire_bits_pins_legacy_formulas():
    """wire_bits / aot_wire_bits ≡ the legacy hand-written per-format
    size formulas for the identity / Rand-K / int8 wire formats."""
    d = 1000
    x = jax.random.normal(jax.random.PRNGKey(2), (d,))
    key = jax.random.PRNGKey(3)

    p, _ = Identity().encode(key, x)
    assert Identity().wire_bits(p) == 32 * d == aot_wire_bits(Identity(), d)

    p, _ = RandK(0.1).encode(key, x)
    assert (RandK(0.1).wire_bits(p) == 100 * (32 + 10)
            == aot_wire_bits(RandK(0.1), d))
    p, _ = RandK(0.1, shared_pattern=True).encode(key, x)
    assert RandK(0.1, shared_pattern=True).wire_bits(p) == 100 * 32

    p, _ = Int8Stochastic().encode(key, x)
    assert Int8Stochastic().wire_bits(p) == 8 * d + 32

    # and the other wire formats keep their legacy sizes too
    assert aot_wire_bits(TopK(0.1), d) == 100 * (32 + 10)
    assert aot_wire_bits(ScaledSign(), d) == d + 32
    assert aot_wire_bits(TernGrad(), d) == 2 * d + 32
    assert aot_wire_bits(NaturalCompression(), d) == 9 * d
    assert aot_wire_bits(NaturalDithering(8), d) == d * (1 + 4) + 32
    assert aot_wire_bits(Zero(), d) == 0


def test_bernoulli_composite_aot_bits():
    """Regression: AOT costing must survive codecs whose wire size is a
    random variable, including nested inside Induced — eval_shape
    payloads report the EXPECTED bits."""
    d = 1000
    b = BernoulliP(0.1)
    assert aot_wire_bits(b, d) == b.p * 32 * d + 1.0
    ind = Induced(c=TopK(0.1), q=b)
    assert aot_wire_bits(ind, d) == (aot_wire_bits(TopK(0.1), d)
                                     + aot_wire_bits(b, d))


def test_ring_stages_reject_meta_codecs():
    """Regression: every forwarded-payload stage (ring hops AND the pod
    psum stage) must reject codecs that keep decoder state in meta —
    the receiver only ever sees the payload."""
    from repro.dist.collectives import _encode_meta_free

    key = jax.random.PRNGKey(0)
    x = jnp.ones((1, 16))
    _encode_meta_free(Int8Stochastic(), key, x)  # meta-free: fine
    with pytest.raises(ValueError, match="meta"):
        _encode_meta_free(RandK(0.25, shared_pattern=True), key, x)


def test_wire_bits_from_eval_shape():
    """Payload costs are computable AOT from shapes alone (eval_shape),
    matching the runtime payload exactly."""
    x = jax.random.normal(jax.random.PRNGKey(4), (257,))
    for op in (RandK(0.1), TopK(0.5), Int8Stochastic(), NaturalCompression()):
        aot, _ = jax.eval_shape(
            op.encode, jax.ShapeDtypeStruct((2,), jnp.uint32),
            jax.ShapeDtypeStruct(x.shape, x.dtype),
        )
        run, _ = op.encode(jax.random.PRNGKey(5), x)
        assert wire_bits(aot) == float(op.wire_bits(run))


# ---------------------------------------------------------------------------
# Channels
# ---------------------------------------------------------------------------


def _wtree(key, w=4):
    return {
        "a": jax.random.normal(key, (w, 17)),
        "b": {"c": jax.random.normal(jax.random.fold_in(key, 1), (w, 3, 5))},
    }


def test_sim_vs_mesh_channel_dense_agree():
    """SimChannel and a dense MeshChannel are interchangeable: identical
    messages, identical aggregate, identical wire bits."""
    key = jax.random.PRNGKey(11)
    wtree = _wtree(key)
    for q in (Identity(), NaturalCompression(), RandK(0.5)):
        sim = SimChannel()
        mesh = make_channel("dense")
        assert isinstance(mesh, MeshChannel)
        m_s, bar_s, b_s = sim.push_mean(q, key, wtree)
        m_m, bar_m, b_m = mesh.push_mean(q, key, wtree)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)
            ),
            (m_s, bar_s), (m_m, bar_m),
        )
        assert float(b_s) == float(b_m)


def test_uplink_bits_are_structural():
    """Channel uplink bits == W x per-message wire_bits (no analytic
    formulas on the live path)."""
    key = jax.random.PRNGKey(12)
    w = 4
    wtree = {"a": jax.random.normal(key, (w, 40))}
    q = RandK(0.25)
    _, bits = SimChannel().uplink(q, key, wtree)
    assert float(bits) == w * aot_wire_bits(q, 40)


def test_mesh_channel_randk_shared_is_codec_driven():
    """The shared-pattern Rand-K aggregation equals mean-of-decoded
    shared-pattern messages (the codec law), and the per-worker payload
    is byte-identical to the K-value wire format."""
    key = jax.random.PRNGKey(13)
    w, d, ratio = 6, 50, 0.2
    k = round(ratio * d)
    wtree = {"a": jax.random.normal(key, (w, d))}
    ch = make_channel("randk_shared", randk_q=ratio)
    out = ch.reduce_mean(key, wtree)

    # reference: every worker encodes with the SAME per-leaf key, master
    # averages the decoded messages exactly
    codec = RandK(q=ratio, shared_pattern=True)
    lk = jax.random.fold_in(key, 0)
    dec = jax.vmap(
        lambda row: codec(lk, row)
    )(wtree["a"])
    np.testing.assert_allclose(
        np.asarray(out["a"]), np.asarray(jnp.mean(dec, axis=0)), rtol=1e-6
    )
    assert int(np.sum(np.asarray(out["a"]) != 0)) <= k

    # byte-identical payload: K f32 values per worker message
    payload, _ = codec.encode(lk, wtree["a"][0])
    assert payload["values"].shape == (k,)
    assert codec.wire_bits(payload) == 32 * k


def test_q8_ring_hop_payload_bytes():
    """The ring forwards exactly the Int8Stochastic payload per hop:
    int8 chunk + one f32 scale (8c + 32 bits)."""
    c = 256
    codec = Int8Stochastic()
    payload, meta = jax.eval_shape(
        codec.encode, jax.ShapeDtypeStruct((2,), jnp.uint32),
        jax.ShapeDtypeStruct((1, c), jnp.float32),
    )
    assert not jax.tree_util.tree_leaves(meta)  # ring needs meta-free codecs
    assert payload["q"].dtype == jnp.int8 and payload["q"].shape == (1, c)
    assert wire_bits(payload) == 8 * c + 32


def test_channel_broadcast_downlink():
    """Model-broadcast through the Channel: identity is exact with 32
    bits/scalar; int8 is close with 8 bits/scalar + scale."""
    key = jax.random.PRNGKey(14)
    tree = {"w": jax.random.normal(key, (8, 8)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (8,))}
    n = 64 + 8
    out, bits = SimChannel().broadcast(Identity(), key, tree)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        out, tree,
    )
    assert float(bits) == 32 * n

    out8, bits8 = SimChannel().broadcast(Int8Stochastic(), key, tree)
    assert float(bits8) == 8 * n + 32 * 2  # one scale per leaf
    for k in ("w", "b"):
        err = np.abs(np.asarray(out8[k]) - np.asarray(tree[k])).max()
        assert err < 0.05 * np.abs(np.asarray(tree[k])).max() + 1e-6


def test_serve_broadcast_params_roundtrip():
    from repro.launch.serve import broadcast_params

    tree = {"w": jax.random.normal(jax.random.PRNGKey(15), (16, 4))}
    out, bits = broadcast_params(tree, "identity")
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert float(bits) == 32 * 64


# ---------------------------------------------------------------------------
# EF21 / config plumbing + the HLO payload model
# ---------------------------------------------------------------------------


def test_ef21_comm_mode_config_plumbing():
    cfg = CompressionConfig(comm_mode="ef21", compressor="topk",
                            compressor_kwargs=(("q", 0.25),))
    assert cfg.effective_shift_rule == "ef21"
    assert cfg.aggregation_mode == "dense"
    assert aggregation_mode_of(cfg) == "dense"
    q, rule = cfg.make()
    from repro.core import EF21Shift, TopK as TopKOp

    assert isinstance(rule, EF21Shift)
    assert isinstance(q, TopKOp)
    ch = make_channel(cfg)
    assert isinstance(ch, MeshChannel) and ch.mode == "dense"


def test_mesh_channel_rejects_unknown_mode():
    with pytest.raises(ValueError):
        MeshChannel(mode="carrier_pigeon")


def test_collective_payload_scale():
    """Only EF21 needs a payload scale (dense HLO lowering of decoded
    sparse messages); the codec-driven collectives are structurally
    honest in the HLO already (see the randk_shared lowering test)."""
    # ef21: the wire carries the contractive codec's payload
    cfg = CompressionConfig(comm_mode="ef21", compressor="topk",
                            compressor_kwargs=(("q", 0.1),))
    s = collective_payload_scale(cfg)["all-reduce"]
    assert 0.1 < s < 0.2  # ~q * (32 + log2 d)/32
    # structurally-honest / disabled modes: no scaling
    assert collective_payload_scale(CompressionConfig(comm_mode="dense")) == {}
    assert collective_payload_scale(
        CompressionConfig(comm_mode="randk_shared", randk_q=0.05)) == {}
    assert collective_payload_scale(
        CompressionConfig(enabled=False, comm_mode="ef21")) == {}


_RANDK_LOWERING = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist.collectives import randk_shared_mean
from repro.launch.hlo_stats import collective_bytes

mesh = jax.make_mesh((8,), ("data",))
w, d, ratio = 8, 1024, 0.05
k = round(ratio * d)
wtree = {"a": jax.device_put(
    jax.random.normal(jax.random.PRNGKey(0), (w, d)),
    NamedSharding(mesh, P("data")))}
with jax.sharding.set_mesh(mesh):
    hlo = (jax.jit(lambda key, t: randk_shared_mean(key, t, ratio))
           .lower(jax.random.PRNGKey(1), wtree).compile().as_text())
coll = collective_bytes(hlo)
ar = coll["all-reduce"] + coll["reduce-scatter"] + coll["all-gather"]
# the cross-device reduction moves K values, not d: structural honesty
assert 0 < ar <= 4 * 4 * k, (ar, k)   # <= a few K-sized f32 messages
assert ar < 4 * d, (ar, d)            # and strictly below one dense leaf
print("RANDK_LOWERING_OK", ar)
"""


def test_randk_shared_lowering_is_k_sized_subprocess():
    """The codec-driven randk_shared aggregation is structurally honest
    in the HLO: the cross-device collective carries ~K f32 values per
    leaf, NOT the dense d — which is why collective_payload_scale no
    longer rescales it."""
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-c", _RANDK_LOWERING],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=repo,
    )
    assert "RANDK_LOWERING_OK" in r.stdout, r.stdout + r.stderr[-3000:]


_HLO = """\
HloModule m

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p0), to_apply=%add
}
"""


def test_hlo_cost_collective_scale():
    from repro.launch.hlo_cost import analyze

    base = analyze(_HLO)
    assert base["collective_bytes"] == 4096
    scaled = analyze(_HLO, collective_scale={"all-reduce": 0.05})
    assert scaled["collective_bytes"] == pytest.approx(4096 * 0.05)
    assert scaled["collective_bytes_structural"] == 4096
    assert scaled["collective_bytes_by_kind"]["all-reduce"] == pytest.approx(
        4096 * 0.05
    )


def test_hlo_cost_gradient_payload_model():
    """Only the gradient-message share is re-charged at the wire
    fraction; dense activation collectives keep their structural
    bytes."""
    from repro.launch.hlo_cost import analyze, apply_gradient_payload_model

    base = analyze(_HLO)  # 4096 structural all-reduce bytes
    out = apply_gradient_payload_model(base, "all-reduce",
                                       message_bytes=1000,
                                       wire_fraction=0.1)
    assert out["collective_bytes_by_kind"]["all-reduce"] == pytest.approx(
        (4096 - 1000) + 1000 * 0.1
    )
    assert out["collective_bytes"] == out["collective_bytes_by_kind"]["all-reduce"]
    # message bytes are capped at the structural total
    out = apply_gradient_payload_model(base, "all-reduce",
                                       message_bytes=10_000_000,
                                       wire_fraction=0.1)
    assert out["collective_bytes"] == pytest.approx(4096 * 0.1)
    # untouched input dict
    assert base["collective_bytes"] == 4096
