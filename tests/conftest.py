"""Suite-wide setup: fall back to the deterministic mini-hypothesis shim
when the real `hypothesis` is unavailable (hermetic containers).  CI
installs the real package from requirements.txt, so the shim is only a
no-network fallback — see tests/_mini_hypothesis.py."""

import pathlib
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    import _mini_hypothesis

    _mini_hypothesis.install()
