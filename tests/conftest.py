"""Suite-wide setup: fall back to the deterministic mini-hypothesis shim
when the real `hypothesis` is unavailable (hermetic containers).  CI
installs the real package from requirements.txt, so the shim is only a
no-network fallback — see tests/_mini_hypothesis.py.

Also bounds JAX compilation-cache growth across the suite: every jitted
executable a test compiles stays resident in the process-wide pjit
cache, and with the whole suite in one process the accumulated LLVM JIT
state eventually crashes XLA's CPU compiler mid-``backend_compile``.
Dropping the caches between test modules keeps the high-water mark at
one module's worth of executables; modules recompile what they use."""

import pathlib
import sys

import pytest


@pytest.fixture(scope="module", autouse=True)
def _bounded_jit_cache():
    yield
    import jax

    jax.clear_caches()

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    import _mini_hypothesis

    _mini_hypothesis.install()
