"""repro.tune tests: the predictor's wire accounting against the live
codec payloads (every registered comm mode), TunePlan persistence +
fingerprint cache semantics, the plan search with injected
measurements, the auto comm-mode plumbing, and the drift-resync
satellite (bounded h_bar drift over lossy aggregation)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.comm import MeshChannel, make_channel, resync_h_bar
from repro.comm.wire import encode_workers, leaf_key
from repro.configs.base import CompressionConfig
from repro.core.shift_rules import DianaShift
from repro.core.compressors import NaturalCompression
from repro.tune.model import Candidate, TUNABLE_MODES, predicted_wire_bits, wire_codec

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _wtree(key, w=3):
    """Tiny worker-stacked tree (small grids: the fused modes run
    interpret-mode Pallas per leaf on CPU)."""
    return {
        "a": jax.random.normal(key, (w, 40)),
        "b": {
            "c": jax.random.normal(jax.random.fold_in(key, 1), (w, 3, 5)),
            "d": jax.random.normal(jax.random.fold_in(key, 2), (w,)),
        },
    }


def _candidate(mode: str) -> Candidate:
    if mode == "ef21":  # ef21's wire is the configured CONTRACTIVE codec
        return Candidate(mode, compressor="topk",
                         compressor_kwargs=(("q", 0.25),))
    return Candidate(mode, bucket_bytes=64)


# ---------------------------------------------------------------------------
# The wire-accounting contract (satellite): predicted == live, per mode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", TUNABLE_MODES)
def test_predicted_wire_bits_match_live_payloads(mode):
    """For EVERY registered comm mode, the tuner's AOT wire accounting
    must equal the structural wire_bits of the CONCRETE payloads the
    mode's codec emits on the same tree — the test that catches drift
    between the cost model and the wire protocol."""
    key = jax.random.PRNGKey(5)
    wtree = _wtree(key)
    cand = _candidate(mode)
    codec = wire_codec(cand)
    live = 0.0
    for i, leaf in enumerate(jax.tree_util.tree_leaves(wtree)):
        payload, _ = encode_workers(codec, leaf_key(key, i), leaf)
        live += float(codec.wire_bits(payload))
    assert live == predicted_wire_bits(cand, wtree), mode


def test_fused_mode_charges_zero_standalone_encode():
    """The fused-VJP mode's encode runs inside the backward pass —
    the predictor must charge it ZERO standalone-encode time while
    still charging the post-hoc compressed modes, and must never
    perturb analysis-free (pure wire) rankings with the new term."""
    from repro.tune.measure import DeviceRates, LinkModel
    from repro.tune.model import encode_time_s, predict_step

    wtree = _wtree(jax.random.PRNGKey(2))
    rates = DeviceRates.nominal()
    fused = _candidate("q8_ring_fused_vjp")
    posthoc = _candidate("q8_ring_overlap")
    assert fused.fused and fused.overlap and not posthoc.fused

    assert encode_time_s(fused, wtree, rates) == 0.0
    assert encode_time_s(_candidate("dense"), wtree, rates) == 0.0
    assert encode_time_s(posthoc, wtree, rates) > 0.0
    assert encode_time_s(_candidate("q8_ring"), wtree, rates) > 0.0

    link = LinkModel.nominal()
    analysis = {"flops": 1e9, "bytes": 1e8}
    p_fused = predict_step(fused, wtree, link, 4, analysis=analysis,
                           rates=rates)
    p_post = predict_step(posthoc, wtree, link, 4, analysis=analysis,
                          rates=rates)
    assert p_fused.encode_s == 0.0
    assert p_post.encode_s > 0.0
    # same codec, same payload — the predictions differ ONLY by the
    # deleted encode stage and the bucket granularity
    assert p_fused.wire_bytes == p_post.wire_bytes
    # analysis-free predictions stay pure wire orderings (no encode)
    assert predict_step(posthoc, wtree, link, 4).encode_s == 0.0
    # per-leaf buckets: one launch per leaf, regardless of bucket_bytes
    n_leaves = len(jax.tree_util.tree_leaves(wtree))
    assert p_fused.n_buckets == n_leaves


def test_default_candidates_include_fused_mode():
    comp = CompressionConfig(enabled=True, compressor="natural",
                             shift_rule="diana")
    wtree = _wtree(jax.random.PRNGKey(0))
    cands = tune.default_candidates(comp, wtree)
    fused = [c for c in cands if c.comm_mode == "q8_ring_fused_vjp"]
    assert fused, [c.comm_mode for c in cands]
    assert all("per-leaf" in c.label for c in fused)


def test_candidate_rejects_unknown_mode_naming_modes():
    with pytest.raises(ValueError) as ei:
        Candidate("carrier_pigeon")
    for m in TUNABLE_MODES:
        assert m in str(ei.value)


def test_extra_wire_bits_match_live_payloads():
    """The grad-wire invariant above, extended to EVERY registered wire:
    the tuner's per-wire AOT charge (``extra_wire_bits``) must equal the
    structural wire_bits of the CONCRETE payloads each wire's codec
    emits on its declared traffic — and both must equal the Transport's
    own ``per_wire_bits`` accounting table."""
    from repro.comm import build_transport, wire_flag_codec
    from repro.comm.wire import encode_meta_free
    from repro.configs import get_smoke_config
    from repro.tune.model import extra_wire_bits

    cfg = get_smoke_config("qwen2-moe-a2.7b").with_(dtype="float32")
    comp = CompressionConfig(comm_mode="dense", shift_rule="diana",
                             moe_wire="q8", act_wire="q8")
    transport = build_transport(comp, cfg, None, w=2, tokens_per_worker=64)
    traffic = transport.extra_traffic()
    assert set(traffic) == {"moe", "act"}

    key = jax.random.PRNGKey(9)
    live = {}
    for name, decl in traffic.items():
        codec = wire_flag_codec("q8")
        bits = 0.0
        for sds, count in decl:
            x = jax.random.normal(key, sds.shape, dtype=sds.dtype)
            payload = encode_meta_free(codec, key, x)
            bits += count * float(codec.wire_bits(payload))
        live[name] = bits
        # structural accounting on the Transport agrees per wire
        assert transport.per_wire_bits()[name] == bits, name

    cand = Candidate("dense", moe_wire="q8", act_wire="q8")
    assert extra_wire_bits(cand, traffic) == sum(live.values())
    # a "none" flag still moves the payload — at identity width
    cand_none = Candidate("dense")
    dense_transport = build_transport(
        CompressionConfig(comm_mode="dense", shift_rule="diana",
                          moe_wire="dense", act_wire="dense"),
        cfg, None, w=2, tokens_per_worker=64)
    assert extra_wire_bits(cand_none, traffic) == pytest.approx(
        sum(dense_transport.per_wire_bits()[n] for n in ("moe", "act")))


def test_candidate_rejects_unknown_wire_flag_verbatim():
    from repro.comm import WIRE_CODEC_FLAGS

    for field in ("moe_wire", "act_wire"):
        with pytest.raises(ValueError) as ei:
            Candidate("dense", **{field: "carrier_pigeon"})
        assert "carrier_pigeon" in str(ei.value)
        for f in WIRE_CODEC_FLAGS:
            assert f in str(ei.value)


# ---------------------------------------------------------------------------
# TunePlan persistence + fingerprint cache
# ---------------------------------------------------------------------------


def _plan(fp="f" * 64, mode="dense", **kw):
    defaults = dict(
        fingerprint=fp, comm_mode=mode, overlap_bucket_bytes=4 << 20,
        randk_q=0.05, q8_block_rows=64, efbv_eta=1.0, efbv_nu=1.0,
        predicted_step_s=1e-3,
    )
    defaults.update(kw)
    return tune.TunePlan(**defaults)


def test_plan_json_round_trip_strict(tmp_path):
    plan = _plan(measured_step_s=2e-3,
                 candidates=({"label": "dense", "chosen": True,
                              "measured_step_s": float("inf")},))
    path = tune.save_plan(plan, str(tmp_path / "p.json"))
    # the artifact is STRICT JSON: non-finite floats become null
    raw = open(path).read()
    assert "Infinity" not in raw and "NaN" not in raw
    loaded = tune.load_plan(path)
    assert loaded.comm_mode == plan.comm_mode
    assert loaded.fingerprint == plan.fingerprint
    assert loaded.candidates[0]["measured_step_s"] is None


def test_plan_version_and_unknown_fields_rejected():
    d = _plan().to_dict()
    d["version"] = 0
    with pytest.raises(ValueError, match="version"):
        tune.TunePlan.from_dict(d)
    d = _plan().to_dict()
    d["surprise"] = 1
    with pytest.raises(ValueError, match="surprise"):
        tune.TunePlan.from_dict(d)


def test_fingerprint_sensitivity():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    fp = tune.plan_fingerprint(params, mesh, 4, "natural")
    assert fp == tune.plan_fingerprint(params, mesh, 4, "natural")
    # every keyed ingredient must change the fingerprint
    assert fp != tune.plan_fingerprint(params, mesh, 8, "natural")
    assert fp != tune.plan_fingerprint(params, mesh, 4, "topk")
    other = {"w": jax.ShapeDtypeStruct((8, 5), jnp.float32)}
    assert fp != tune.plan_fingerprint(other, mesh, 4, "natural")
    # the SEARCH SPACE is keyed too: a narrowed --tune_modes run must
    # not satisfy a later full-grid lookup on the same workload
    assert fp != tune.plan_fingerprint(
        params, mesh, 4, "natural", search={"modes": ("dense",)}
    )


def test_autotune_restricted_modes_do_not_poison_full_cache(tmp_path):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    comp = CompressionConfig(comm_mode="auto")
    params = {"w": jax.ShapeDtypeStruct((16, 8), jnp.float32)}
    kw = dict(cache_dir=str(tmp_path), link=tune.LinkModel.nominal(),
              verify_top=0)
    _, hit = tune.autotune(comp, params, mesh, 2,
                           modes=("dense", "randk_shared"), **kw)
    assert not hit
    # same workload, FULL grid: the narrowed plan must miss
    _, hit_full = tune.autotune(comp, params, mesh, 2, **kw)
    assert not hit_full
    # and each keeps its own cache entry
    assert len(list(tmp_path.glob("tuneplan_*.json"))) == 2


def test_autotune_lazy_analysis_only_on_miss(tmp_path):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    comp = CompressionConfig(comm_mode="auto")
    params = {"w": jax.ShapeDtypeStruct((16, 8), jnp.float32)}
    calls = []

    def analysis_fn():
        calls.append(1)
        return {"flops": 1e9, "bytes": 1e8}

    kw = dict(cache_dir=str(tmp_path), modes=("dense", "q8_ring"),
              link=tune.LinkModel.nominal(), verify_top=0,
              analysis_fn=analysis_fn, rates_fn=tune.DeviceRates.nominal)
    plan, hit = tune.autotune(comp, params, mesh, 2, **kw)
    assert not hit and len(calls) == 1
    assert plan.predicted_step_s > 0.0  # the compute term is really in
    _, hit2 = tune.autotune(comp, params, mesh, 2, **kw)
    assert hit2 and len(calls) == 1  # a hit stays free of analysis work


def test_cached_plan_miss_on_corrupt_or_mismatched_file(tmp_path):
    fp = "a" * 64
    path = tune.cache_path(str(tmp_path), fp)
    assert tune.load_cached_plan(str(tmp_path), fp) is None
    tune.save_plan(_plan(fp="b" * 64), path)  # wrong fingerprint inside
    assert tune.load_cached_plan(str(tmp_path), fp) is None
    with open(path, "w") as f:
        f.write("{not json")
    assert tune.load_cached_plan(str(tmp_path), fp) is None
    tune.save_plan(_plan(fp=fp), path)
    assert tune.load_cached_plan(str(tmp_path), fp).fingerprint == fp


# ---------------------------------------------------------------------------
# The search + autotune cache
# ---------------------------------------------------------------------------


def test_search_plan_measured_winner_and_evidence():
    """Injected measurements decide among the verified candidates; the
    plan records predicted AND measured times with the winner marked."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    comp = CompressionConfig()
    wtree = _wtree(jax.random.PRNGKey(0), w=4)
    fake = {"dense": 5e-3, "randk_shared": 2e-3, "q8_ring": 1e-3}
    plan = tune.search_plan(
        comp, wtree, mesh, 4,
        modes=("dense", "randk_shared", "q8_ring"), randk_grid=(0.05,),
        link=tune.LinkModel.nominal(), verify_top=3,
        measure_fn=lambda c, t, k: fake[c.comm_mode],
    )
    assert plan.comm_mode == "q8_ring"
    assert plan.measured_step_s == pytest.approx(1e-3)
    chosen = [r for r in plan.candidates if r["chosen"]]
    assert len(chosen) == 1 and chosen[0]["comm_mode"] == "q8_ring"
    for row in plan.candidates:
        assert row["predicted_step_s"] >= 0.0
        assert row["measured_step_s"] is not None  # verify_top covered all


def test_search_plan_prediction_only_when_verify_zero():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wtree = _wtree(jax.random.PRNGKey(0), w=4)
    boom = lambda c, t, k: (_ for _ in ()).throw(AssertionError)  # noqa
    plan = tune.search_plan(
        CompressionConfig(), wtree, mesh, 4,
        modes=("dense", "randk_shared"), link=tune.LinkModel.nominal(),
        verify_top=0, measure_fn=boom,
    )
    assert plan.measured_step_s is None
    # per-worker compressed payloads are smaller than dense: with a
    # nominal bandwidth-dominated link the sparser mode must rank first
    assert plan.comm_mode == "randk_shared"


def test_measured_omega_lands_in_tune_plan(tmp_path):
    """Satellite: a measured ``omega_hat`` replaces the analytic
    certificate in the EF-BV eta/nu derivation, the plan records the
    value AND its provenance (v6 fields), and both survive the
    strict-JSON round trip."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wtree = _wtree(jax.random.PRNGKey(0), w=4)
    comp = CompressionConfig(compressor="natural")
    kw = dict(modes=("efbv",), link=tune.LinkModel.nominal(),
              verify_top=0)

    analytic = tune.search_plan(comp, wtree, mesh, 4, **kw)
    assert analytic.omega == pytest.approx(0.125)   # natural certificate
    assert analytic.omega_source == "analytic"
    assert analytic.efbv_eta == pytest.approx(1.0 / 1.125)

    measured = tune.search_plan(comp, wtree, mesh, 4, omega=0.5, **kw)
    assert measured.omega == pytest.approx(0.5)
    assert measured.omega_source == "measured"
    # the damping really runs on the observed variance, not the bound
    assert measured.efbv_eta == pytest.approx(1.0 / 1.5)
    assert measured.efbv_eta != analytic.efbv_eta

    rt = tune.load_plan(tune.save_plan(measured, str(tmp_path / "p.json")))
    assert rt.omega == pytest.approx(0.5)
    assert rt.omega_source == "measured"


def test_no_certificate_codec_warns_with_structured_event():
    """Satellite: a codec with NO unbiased certificate (TopK has only
    ``delta``) yields ``omega_source="none"`` and a structured
    ``omega_unavailable`` obs event naming the codec — a warning a
    dashboard can alert on, not a lost stdout line."""
    from repro import obs

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wtree = _wtree(jax.random.PRNGKey(0), w=4)
    comp = CompressionConfig(compressor="topk",
                             compressor_kwargs=(("q", 0.25),))
    sink = obs.MemorySink()
    plan = tune.search_plan(comp, wtree, mesh, 4, modes=("dense", "ef21"),
                            link=tune.LinkModel.nominal(), verify_top=0,
                            obs_sink=sink)
    assert plan.omega is None
    assert plan.omega_source == "none"
    events = sink.events("omega_unavailable")
    assert len(events) == 1
    assert events[0]["data"]["codec"] == "TopK"
    assert events[0]["data"]["compressor"] == "topk"
    obs.validate_record(events[0])


def test_autotune_measured_omega_lazy_only_on_miss(tmp_path):
    """``omega_fn`` mirrors ``hide_fn``: invoked once on a cache miss
    with measured verification, never on a hit — and the cached plan
    round-trips the measured value."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    comp = CompressionConfig(comm_mode="auto", compressor="natural")
    params = {"w": jax.ShapeDtypeStruct((16, 8), jnp.float32)}
    calls = []

    def omega_fn():
        calls.append(1)
        return tune.OmegaMeasurement(omega_hat=0.5, nmse=0.4,
                                     n_leaves=1, d_total=128)

    kw = dict(cache_dir=str(tmp_path), modes=("dense", "efbv"),
              link=tune.LinkModel.nominal(), verify_top=1,
              measure_fn=lambda c, t, k: 1e-3,
              analysis_fn=lambda: {"flops": 1e9, "bytes": 1e8},
              rates_fn=tune.DeviceRates.nominal, omega_fn=omega_fn)
    plan, hit = tune.autotune(comp, params, mesh, 2, **kw)
    assert not hit and len(calls) == 1
    assert plan.omega == pytest.approx(0.5)
    assert plan.omega_source == "measured"
    plan2, hit2 = tune.autotune(comp, params, mesh, 2, **kw)
    assert hit2 and len(calls) == 1       # a hit stays free of probe work
    assert plan2.omega == pytest.approx(0.5)
    assert plan2.omega_source == "measured"


def test_measure_omega_probe_matches_certificate():
    """The probe the trainer's ``--comm_mode auto`` path feeds the
    tuner: d-weighted like ``estimate_omega``, so the two are directly
    comparable (RandK's certificate is exact in expectation)."""
    like = {"a": jax.ShapeDtypeStruct((4, 1000), jnp.float32)}
    from repro.core.compressors import RandK

    m = tune.measure_omega(RandK(0.1), like, iters=4)
    assert m.source == "measured"
    assert m.n_leaves == 1 and m.d_total == 1000
    assert m.omega_hat == pytest.approx(
        tune.estimate_omega(RandK(0.1), like), rel=0.15)


def test_default_candidates_grid_and_filters():
    comp = CompressionConfig(compressor="topk",
                             compressor_kwargs=(("q", 0.25),))
    wtree = _wtree(jax.random.PRNGKey(0))
    cands = tune.default_candidates(comp, wtree)
    modes = {c.comm_mode for c in cands}
    assert "ef21" in modes  # contractive compressor -> ef21 searchable
    comp_u = CompressionConfig(compressor="natural")
    modes_u = {c.comm_mode for c in tune.default_candidates(comp_u, wtree)}
    assert "ef21" not in modes_u  # no contraction certificate, no ef21
    # efbv eta derives from the ESTIMATED omega (natural: omega=1/8)
    efbv = [c for c in tune.default_candidates(comp_u, wtree)
            if c.comm_mode == "efbv"]
    assert efbv and efbv[0].efbv_eta == pytest.approx(1.0 / (1.0 + 0.125))
    with pytest.raises(ValueError, match="carrier_pigeon"):
        tune.default_candidates(comp, wtree, modes=("carrier_pigeon",))


def test_search_plan_wire_grids_cross_product():
    """Wire grids cross the comm-mode grid: the search can pick a
    DIFFERENT codec per wire, the plan records the winning flags, and
    the per-wire bytes show up in the candidates' wire_bytes charge."""
    from repro.comm import build_transport
    from repro.configs import get_smoke_config

    cfg = get_smoke_config("qwen2-moe-a2.7b").with_(dtype="float32")
    comp = CompressionConfig(comm_mode="auto", moe_wire="q8", act_wire="q8")
    traffic = build_transport(
        CompressionConfig(comm_mode="dense", shift_rule="diana",
                          moe_wire="q8", act_wire="q8"),
        cfg, None, w=4, tokens_per_worker=64,
    ).extra_traffic()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wtree = _wtree(jax.random.PRNGKey(0), w=4)
    plan = tune.search_plan(
        comp, wtree, mesh, 4, modes=("dense", "randk_shared"),
        randk_grid=(0.05,), link=tune.LinkModel.nominal(), verify_top=0,
        moe_wire_grid=("none", "q8"), act_wire_grid=("none", "q8"),
        wire_traffic=traffic,
    )
    rows = plan.candidates
    # 2 modes x 2 moe flags x 2 act flags
    assert len(rows) == 8
    assert {(r["moe_wire"], r["act_wire"]) for r in rows} == {
        ("none", "none"), ("none", "q8"), ("q8", "none"), ("q8", "q8")}
    # q8 wires strictly beat identity-width wires on a bandwidth link
    by = {(r["comm_mode"], r["moe_wire"], r["act_wire"]):
          r["predicted_step_s"] for r in rows}
    assert by[("randk_shared", "q8", "q8")] < by[("randk_shared", "none",
                                                  "none")]
    assert (plan.moe_wire, plan.act_wire) == ("q8", "q8")


def test_autotune_cache_hit_skips_search(tmp_path):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    comp = CompressionConfig(comm_mode="auto")
    params = {"w": jax.ShapeDtypeStruct((16, 8), jnp.float32),
              "b": jax.ShapeDtypeStruct((8,), jnp.float32)}
    calls = []

    def counting_measure(c, t, k):
        calls.append(c.label)
        return 1e-3

    kw = dict(cache_dir=str(tmp_path), modes=("dense", "randk_shared"),
              link=tune.LinkModel.nominal(), verify_top=2,
              measure_fn=counting_measure)
    plan, hit = tune.autotune(comp, params, mesh, 2, **kw)
    assert not hit and len(calls) == 2
    assert os.path.exists(tune.cache_path(str(tmp_path), plan.fingerprint))
    plan2, hit2 = tune.autotune(comp, params, mesh, 2, **kw)
    assert hit2 and len(calls) == 2  # no re-measure on the hit
    assert plan2 == plan
    _, hit3 = tune.autotune(comp, params, mesh, 2, force=True, **kw)
    assert not hit3 and len(calls) == 4  # --autotune forces a re-search


# ---------------------------------------------------------------------------
# auto comm-mode plumbing
# ---------------------------------------------------------------------------


def test_auto_mode_must_be_resolved_before_channels():
    comp = CompressionConfig(comm_mode="auto")
    with pytest.raises(ValueError, match="auto"):
        _ = comp.aggregation_mode
    with pytest.raises(ValueError, match="repro.tune|resolve"):
        make_channel(comp)
    with pytest.raises(ValueError, match="resolve"):
        make_channel("auto")
    resolved = tune.apply_plan(comp, _plan(mode="q8_ring"))
    assert resolved.comm_mode == "q8_ring"
    assert isinstance(make_channel(resolved), MeshChannel)


def test_apply_plan_sets_every_searched_knob():
    comp = CompressionConfig(comm_mode="auto")
    plan = _plan(mode="q8_ring_overlap", overlap_bucket_bytes=123456,
                 randk_q=0.02, q8_block_rows=32, efbv_eta=0.5, efbv_nu=0.9,
                 moe_wire="q8", act_wire="dense")
    r = tune.apply_plan(comp, plan)
    assert (r.comm_mode, r.overlap_bucket_bytes, r.randk_q,
            r.q8_block_rows, r.efbv_eta, r.efbv_nu) == (
        "q8_ring_overlap", 123456, 0.02, 32, 0.5, 0.9)
    assert (r.moe_wire, r.act_wire) == ("q8", "dense")
    ch = make_channel(r)
    assert ch.bucket_bytes == 123456 and ch.q8_block_rows == 32


def test_make_channel_plumbs_q8_block_rows():
    ch = make_channel("q8_ring_fused", q8_block_rows=32)
    assert isinstance(ch, MeshChannel) and ch.q8_block_rows == 32


def test_autotune_flag_requires_auto_mode():
    """--autotune/--tune_plan with an explicit concrete --comm_mode must
    refuse instead of silently replacing the requested mode."""
    from repro.launch.train import main

    with pytest.raises(SystemExit, match="comm_mode auto"):
        main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "1",
              "--batch", "1", "--seq", "8", "--comm_mode", "q8_ring",
              "--autotune"])


def test_disabled_config_with_auto_mode_is_dense():
    """A disabled CompressionConfig never resolves through the tuner:
    its transport is the dense mean (--no-compression --comm_mode auto
    must not trip the unresolved-auto guard)."""
    comp = CompressionConfig(enabled=False, comm_mode="auto")
    assert comp.aggregation_mode == "dense"
    ch = make_channel(comp)
    assert isinstance(ch, MeshChannel) and ch.mode == "dense"


# ---------------------------------------------------------------------------
# Drift resync (satellite): bounded h_bar drift over lossy aggregation
# ---------------------------------------------------------------------------


def _drift(h, h_bar):
    exact = jax.tree_util.tree_map(lambda a: jnp.mean(a, axis=0), h)
    sq = jax.tree_util.tree_map(
        lambda e, b: jnp.sum((e - b) ** 2), exact, h_bar
    )
    return float(jnp.sqrt(sum(jax.tree_util.tree_leaves(sq))))


def _run_drift(steps, every, w=4, seed=0):
    """DIANA rounds over the LOSSY randk_shared aggregation: workers
    integrate their exact messages while h_bar tracks the sparsified
    aggregate — the ROADMAP's shift-tracking random walk."""
    key = jax.random.PRNGKey(seed)
    rule = DianaShift(alpha=0.5)
    q = NaturalCompression()
    ch = MeshChannel(mode="randk_shared", randk_q=0.1)
    like = _wtree(key, w=w)
    h = rule.init(like)
    h_bar = rule.init_bar(like)
    drifts = []
    for step in range(steps):
        k = jax.random.fold_in(key, 1000 + step)
        grads = jax.tree_util.tree_map(
            lambda a: jax.random.normal(jax.random.fold_in(k, 7), a.shape),
            like,
        )
        _, h, h_bar, _ = rule.round(q, k, grads, h, h_bar, channel=ch)
        h_bar = resync_h_bar(h, h_bar, jnp.int32(step), every)
        drifts.append(_drift(h, h_bar))
    return drifts


def test_resync_h_bar_unit():
    key = jax.random.PRNGKey(3)
    h = {"x": jax.random.normal(key, (4, 6))}
    h_bar = {"x": jax.random.normal(jax.random.fold_in(key, 1), (6,))}
    # non-firing step: untouched; firing step: the exact worker mean
    same = resync_h_bar(h, h_bar, jnp.int32(0), 5)
    np.testing.assert_array_equal(np.asarray(same["x"]),
                                  np.asarray(h_bar["x"]))
    fired = resync_h_bar(h, h_bar, jnp.int32(4), 5)
    np.testing.assert_allclose(np.asarray(fired["x"]),
                               np.asarray(h["x"]).mean(0), rtol=1e-6)
    # disabled / stateless: no-ops
    assert resync_h_bar(h, h_bar, jnp.int32(4), 0) is h_bar
    assert resync_h_bar(None, None, jnp.int32(4), 5) is None


def test_h_bar_drift_bounded_by_resync():
    """Over many lossy rounds the un-resynced drift RANDOM-WALKS away;
    with drift_resync_every=N it is pinned to ~0 at every resync and its
    running maximum stays bounded by the free-walk's."""
    steps, every = 40, 5
    free = _run_drift(steps, every=0)
    pinned = _run_drift(steps, every=every)
    assert free[-1] > 0.0  # the walk is real (lossy aggregation)
    # at every firing step the drift collapses to numerical zero
    fire_vals = [pinned[s] for s in range(every - 1, steps, every)]
    assert max(fire_vals) < 1e-4 * max(max(free), 1.0)
    # and the pinned walk never exceeds the free walk's excursion
    assert max(pinned) <= max(free) + 1e-9
    # the tail comparison: resync keeps the end-state drift strictly
    # below the free walk's end-state drift
    assert pinned[-1] < free[-1]


def test_train_step_resyncs_h_bar_from_worker_shifts():
    """drift_resync_every wired through the PRODUCTION train step: after
    a firing step the state's h_bar equals the exact worker mean of its
    shifts, where the unsynced run has drifted away."""
    from repro.configs import get_smoke_config
    from repro.configs.base import TrainConfig
    from repro.data.tokens import TokenStream
    from repro.launch.mesh import make_host_mesh, n_workers
    from repro.launch.train import build_train_step, init_state

    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    outs = {}
    for every in (0, 3):
        comp = CompressionConfig(
            enabled=True, compressor="natural", shift_rule="diana",
            comm_mode="randk_shared", drift_resync_every=every,
        )
        tcfg = TrainConfig(learning_rate=1e-2, total_steps=3,
                           warmup_steps=1, compression=comp)
        mesh = make_host_mesh()
        w = n_workers(mesh)
        state = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
        step = jax.jit(build_train_step(cfg, tcfg, mesh, w))
        stream = TokenStream(cfg, 32, 4)
        for i in range(3):  # steps 0,1,2 -> step 2 fires (2 % 3 == 2)
            state, _ = step(state, stream.batch(i))
        outs[every] = _drift(state.h, state.h_bar)
    assert outs[3] < 1e-5          # resynced: h_bar == mean(h)
    assert outs[0] > outs[3]       # un-resynced run really had drifted


# ---------------------------------------------------------------------------
# --comm_mode auto end-to-end (the acceptance path): tuner emits a plan
# JSON, train consumes it, the second invocation is a fingerprint hit
# ---------------------------------------------------------------------------


_AUTO_CLI = textwrap.dedent("""
    import os, glob, io, contextlib
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.launch.train import main

    cache = os.path.join("{tmp}", "tune_cache")
    args = ["--arch", "qwen3-0.6b", "--smoke", "--steps", "2",
            "--batch", "8", "--seq", "32",
            "--comm_mode", "auto", "--tune_cache", cache,
            # tiny measured grid: no interpret-mode Pallas on this path
            "--tune_modes", "dense,randk_shared,q8_ring"]

    buf1 = io.StringIO()
    with contextlib.redirect_stdout(buf1):
        state1 = main(args)
    out1 = buf1.getvalue()
    assert "tune: searched" in out1, out1
    assert "comm_mode=" in out1, out1
    assert np.isfinite(float(state1.bits)) and float(state1.bits) >= 0

    plans = glob.glob(os.path.join(cache, "tuneplan_*.json"))
    assert len(plans) == 1, plans  # the tuner emitted ONE TunePlan JSON
    import json
    plan = json.load(open(plans[0]))
    measured = [c for c in plan["candidates"]
                if c["measured_step_s"] is not None]
    assert len(measured) >= 1 and any(c["chosen"] for c in plan["candidates"])

    buf2 = io.StringIO()
    with contextlib.redirect_stdout(buf2):
        state2 = main(args)
    out2 = buf2.getvalue()
    assert "tune: cache hit" in out2, out2  # fingerprint hit, no re-search
    assert len(glob.glob(os.path.join(cache, "tuneplan_*.json"))) == 1
    print("AUTO_CLI_OK")
""")


def test_train_cli_auto_mode_8dev_subprocess(tmp_path):
    """--comm_mode auto end-to-end through the train CLI on 8 fake
    devices: search + plan JSON on the first run, fingerprint cache hit
    on the second."""
    r = subprocess.run(
        [sys.executable, "-c", _AUTO_CLI.format(tmp=str(tmp_path))],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=_REPO_ROOT,
    )
    assert "AUTO_CLI_OK" in r.stdout, r.stdout[-3000:] + r.stderr[-3000:]