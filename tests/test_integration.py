"""End-to-end integration: checkpoint/resume determinism of the full
TrainState, and sharded-vs-unsharded loss equivalence (the distributed
forward must compute the SAME numbers as the single-device one)."""

import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save
from repro.configs import get_smoke_config
from repro.configs.base import CompressionConfig, TrainConfig
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_host_mesh, n_workers
from repro.launch.train import TrainState, build_train_step, init_state

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_checkpoint_resume_exact():
    """Train 6 steps; OR train 3, checkpoint the FULL TrainState (params,
    opt moments, DIANA shifts, PRNG key), restore, train 3 more — the
    loss trajectories must be bit-identical."""
    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=6, warmup_steps=1,
                       compression=CompressionConfig(
                           compressor="natural", shift_rule="diana"))
    mesh = make_host_mesh()
    w = n_workers(mesh)
    step = jax.jit(build_train_step(cfg, tcfg, mesh, w))
    stream = TokenStream(cfg, 32, 4)

    # straight run
    st = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
    losses_a = []
    for i in range(6):
        st, m = step(st, stream.batch(i))
        losses_a.append(float(m["loss"]))

    # checkpointed run
    st = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
    losses_b = []
    for i in range(3):
        st, m = step(st, stream.batch(i))
        losses_b.append(float(m["loss"]))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "state.npz")
        save(path, st._asdict(), step=3)
        like = jax.tree_util.tree_map(jnp.zeros_like, st._asdict())
        st2 = TrainState(**restore(path, like))
    for i in range(3, 6):
        st2, m = step(st2, stream.batch(i))
        losses_b.append(float(m["loss"]))

    np.testing.assert_array_equal(losses_a, losses_b)


_SHARDED_LOSS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.dist import params_pspecs, validate_pspecs
    from repro.models import model as M

    for arch in ("qwen3-0.6b", "qwen2-moe-a2.7b"):
        cfg = get_smoke_config(arch).with_(
            dtype="float32", d_model=256, n_heads=4, n_kv_heads=4,
            vocab_size=512,
        )
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks}
        loss_ref, _ = M.train_loss(params, cfg, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        specs = validate_pspecs(params, params_pspecs(params), mesh)
        sharded = jax.device_put(
            params, jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                                 is_leaf=lambda x: isinstance(x, P)))
        sb = jax.device_put(batch, NamedSharding(mesh, P("data")))
        with jax.sharding.set_mesh(mesh):
            loss_sh, _ = jax.jit(
                lambda p, b: M.train_loss(p, cfg, b))(sharded, sb)
        err = abs(float(loss_ref) - float(loss_sh))
        assert err < 5e-4, (arch, float(loss_ref), float(loss_sh))
    print("SHARDED_LOSS_OK")
""")


def test_sharded_loss_matches_single_device():
    """The 8-fake-device sharded forward computes the same loss as the
    single-device one (GSPMD partitioning preserves the math)."""
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_LOSS],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=_REPO_ROOT,
    )
    assert "SHARDED_LOSS_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
