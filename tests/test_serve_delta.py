"""The trainer->fleet model-delta stream: the ISSUE's three contracts.

  (a) dense wire (lossless integer bit-pattern deltas): a replica that
      applied every message is BIT-IDENTICAL to the trainer — identical
      decode logits — even after a LOSSY initial sync;
  (b) lossy wire (q8): bounded parameter error that the publisher
      reports exactly (the replica is in bitwise lockstep with the
      publisher's h_bar), resetting to exactly zero at resync;
  (c) the fleet serves continuous-batching traffic off the stream with
      staleness <= K, and a staleness breach triggers a dense resync.

Plus the accounting seams: the transport's model wire amortizes its
bytes/step by publish_every, and the tune layer carries model_wire
through Candidate labels, predictor charging, and TunePlan round-trips.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import SimChannel, Wire, build_transport, wire_flag_codec
from repro.configs import get_smoke_config
from repro.configs.base import CompressionConfig
from repro.models import model as M
from repro.serving import (
    DeltaPublisher,
    Engine,
    Request,
    ServingFleet,
    apply_msg,
    dense_tree_bits,
    tree_rel_err,
)

tmap = jax.tree_util.tree_map


def _model_wire(flag: str) -> Wire:
    return Wire(name="model", topology="broadcast",
                codec=wire_flag_codec(flag), channel=SimChannel())


def _perturb(params, i: int, scale: float = 0.01):
    """A synthetic optimizer step: params + scale * N(0, 1)."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    key = jax.random.fold_in(jax.random.PRNGKey(777), i)
    out = []
    for j, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, j)
        out.append(leaf + scale * jax.random.normal(k, leaf.shape,
                                                    leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def _trees_bit_equal(a, b) -> bool:
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b))
    )


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _probe_logits(cfg, params, toks):
    state = M.make_decode_state(cfg, 1, 16)
    out = []
    for t, tok in enumerate(toks):
        logits, state = M.decode_step(
            params, cfg, jnp.asarray([[tok]], jnp.int32), state, jnp.int32(t)
        )
        out.append(np.asarray(logits))
    return out


# -- contract (a): lossless stream ------------------------------------------


def test_dense_wire_bit_identical_logits(dense_setup):
    """K=1 + lossless codec => replica params and decode logits are
    BIT-identical to the trainer's after every publish."""
    cfg, params = dense_setup
    pub = DeltaPublisher(_model_wire("dense"), key=jax.random.PRNGKey(3))
    sync = pub.initial_sync(params)
    replica = sync.payload
    assert _trees_bit_equal(replica, params)  # dense sync is exact too

    for i in range(3):
        params = _perturb(params, i)
        msg = pub.publish(params, step=i + 1)
        assert msg.exact
        replica = apply_msg(replica, msg)
        assert _trees_bit_equal(replica, params)
        assert msg.err_rel == 0.0

    ref = _probe_logits(cfg, params, [5, 17, 99])
    got = _probe_logits(cfg, replica, [5, 17, 99])
    for r, g in zip(ref, got):
        assert np.array_equal(r, g)


def test_dense_wire_exact_after_lossy_sync(dense_setup):
    """One exact publish makes the replica bit-identical even when the
    bootstrap broadcast was lossy (natural, ~9 bits/scalar)."""
    _, params = dense_setup
    pub = DeltaPublisher(_model_wire("dense"), key=jax.random.PRNGKey(4))
    sync = pub.initial_sync(params, sync_codec=wire_flag_codec("natural"))
    replica = sync.payload
    assert not _trees_bit_equal(replica, params)   # lossy bootstrap
    assert sync.err_rel > 0.0

    msg = pub.publish(params, step=1)
    replica = apply_msg(replica, msg)
    assert _trees_bit_equal(replica, params)
    assert msg.err_rel == 0.0


# -- contract (b): lossy stream, bounded + publisher-known error -------------


def test_q8_wire_bounded_error_and_lockstep(dense_setup):
    """Lossy stream: error stays bounded, the replica is in bitwise
    lockstep with the publisher's h_bar (so err_rel IS the replica's
    error), and a snapshot resync resets it to exactly zero."""
    _, params = dense_setup
    pub = DeltaPublisher(_model_wire("q8"), key=jax.random.PRNGKey(5))
    sync = pub.initial_sync(params)
    replica = sync.payload

    errs = []
    for i in range(4):
        params = _perturb(params, 100 + i)
        msg = pub.publish(params, step=i + 1)
        assert not msg.exact
        replica = apply_msg(replica, msg)
        # lockstep: the replica holds EXACTLY the publisher's shift
        assert _trees_bit_equal(replica, pub.h_bar)
        assert msg.err_rel == pytest.approx(tree_rel_err(params, replica))
        errs.append(msg.err_rel)
    assert max(errs) < 0.05            # bounded
    assert max(errs) > 0.0             # genuinely lossy

    snap = pub.snapshot(params, step=5)
    replica = apply_msg(replica, snap)
    assert _trees_bit_equal(replica, params)
    assert snap.err_rel == 0.0


# -- contract (c): the fleet -------------------------------------------------


def test_fleet_serves_off_dense_stream(dense_setup):
    """Two replicas serve real requests while the stream advances; end
    state is bit-equal to the trainer and staleness never exceeded K."""
    cfg, params = dense_setup
    pub = DeltaPublisher(_model_wire("dense"), key=jax.random.PRNGKey(6))
    sync = pub.initial_sync(params)
    fleet = ServingFleet(cfg, sync, 2, stale_k=4, max_batch=2, cache_len=64)
    for i, prompt in enumerate([[5, 17, 99], [42, 7], [123, 9, 11], [88, 3]]):
        fleet.submit(Request(uid=i, prompt=prompt, max_new_tokens=6))

    done = []
    for i in range(6):
        params = _perturb(params, 200 + i, scale=1e-3)
        fleet.deliver(pub.publish(params, step=i + 1))
        done.extend(fleet.tick())
    done.extend(fleet.run_drain())

    assert sorted(r.uid for r in done) == [0, 1, 2, 3]
    assert all(r.done for r in done)
    assert fleet.max_staleness_seen <= 4
    for rep in fleet.replicas:
        assert _trees_bit_equal(rep.params, params)


def test_fleet_staleness_triggers_resync(dense_setup):
    """A replica capped at one apply per tick falls behind a publish
    burst; the staleness bound flags it and a snapshot fast-forwards it
    (pending backlog dropped, not replayed)."""
    cfg, params = dense_setup
    pub = DeltaPublisher(_model_wire("q8"), key=jax.random.PRNGKey(7))
    sync = pub.initial_sync(params)
    fleet = ServingFleet(cfg, sync, 1, stale_k=2, max_batch=1, cache_len=64,
                         max_apply_per_tick=1)
    fleet.submit(Request(uid=0, prompt=[5, 17], max_new_tokens=32))

    for i in range(5):   # burst: 5 publishes land before the next tick
        params = _perturb(params, 300 + i, scale=1e-3)
        fleet.deliver(pub.publish(params, step=i + 1))
    fleet.tick()         # 1 apply/tick: the replica reaches step 1 of 5
    lagging = fleet.needs_resync()
    assert lagging, "staleness bound K=2 never tripped under the burst"
    assert fleet.max_staleness_seen > 2

    snap = pub.snapshot(params, step=fleet.trainer_step)
    backlog = len(fleet.replicas[0].pending)
    fleet.deliver(snap)
    fleet.tick()
    rep = fleet.replicas[0]
    assert not fleet.needs_resync()
    assert rep.staleness(fleet.trainer_step) == 0
    assert rep.resyncs == 1
    assert _trees_bit_equal(rep.params, params)
    # fast-forward: the backlog was dropped, not replayed
    assert rep.applied < backlog + 5


# -- engine slot-lifecycle edge cases use tests/test_serving.py --------------
# -- accounting seams --------------------------------------------------------


def _transport_for(cfg, flag, publish_every):
    comp = CompressionConfig(enabled=False, model_wire=flag,
                             publish_every=publish_every)
    shapes = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    return build_transport(comp, cfg, SimChannel(), params_like=shapes)


def test_transport_model_wire_accounting(dense_setup):
    """The model wire's bytes/step amortize by publish_every, and q8
    rides under the dense broadcast."""
    cfg, _ = dense_setup
    b1 = _transport_for(cfg, "q8", 1).per_wire_bits()["model"]
    b4 = _transport_for(cfg, "q8", 4).per_wire_bits()["model"]
    assert b4 == pytest.approx(b1 / 4.0)
    dense = _transport_for(cfg, "dense", 1).per_wire_bits()["model"]
    assert b1 < dense
    assert _transport_for(cfg, "q8", 1)["model"].topology == "broadcast"


def test_tune_carries_model_wire():
    """Candidate validates/labels the flag, the predictor charges the
    model wire's declared traffic, and TunePlan round-trips it."""
    from repro import tune
    from repro.tune.model import Candidate, extra_wire_bits

    cand = Candidate("dense", model_wire="q8")
    assert "model=q8" in cand.label
    with pytest.raises(ValueError, match="wire codec flag"):
        Candidate("dense", model_wire="bogus")

    sds = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    traffic = {"model": ((sds, 0.5),)}
    charged = extra_wire_bits(cand, traffic)
    uncharged = extra_wire_bits(Candidate("dense"), traffic)
    assert 0.0 < charged < uncharged   # q8 < identity width

    plan = tune.TunePlan(
        fingerprint="fp", comm_mode="dense", overlap_bucket_bytes=1 << 20,
        randk_q=0.05, q8_block_rows=64, efbv_eta=1.0, efbv_nu=1.0,
        predicted_step_s=1.0, model_wire="q8",
    )
    rt = tune.TunePlan.from_dict(plan.to_dict())
    assert rt.model_wire == "q8"
    comp = tune.apply_plan(CompressionConfig(comm_mode="auto"), plan)
    assert comp.model_wire == "q8"


def test_broadcast_params_rejects_auto():
    """Satellite: the serve-side broadcast goes through make_channel,
    so the 'auto' tuner sentinel fails loudly with the accepted modes."""
    from repro.launch.serve import broadcast_params

    params = {"w": jnp.ones((4, 4), jnp.float32)}
    with pytest.raises(ValueError, match="auto"):
        broadcast_params(params, comm_mode="auto")
    with pytest.raises(ValueError, match="sim"):
        broadcast_params(params, comm_mode="definitely-not-a-mode")


def test_dense_tree_bits_matches_identity_payload():
    tree = {"a": jnp.zeros((3, 5), jnp.float32),
            "b": jnp.zeros((7,), jnp.float32)}
    assert dense_tree_bits(tree) == 32.0 * (15 + 7)
