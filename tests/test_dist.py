"""Distribution substrate tests.  The multi-device collective paths run
in a SUBPROCESS with --xla_force_host_platform_device_count (the main
pytest process must keep 1 device for the smoke tests)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.collectives import dense_mean, randk_shared_mean
from repro.dist.worker_grads import per_worker_grads, split_batch

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_split_batch_roundtrip():
    b = {"tokens": jnp.arange(24).reshape(12, 2)}
    wb = split_batch(b, 4)
    assert wb["tokens"].shape == (4, 3, 2)
    np.testing.assert_array_equal(
        np.asarray(wb["tokens"]).reshape(12, 2), np.asarray(b["tokens"])
    )


def test_per_worker_grads_match_full_grad():
    """mean_i grad_i == grad of the mean loss (sanity of the vmap path)."""
    w = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    batch = {"x": jnp.arange(8.0).reshape(8, 1), "y": jnp.arange(8.0)}

    def loss_fn(params, b):
        pred = (b["x"] * params["w"][0, 0] + params["w"][1, 1]).squeeze(-1)
        l = jnp.mean((pred - b["y"]) ** 2)
        return l, {"l": l}

    params = {"w": w}
    wbatch = split_batch(batch, 4)
    wg, loss, _ = per_worker_grads(loss_fn, params, wbatch)
    assert wg["w"].shape == (4, 2, 2)
    full, _ = jax.grad(loss_fn, has_aux=True)(params, batch)
    np.testing.assert_allclose(
        np.asarray(jnp.mean(wg["w"], 0)), np.asarray(full["w"]), rtol=1e-6
    )


def test_randk_shared_mean_unbiased():
    key = jax.random.PRNGKey(0)
    wtree = {"a": jax.random.normal(key, (6, 50))}
    true_mean = np.asarray(jnp.mean(wtree["a"], 0))
    acc = np.zeros(50)
    n = 600
    for i in range(n):
        out = randk_shared_mean(jax.random.PRNGKey(i), wtree, 0.2)
        acc += np.asarray(out["a"])
    np.testing.assert_allclose(acc / n, true_mean, atol=0.15)


def test_randk_shared_mean_sparsity():
    wtree = {"a": jnp.ones((4, 100))}
    out = randk_shared_mean(jax.random.PRNGKey(1), wtree, 0.1)
    nz = (np.asarray(out["a"]) != 0).sum()
    assert nz == 10  # exactly K coordinates survive


_RING_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.dist.collectives import q8_ring_tree_mean

    mesh = jax.make_mesh((8, 1), ("data", "model"))
    key = jax.random.PRNGKey(0)
    w = 8
    tree = {"a": jax.random.normal(key, (w, 1000)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (w, 33))}
    tree = jax.device_put(tree, NamedSharding(mesh, P("data")))

    out = jax.jit(
        lambda k, t: q8_ring_tree_mean(k, t, mesh, worker_axes=("data",),
                                       pod_axis=None)
    )(key, tree)
    ref = jax.tree.map(lambda a: jnp.mean(a, 0), tree)
    for k in ("a", "b"):
        err = np.abs(np.asarray(out[k]) - np.asarray(ref[k])).max()
        scale = np.abs(np.asarray(ref[k])).max() + 1.0
        assert err < 0.05 * scale, (k, err, scale)
    print("RING_OK")
""")


def test_q8_ring_allreduce_subprocess():
    """int8 ring all-reduce ~= exact mean over 8 fake devices."""
    r = subprocess.run(
        [sys.executable, "-c", _RING_TEST],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=_REPO_ROOT,
    )
    assert "RING_OK" in r.stdout, r.stdout + r.stderr


_SHARDING_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import params_pspecs, validate_pspecs
    from repro.models import model as M
    from repro.configs import get_smoke_config

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    for arch in ("qwen3-0.6b", "qwen2-moe-a2.7b", "rwkv6-3b", "zamba2-1.2b"):
        cfg = get_smoke_config(arch)
        shapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = validate_pspecs(shapes, params_pspecs(shapes), mesh)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        def check(leaf, sp):
            for size, ax in zip(leaf.shape, tuple(sp)):
                if ax is None: continue
                axs = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axs: n *= sizes[a]
                assert size % n == 0, (arch, leaf.shape, sp)
        jax.tree.map(check, shapes, specs,
                     is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    print("SPECS_OK")
""")


def test_param_specs_valid_on_mesh_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _SHARDING_TEST],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=_REPO_ROOT,
    )
    assert "SPECS_OK" in r.stdout, r.stdout + r.stderr


def test_compressed_tree_mean_dense_matches_dense_mean():
    """The identity/dense wire format is EXACTLY the plain mean — both
    via the comm-mode string and via CompressionConfig dispatch."""
    from repro.configs.base import CompressionConfig
    from repro.dist.collectives import compressed_tree_mean

    key = jax.random.PRNGKey(3)
    wtree = {
        "a": jax.random.normal(key, (4, 17)),
        "b": {"c": jax.random.normal(jax.random.fold_in(key, 1), (4, 3, 5))},
    }
    ref = dense_mean(wtree)
    outs = [
        compressed_tree_mean(wtree, "dense", key),
        compressed_tree_mean(
            wtree,
            CompressionConfig(enabled=True, compressor="identity",
                              comm_mode="dense"),
            key,
        ),
        # a disabled config is dense regardless of its comm_mode
        compressed_tree_mean(
            wtree, CompressionConfig(enabled=False, comm_mode="q8_ring"), key
        ),
    ]
    for out in outs:
        jax.tree_util.tree_map(
            lambda x, y: np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y)
            ),
            out, ref,
        )


def test_worker_stacked_pspec_prepends_worker_axes():
    """worker_stacked_pspec = P(worker_axes, *params_pspecs entry) for
    EVERY parameter leaf, on both host and multi-pod meshes."""
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.dist.sharding import params_pspecs, worker_stacked_pspec
    from repro.models import model as M

    cfg = get_smoke_config("qwen3-0.6b")
    shapes = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    specs = params_pspecs(shapes)
    is_p = lambda x: isinstance(x, P)

    for mesh_shape, axes, lead in (
        ((1, 1), ("data", "model"), "data"),
        ((1, 1, 1), ("pod", "data", "model"), ("pod", "data")),
    ):
        mesh = jax.make_mesh(mesh_shape, axes)
        wspecs = jax.tree_util.tree_map(
            lambda sp: worker_stacked_pspec(mesh, sp), specs, is_leaf=is_p
        )

        def check(sp, wsp):
            assert tuple(wsp)[0] == lead, (sp, wsp)
            assert tuple(wsp)[1:] == tuple(sp), (sp, wsp)

        jax.tree_util.tree_map(check, specs, wspecs, is_leaf=is_p)
