"""Transport-layer tests (the Wire/Transport refactor contract):

  * grad wire: ``Wire.shift_round`` is bit-exact with the pre-refactor
    ``Channel.shift_round`` for every shift rule x {SimChannel, dense
    MeshChannel, drained AsyncChannel} — the refactor moved the call
    site, never the math or the key derivation.
  * moe wire: dispatch/combine through the dense (identity) codec is
    value-identical to the uncompressed einsum path, single-group AND
    grouped-scan; q8 stays within a small relative error of it.
  * forwarded sends: ``Wire.send`` obeys the codec's unbiased variance
    contract, and the threaded shift is classic error feedback
    (``y + e_new == x + e``).
  * accounting: structural ``wire_bits`` of every registered wire equals
    the wire_bits of the CONCRETE payloads its codec emits.
  * registry/config errors name the offending string verbatim next to
    the accepted list (wire topology, wire codec flag, comm mode,
    duplicate registration, moe_wire on an expert-free arch).
  * end to end: the production train step runs with the moe and act
    wires compressed, and dense wires reproduce the unwired forward
    exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    AsyncChannel,
    MeshChannel,
    SimChannel,
    Transport,
    Wire,
    WIRE_CODEC_FLAGS,
    WIRE_TOPOLOGIES,
    aggregation_wire_codec,
    build_transport,
    make_channel,
    wire_flag_codec,
    wire_stream,
)
from repro.comm.channel import Channel
from repro.comm.wire import encode_workers, leaf_key
from repro.configs import get_smoke_config
from repro.configs.base import CompressionConfig, TrainConfig
from repro.core.compressors import Identity, Int8Stochastic, RandK
from repro.models import model as M
from repro.models import moe as MOE

tmap = jax.tree_util.tree_map

RULE_CONFIGS = {
    "fixed": CompressionConfig(enabled=True, compressor="natural",
                               shift_rule="fixed"),
    "diana": CompressionConfig(enabled=True, compressor="natural",
                               shift_rule="diana", shift_alpha=0.25),
    "rand_diana": CompressionConfig(enabled=True, compressor="natural",
                                    shift_rule="rand_diana", shift_p=0.5),
    "ef21": CompressionConfig(enabled=True, compressor="topk",
                              compressor_kwargs=(("q", 0.25),),
                              shift_rule="ef21"),
    "efbv": CompressionConfig(enabled=True, compressor="natural",
                              shift_rule="efbv", efbv_eta=0.5, efbv_nu=0.9),
}

CHANNELS = {
    "sim": lambda: SimChannel(),
    "mesh_dense": lambda: MeshChannel(mode="dense"),
    "async_drained": lambda: AsyncChannel(mode="dense", bucket_bytes=64),
}


def _wtree(key, w=4):
    return {
        "a": jax.random.normal(key, (w, 40)),
        "b": {
            "c": jax.random.normal(jax.random.fold_in(key, 1), (w, 3, 5)),
            "d": jax.random.normal(jax.random.fold_in(key, 2), (w,)),
        },
    }


def _assert_trees_equal(a, b):
    tmap(lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                    np.asarray(y)), a, b)


# ---------------------------------------------------------------------------
# Grad wire: the refactor is bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chan", sorted(CHANNELS))
@pytest.mark.parametrize("name", sorted(RULE_CONFIGS))
def test_grad_wire_shift_round_bit_exact(name, chan):
    """``transport["grad"].shift_round(key, ...)`` == the pre-refactor
    ``Channel.shift_round(rule, q, key, ...)`` — same key, verbatim, for
    every rule x channel.  THE pin that lets the trainer route grads
    through the Transport without a bitwise behavior change."""
    comp = RULE_CONFIGS[name]
    q, rule = comp.make()
    ch = CHANNELS[chan]()
    transport = build_transport(comp, None, ch, rule=rule, msg_codec=q, w=4)
    wire = transport["grad"]
    assert wire.topology == "allreduce"

    key = jax.random.PRNGKey(17)
    wtree = _wtree(key)
    h, h_bar = rule.init(wtree), rule.init_bar(wtree)
    ref = ch.shift_round(rule, q, key, wtree, h, h_bar)
    out = wire.shift_round(key, wtree, h, h_bar)
    _assert_trees_equal(ref[:3], out[:3])
    assert float(ref[3]) == float(out[3])


def test_grad_wire_reduce_mean_matches_channel():
    comp = CompressionConfig(comm_mode="dense", shift_rule="diana")
    ch = MeshChannel(mode="dense")
    wire = build_transport(comp, None, ch, w=4)["grad"]
    key = jax.random.PRNGKey(3)
    wtree = _wtree(key)
    _assert_trees_equal(wire.reduce_mean(key, wtree),
                        ch.reduce_mean(key, wtree))


# ---------------------------------------------------------------------------
# MoE wire: dense codec == uncompressed einsum path; q8 stays close
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_smoke_config("qwen2-moe-a2.7b").with_(dtype="float32")
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    return cfg, p, x


def _moe_wire(codec):
    return Wire(name="moe", topology="all_to_all", codec=codec,
                channel=make_channel("dense"))


@pytest.mark.parametrize("group_size", [64, 16])
def test_moe_dense_wire_identical_to_uncompressed(moe_setup, group_size):
    """Identity-codec dispatch/combine through the wire reproduce the
    plain einsum path VALUE-exactly, single-group and grouped-scan.
    (array_equal, not bit comparison: the straight-through estimator
    ``x + stop_gradient(d - x)`` maps -0.0 to +0.0.)"""
    cfg, p, x = moe_setup
    cfg = cfg.with_(moe_group_size=group_size)
    y0, aux0 = MOE.moe_apply(p, x, cfg)
    y1, aux1 = MOE.moe_apply(p, x, cfg, wire=_moe_wire(Identity()),
                             key=jax.random.PRNGKey(5))
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(aux0), np.asarray(aux1))


def test_moe_q8_wire_bounded_error(moe_setup):
    """q8 dispatch/combine stays within a small relative error of the
    uncompressed path — the int8 codec's resolution, not a routing
    change (the same tokens reach the same experts)."""
    cfg, p, x = moe_setup
    y0, _ = MOE.moe_apply(p, x, cfg)
    y8, _ = MOE.moe_apply(p, x, cfg, wire=_moe_wire(Int8Stochastic()),
                          key=jax.random.PRNGKey(5))
    err = float(jnp.linalg.norm(y8 - y0))
    ref = float(jnp.linalg.norm(y0))
    assert np.isfinite(err) and err < 0.2 * ref, (err, ref)


def test_moe_wire_traffic_matches_apply_grouping():
    """The declared traffic reproduces moe_apply's group math: 2 sends
    (dispatch + combine) of the (E, C, D) buffer per GShard group."""
    cfg = get_smoke_config("qwen2-moe-a2.7b").with_(dtype="float32")
    n = 64
    g = min(cfg.moe_group_size, n)
    n_groups = (n + (-n) % g) // g
    ((sds, count),) = MOE.moe_wire_traffic(cfg, n)
    assert count == 2 * n_groups
    e, c, d = sds.shape
    assert e == cfg.n_experts and d == cfg.d_model
    assert c == MOE._capacity(g, cfg)
    assert MOE.moe_wire_traffic(cfg, 0) == ()


# ---------------------------------------------------------------------------
# Forwarded sends: variance contract + error feedback
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", [Int8Stochastic(), RandK(0.25)],
                         ids=["q8", "randk"])
def test_wire_send_variance_contract(codec):
    """E||send(x) - x||^2 <= omega(d) ||x||^2 — the send path IS the
    codec (encode -> forwarded payload -> decode), so it inherits the
    codec's unbiased variance certificate."""
    d = 48
    x = jax.random.normal(jax.random.PRNGKey(2), (d,)) * 2.0 + 0.5
    wire = _moe_wire(codec)
    keys = jax.random.split(jax.random.PRNGKey(4), 2000)
    ys = jax.vmap(lambda k: wire.send(k, x)[0])(keys)
    var = float(jnp.mean(jnp.sum((ys - x) ** 2, axis=1)))
    bound = codec.omega(d) * float(jnp.sum(x**2))
    assert var <= bound * 1.05 + 1e-6, (var, bound)


def test_wire_send_error_feedback_identity():
    """With a threaded shift the send is classic error feedback: the
    compensated signal x + e rides the wire and y + e_new == x + e."""
    x = jax.random.normal(jax.random.PRNGKey(6), (32,))
    e = jax.random.normal(jax.random.PRNGKey(7), (32,)) * 0.1
    wire = _moe_wire(Int8Stochastic())
    y, e_new = wire.send(jax.random.PRNGKey(8), x, e)
    np.testing.assert_allclose(np.asarray(y + e_new), np.asarray(x + e),
                               rtol=1e-5, atol=1e-6)
    # no shift threaded -> no residual tracked
    y2, e2 = wire.send(jax.random.PRNGKey(8), x)
    assert e2 is None


# ---------------------------------------------------------------------------
# Accounting: structural wire_bits == concrete payload bits, every wire
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["dense", "q8_ring"])
def test_grad_wire_bits_match_concrete_payloads(mode):
    """Grad-wire accounting charges the worker-stacked uplink payloads
    the channel actually emits (same encode_workers path)."""
    comp = CompressionConfig(comm_mode=mode, shift_rule="diana")
    w = 4
    key = jax.random.PRNGKey(11)
    wtree = _wtree(key, w=w)
    params_like = tmap(lambda a: a[0], wtree)
    transport = build_transport(comp, None, make_channel(mode), w=w,
                                params_like=params_like)
    codec = aggregation_wire_codec(comp)
    live = 0.0
    for i, leaf in enumerate(jax.tree_util.tree_leaves(wtree)):
        payload, _ = encode_workers(codec, leaf_key(key, i), leaf)
        live += float(codec.wire_bits(payload))
    assert transport.per_wire_bits()["grad"] == live, mode


def test_all_wires_bits_match_concrete_payloads():
    """For EVERY registered wire of a fully-wired MoE transport, the
    structural per-step wire_bits equals count x the concrete payload's
    wire_bits on the declared shapes."""
    from repro.comm.wire import encode_meta_free

    cfg = get_smoke_config("qwen2-moe-a2.7b").with_(dtype="float32")
    comp = CompressionConfig(comm_mode="q8_ring", shift_rule="diana",
                             moe_wire="q8", act_wire="natural")
    w = 2
    params_like = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    transport = build_transport(comp, cfg, make_channel(comp), w=w,
                                params_like=params_like,
                                tokens_per_worker=64)
    assert transport.names() == ("grad", "moe", "act")
    table = transport.per_wire_bits()
    key = jax.random.PRNGKey(13)
    for wire in transport:
        live = 0.0
        for sds, count in wire.traffic:
            x = jax.random.normal(key, sds.shape, dtype=jnp.float32).astype(
                sds.dtype)
            if wire.topology == "allreduce":
                payload, _ = encode_workers(wire.codec, key, x)
            else:
                payload = encode_meta_free(wire.codec, key, x)
            live += count * float(wire.codec.wire_bits(payload))
        assert table[wire.name] == live, wire.name
        assert table[wire.name] > 0.0


def test_wire_stream_is_name_keyed_and_stable():
    key = jax.random.PRNGKey(0)
    a, b = wire_stream(key, "moe"), wire_stream(key, "act")
    assert not np.array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a),
                                  np.asarray(wire_stream(key, "moe")))


# ---------------------------------------------------------------------------
# Errors name the offending string verbatim
# ---------------------------------------------------------------------------


def test_wire_rejects_unknown_topology_verbatim():
    with pytest.raises(ValueError) as ei:
        Wire(name="x", topology="carrier_pigeon", codec=Identity())
    msg = str(ei.value)
    assert "carrier_pigeon" in msg
    for t in WIRE_TOPOLOGIES:
        assert t in msg


def test_wire_flag_codec_rejects_unknown_flag_verbatim():
    with pytest.raises(ValueError) as ei:
        wire_flag_codec("carrier_pigeon")
    msg = str(ei.value)
    assert "carrier_pigeon" in msg
    for f in WIRE_CODEC_FLAGS:
        assert f in msg


def test_build_transport_moe_wire_needs_experts():
    cfg = get_smoke_config("qwen3-0.6b")
    comp = CompressionConfig(comm_mode="dense", moe_wire="q8")
    with pytest.raises(ValueError, match="q8.*MoE|MoE.*q8"):
        build_transport(comp, cfg, None)


def test_transport_duplicate_and_missing_wires():
    t = Transport([Wire(name="grad", topology="allreduce", codec=Identity())])
    with pytest.raises(ValueError, match="already registered"):
        t.register(Wire(name="grad", topology="allreduce", codec=Identity()))
    with pytest.raises(KeyError, match="nope"):
        t["nope"]
    assert t.get("nope") is None and "grad" in t


def test_make_channel_names_mode_verbatim():
    with pytest.raises(ValueError) as ei:
        make_channel("carrier_pigeon")
    msg = str(ei.value)
    assert "carrier_pigeon" in msg
    for m in ("dense", "randk_shared", "q8_ring"):
        assert m in msg


def test_compressed_tree_mean_names_mode_verbatim():
    from repro.dist.collectives import compressed_tree_mean

    wtree = {"a": jnp.ones((2, 4))}
    with pytest.raises(ValueError) as ei:
        compressed_tree_mean(wtree, "carrier_pigeon", jax.random.PRNGKey(0))
    msg = str(ei.value)
    assert "carrier_pigeon" in msg and "dense" in msg


# ---------------------------------------------------------------------------
# End to end: wired forward + the production train step
# ---------------------------------------------------------------------------


def test_dense_wires_reproduce_unwired_forward(moe_setup):
    """Identity codecs on BOTH non-grad wires reproduce the unwired
    forward value-exactly — the wires are pure pass-throughs at
    identity width."""
    cfg, _, _ = moe_setup
    comp = CompressionConfig(comm_mode="dense", shift_rule="diana",
                             moe_wire="dense", act_wire="dense")
    transport = build_transport(comp, cfg, make_channel("dense"), w=1)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    from repro.data.tokens import synth_batch

    batch = synth_batch(jax.random.PRNGKey(1), cfg, 32, 2)
    loss0, _ = M.train_loss(params, cfg, batch)
    loss1, _ = M.train_loss(params, cfg, batch, wires=transport,
                            wire_key=jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(loss0), np.asarray(loss1))


def test_train_step_with_wires_end_to_end():
    """The production train step with moe_wire=q8 / act_wire=q8: loses
    nothing structural (finite loss, positive grad bits) and perturbs
    the unwired trajectory only through codec noise."""
    from repro.data.tokens import TokenStream
    from repro.launch.mesh import make_host_mesh, n_workers
    from repro.launch.train import build_train_step, init_state

    cfg = get_smoke_config("qwen2-moe-a2.7b").with_(dtype="float32")
    comp = CompressionConfig(enabled=True, compressor="natural",
                             shift_rule="diana", comm_mode="dense",
                             moe_wire="q8", act_wire="q8")
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=2, warmup_steps=1,
                       compression=comp)
    mesh = make_host_mesh()
    w = n_workers(mesh)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
    step = jax.jit(build_train_step(cfg, tcfg, mesh, w))
    stream = TokenStream(cfg, 32, 4)
    for i in range(2):
        state, metrics = step(state, stream.batch(i))
    assert np.isfinite(float(metrics["loss"]))
    assert float(state.bits) > 0.0
