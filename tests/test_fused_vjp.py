"""Fused backward-encode tests: THE CONTRACT — the fused-VJP path
(messages emitted as cotangents, ``repro.comm.fused_vjp``) is BITWISE
identical to the post-hoc encode path, per shift rule x channel.

Three layers of pinning, mirroring tests/test_overlap.py:

  * unit: the per-worker tag body vmaps to exactly ``message_leaf``,
    the key derivation reproduces ``Channel.shift_round``'s, and
    ``jax.grad`` through ``message_tag`` emits the message;
  * round: ``fused_round`` == ``shift_round`` bitwise on SimChannel,
    MeshChannel and the drained AsyncChannel, for every fusible rule,
    including the f32 bits counter;
  * end-to-end: the full train step (8 fake devices, subprocess) —
    ``q8_ring_fused_vjp`` reproduces ``q8_ring_overlap``'s TrainState
    bitwise, plus awkward shapes on an ODD world size (5 devices).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    AsyncChannel,
    FUSED_VJP_MODES,
    SimChannel,
    check_fusible,
    encode_on_backward,
    fused_message_bits,
    make_channel,
    message_tag,
    plan_buckets,
    round_message_keys,
    worker_keys,
)
from repro.comm.wire import leaf_key
from repro.core.compressors import make_compressor
from repro.core.shift_rules import make_shift_rule

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: every fusible registered rule (dcgd is FixedShift under a second name)
FUSIBLE_RULES = ("fixed", "dcgd", "diana", "ef21", "efbv")


def _rule(name):
    if name == "diana":
        return make_shift_rule("diana", alpha=0.125,
                               c=make_compressor("natural"))
    return make_shift_rule(name)


def _wtree(key, w=4):
    # awkward on purpose: scalar-per-worker leaf, non-lane-divisible dims
    return {
        "a": jax.random.normal(key, (w, 40)),
        "b": {
            "c": jax.random.normal(jax.random.fold_in(key, 1), (w, 3, 5)),
            "d": jax.random.normal(jax.random.fold_in(key, 2), (w,)),
        },
        "e": jax.random.normal(jax.random.fold_in(key, 3), (w, 7)),
    }


def _assert_trees_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)
        ),
        a, b,
    )


def _fused_msgs(rule, q, key, wtree, h, w):
    """Emulate what the fused backward emits: vmap the tag's per-worker
    body over the pre-derived round keys (the value contract)."""
    params_like = jax.tree_util.tree_map(lambda x: x[0], wtree)
    keys = round_message_keys(rule, q, key, params_like, w)
    leaves, treedef = jax.tree_util.tree_flatten(wtree)
    h_leaves = ([None] * len(leaves) if h is None
                else jax.tree_util.tree_leaves(h))
    out = []
    for lk, g, hl in zip(keys, leaves, h_leaves):
        if hl is None:
            m = jax.vmap(
                lambda kk, gg: rule.message_leaf_worker(q, kk, gg, None)
            )(lk, g)
        else:
            m = jax.vmap(
                lambda kk, gg, hv: rule.message_leaf_worker(q, kk, gg, hv)
            )(lk, g, hl)
        out.append(m)
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Unit: keys, values, bits
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule_name", FUSIBLE_RULES)
def test_worker_body_vmaps_to_message_leaf(rule_name):
    """VALUES: vmapped ``message_leaf_worker`` over ``message_keys`` is
    bitwise the post-hoc ``message_leaf``, and ``message_bits_aot``
    equals its live bits — per leaf, including scalar leaves."""
    rule, q = _rule(rule_name), make_compressor("natural")
    key = jax.random.PRNGKey(3)
    w = 4
    wtree = _wtree(key, w)
    h = rule.init(jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), wtree
    ))
    leaves = jax.tree_util.tree_leaves(wtree)
    h_leaves = ([None] * len(leaves) if h is None
                else jax.tree_util.tree_leaves(h))
    for i, (g, hl) in enumerate(zip(leaves, h_leaves)):
        lk = leaf_key(key, i)
        ref_m, ref_bits = rule.message_leaf(q, lk, g, hl)
        wkeys = rule.message_keys(q, lk, w)
        if hl is None:
            got = jax.vmap(
                lambda kk, gg: rule.message_leaf_worker(q, kk, gg, None)
            )(wkeys, g)
        else:
            got = jax.vmap(
                lambda kk, gg, hv: rule.message_leaf_worker(q, kk, gg, hv)
            )(wkeys, g, hl)
        np.testing.assert_array_equal(np.asarray(ref_m), np.asarray(got))
        assert float(ref_bits) == rule.message_bits_aot(q, g)


def test_round_message_keys_match_shift_round_derivation():
    """KEYS: the pre-derived fused keys are exactly the post-hoc
    derivation — round key's first 3-split row, folded to each leaf's
    GLOBAL position, then the codec's worker derivation."""
    q = make_compressor("natural")
    rule = _rule("fixed")
    key = jax.random.PRNGKey(9)
    w = 4
    params = {"a": jnp.zeros((40,)), "b": {"c": jnp.zeros((3, 5))}}
    keys = round_message_keys(rule, q, key, params, w)
    k_msg = jax.random.split(key, 3)[0]
    assert len(keys) == 2
    for i, k in enumerate(keys):
        np.testing.assert_array_equal(
            np.asarray(k), np.asarray(worker_keys(q, leaf_key(k_msg, i), w))
        )


def test_message_tag_grad_emits_message():
    """``jax.grad`` through a tagged loss yields
    ``message_leaf_worker`` of the dense cotangent — the tag really
    rewrites the backward, not the value."""
    q = make_compressor("natural")
    rule = _rule("fixed")
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (13,))
    cot = jax.random.normal(jax.random.fold_in(key, 1), (13,))
    wkeys = rule.message_keys(q, key, 1)
    k0 = jax.tree_util.tree_map(lambda k: k[0], wkeys)

    def loss(p):
        return jnp.vdot(cot, message_tag(rule, q, p, k0, None))

    assert float(loss(x)) == float(jnp.vdot(cot, x))  # forward: identity
    g = jax.grad(loss)(x)
    ref = rule.message_leaf_worker(q, k0, cot, None)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(ref))


def test_encode_on_backward_grad_is_message_tree():
    """Tree-level: grad of a tapped synthetic loss == the vmapped
    message tree the fused round consumes (params value unchanged)."""
    q = make_compressor("natural")
    w = 3
    key = jax.random.PRNGKey(7)
    params = {"a": jax.random.normal(key, (11,)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (2, 3))}
    wcot = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 2), (w, *p.shape)),
        params,
    )
    for rule_name in ("fixed", "diana"):
        rule = _rule(rule_name)
        keys = round_message_keys(rule, q, key, params, w)

        def one_worker(cot, kt):
            def loss(p):
                tapped = encode_on_backward(rule, q, p, kt, None)
                return sum(
                    jnp.vdot(c, t)
                    for c, t in zip(jax.tree_util.tree_leaves(cot),
                                    jax.tree_util.tree_leaves(tapped))
                )
            return jax.grad(loss)(params)

        got = jax.vmap(one_worker)(wcot, keys)
        ref = _fused_msgs(rule, q, key, wcot, None, w)
        _assert_trees_equal(got, ref)


def test_fused_message_bits_matches_round_bits():
    q = make_compressor("natural")
    rule = _rule("diana")
    wtree = _wtree(jax.random.PRNGKey(0))
    total = fused_message_bits(rule, q, wtree)
    assert total == sum(
        rule.message_bits_aot(q, leaf)
        for leaf in jax.tree_util.tree_leaves(wtree)
    )
    assert total > 0


# ---------------------------------------------------------------------------
# Fusibility gate
# ---------------------------------------------------------------------------


def test_check_fusible_accepts_all_fusible_rules():
    for name in FUSIBLE_RULES:
        check_fusible(_rule(name))  # must not raise


def test_check_fusible_rejects_dense_grad_rules():
    from repro.core.iterate_comp import VRGDCI

    bad = [
        make_shift_rule("star", c=make_compressor("natural")),
        make_shift_rule("rand_diana"),
        VRGDCI(),
    ]
    for rule in bad:
        with pytest.raises(ValueError, match="not fusible"):
            check_fusible(rule)


def test_train_step_rejects_non_fusible_config():
    """The trainer refuses rule x fused-mode combos at BUILD time."""
    from repro.configs import get_smoke_config
    from repro.configs.base import CompressionConfig, TrainConfig
    from repro.launch.train import build_train_step

    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    for rule_name, match in (("rand_diana", "not fusible"),
                             ("vr_gdci", "no gradient message")):
        comp = CompressionConfig(comm_mode="q8_ring_fused_vjp",
                                 shift_rule=rule_name)
        tcfg = TrainConfig(learning_rate=1e-3, total_steps=1,
                           compression=comp)
        with pytest.raises(ValueError, match=match):
            build_train_step(cfg, tcfg, None, 1)


def test_encode_on_backward_validates_key_count():
    q = make_compressor("natural")
    rule = _rule("fixed")
    params = {"a": jnp.zeros((3,)), "b": jnp.zeros((4,))}
    keys = round_message_keys(rule, q, jax.random.PRNGKey(0),
                              {"a": jnp.zeros((3,))}, 2)
    with pytest.raises(ValueError, match="leaf"):
        encode_on_backward(rule, q, params, keys, None)


# ---------------------------------------------------------------------------
# Per-leaf bucket plan
# ---------------------------------------------------------------------------


def test_plan_buckets_per_leaf():
    """per_leaf plans give every leaf its own bucket, in the same
    reverse-layer order as the byte-budget plan — the property that
    makes fused-vs-overlap bits accumulation order identical."""
    wtree = _wtree(jax.random.PRNGKey(0))
    plan = plan_buckets(wtree, 1 << 30, per_leaf=True)
    assert len(plan) == plan.n_leaves
    assert [b.indices for b in plan.buckets] == [
        (i,) for i in reversed(range(plan.n_leaves))
    ]


def test_make_channel_fused_mode_is_per_leaf_async():
    ch = make_channel("q8_ring_fused_vjp")
    assert isinstance(ch, AsyncChannel)
    assert ch.per_leaf and ch.mode == "q8_ring_fused"
    from repro.configs.base import CompressionConfig

    cfg = CompressionConfig(comm_mode="q8_ring_fused_vjp")
    assert cfg.aggregation_mode == "q8_ring_fused"
    assert make_channel(cfg).per_leaf


# ---------------------------------------------------------------------------
# Round-level contract: fused_round == shift_round, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule_name", FUSIBLE_RULES)
def test_fused_round_bitexact_sim_and_async(rule_name):
    """``fused_round`` on the emitted message tree reproduces
    ``shift_round`` on the dense tree BITWISE — outputs, new shifts,
    and the f32 bits counter — on SimChannel and the drained
    AsyncChannel across bucket granularities."""
    rule, q = _rule(rule_name), make_compressor("natural")
    key = jax.random.PRNGKey(21)
    w = 4
    wtree = _wtree(key, w)
    wlike = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), wtree
    )
    h0, hb0 = rule.init(wlike), rule.init_bar(wlike)
    msgs = _fused_msgs(rule, q, key, wtree, h0, w)

    channels = [SimChannel(),
                AsyncChannel(mode="dense", bucket_bytes=64),
                AsyncChannel(mode="dense", bucket_bytes=1 << 30)]
    for ch in channels:
        ref = ch.shift_round(rule, q, key, wtree, h0, hb0)
        got = ch.fused_round(rule, q, key, msgs, h0, hb0)
        _assert_trees_equal(ref[:3], got[:3])
        assert float(ref[3]) == float(got[3]), (rule_name, type(ch).__name__)


def test_fused_round_rejects_non_fusible_rule():
    rule = make_shift_rule("rand_diana")
    q = make_compressor("natural")
    wtree = _wtree(jax.random.PRNGKey(0))
    wlike = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), wtree
    )
    h, hb = rule.init(wlike), rule.init_bar(wlike)
    for ch in (SimChannel(), AsyncChannel(mode="dense", bucket_bytes=64)):
        with pytest.raises(ValueError, match="not fusible"):
            ch.fused_round(rule, q, jax.random.PRNGKey(0), wtree, h, hb)


# ---------------------------------------------------------------------------
# End-to-end: the full train step, 8 fake devices (subprocess)
# ---------------------------------------------------------------------------


_E2E = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.configs import get_smoke_config
    from repro.configs.base import CompressionConfig, TrainConfig
    from repro.data.tokens import TokenStream
    from repro.launch.train import build_train_step, init_state

    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    w, batch, seq, steps = 8, 8, 32, 2

    states = {}
    for mode in ("q8_ring_overlap", "q8_ring_fused_vjp"):
        comp = CompressionConfig(comm_mode=mode, shift_rule="diana",
                                 compressor="natural",
                                 overlap_bucket_bytes=256 << 10)
        tcfg = TrainConfig(learning_rate=1e-3, total_steps=steps,
                           compression=comp)
        state = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
        step_fn = jax.jit(build_train_step(cfg, tcfg, mesh, w))
        stream = TokenStream(cfg, seq, batch)
        for i in range(steps):
            state, m = step_fn(state, stream.batch(i))
        jax.block_until_ready(m["loss"])
        states[mode] = state

    a, b = states["q8_ring_overlap"], states["q8_ring_fused_vjp"]
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)),
        (a.params, a.h, a.h_bar), (b.params, b.h, b.h_bar))
    assert float(a.bits) == float(b.bits), (float(a.bits), float(b.bits))
    print("FUSED_E2E_OK")
""")


def test_train_step_fused_bitexact_vs_overlap_8dev_subprocess():
    """THE CONTRACT end-to-end: the fused train step reproduces the
    post-hoc overlap step's TrainState (params, shifts, h_bar, bits)
    bitwise over 2 real steps on 8 fake devices."""
    r = subprocess.run(
        [sys.executable, "-c", _E2E],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=_REPO_ROOT,
    )
    assert "FUSED_E2E_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


_AWKWARD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=5"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.comm import AsyncChannel
    from repro.comm.fused_vjp import round_message_keys
    from repro.core.compressors import make_compressor
    from repro.core.shift_rules import make_shift_rule

    # odd world size; leaf sizes not divisible by lanes or world size;
    # a scalar-per-worker leaf — mirrors tests/test_overlap.py
    mesh = jax.make_mesh((5,), ("data",))
    key = jax.random.PRNGKey(0)
    w = 5
    tree = {"a": jax.random.normal(key, (w, 777)),
            "s": jax.random.normal(jax.random.fold_in(key, 1), (w,)),
            "m": jax.random.normal(jax.random.fold_in(key, 2), (w, 13, 3))}
    tree = jax.device_put(tree, NamedSharding(mesh, P("data")))

    q = make_compressor("natural")
    rule = make_shift_rule("diana", alpha=0.125,
                           c=make_compressor("natural"))
    wlike = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         tree)
    h0, hb0 = rule.init(wlike), rule.init_bar(wlike)

    params_like = jax.tree.map(lambda x: x[0], tree)
    keys = round_message_keys(rule, q, key, params_like, w)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    h_leaves = jax.tree_util.tree_leaves(h0)
    msgs = jax.tree_util.tree_unflatten(treedef, [
        jax.vmap(lambda kk, gg, hv: rule.message_leaf_worker(q, kk, gg, hv))(
            lk, g, hl)
        for lk, g, hl in zip(keys, leaves, h_leaves)
    ])

    post = AsyncChannel(mode="dense", mesh=mesh, bucket_bytes=1024)
    fused = AsyncChannel(mode="dense", mesh=mesh, bucket_bytes=1024,
                         per_leaf=True)
    ref = jax.jit(lambda k, t: post.shift_round(rule, q, k, t, h0, hb0))(
        key, tree)
    got = jax.jit(lambda k, t: fused.fused_round(rule, q, k, t, h0, hb0))(
        key, msgs)
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)),
        ref[:3], got[:3])
    assert float(ref[3]) == float(got[3])
    print("FUSED_AWKWARD_OK")
""")


def test_fused_round_awkward_shapes_odd_workers_subprocess():
    """Awkward shapes on an ODD world size (5): per-leaf fused round ==
    byte-bucketed post-hoc round, bitwise, through a real mesh."""
    r = subprocess.run(
        [sys.executable, "-c", _AWKWARD],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=_REPO_ROOT,
    )
    assert "FUSED_AWKWARD_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


_FUSED_CLI = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.launch.train import main
    state = main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "2",
                  "--batch", "8", "--seq", "32",
                  "--compressor", "natural", "--comm_mode",
                  "q8_ring_fused_vjp"])
    assert np.isfinite(float(state.bits)) and float(state.bits) > 0
    print("FUSED_CLI_OK")
""")


def test_train_cli_fused_vjp_8dev_subprocess():
    """--comm_mode q8_ring_fused_vjp end-to-end through the train CLI
    on 8 fake devices (the acceptance path for the fused runtime)."""
    assert "q8_ring_fused_vjp" in FUSED_VJP_MODES
    r = subprocess.run(
        [sys.executable, "-c", _FUSED_CLI],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=_REPO_ROOT,
    )
    assert "FUSED_CLI_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
