"""Property-based tests (hypothesis) on the system's invariants:
compressor contracts (Definitions 1-3), shifted-compressor algebra
(Lemma 1), induced compressor (Lemma 3), and sharding-spec validity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.compressors import (
    BernoulliP,
    Identity,
    Induced,
    Int8Stochastic,
    NaturalCompression,
    NaturalDithering,
    RandK,
    ScaledSign,
    TernGrad,
    TopK,
    shifted,
)

UNBIASED = [
    RandK(0.25), BernoulliP(0.5), NaturalCompression(),
    NaturalDithering(8), TernGrad(), Int8Stochastic(), Identity(),
]
CONTRACTIVE = [TopK(0.25), ScaledSign(), Identity()]

vec = st.lists(
    st.floats(min_value=-100, max_value=100, allow_nan=False,
              width=32).filter(lambda v: v == 0 or abs(v) > 1e-6),
    min_size=8, max_size=64,
)


def _mc(op, x, n=400, seed=0):
    outs = jnp.stack([
        op(jax.random.PRNGKey(seed + i), x) for i in range(n)
    ]).astype(jnp.float32)
    return outs


@pytest.mark.parametrize("op", UNBIASED, ids=lambda o: type(o).__name__)
@settings(max_examples=8, deadline=None, derandomize=True)
@given(data=vec)
def test_unbiasedness(op, data):
    """E C(x) = x within Monte-Carlo error."""
    x = jnp.asarray(data, jnp.float32)
    outs = _mc(op, x)
    mean = jnp.mean(outs, axis=0)
    sd = jnp.std(outs, axis=0) / np.sqrt(outs.shape[0])
    err = np.abs(np.asarray(mean - x))
    # third term: rare-event coords may see ZERO firings in n samples
    # (sample sd = 0), e.g. TernGrad's p = |x_i|/max|x|; cover them with
    # a max-scaled slack.
    bound = (6 * np.asarray(sd) + 0.02 * np.abs(np.asarray(x))
             + 0.25 * float(np.max(np.abs(np.asarray(x)))) / np.sqrt(outs.shape[0])
             + 1e-3)
    assert (err <= bound).all(), (err - bound).max()


@pytest.mark.parametrize("op", UNBIASED, ids=lambda o: type(o).__name__)
@settings(max_examples=8, deadline=None, derandomize=True)
@given(data=vec)
def test_variance_bound(op, data):
    """E||C(x)-x||^2 <= omega ||x||^2 (Def. 2b) within MC error."""
    x = jnp.asarray(data, jnp.float32)
    d = x.size
    outs = _mc(op, x, n=300)
    sq = jnp.sum((outs - x) ** 2, axis=1)
    mean_sq = float(jnp.mean(sq))
    se = float(jnp.std(sq)) / np.sqrt(outs.shape[0])
    omega = op.omega(d)
    bound = omega * float(jnp.sum(x**2))
    assert mean_sq <= bound * (1 + 1e-5) + 4 * se + 1e-5, (mean_sq, bound)


@pytest.mark.parametrize("op", CONTRACTIVE, ids=lambda o: type(o).__name__)
@settings(max_examples=10, deadline=None, derandomize=True)
@given(data=vec)
def test_contraction(op, data):
    """||C(x)-x||^2 <= (1-delta)||x||^2 (Def. 1) — deterministic ops."""
    x = jnp.asarray(data, jnp.float32)
    d = x.size
    out = op(jax.random.PRNGKey(0), x)
    err = float(jnp.sum((out - x) ** 2))
    bound = (1 - op.delta(d)) * float(jnp.sum(x**2))
    assert err <= bound + 1e-4 * max(bound, 1.0)


@settings(max_examples=10, deadline=None, derandomize=True)
@given(data=vec, hdata=vec)
def test_shifted_compressor_lemma1(data, hdata):
    """Q_h(x) = h + Q(x-h): E = x; variance scales with ||x-h||^2."""
    d = min(len(data), len(hdata))
    x = jnp.asarray(data[:d], jnp.float32)
    h = jnp.asarray(hdata[:d], jnp.float32)
    op = NaturalCompression()
    outs = jnp.stack([
        shifted(op, h, jax.random.PRNGKey(i), x) for i in range(300)
    ])
    mean = jnp.mean(outs, axis=0)
    err = np.abs(np.asarray(mean - x))
    sd = np.asarray(jnp.std(outs, axis=0)) / np.sqrt(300)
    # rare-event slack: coords whose stochastic rounding fires ~never in
    # 300 draws have sample sd = 0 but true bias up to half a lattice gap
    scale = max(float(np.max(np.abs(np.asarray(x)))),
                float(np.max(np.abs(np.asarray(h)))), 1.0)
    assert (err <= 6 * sd + 0.02 * np.abs(np.asarray(x))
            + 0.25 * scale / np.sqrt(300) + 1e-3).all()
    # variance bound: omega * ||x-h||^2
    sq = float(jnp.mean(jnp.sum((outs - x) ** 2, axis=1)))
    bound = op.omega(d) * float(jnp.sum((x - h) ** 2))
    assert sq <= bound * 1.3 + 1e-4


def test_shift_exactness_at_shift():
    """Q_h(h) = h exactly — the defining property of the shifted class:
    variance vanishes at the SHIFT, not at the origin."""
    h = jnp.asarray([0.5, -2.0, 3.25, 1e-3] * 8, jnp.float32)
    for op in UNBIASED:
        out = shifted(op, h, jax.random.PRNGKey(0), h)
        np.testing.assert_allclose(np.asarray(out), np.asarray(h), rtol=1e-6)


@settings(max_examples=10, deadline=None, derandomize=True)
@given(data=vec)
def test_induced_compressor_lemma3(data):
    """C_ind = C + Q(x - C(x)) is unbiased with omega*(1-delta)."""
    x = jnp.asarray(data, jnp.float32)
    d = x.size
    op = Induced(c=TopK(0.5), q=RandK(0.5))
    outs = _mc(op, x, n=300)
    mean = jnp.mean(outs, axis=0)
    sd = np.asarray(jnp.std(outs, axis=0)) / np.sqrt(300)
    err = np.abs(np.asarray(mean - x))
    assert (err <= 6 * sd + 0.02 * np.abs(np.asarray(x)) + 1e-3).all()
    # variance strictly better than Q alone (statistically)
    assert op.omega(d) <= RandK(0.5).omega(d) + 1e-9


# ---------------------------------------------------------------------------
# sharding-spec validity
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None, derandomize=True)
@given(
    dims=st.lists(st.integers(min_value=1, max_value=64), min_size=1,
                  max_size=4),
)
def test_validate_pspecs_always_divides(dims):
    """After validation, every sharded dim divides its mesh axis product."""
    import os
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import validate_pspecs

    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shapes = [jax.ShapeDtypeStruct(tuple(dims), jnp.float32)]
    specs = [P(*( ["model"] + [None] * (len(dims) - 1) ))]
    fixed = validate_pspecs(shapes, specs, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for leaf, sp in zip(shapes, fixed):
        for size, ax in zip(leaf.shape, tuple(sp)):
            if ax is not None:
                axs = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axs:
                    n *= sizes[a]
                assert size % n == 0
