"""Minimal, deterministic stand-in for the `hypothesis` API surface the
test suite uses, for hermetic environments where the real package cannot
be installed (CI installs the real one from requirements.txt; conftest
registers this shim only when `import hypothesis` fails).

Covers: ``given`` (positional + keyword strategies), ``settings``
(max_examples / deadline / derandomize), ``strategies.lists / floats /
integers / one_of / just`` with ``.filter``, and
``hypothesis.extra.numpy.arrays``.  Example generation is uniform and
seeded from the test name, so runs are reproducible (derandomize
semantics always on).
"""

from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib

import numpy as np


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def sample(self, rng):
        return self._draw(rng)

    def filter(self, pred):
        base = self

        def draw(rng):
            for _ in range(10_000):
                v = base.sample(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate rejected 10k samples")

        return Strategy(draw)


def floats(min_value=None, max_value=None, *, allow_nan=None,
           allow_infinity=None, width=64, **_):
    lo = -1e9 if min_value is None else float(min_value)
    hi = 1e9 if max_value is None else float(max_value)

    def draw(rng):
        v = rng.uniform(lo, hi)
        if width == 32:
            v = float(np.float32(v))
            v = min(max(v, lo), hi)
        return v

    return Strategy(draw)


def integers(min_value, max_value):
    def draw(rng):
        return int(rng.randint(int(min_value), int(max_value) + 1))

    return Strategy(draw)


def lists(elements, *, min_size=0, max_size=None, **_):
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng):
        n = int(rng.randint(min_size, hi + 1))
        return [elements.sample(rng) for _ in range(n)]

    return Strategy(draw)


def one_of(*strats):
    def draw(rng):
        return strats[int(rng.randint(len(strats)))].sample(rng)

    return Strategy(draw)


def just(value):
    return Strategy(lambda rng: value)


def _np_arrays(dtype, shape, *, elements=None, **_):
    def draw(rng):
        shp = shape.sample(rng) if isinstance(shape, Strategy) else shape
        if isinstance(shp, int):
            shp = (shp,)
        size = int(np.prod(shp))
        vals = [elements.sample(rng) for _ in range(size)]
        return np.asarray(vals, dtype=dtype).reshape(shp)

    return Strategy(draw)


class settings:
    """Decorator recording run parameters for the paired ``given``."""

    def __init__(self, max_examples=100, deadline=None, derandomize=False,
                 **_):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._mini_hyp_settings = self
        return fn


_DEFAULT_SETTINGS = settings()


def given(*pos_strats, **kw_strats):
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        remaining = [p for p in params if p.name not in kw_strats]
        if pos_strats:
            pos_names = [p.name for p in remaining[-len(pos_strats):]]
            remaining = remaining[: -len(pos_strats)]
        else:
            pos_names = []

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_mini_hyp_settings", _DEFAULT_SETTINGS)
            seed = zlib.crc32(fn.__qualname__.encode())
            for i in range(cfg.max_examples):
                rng = np.random.RandomState((seed + 7919 * i) % (2**31 - 1))
                drawn = {
                    n: s.sample(rng) for n, s in zip(pos_names, pos_strats)
                }
                for n, s in kw_strats.items():
                    drawn[n] = s.sample(rng)
                fn(*args, **kwargs, **drawn)

        wrapper.__signature__ = sig.replace(parameters=remaining)
        return wrapper

    return deco


def install():
    """Register shim modules under the `hypothesis` names."""
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    extra = types.ModuleType("hypothesis.extra")
    hnp = types.ModuleType("hypothesis.extra.numpy")

    st.lists = lists
    st.floats = floats
    st.integers = integers
    st.one_of = one_of
    st.just = just
    hnp.arrays = _np_arrays
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    extra.numpy = hnp
    hyp.extra = extra

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
    sys.modules["hypothesis.extra"] = extra
    sys.modules["hypothesis.extra.numpy"] = hnp
