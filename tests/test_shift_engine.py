"""The shift-rule ENGINE contract: one phased rule object drives the
reference simulator, the production train step, and the overlap runtime
with bit-identical results.

Three layers of pinning:

  * engine x channel: every trainer rule's ``round`` is bit-exact
    between ``MeshChannel`` and the bucketed ``AsyncChannel`` (drained),
    and between the AsyncChannel's interleaved schedule and the default
    ``Channel.shift_round`` schedule — bucketing changes scheduling,
    never math (the PR-3 contract, extended to shifted rules).
  * trainer x reference: ``launch/train.py``'s jitted step reproduces
    ``DCGDShift`` (the paper's Algorithm-1 object) / ``VRGDCI`` exactly
    — params, h, h_bar and bits — for every rule on the ``sim`` and
    ``dense`` channels.  This is what guarantees the trainer contains
    no drifted re-implementation of the rule algebra.
  * config plumbing and the EF-BV comm modes (``efbv``,
    ``efbv_overlap``), including the end-to-end train CLI on 8 fake
    devices.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import AsyncChannel, MeshChannel, SimChannel, make_channel
from repro.comm.channel import Channel
from repro.configs import get_smoke_config
from repro.configs.base import CompressionConfig, TrainConfig
from repro.core import (
    DCGDShift,
    DCGDState,
    EF21Shift,
    EFBVShift,
    dense_message_bits,
    make_shift_rule,
)
from repro.core.compressors import NaturalCompression, TopK, wire_bits
from repro.data.tokens import TokenStream
from repro.dist import per_worker_grads, split_batch
from repro.launch.mesh import make_host_mesh, n_workers
from repro.launch.train import (
    TrainState,
    build_channel,
    build_train_step,
    init_state,
)
from repro.models import model as M
from repro.optim import make_optimizer

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

tmap = jax.tree_util.tree_map

#: every gradient-direction rule the trainer accepts, with the config
#: that selects it (compressors chosen to exercise the rule's regime)
RULE_CONFIGS = {
    "fixed": CompressionConfig(enabled=True, compressor="natural",
                               shift_rule="fixed"),
    "diana": CompressionConfig(enabled=True, compressor="natural",
                               shift_rule="diana", shift_alpha=0.25),
    "rand_diana": CompressionConfig(enabled=True, compressor="natural",
                                    shift_rule="rand_diana", shift_p=0.5),
    "ef21": CompressionConfig(enabled=True, compressor="topk",
                              compressor_kwargs=(("q", 0.25),),
                              shift_rule="ef21"),
    "efbv": CompressionConfig(enabled=True, compressor="natural",
                              shift_rule="efbv", efbv_eta=0.5, efbv_nu=0.9),
}


def _wtree(key, w=4):
    return {
        "a": jax.random.normal(key, (w, 40)),
        "b": {
            "c": jax.random.normal(jax.random.fold_in(key, 1), (w, 3, 5)),
            "d": jax.random.normal(jax.random.fold_in(key, 2), (w,)),
        },
        "e": jax.random.normal(jax.random.fold_in(key, 3), (w, 7)),
    }


def _assert_trees_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)),
        a, b,
    )


def _rule_and_q(name):
    comp = RULE_CONFIGS[name]
    return comp.make()


# ---------------------------------------------------------------------------
# Engine x channel: shifted rules ride the overlap runtime bit-exactly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(RULE_CONFIGS))
def test_rule_round_async_drained_bit_exact_vs_mesh(name):
    """For every rule, ``round`` over the bucketed AsyncChannel (drained
    synchronously) is bit-exact with MeshChannel — across bucket
    granularities.  This is what makes shifted modes SUPPORTED on the
    overlap runtime rather than silently serialized or wrong."""
    q, rule = _rule_and_q(name)
    key = jax.random.PRNGKey(7)
    wtree = _wtree(key)
    h, h_bar = rule.init(wtree), rule.init_bar(wtree)
    ref = rule.round(q, key, wtree, h, h_bar,
                     channel=MeshChannel(mode="dense"))
    for budget in (1, 64, 1 << 30):
        ach = AsyncChannel(mode="dense", bucket_bytes=budget)
        out = rule.round(q, key, wtree, h, h_bar, channel=ach)
        _assert_trees_equal(ref[:3], out[:3])
        assert float(ref[3]) == float(out[3])


@pytest.mark.parametrize("name", sorted(RULE_CONFIGS))
def test_async_interleaved_schedule_matches_default_schedule(name):
    """AsyncChannel.shift_round (message/reduce interleaved per bucket)
    equals the DEFAULT whole-tree schedule run over the same channel:
    the override re-schedules, never re-derives keys or math."""
    q, rule = _rule_and_q(name)
    key = jax.random.PRNGKey(8)
    wtree = _wtree(key)
    h, h_bar = rule.init(wtree), rule.init_bar(wtree)
    ach = AsyncChannel(mode="dense", bucket_bytes=64)
    base = Channel.shift_round(ach, rule, q, key, wtree, h, h_bar)
    over = ach.shift_round(rule, q, key, wtree, h, h_bar)
    _assert_trees_equal(base[:3], over[:3])
    assert float(base[3]) == float(over[3])


def test_sim_channel_round_is_exact_worker_mean():
    """SimChannel aggregation is the exact mean: with the Identity codec
    and zero shifts, fixed-rule g_bar equals mean(wgrads)."""
    from repro.core.compressors import Identity

    key = jax.random.PRNGKey(9)
    wtree = _wtree(key)
    rule = make_shift_rule("fixed")
    g_bar, _, _, bits = rule.round(Identity(), key, wtree, None, None,
                                   channel=SimChannel())
    _assert_trees_equal(g_bar, tmap(lambda a: jnp.mean(a, axis=0), wtree))
    assert float(bits) > 0


def test_legacy_step_shim_matches_round():
    """The deprecated ``step`` entry (h-only state) returns the same
    estimator/shift/bits as ``round`` with the mean-h h_bar."""
    q, rule = _rule_and_q("diana")
    key = jax.random.PRNGKey(10)
    wtree = _wtree(key)
    h = rule.init(wtree)
    h_bar = tmap(lambda a: jnp.mean(a, axis=0), h)
    g1, h1, b1 = rule.step(q, key, wtree, h)
    g2, h2, _, b2 = rule.round(q, key, wtree, h, h_bar)
    _assert_trees_equal((g1, h1), (g2, h2))
    assert float(b1) == float(b2)


# ---------------------------------------------------------------------------
# Trainer x reference: the production step IS the reference algebra
# ---------------------------------------------------------------------------


def _train_setup(comp, lr=1e-2):
    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    tcfg = TrainConfig(learning_rate=lr, total_steps=10, warmup_steps=2,
                       compression=comp)
    mesh = make_host_mesh()
    w = n_workers(mesh)
    return cfg, tcfg, mesh, w


@pytest.mark.parametrize("comm_mode", ["sim", "dense"])
@pytest.mark.parametrize("name", sorted(RULE_CONFIGS))
def test_train_step_bit_exact_vs_reference_rule(name, comm_mode):
    """THE harness: the jitted production train_step reproduces the
    reference ``DCGDShift`` round (same rule object, same channel, same
    key derivation) EXACTLY — params, h, h_bar, bits — for every rule
    x {SimChannel, MeshChannel}."""
    import dataclasses

    comp = dataclasses.replace(RULE_CONFIGS[name], comm_mode=comm_mode)
    cfg, tcfg, mesh, w = _train_setup(comp)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
    step = jax.jit(build_train_step(cfg, tcfg, mesh, w))

    channel = build_channel(comp, cfg, mesh, w)
    q, rule = comp.make(learning_rate=tcfg.learning_rate)
    optimizer = make_optimizer(tcfg)
    method = DCGDShift(q=q, rule=rule, channel=channel)

    def loss_fn(p, b):
        return M.train_loss(p, cfg, b)

    def ref_step(state, batch):
        grads, _, _ = per_worker_grads(loss_fn, state.params,
                                       split_batch(batch, w))
        g_bar, ref = method.estimate(
            DCGDState(h=state.h, h_bar=state.h_bar, key=state.key,
                      step=state.step, bits=state.bits),
            grads,
        )
        new_params, opt = optimizer.update(g_bar, state.opt, state.params)
        return TrainState(new_params, opt, ref.h, ref.h_bar, ref.key,
                          ref.step, ref.bits)

    ref_jit = jax.jit(ref_step)
    stream = TokenStream(cfg, 32, 4)
    for i in range(2):
        batch = stream.batch(i)
        got, _ = step(state, batch)
        want = ref_jit(state, batch)
        _assert_trees_equal(
            (got.params, got.h, got.h_bar, got.bits, got.key),
            (want.params, want.h, want.h_bar, want.bits, want.key),
        )
        state = got


def test_train_step_vr_gdci_bit_exact_vs_reference():
    """Algorithm 2 (compressed iterates): the trainer plumbs TrainState
    through ``VRGDCI.round`` — compare against the core object driving
    the same grads."""
    comp = CompressionConfig(enabled=True, compressor="natural",
                             shift_rule="vr_gdci", shift_alpha=0.5,
                             gdci_eta=0.9)
    cfg, tcfg, mesh, w = _train_setup(comp, lr=0.2)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
    step = jax.jit(build_train_step(cfg, tcfg, mesh, w))
    channel = build_channel(comp, cfg, mesh, w)
    _, rule = comp.make(learning_rate=tcfg.learning_rate)

    def loss_fn(p, b):
        return M.train_loss(p, cfg, b)

    def ref_step(state, batch):
        grads, _, _ = per_worker_grads(loss_fn, state.params,
                                       split_batch(batch, w))
        key, sub = jax.random.split(state.key)
        new_params, h, h_bar, bits = rule.round(
            sub, state.params, grads, state.h, state.h_bar, channel
        )
        return new_params, h, h_bar, state.bits + bits, key

    ref_jit = jax.jit(ref_step)
    stream = TokenStream(cfg, 32, 4)
    for i in range(2):
        batch = stream.batch(i)
        got, _ = step(state, batch)
        want = ref_jit(state, batch)
        _assert_trees_equal(
            (got.params, got.h, got.h_bar, got.bits, got.key), want
        )
        state = got


def test_train_step_fixed_rule_allocates_no_shift_state():
    """Stateless rules keep h/h_bar = None in TrainState — no worker-
    stacked shift tensors for plain DCGD."""
    comp = CompressionConfig(enabled=True, compressor="natural",
                             shift_rule="fixed")
    cfg, tcfg, mesh, w = _train_setup(comp)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
    assert state.h is None and state.h_bar is None


# ---------------------------------------------------------------------------
# Satellites: structural refresh bits, registry errors, efbv plumbing
# ---------------------------------------------------------------------------


def test_rand_diana_refresh_bits_are_structural():
    """The refresh cost charges the leaves' TRUE dtype widths (wire_bits
    of a dense payload), not a hand-written 32*d: a bf16 leaf is charged
    16 bits/scalar."""
    rule = make_shift_rule("rand_diana", p=1.0)  # always fires
    w = 4
    wtree = {
        "f32": jnp.ones((w, 40), jnp.float32),
        "bf16": jnp.ones((w, 3, 5), jnp.bfloat16),
    }
    per_worker = 40 * 32 + 15 * 16
    assert dense_message_bits(wtree) == float(per_worker)
    assert dense_message_bits(wtree) == float(
        sum(
            wire_bits(jax.ShapeDtypeStruct(a.shape[1:], a.dtype))
            for a in jax.tree_util.tree_leaves(wtree)
        )
    )
    refresh, extra = rule.aux(jax.random.PRNGKey(0), wtree, rule.init(wtree))
    assert bool(jnp.all(refresh))  # p=1: every worker fired
    assert float(extra) == float(w * per_worker)


def test_make_shift_rule_rejects_unknown_naming_rules():
    with pytest.raises(ValueError) as ei:
        make_shift_rule("carrier_pigeon")
    msg = str(ei.value)
    for name in ("fixed", "diana", "rand_diana", "ef21", "efbv", "star"):
        assert name in msg


def test_config_make_rejects_unknown_naming_rules():
    cfg = CompressionConfig(shift_rule="carrier_pigeon")
    with pytest.raises(ValueError) as ei:
        cfg.make()
    msg = str(ei.value)
    for name in ("fixed", "diana", "rand_diana", "ef21", "efbv", "vr_gdci"):
        assert name in msg


def test_config_make_vr_gdci_requires_learning_rate():
    cfg = CompressionConfig(shift_rule="vr_gdci")
    with pytest.raises(ValueError, match="learning_rate"):
        cfg.make()
    from repro.core.iterate_comp import VRGDCI

    _, rule = cfg.make(learning_rate=0.1)
    assert isinstance(rule, VRGDCI) and rule.gamma == 0.1


def test_efbv_comm_mode_config_plumbing():
    cfg = CompressionConfig(comm_mode="efbv", compressor="topk",
                            compressor_kwargs=(("q", 0.25),),
                            efbv_eta=0.5, efbv_nu=0.9)
    assert cfg.effective_shift_rule == "efbv"
    assert cfg.aggregation_mode == "dense"
    q, rule = cfg.make()
    assert isinstance(rule, EFBVShift)
    assert rule.eta == 0.5 and rule.nu == 0.9
    ch = make_channel(cfg)
    assert isinstance(ch, MeshChannel) and ch.mode == "dense"

    ov = CompressionConfig(comm_mode="efbv_overlap",
                           overlap_bucket_bytes=12345)
    assert ov.effective_shift_rule == "efbv"
    assert ov.aggregation_mode == "q8_ring_fused"
    ch = make_channel(ov)
    assert isinstance(ch, AsyncChannel) and ch.bucket_bytes == 12345

    from repro.comm import collective_payload_scale

    scale = collective_payload_scale(cfg)
    assert 0.0 < scale["all-reduce"] < 1.0


def test_efbv_unit_knobs_identical_to_ef21():
    """eta = nu = 1 is EXACTLY EF21 — same message keys, bitwise-equal
    estimator, shifts and bits."""
    key = jax.random.PRNGKey(3)
    wtree = _wtree(key)
    c = TopK(0.25)
    ef, bv = EF21Shift(), EFBVShift(eta=1.0, nu=1.0)
    h, h_bar = ef.init(wtree), ef.init_bar(wtree)
    o1 = ef.round(c, key, wtree, h, h_bar)
    o2 = bv.round(c, key, wtree, h, h_bar)
    _assert_trees_equal(o1[:3], o2[:3])
    assert float(o1[3]) == float(o2[3])


def test_stepsize_efbv_reduces_to_ef21_and_damps_variance():
    from repro.core import efbv_params, stepsize_ef21, stepsize_efbv

    assert stepsize_efbv(10.0, 12.0, delta=0.25) == \
        stepsize_ef21(10.0, 12.0, 0.25)
    # undamped unbiased recursion has no contraction certificate
    assert stepsize_efbv(10.0, 12.0, omega=3.0, eta=1.0) == 0.0
    # the recommended damping restores one
    eta, nu = efbv_params(omega=3.0)
    assert eta == pytest.approx(0.25) and nu == 1.0
    assert stepsize_efbv(10.0, 12.0, omega=3.0, eta=eta, nu=nu) > 0.0
    # contractive-only compressors keep the EF21 choice
    assert efbv_params(delta=0.25) == (1.0, 1.0)


# ---------------------------------------------------------------------------
# End-to-end: the efbv comm modes through the train CLI (8 fake devices)
# ---------------------------------------------------------------------------


_EFBV_CLI = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.launch.train import main
    state = main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "2",
                  "--batch", "8", "--seq", "32",
                  "--compressor", "topk", "--comm_mode", "efbv",
                  "--efbv_eta", "0.9", "--efbv_nu", "0.95"])
    assert np.isfinite(float(state.bits)) and float(state.bits) > 0
    assert state.h is not None  # EF-BV shift state allocated (8 workers)
    import jax
    assert jax.tree_util.tree_leaves(state.h)[0].shape[0] == 8
    print("EFBV_CLI_OK")
""")


def test_train_cli_efbv_8dev_subprocess():
    """--comm_mode efbv end-to-end through the train CLI on 8 fake
    devices (the acceptance path for the EF-BV comm mode)."""
    r = subprocess.run(
        [sys.executable, "-c", _EFBV_CLI],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=_REPO_ROOT,
    )
    assert "EFBV_CLI_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


_EFBV_OVERLAP_CLI = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.launch.train import main
    state = main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "2",
                  "--batch", "8", "--seq", "32",
                  "--compressor", "natural", "--comm_mode", "efbv_overlap"])
    assert np.isfinite(float(state.bits)) and float(state.bits) > 0
    assert state.h is not None
    print("EFBV_OVERLAP_CLI_OK")
""")


def test_train_cli_efbv_overlap_8dev_subprocess():
    """--comm_mode efbv_overlap: a SHIFTED rule riding the bucketed
    Pallas-fused overlap runtime end-to-end (the capability PR-3 lacked
    — its overlap mode composed with shift-free aggregation only)."""
    r = subprocess.run(
        [sys.executable, "-c", _EFBV_OVERLAP_CLI],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=_REPO_ROOT,
    )
    assert "EFBV_OVERLAP_CLI_OK" in r.stdout, (
        r.stdout[-2000:] + r.stderr[-3000:]
    )
