"""Per-architecture smoke tests: reduced config, one forward/train step and
one decode step on CPU; asserts shapes and finiteness.  (Deliverable f.)"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import model as M

BATCH, SEQ = 2, 16


def _batch(cfg):
    key = jax.random.PRNGKey(0)
    b = {"tokens": jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab_size)}
    if cfg.modality == "vision_prefix":
        b["prefix"] = jax.random.normal(
            key, (BATCH, cfg.num_prefix_tokens, cfg.d_model), jnp.float32
        )
    if cfg.is_encoder_decoder:
        b["frames"] = jax.random.normal(key, (BATCH, SEQ, cfg.d_model), jnp.float32)
    return b


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    return request.param


def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch).with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(M.train_loss, has_aux=True)(
        params, cfg, batch
    )
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves and all(jnp.all(jnp.isfinite(g)) for g in leaves), (
        f"{arch}: non-finite grads"
    )
    # one SGD step changes the loss
    new_params = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2, _ = M.train_loss(new_params, cfg, batch)
    assert jnp.isfinite(loss2)


def test_logit_shapes(arch):
    cfg = get_smoke_config(arch).with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg)
    logits, aux = M.forward_train(params, cfg, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits))


def test_decode_step_smoke(arch):
    cfg = get_smoke_config(arch).with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    cache_len = 32
    enc_len = SEQ if cfg.is_encoder_decoder else 0
    state = M.make_decode_state(cfg, BATCH, cache_len, enc_len)
    if cfg.is_encoder_decoder:
        # fill cross-attention KV from an encoder pass
        frames = jax.random.normal(
            jax.random.PRNGKey(2), (BATCH, SEQ, cfg.d_model), jnp.float32
        )
        enc_out = M._encoder(params, cfg, frames)
        import repro.models.layers as L
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            k, v = L.cross_attention_kv(lp["xattn"], enc_out, cfg)
            ks.append(k); vs.append(v)
        state = {**state, "xkv": {"k": jnp.stack(ks), "v": jnp.stack(vs)}}
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    logits, state2 = M.decode_step(params, cfg, tok, state, jnp.int32(0))
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits))
    logits3, _ = M.decode_step(params, cfg, tok, state2, jnp.int32(1))
    assert jnp.all(jnp.isfinite(logits3))


def test_decode_matches_prefill_dense():
    """Greedy parity: decoding token-by-token equals the train forward for
    a dense arch (the strongest correctness check of the cache path)."""
    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab_size)
    full_logits, _ = M.forward_train(params, cfg, {"tokens": toks})
    state = M.make_decode_state(cfg, 1, 16)
    outs = []
    for t in range(8):
        lg, state = M.decode_step(params, cfg, toks[:, t:t+1], state, jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    assert jnp.allclose(full_logits, dec_logits, atol=2e-2, rtol=2e-2), (
        jnp.max(jnp.abs(full_logits - dec_logits))
    )


def test_decode_matches_scan_ssm():
    """Same parity for the RWKV recurrence (state carry path)."""
    cfg = get_smoke_config("rwkv6-3b").with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab_size)
    full_logits, _ = M.forward_train(params, cfg, {"tokens": toks})
    state = M.make_decode_state(cfg, 1, 16)
    outs = []
    for t in range(8):
        lg, state = M.decode_step(params, cfg, toks[:, t:t+1], state, jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    assert jnp.allclose(full_logits, dec_logits, atol=2e-2, rtol=2e-2), (
        jnp.max(jnp.abs(full_logits - dec_logits))
    )
