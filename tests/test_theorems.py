"""Theorem-level integration tests: each convergence guarantee of the
paper, validated empirically on the paper's own ridge-regression setup.

These are the strongest paper-fidelity checks in the suite: Theorems
1-6 all predict either exact linear convergence or convergence to a
specific neighborhood under their step-size rules.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DCGDShift,
    EF21Shift,
    EFBVShift,
    FixedShift,
    DianaShift,
    GDCI,
    Identity,
    NaturalCompression,
    RandDianaShift,
    RandK,
    StarShift,
    TopK,
    VRGDCI,
    efbv_params,
    rand_diana_default_p,
    stepsize_dcgd_fixed,
    stepsize_dcgd_star,
    stepsize_diana,
    stepsize_ef21,
    stepsize_efbv,
    stepsize_gdci,
    stepsize_rand_diana,
    stepsize_vr_gdci,
)
from repro.core.simulate import run_dcgd_shift, run_gdci
from repro.data.problems import make_ridge


@pytest.fixture(scope="module")
def ridge():
    # noise > 0 puts the instance in the non-interpolating regime the
    # theorems are about: with noise=0 the workers nearly share the
    # optimum (mean_i ||grad_i(x*)||^2 ~ 1e3, only the lam-residual), so
    # DCGD's Theorem-1 neighborhood collapses to ~1e-7 rel-err and the
    # DCGD-vs-STAR separation is decided by float32 luck.  noise=10
    # gives mean_i ||grad_i(x*)||^2 ~ 1e6 and a ~3e-4 DCGD floor.
    return make_ridge(m=100, d=80, n_workers=10, seed=0, noise=10.0)


def test_theorem1_dcgd_neighborhood(ridge):
    """DCGD (zero fixed shift): linear to a neighborhood, NOT to zero —
    the paper's motivating failure."""
    q = RandK(0.25)
    omega = q.omega(ridge.d)
    gamma = stepsize_dcgd_fixed(ridge.L, ridge.L_max, omega, ridge.n_workers)
    tr = run_dcgd_shift(ridge, DCGDShift(q=q, rule=FixedShift()),
                        gamma, 4000, seed=0)
    # converges into a plateau well above machine precision
    tail = tr.rel_err[-500:]
    assert tail.mean() < 1e-2              # it does make progress
    assert tail.mean() > 1e-12             # ...but stalls (neighborhood)


def test_theorem2_dcgd_star_exact(ridge):
    """DCGD-STAR: exact linear convergence with oracle shifts."""
    q = RandK(0.25)
    omega = q.omega(ridge.d)
    gamma = stepsize_dcgd_star(ridge.L, ridge.L_max, omega, 0.0,
                               ridge.n_workers)
    tr = run_dcgd_shift(ridge, DCGDShift(q=q, rule=StarShift()),
                        gamma, 6000, seed=0, use_star=True)
    assert tr.rel_err[-1] < 1e-9, tr.rel_err[-1]


def test_theorem2_star_beats_dcgd(ridge):
    q = RandK(0.25)
    omega = q.omega(ridge.d)
    g1 = stepsize_dcgd_fixed(ridge.L, ridge.L_max, omega, ridge.n_workers)
    t_dcgd = run_dcgd_shift(ridge, DCGDShift(q=q, rule=FixedShift()),
                            g1, 3000, seed=0)
    g2 = stepsize_dcgd_star(ridge.L, ridge.L_max, omega, 0.0, ridge.n_workers)
    t_star = run_dcgd_shift(ridge, DCGDShift(q=q, rule=StarShift()),
                            g2, 3000, seed=0, use_star=True)
    assert t_star.rel_err[-1] < t_dcgd.rel_err[-1] * 1e-2


def test_theorem3_diana_exact(ridge):
    """DIANA learns the optimal shifts -> exact linear convergence."""
    q = RandK(0.25)
    omega = q.omega(ridge.d)
    alpha, gamma = stepsize_diana(ridge.L_max, omega, 0.0, ridge.n_workers)
    tr = run_dcgd_shift(
        ridge, DCGDShift(q=q, rule=DianaShift(alpha=alpha)),
        gamma, 8000, seed=0,
    )
    assert tr.rel_err[-1] < 1e-6, tr.rel_err[-1]
    # still descending linearly (no plateau) at the end of the run
    assert tr.rel_err[-1] < 0.05 * tr.rel_err[4000]


def test_theorem3_generalized_diana_biased_c(ridge):
    """Generalized DIANA with a BIASED C_i (TopK) in the shift update
    still converges exactly — the paper's extension of DIANA."""
    q = RandK(0.25)
    omega = q.omega(ridge.d)
    delta = TopK(0.25).delta(ridge.d)
    alpha, gamma = stepsize_diana(ridge.L_max, omega, delta, ridge.n_workers)
    tr = run_dcgd_shift(
        ridge,
        DCGDShift(q=q, rule=DianaShift(alpha=alpha, c=TopK(0.25))),
        gamma, 8000, seed=0,
    )
    assert tr.rel_err[-1] < 1e-6, tr.rel_err[-1]
    assert tr.rel_err[-1] < 0.05 * tr.rel_err[4000]


def test_theorem4_rand_diana_exact(ridge):
    """Rand-DIANA (the paper's NEW algorithm): exact linear convergence
    with the recommended p = 1/(omega+1), M = 4 omega/(n p)."""
    q = RandK(0.25)
    omega = q.omega(ridge.d)
    p = rand_diana_default_p(omega)
    _, gamma = stepsize_rand_diana(ridge.L_max, omega, ridge.n_workers, p)
    tr = run_dcgd_shift(
        ridge, DCGDShift(q=q, rule=RandDianaShift(p=p)), gamma, 20000, seed=0,
    )
    assert tr.rel_err[-1] < 1e-6, tr.rel_err[-1]
    assert tr.rel_err[-1] < 0.05 * tr.rel_err[8000]


def test_ef21_topk_converges_where_dcgd_topk_stalls(ridge):
    """EF21 (Richtárik et al., 2021) with the BIASED TopK(0.1) codec
    converges linearly on the ridge fixture; plain DCGD with the same
    operator and no feedback stalls at its bias floor.  Both run at the
    same tuned gamma (16x the EF21 theory step — the benchmarks'
    tuned-gamma protocol; theory-gamma EF21 also converges, just
    slowly)."""
    c = TopK(0.1)
    gamma = 16.0 * stepsize_ef21(ridge.L, ridge.L_max, c.delta(ridge.d))
    tr_ef = run_dcgd_shift(ridge, DCGDShift(q=c, rule=EF21Shift()),
                           gamma, 12000, seed=0)
    tr_dc = run_dcgd_shift(ridge, DCGDShift(q=c, rule=FixedShift()),
                           gamma, 12000, seed=0)
    assert tr_ef.rel_err[-1] < 1e-8, tr_ef.rel_err[-1]
    # still contracting at the end (linear, no plateau)
    assert tr_ef.rel_err[-1] < 0.05 * tr_ef.rel_err[6000]
    dcgd_tail = float(np.median(tr_dc.rel_err[-1000:]))
    assert dcgd_tail > 1e-4, dcgd_tail      # the bias floor (no feedback)
    assert tr_ef.rel_err[-1] < 1e-3 * dcgd_tail


def test_efbv_unit_knobs_trajectory_identical_to_ef21(ridge):
    """EF-BV with eta = nu = 1 IS EF21: the whole optimization
    trajectory (errors and bits) matches bitwise."""
    c = TopK(0.1)
    gamma = 16.0 * stepsize_ef21(ridge.L, ridge.L_max, c.delta(ridge.d))
    tr_ef = run_dcgd_shift(ridge, DCGDShift(q=c, rule=EF21Shift()),
                           gamma, 2000, seed=0)
    tr_bv = run_dcgd_shift(
        ridge, DCGDShift(q=c, rule=EFBVShift(eta=1.0, nu=1.0)),
        gamma, 2000, seed=0,
    )
    np.testing.assert_array_equal(tr_ef.rel_err, tr_bv.rel_err)
    np.testing.assert_array_equal(tr_ef.bits, tr_bv.bits)


def test_efbv_biased_topk_converges_exactly(ridge):
    """The EF21 side of the unification: biased Top-K with the
    recommended (eta, nu) converges linearly to the exact optimum under
    the tuned-gamma protocol (same as the EF21 theorem test)."""
    c = TopK(0.1)
    eta, nu = efbv_params(delta=c.delta(ridge.d))
    gamma = 16.0 * stepsize_efbv(ridge.L, ridge.L_max,
                                 delta=c.delta(ridge.d), eta=eta, nu=nu)
    tr = run_dcgd_shift(
        ridge, DCGDShift(q=c, rule=EFBVShift(eta=eta, nu=nu)),
        gamma, 12000, seed=0,
    )
    assert tr.rel_err[-1] < 1e-8, tr.rel_err[-1]
    assert tr.rel_err[-1] < 0.05 * tr.rel_err[6000]  # still contracting


def test_efbv_damped_unbiased_randk_converges_exactly(ridge):
    """The DIANA side: an UNBIASED non-contractive Rand-K, for which the
    undamped (EF21) recursion certifies nothing (stepsize_efbv returns
    0 at eta=1), converges exactly once damped to eta = 1/(1+omega) —
    the variance-reduction mechanism EF-BV adds over EF21."""
    u = RandK(0.25)
    omega = u.omega(ridge.d)
    assert stepsize_efbv(ridge.L, ridge.L_max, omega=omega, eta=1.0) == 0.0
    eta, nu = efbv_params(omega=omega)
    gamma = 16.0 * stepsize_efbv(ridge.L, ridge.L_max, omega=omega,
                                 eta=eta, nu=nu)
    tr = run_dcgd_shift(
        ridge, DCGDShift(q=u, rule=EFBVShift(eta=eta, nu=nu)),
        gamma, 12000, seed=0,
    )
    # exact convergence: through 1e-6 well within budget, down to the
    # f32 floor by the end (no variance neighborhood anywhere above it)
    assert tr.steps_to_tol(1e-6) < 4000, tr.rel_err[-1]
    assert tr.rel_err[-1] < 1e-10, tr.rel_err[-1]


def test_theorem5_gdci_neighborhood(ridge):
    """GDCI (compressed iterates): linear to a neighborhood."""
    q = RandK(0.5)
    omega = q.omega(ridge.d)
    eta, gamma = stepsize_gdci(ridge.L, ridge.L_max, ridge.mu, omega,
                               ridge.n_workers)
    tr = run_gdci(ridge, GDCI(q=q, gamma=gamma, eta=eta), 6000, seed=0)
    tail = tr.rel_err[-200:]
    assert tail.mean() < 1e-1
    assert tail.mean() > 1e-14


def test_theorem6_vr_gdci_exact(ridge):
    """VR-GDCI eliminates the neighborhood (improved analysis, App. B.7)."""
    q = RandK(0.5)
    omega = q.omega(ridge.d)
    alpha, eta, gamma = stepsize_vr_gdci(ridge.L, ridge.L_max, ridge.mu,
                                         omega, ridge.n_workers)
    tr = run_gdci(ridge, VRGDCI(q=q, gamma=gamma, eta=eta, alpha=alpha),
                  20000, seed=0)
    assert tr.rel_err[-1] < 1e-8, tr.rel_err[-1]
    # and it beats plain GDCI's floor
    eta2, gamma2 = stepsize_gdci(ridge.L, ridge.L_max, ridge.mu, omega,
                                 ridge.n_workers)
    tr2 = run_gdci(ridge, GDCI(q=q, gamma=gamma2, eta=eta2), 20000, seed=0)
    assert tr.rel_err[-1] < tr2.rel_err[-1]


def test_rate_scaling_with_omega(ridge):
    """Iteration complexity grows with omega as kappa(1+omega/n) predicts:
    more compression => proportionally more steps (Table 1 scaling)."""
    steps_needed = []
    for qfrac in (1.0, 0.25):
        q = Identity() if qfrac == 1.0 else RandK(qfrac)
        omega = 0.0 if qfrac == 1.0 else q.omega(ridge.d)
        alpha, gamma = stepsize_diana(ridge.L_max, omega, 0.0,
                                      ridge.n_workers)
        if qfrac == 1.0:
            alpha = 1.0
        tr = run_dcgd_shift(
            ridge, DCGDShift(q=q, rule=DianaShift(alpha=alpha)), gamma,
            8000, seed=0,
        )
        steps_needed.append(tr.steps_to_tol(1e-6))
    assert steps_needed[1] > steps_needed[0]  # omega>0 needs more steps
