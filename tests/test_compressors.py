"""Unit + property tests for the compression operator algebra (Defs 1-4)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.compressors import (
    BernoulliP,
    Identity,
    Induced,
    Int8Stochastic,
    NaturalCompression,
    NaturalDithering,
    RandK,
    ScaledSign,
    TernGrad,
    TopK,
    Zero,
    aot_wire_bits,
    make_compressor,
    shifted,
    tree_bits,
)

UNBIASED = [
    RandK(0.25),
    RandK(0.5),
    BernoulliP(0.3),
    NaturalDithering(s=4),
    NaturalDithering(s=8),
    NaturalCompression(),
    TernGrad(),
    Int8Stochastic(),
    Induced(TopK(0.25), RandK(0.25)),
]

CONTRACTIVE = [TopK(0.1), TopK(0.5), ScaledSign(), Identity()]

N_SAMPLES = 4000
D = 32


def _samples(q, x, n=N_SAMPLES, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), n)
    return jax.vmap(lambda k: q(k, x))(keys)


@pytest.fixture(scope="module")
def xvec():
    return jax.random.normal(jax.random.PRNGKey(42), (D,)) * 3.0 + 1.0


@pytest.mark.parametrize("q", UNBIASED, ids=lambda q: type(q).__name__ + repr(getattr(q, 'q', getattr(q, 'p', getattr(q, 's', '')))))
def test_unbiasedness(q, xvec):
    s = _samples(q, xvec)
    mean = jnp.mean(s, axis=0)
    # CLT tolerance: std of the mean ~ sqrt(omega/n_samples)*|x|
    omega = q.omega(D)
    tol = 4.0 * math.sqrt(max(omega, 0.05) / N_SAMPLES) * float(
        jnp.linalg.norm(xvec)
    )
    assert float(jnp.linalg.norm(mean - xvec)) < tol


@pytest.mark.parametrize("q", UNBIASED, ids=lambda q: type(q).__name__ + repr(getattr(q, 'q', getattr(q, 'p', getattr(q, 's', '')))))
def test_variance_bound(q, xvec):
    s = _samples(q, xvec)
    var = float(jnp.mean(jnp.sum((s - xvec) ** 2, axis=1)))
    bound = q.omega(D) * float(jnp.sum(xvec**2))
    assert var <= bound * 1.05 + 1e-6, f"emp var {var} > omega bound {bound}"


@pytest.mark.parametrize("c", CONTRACTIVE, ids=lambda c: type(c).__name__)
def test_contractive_bound(c, xvec):
    out = c(jax.random.PRNGKey(0), xvec)
    lhs = float(jnp.sum((out - xvec) ** 2))
    rhs = (1.0 - c.delta(D)) * float(jnp.sum(xvec**2))
    assert lhs <= rhs * (1.0 + 1e-5) + 1e-6


def test_zero_maps_to_zero(xvec):
    assert jnp.all(Zero()(jax.random.PRNGKey(0), xvec) == 0)


def test_randk_keeps_exactly_k():
    x = jnp.ones(40)
    q = RandK(0.25)
    out = q(jax.random.PRNGKey(3), x)
    assert int(jnp.sum(out != 0)) == 10
    np.testing.assert_allclose(out[out != 0], 4.0)  # d/k scaling


def test_randk_exact_k_regression_large_d():
    """Regression: the old threshold-on-uniform-scores selection kept
    MORE than K coordinates whenever float32 scores tied at the
    threshold (prob ~ d/2^24 per draw — near-certain over many draws at
    large d), and the d/k rescale then made the operator BIASED upward.
    The permutation-prefix pattern keeps exactly K for every key."""
    d, qfrac = 1 << 18, 0.1
    q = RandK(qfrac)
    k = max(1, round(qfrac * d))
    x = jnp.ones((d,))
    count = jax.jit(lambda kk: jnp.sum(q(kk, x) != 0))
    for batch in range(6):
        keys = jax.random.split(jax.random.PRNGKey(100 + batch), 50)
        counts = np.asarray(jax.vmap(count)(keys))
        assert (counts == k).all(), (batch, counts[counts != k])


def test_topk_exact_k_on_ties():
    """All-equal magnitudes are a guaranteed tie: the old >=-threshold
    mask kept EVERY coordinate; top_k index order keeps exactly K."""
    x = jnp.ones(40)
    out = TopK(0.25)(None, x)
    assert int(jnp.sum(out != 0)) == 10


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 1.0])
    out = TopK(0.5)(None, x)
    np.testing.assert_allclose(np.asarray(out), [0, -5.0, 0, 3.0, 0, 1.0])


def test_shifted_variance_vanishes_at_shift(xvec):
    """Def. 3: the compressed message has zero variance at x == h."""
    q = RandK(0.25)
    out = jax.vmap(lambda k: shifted(q, xvec, k, xvec))(
        jax.random.split(jax.random.PRNGKey(0), 64)
    )
    np.testing.assert_allclose(
        np.asarray(out), np.broadcast_to(np.asarray(xvec), out.shape), rtol=1e-6
    )


def test_shifted_variance_bound(xvec):
    """E||Q_h(x) - x||^2 <= omega ||x - h||^2 (Lemma 1)."""
    q = RandK(0.25)
    h = xvec * 0.5 + 1.0
    s = jax.vmap(lambda k: shifted(q, h, k, xvec))(
        jax.random.split(jax.random.PRNGKey(1), N_SAMPLES)
    )
    var = float(jnp.mean(jnp.sum((s - xvec) ** 2, axis=1)))
    bound = q.omega(D) * float(jnp.sum((xvec - h) ** 2))
    assert var <= bound * 1.05


def test_induced_variance_improves(xvec):
    """Lemma 3: omega_ind = omega (1 - delta) < omega."""
    q = RandK(0.25)
    ind = Induced(TopK(0.25), q)
    s_q = _samples(q, xvec)
    s_i = _samples(ind, xvec)
    var_q = float(jnp.mean(jnp.sum((s_q - xvec) ** 2, axis=1)))
    var_i = float(jnp.mean(jnp.sum((s_i - xvec) ** 2, axis=1)))
    assert var_i < var_q
    assert var_i <= ind.omega(D) * float(jnp.sum(xvec**2)) * 1.05


@settings(max_examples=30, deadline=None)
@given(
    hnp.arrays(
        np.float32,
        st.integers(4, 64),
        elements=st.floats(-100, 100, width=32, allow_nan=False),
    )
)
def test_topk_contractive_property(x):
    """Property: Top-K satisfies Def. 1 for every input."""
    xj = jnp.asarray(x)
    c = TopK(0.25)
    out = c(None, xj)
    lhs = float(jnp.sum((out - xj) ** 2))
    rhs = (1 - c.delta(x.size)) * float(jnp.sum(xj**2))
    assert lhs <= rhs * (1 + 1e-4) + 1e-5


@settings(max_examples=20, deadline=None)
@given(
    hnp.arrays(
        np.float32,
        st.integers(4, 64),
        elements=st.one_of(
            st.just(0.0),
            st.floats(9.999999682655225e-21, 50, width=32),
            st.floats(-50, -9.999999682655225e-21, width=32),
        ),
    ),
    st.integers(0, 10),
)
def test_natural_compression_within_factor2(x, seed):
    """C_nat rounds to an adjacent power of two: |out| in {0} U [|x|/2, 2|x|]."""
    xj = jnp.asarray(x)
    out = np.asarray(NaturalCompression()(jax.random.PRNGKey(seed), xj))
    a = np.abs(x)
    oa = np.abs(out)
    nz = a > 0
    assert np.all(oa[nz] >= a[nz] / 2 - 1e-6)
    assert np.all(oa[nz] <= a[nz] * 2 + 1e-6)
    assert np.all(np.sign(out[nz]) == np.sign(x[nz]))


def test_bits_accounting():
    d = 1000
    assert aot_wire_bits(RandK(0.1), d) == 100 * (32 + 10)
    assert aot_wire_bits(RandK(0.1, shared_pattern=True), d) == 100 * 32
    assert aot_wire_bits(TopK(0.1), d) == 100 * (32 + 10)
    assert aot_wire_bits(Identity(), d) == 32 * d
    assert aot_wire_bits(Zero(), d) == 0
    assert aot_wire_bits(Int8Stochastic(), d) == 8 * d + 32
    tree = {"a": jnp.zeros(10), "b": jnp.zeros((5, 2))}
    assert tree_bits(Identity(), tree) == 32 * 20


def test_registry():
    assert isinstance(make_compressor("randk", q=0.5), RandK)
    with pytest.raises(ValueError):
        make_compressor("nope")


def test_registry_rejects_unknown_kwargs():
    """The convenience entries must raise on unknown kwargs exactly like
    the dataclass paths do (no silent **kwargs sink)."""
    ind = make_compressor("induced_topk_randk", q=0.25)
    assert isinstance(ind, Induced)
    for name in ("induced_topk_randk", "induced_topk_natural", "randk",
                 "int8", "topk"):
        with pytest.raises(TypeError):
            make_compressor(name, not_a_real_kwarg=1)


def test_tree_shifted_compress_structure_mismatch():
    from repro.core.compressors import tree_shifted_compress

    key = jax.random.PRNGKey(0)
    tree = {"a": jnp.ones(4), "b": jnp.ones(3)}
    with pytest.raises(ValueError, match="structure"):
        tree_shifted_compress(Identity(), key, tree,
                              {"a": jnp.ones(4), "c": jnp.ones(3)})
    # matching structures still work
    out = tree_shifted_compress(Identity(), key, tree,
                                {"a": jnp.zeros(4), "b": jnp.zeros(3)})
    np.testing.assert_allclose(np.asarray(out["a"]), 1.0)
