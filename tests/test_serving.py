"""Continuous-batching engine correctness.

The load-bearing test: a request served THROUGH the engine (admitted at
an arbitrary clock offset, sharing its batch with other requests) must
produce exactly the tokens of an offline single-request greedy decode —
per-slot cache invalidation + RoPE position-coherence working together.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import Engine, Request


def _offline_greedy(cfg, params, prompt, n_new):
    state = M.make_decode_state(cfg, 1, 256)
    out = []
    tok = None
    for t in range(len(prompt) + n_new - 1):
        cur = prompt[t] if t < len(prompt) else out[-1]
        logits, state = M.decode_step(
            params, cfg, jnp.asarray([[cur]], jnp.int32), state, jnp.int32(t)
        )
        if t >= len(prompt) - 1:
            out.append(int(jnp.argmax(logits[0, -1])))
    return out


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_single_request_matches_offline(dense_setup):
    cfg, params = dense_setup
    prompt = [5, 17, 99, 3]
    ref = _offline_greedy(cfg, params, prompt, 8)
    eng = Engine(cfg, params, max_batch=2, cache_len=64)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    done = eng.run()
    assert len(done) == 1
    assert done[0].output == ref, (done[0].output, ref)


def test_engine_continuous_batching_isolation(dense_setup):
    """Requests admitted at different clock offsets into recycled slots
    must each match their own offline decode (no KV leakage)."""
    cfg, params = dense_setup
    prompts = [[5, 17, 99], [42, 7], [123, 9, 11, 2], [88], [3, 1, 4, 1, 5]]
    refs = [_offline_greedy(cfg, params, p, 6) for p in prompts]
    eng = Engine(cfg, params, max_batch=2, cache_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert len(done) == len(prompts)
    for r, ref in zip(done, refs):
        assert r.output == ref, (r.uid, r.output, ref)


def test_engine_rwkv_state_isolation():
    """Recurrent-state arch: slot reuse must zero the previous request's
    state (the SSM analogue of KV invalidation)."""
    cfg = get_smoke_config("rwkv6-3b").with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    prompts = [[5, 17, 99], [42, 7, 13], [123, 9]]
    refs = []
    for p in prompts:
        state = M.make_decode_state(cfg, 1, 64)
        out, last = [], None
        for t in range(len(p) + 4 - 1):
            cur = p[t] if t < len(p) else out[-1]
            lg, state = M.decode_step(
                params, cfg, jnp.asarray([[cur]], jnp.int32), state,
                jnp.int32(t),
            )
            if t >= len(p) - 1:
                out.append(int(jnp.argmax(lg[0, -1])))
        refs.append(out)
    eng = Engine(cfg, params, max_batch=1, cache_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    done = sorted(eng.run(), key=lambda r: r.uid)
    for r, ref in zip(done, refs):
        assert r.output == ref, (r.uid, r.output, ref)


def test_engine_eos_stops_early(dense_setup):
    cfg, params = dense_setup
    # discover the greedy first token, then use it as "EOS"
    ref = _offline_greedy(cfg, params, [5, 17], 1)
    eng = Engine(cfg, params, max_batch=1, cache_len=64)
    eng.submit(Request(uid=0, prompt=[5, 17], max_new_tokens=50,
                       eos_id=ref[0]))
    done = eng.run()
    assert done[0].output == [ref[0]]


def test_engine_eos_in_prompt_ignored_during_prefill(dense_setup):
    """An EOS id that happens to appear INSIDE the prompt must not
    terminate the request while the prompt is still being fed — only
    GENERATED tokens are checked against eos_id."""
    cfg, params = dense_setup
    prompt = [5, 17, 99, 3]
    ref = _offline_greedy(cfg, params, prompt, 6)
    eos = prompt[1]
    assert eos not in ref   # the generated stream itself never emits it
    eng = Engine(cfg, params, max_batch=2, cache_len=64)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=6, eos_id=eos))
    done = eng.run()
    assert len(done) == 1 and done[0].output == ref


def test_engine_admit_into_just_freed_slot(dense_setup):
    """Mid-run submission into a slot freed the SAME tick: the new
    request must see an invalidated cache (kpos reset), not the old
    occupant's KV — driven through step_tick, not run()."""
    cfg, params = dense_setup
    a, b = [5, 17, 99], [42, 7, 13]
    ref_b = _offline_greedy(cfg, params, b, 6)
    eng = Engine(cfg, params, max_batch=1, cache_len=64)
    eng.submit(Request(uid=0, prompt=a, max_new_tokens=4))
    done = []
    for _ in range(100):
        done.extend(eng.step_tick())
        if done:
            break
    assert done and done[0].uid == 0
    # slot 0 is free as of this tick; B lands in it at a later clock
    eng.submit(Request(uid=1, prompt=b, max_new_tokens=6))
    for _ in range(100):
        done.extend(eng.step_tick())
        if len(done) == 2:
            break
    assert done[1].uid == 1 and done[1].output == ref_b


def test_engine_recurrent_slot_zeroed_on_admit():
    """Mamba2-family recurrent state: admitting into a reused slot must
    zero the previous request's SSM state (the recurrent analogue of KV
    invalidation) — back-to-back requests each match offline decode."""
    cfg = get_smoke_config("zamba2-1.2b").with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    prompts = [[5, 17, 99], [42, 7, 13]]
    refs = []
    for p in prompts:
        state = M.make_decode_state(cfg, 1, 64)
        out = []
        for t in range(len(p) + 4 - 1):
            cur = p[t] if t < len(p) else out[-1]
            lg, state = M.decode_step(
                params, cfg, jnp.asarray([[cur]], jnp.int32), state,
                jnp.int32(t),
            )
            if t >= len(p) - 1:
                out.append(int(jnp.argmax(lg[0, -1])))
        refs.append(out)
    eng = Engine(cfg, params, max_batch=1, cache_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    done = sorted(eng.run(), key=lambda r: r.uid)
    for r, ref in zip(done, refs):
        assert r.output == ref, (r.uid, r.output, ref)
