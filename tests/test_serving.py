"""Continuous-batching engine correctness.

The load-bearing test: a request served THROUGH the engine (admitted at
an arbitrary clock offset, sharing its batch with other requests) must
produce exactly the tokens of an offline single-request greedy decode —
per-slot cache invalidation + RoPE position-coherence working together.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import Engine, Request


def _offline_greedy(cfg, params, prompt, n_new):
    state = M.make_decode_state(cfg, 1, 256)
    out = []
    tok = None
    for t in range(len(prompt) + n_new - 1):
        cur = prompt[t] if t < len(prompt) else out[-1]
        logits, state = M.decode_step(
            params, cfg, jnp.asarray([[cur]], jnp.int32), state, jnp.int32(t)
        )
        if t >= len(prompt) - 1:
            out.append(int(jnp.argmax(logits[0, -1])))
    return out


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_single_request_matches_offline(dense_setup):
    cfg, params = dense_setup
    prompt = [5, 17, 99, 3]
    ref = _offline_greedy(cfg, params, prompt, 8)
    eng = Engine(cfg, params, max_batch=2, cache_len=64)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    done = eng.run()
    assert len(done) == 1
    assert done[0].output == ref, (done[0].output, ref)


def test_engine_continuous_batching_isolation(dense_setup):
    """Requests admitted at different clock offsets into recycled slots
    must each match their own offline decode (no KV leakage)."""
    cfg, params = dense_setup
    prompts = [[5, 17, 99], [42, 7], [123, 9, 11, 2], [88], [3, 1, 4, 1, 5]]
    refs = [_offline_greedy(cfg, params, p, 6) for p in prompts]
    eng = Engine(cfg, params, max_batch=2, cache_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert len(done) == len(prompts)
    for r, ref in zip(done, refs):
        assert r.output == ref, (r.uid, r.output, ref)


def test_engine_rwkv_state_isolation():
    """Recurrent-state arch: slot reuse must zero the previous request's
    state (the SSM analogue of KV invalidation)."""
    cfg = get_smoke_config("rwkv6-3b").with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    prompts = [[5, 17, 99], [42, 7, 13], [123, 9]]
    refs = []
    for p in prompts:
        state = M.make_decode_state(cfg, 1, 64)
        out, last = [], None
        for t in range(len(p) + 4 - 1):
            cur = p[t] if t < len(p) else out[-1]
            lg, state = M.decode_step(
                params, cfg, jnp.asarray([[cur]], jnp.int32), state,
                jnp.int32(t),
            )
            if t >= len(p) - 1:
                out.append(int(jnp.argmax(lg[0, -1])))
        refs.append(out)
    eng = Engine(cfg, params, max_batch=1, cache_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    done = sorted(eng.run(), key=lambda r: r.uid)
    for r, ref in zip(done, refs):
        assert r.output == ref, (r.uid, r.output, ref)


def test_engine_eos_stops_early(dense_setup):
    cfg, params = dense_setup
    # discover the greedy first token, then use it as "EOS"
    ref = _offline_greedy(cfg, params, [5, 17], 1)
    eng = Engine(cfg, params, max_batch=1, cache_len=64)
    eng.submit(Request(uid=0, prompt=[5, 17], max_new_tokens=50,
                       eos_id=ref[0]))
    done = eng.run()
    assert done[0].output == [ref[0]]
