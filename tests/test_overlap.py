"""Overlap runtime tests: the reverse-layer bucketer, the AsyncChannel
start/finish protocol, and THE CONTRACT — drained synchronously the
AsyncChannel is bit-exact with MeshChannel in the same aggregation mode
(q8_ring over 8 fake devices runs in a subprocess, like the dist
tests).  Plus the comm-mode validation satellites."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    AGGREGATION_MODES,
    AsyncChannel,
    MeshChannel,
    SimChannel,
    make_channel,
    plan_buckets,
)
from repro.comm.overlap import Handle, Inflight
from repro.configs.base import CompressionConfig
from repro.core.compressors import NaturalCompression, RandK

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _wtree(key, w=4):
    return {
        "a": jax.random.normal(key, (w, 40)),
        "b": {
            "c": jax.random.normal(jax.random.fold_in(key, 1), (w, 3, 5)),
            "d": jax.random.normal(jax.random.fold_in(key, 2), (w,)),
        },
        "e": jax.random.normal(jax.random.fold_in(key, 3), (w, 7)),
    }


# ---------------------------------------------------------------------------
# Bucketer
# ---------------------------------------------------------------------------


def test_plan_buckets_reverse_order_and_coverage():
    """Buckets walk leaves LAST first (reverse-layer order: what makes
    overlap with backward compute possible), cover every leaf exactly
    once, and respect the byte budget for multi-leaf buckets."""
    wtree = _wtree(jax.random.PRNGKey(0))
    budget = 64  # bytes: d (4) + c (60) fit; a (160) and e (28) split off
    plan = plan_buckets(wtree, budget)
    flat_order = [i for b in plan.buckets for i in b.indices]
    assert sorted(flat_order) == list(range(plan.n_leaves))
    assert flat_order == sorted(flat_order, reverse=True)  # reverse-layer
    for b in plan.buckets:
        if len(b.indices) > 1:
            assert b.nbytes <= budget


def test_plan_buckets_oversize_leaf_gets_own_bucket():
    """Leaves are never split: one above-budget leaf = one bucket."""
    wtree = {"big": jnp.zeros((2, 1000)), "small": jnp.zeros((2, 2))}
    plan = plan_buckets(wtree, 16)
    assert [b.indices for b in plan.buckets] == [(1,), (0,)]
    assert plan.buckets[1].nbytes == 4000


def test_plan_buckets_single_bucket_when_budget_large():
    wtree = _wtree(jax.random.PRNGKey(0))
    plan = plan_buckets(wtree, 1 << 30)
    assert len(plan) == 1
    assert plan.buckets[0].indices == tuple(reversed(range(plan.n_leaves)))


def test_plan_buckets_aot_from_shapes():
    """Plans are buildable from eval_shape trees (no data movement)."""
    wtree = _wtree(jax.random.PRNGKey(0))
    shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), wtree
    )
    assert plan_buckets(shapes, 64) == plan_buckets(wtree, 64)


def test_plan_buckets_rejects_bad_budget():
    with pytest.raises(ValueError, match="bucket_bytes"):
        plan_buckets(_wtree(jax.random.PRNGKey(0)), 0)


# ---------------------------------------------------------------------------
# AsyncChannel: dense-mode contract on one device + the handle protocol
# ---------------------------------------------------------------------------


def test_async_channel_dense_bit_exact_vs_mesh():
    """Every Channel op, bit-exact against MeshChannel("dense") across
    bucket granularities — bucketing must change scheduling, not math."""
    key = jax.random.PRNGKey(11)
    wtree = _wtree(key)
    mesh_ch = MeshChannel(mode="dense")
    for q in (NaturalCompression(), RandK(0.5)):
        for budget in (1, 64, 1 << 30):
            a = AsyncChannel(mode="dense", bucket_bytes=budget)
            m_m, bar_m, b_m = mesh_ch.push_mean(q, key, wtree)
            m_a, bar_a, b_a = a.push_mean(q, key, wtree)
            jax.tree_util.tree_map(
                lambda x, y: np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y)
                ),
                (m_m, bar_m), (m_a, bar_a),
            )
            assert float(b_m) == float(b_a)


def test_async_channel_uplink_matches_base_channel():
    key = jax.random.PRNGKey(12)
    wtree = _wtree(key)
    q = NaturalCompression()
    m_s, b_s = SimChannel().uplink(q, key, wtree)
    m_a, b_a = AsyncChannel(mode="dense", bucket_bytes=64).uplink(q, key, wtree)
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)
        ),
        m_s, m_a,
    )
    assert float(b_s) == float(b_a)


def test_async_channel_handles_finish_any_order():
    """reduce_start issues one handle per bucket; reordered handles
    still reassemble the exact tree, and a dropped handle raises."""
    key = jax.random.PRNGKey(13)
    wtree = _wtree(key)
    ch = AsyncChannel(mode="dense", bucket_bytes=64)
    inflight = ch.reduce_start(key, wtree)
    assert len(inflight.handles) == len(plan_buckets(wtree, 64))
    assert all(isinstance(h, Handle) for h in inflight.handles)
    ref = ch.finish(inflight)
    shuffled = Inflight(
        inflight.treedef, inflight.n_leaves, tuple(inflight.handles[::-1])
    )
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)
        ),
        ref, ch.finish(shuffled),
    )
    partial = Inflight(
        inflight.treedef, inflight.n_leaves, tuple(inflight.handles[:-1])
    )
    with pytest.raises(ValueError, match="handles cover"):
        ch.finish(partial)


def test_async_channel_rejects_bad_config():
    with pytest.raises(ValueError, match="aggregation mode"):
        AsyncChannel(mode="carrier_pigeon")
    # a bad bucket budget fails at CONSTRUCTION, not in the first
    # jitted collective — and an explicit 0 is an error, not the default
    with pytest.raises(ValueError, match="bucket_bytes"):
        AsyncChannel(mode="dense", bucket_bytes=0)
    with pytest.raises(ValueError, match="bucket_bytes"):
        make_channel("q8_ring_overlap", bucket_bytes=-4096)
    # a bucket budget on a non-overlap channel would be silently
    # ignored — reject the meaningless combination at construction
    with pytest.raises(ValueError, match="bucket_bytes"):
        make_channel("q8_ring", bucket_bytes=1 << 20)


# ---------------------------------------------------------------------------
# comm-mode plumbing (satellites)
# ---------------------------------------------------------------------------


def test_make_channel_overlap_mode_and_config():
    ch = make_channel("q8_ring_overlap")
    assert isinstance(ch, AsyncChannel) and ch.mode == "q8_ring_fused"
    cfg = CompressionConfig(comm_mode="q8_ring_overlap",
                            overlap_bucket_bytes=12345)
    assert cfg.aggregation_mode == "q8_ring_fused"
    assert cfg.effective_shift_rule == "diana"  # overlap is transport-only
    ch = make_channel(cfg)
    assert isinstance(ch, AsyncChannel) and ch.bucket_bytes == 12345


def test_make_channel_sim_uniform_for_string_and_config():
    """'sim' selects the parameter-server channel whether it arrives as
    a mode string or inside a CompressionConfig (regression: the config
    path used to slip past the sim branch into MeshChannel validation)."""
    assert isinstance(make_channel("sim"), SimChannel)
    assert isinstance(
        make_channel(CompressionConfig(comm_mode="sim")), SimChannel
    )


def test_make_channel_rejects_unknown_mode_listing_modes():
    """A typo'd comm mode must fail AT CONSTRUCTION with the accepted
    modes in the message, not as a confusing downstream failure."""
    for bad in ("q8ring", "carrier_pigeon"):
        with pytest.raises(ValueError) as ei:
            make_channel(bad)
        for m in AGGREGATION_MODES:
            assert m in str(ei.value)
        assert "q8_ring_overlap" in str(ei.value)
    with pytest.raises(ValueError) as ei:
        make_channel(CompressionConfig(comm_mode="q8ring"))
    assert "q8ring" in str(ei.value)


def test_compressed_tree_mean_rejects_unknown_mode_listing_modes():
    from repro.dist.collectives import compressed_tree_mean

    with pytest.raises(ValueError) as ei:
        compressed_tree_mean({"a": jnp.ones((2, 4))}, "q8ring",
                             jax.random.PRNGKey(0))
    for m in AGGREGATION_MODES:
        assert m in str(ei.value)


# ---------------------------------------------------------------------------
# THE CONTRACT on the q8 ring + fused-ring accuracy (8 fake devices)
# ---------------------------------------------------------------------------


_CONTRACT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.comm import AsyncChannel, MeshChannel
    from repro.core.compressors import NaturalCompression

    mesh = jax.make_mesh((8, 1), ("data", "model"))
    key = jax.random.PRNGKey(0)
    w = 8
    tree = {"a": jax.random.normal(key, (w, 1000)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (w, 33)),
            "c": jax.random.normal(jax.random.fold_in(key, 2), (w,))}
    tree = jax.device_put(tree, NamedSharding(mesh, P("data")))

    mch = MeshChannel(mode="q8_ring", mesh=mesh)
    ach = AsyncChannel(mode="q8_ring", mesh=mesh, bucket_bytes=512)
    assert len(ach.reduce_start(key, tree).handles) > 1  # really bucketed

    # drained sync == MeshChannel, bit-exact
    rm = jax.jit(mch.reduce_mean)(key, tree)
    ra = jax.jit(ach.reduce_mean)(key, tree)
    jax.tree_util.tree_map(
        lambda p, q: np.testing.assert_array_equal(np.asarray(p),
                                                   np.asarray(q)), rm, ra)

    # the composed overlapped round too (messages, aggregate, bits)
    q = NaturalCompression()
    mm, rm2, bm = jax.jit(lambda k, t: mch.push_mean(q, k, t))(key, tree)
    ma, ra2, ba = jax.jit(lambda k, t: ach.push_mean(q, k, t))(key, tree)
    jax.tree_util.tree_map(
        lambda p, q_: np.testing.assert_array_equal(np.asarray(p),
                                                    np.asarray(q_)),
        (mm, rm2), (ma, ra2))
    assert float(bm) == float(ba)

    # the fused overlap mode stays within int8 tolerance of the exact mean
    ref = jax.tree.map(lambda a: jnp.mean(a, 0), tree)
    af = AsyncChannel(mode="q8_ring_fused", mesh=mesh, bucket_bytes=512)
    rf = jax.jit(af.reduce_mean)(key, tree)
    for k in tree:
        err = np.abs(np.asarray(rf[k]) - np.asarray(ref[k])).max()
        scale = np.abs(np.asarray(ref[k])).max() + 1.0
        assert err < 0.06 * scale, (k, err, scale)
    print("CONTRACT_OK")
""")


def test_async_channel_q8_ring_contract_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _CONTRACT],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=_REPO_ROOT,
    )
    assert "CONTRACT_OK" in r.stdout, r.stdout + r.stderr[-3000:]


_AWKWARD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=5"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.compressors import Int8Stochastic
    from repro.dist.collectives import q8_ring_tree_mean
    from repro.kernels.q8ring.ops import FusedQ8

    # odd world size; leaf sizes not divisible by lanes or world size;
    # a scalar-per-worker leaf
    mesh = jax.make_mesh((5,), ("data",))
    key = jax.random.PRNGKey(0)
    w = 5
    tree = {"a": jax.random.normal(key, (w, 777)),
            "s": jax.random.normal(jax.random.fold_in(key, 1), (w,)),
            "m": jax.random.normal(jax.random.fold_in(key, 2), (w, 13, 3))}
    tree = jax.device_put(tree, NamedSharding(mesh, P("data")))
    ref = jax.tree.map(lambda a: jnp.mean(a, 0), tree)

    outs = {}
    for name, codec in (("unfused", Int8Stochastic()), ("fused", FusedQ8())):
        out = jax.jit(lambda k, t: q8_ring_tree_mean(
            k, t, mesh, worker_axes=("data",), pod_axis=None,
            codec=codec))(key, tree)
        outs[name] = out
        for k in tree:
            err = np.abs(np.asarray(out[k]) - np.asarray(ref[k])).max()
            scale = np.abs(np.asarray(ref[k])).max() + 1.0
            assert err < 0.06 * scale, (name, k, err, scale)
    # fused vs unfused agree within int8 quantization tolerance
    for k in tree:
        d = np.abs(np.asarray(outs["fused"][k])
                   - np.asarray(outs["unfused"][k])).max()
        scale = np.abs(np.asarray(ref[k])).max() + 1.0
        assert d < 0.1 * scale, (k, d, scale)
    print("AWKWARD_OK")
""")


def test_q8_ring_awkward_shapes_odd_workers_subprocess():
    """Satellite: fused vs unfused q8 ring on leaf sizes not divisible
    by the lane/world size, scalar leaves, and an ODD worker count."""
    r = subprocess.run(
        [sys.executable, "-c", _AWKWARD],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=_REPO_ROOT,
    )
    assert "AWKWARD_OK" in r.stdout, r.stdout + r.stderr[-3000:]


_OVERLAP_CLI = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.launch.train import main
    state = main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "2",
                  "--batch", "8", "--seq", "32",
                  "--compressor", "natural", "--comm_mode",
                  "q8_ring_overlap"])
    assert np.isfinite(float(state.bits)) and float(state.bits) > 0
    print("OVERLAP_CLI_OK")
""")


def test_train_cli_q8_ring_overlap_8dev_subprocess():
    """--comm_mode q8_ring_overlap end-to-end through the train CLI on 8
    fake devices (the acceptance path for the overlapped runtime)."""
    r = subprocess.run(
        [sys.executable, "-c", _OVERLAP_CLI],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=_REPO_ROOT,
    )
    assert "OVERLAP_CLI_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
