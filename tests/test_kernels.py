"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweeps
with assert_allclose against the pure-jnp oracles (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.natural.kernel import shifted_natural_2d
from repro.kernels.natural.ops import shifted_natural
from repro.kernels.natural.ref import shifted_natural_ref
from repro.kernels.q8ring.kernel import (
    q8_dequant_add_2d,
    q8_quantize_2d,
    q8_quantize_chunk_3d,
)
from repro.kernels.q8ring.ops import FusedQ8
from repro.kernels.q8ring.ref import q8_dequant_add_ref, q8_quantize_ref
from repro.kernels.topk.kernel import block_topk_2d
from repro.kernels.topk.ops import block_topk
from repro.kernels.topk.ref import block_topk_ref
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref
from repro.models.rwkv6 import wkv_scan


# ---------------------------------------------------------------------------
# shifted natural compression
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,block", [(256, 256), (512, 256), (64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_shifted_natural_matches_ref(rows, block, dtype):
    key = jax.random.PRNGKey(0)
    kg, kh, ku = jax.random.split(key, 3)
    g = jax.random.normal(kg, (rows, 128), jnp.float32).astype(dtype)
    h = jax.random.normal(kh, (rows, 128), jnp.float32).astype(dtype)
    u = jax.random.uniform(ku, (rows, 128), jnp.float32)
    out = shifted_natural_2d(g, h, u, block_rows=block)
    ref = shifted_natural_ref(g, h, u)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=1e-5, atol=1e-5,
    )


@pytest.mark.parametrize("shape", [(100,), (33, 7), (5, 4, 3, 2), (8192,)])
def test_shifted_natural_arbitrary_shapes(shape):
    key = jax.random.PRNGKey(1)
    g = jax.random.normal(key, shape, jnp.float32)
    h = jnp.zeros(shape, jnp.float32)
    out = shifted_natural(key, g, h)
    assert out.shape == shape
    # with h=0 the output is natural compression: |out| in {0, 2^e, 2^{e+1}}
    nz = np.asarray(out).ravel()
    nz = nz[nz != 0]
    lg = np.log2(np.abs(nz))
    np.testing.assert_allclose(lg, np.round(lg), atol=1e-6)


def test_shifted_natural_unbiased():
    """Monte-Carlo unbiasedness of the kernel as a U(1/8) member."""
    g = jnp.asarray([0.3, -1.7, 5.0, 0.011] * 32, jnp.float32)
    h = jnp.asarray([0.1, -1.0, 4.0, 0.0] * 32, jnp.float32)
    outs = []
    for i in range(512):
        outs.append(shifted_natural(jax.random.PRNGKey(i), g, h))
    mean = np.mean(np.stack(outs), axis=0)
    np.testing.assert_allclose(mean, np.asarray(g), rtol=0.05, atol=0.01)


# ---------------------------------------------------------------------------
# block top-k
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,block,k", [(64, 64, 128), (128, 64, 64),
                                          (256, 64, 819), (64, 64, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_topk_matches_ref(rows, block, k, dtype):
    x = jax.random.normal(jax.random.PRNGKey(2), (rows, 128), jnp.float32)
    x = x.astype(dtype)
    out = block_topk_2d(x, k=k, block_rows=block)
    ref = block_topk_ref(x, k=k, block=block)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=1e-6, atol=1e-6,
    )


@pytest.mark.parametrize("q", [0.01, 0.1, 0.5])
def test_block_topk_keep_fraction(q):
    x = jax.random.normal(jax.random.PRNGKey(3), (100_000,), jnp.float32)
    out = np.asarray(block_topk(x, q=q))
    frac = (out != 0).mean()
    assert abs(frac - q) < 0.02, (frac, q)
    # kept values are exactly the input values (no scaling: biased operator)
    kept = out != 0
    np.testing.assert_array_equal(out[kept], np.asarray(x)[kept])


def test_block_topk_contraction():
    """E||C(x)-x||^2 <= (1-delta)||x||^2 with delta = q (per block)."""
    for seed in range(5):
        x = jax.random.normal(jax.random.PRNGKey(seed), (8192,), jnp.float32)
        out = np.asarray(block_topk(x, q=0.2))
        xn = np.asarray(x)
        err = np.sum((out - xn) ** 2)
        assert err <= (1 - 0.2) * np.sum(xn**2) + 1e-4


# ---------------------------------------------------------------------------
# fused q8 ring (quantize + chunk-select + dequant-accumulate)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,block", [(8, 8), (64, 8), (64, 64), (96, 32),
                                        (1, 1)])
def test_q8_quantize_matches_ref(rows, block):
    x = jax.random.normal(jax.random.PRNGKey(0), (rows, 128)) * 3.0
    u = jax.random.uniform(jax.random.PRNGKey(1), (rows, 128))
    q, s = q8_quantize_2d(x, u, block_rows=block)
    qr, sr = q8_quantize_ref(x, u, block=block)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_q8_quantize_chunk_select_matches_2d():
    """The scalar-prefetch chunk variant (the fused ring-hop gather)
    equals quantizing the sliced chunk — for static AND traced ids."""
    chunks = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 128))
    u = jax.random.uniform(jax.random.PRNGKey(3), (16, 128))
    for cid in range(4):
        q, s = q8_quantize_chunk_3d(chunks, u, cid, block_rows=8)
        qr, sr = q8_quantize_2d(chunks[cid], u, block_rows=8)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    qt, st = jax.jit(
        lambda c, u_, i: q8_quantize_chunk_3d(c, u_, i, block_rows=8)
    )(chunks, u, jnp.int32(3))
    qr, sr = q8_quantize_2d(chunks[3], u, block_rows=8)
    np.testing.assert_array_equal(np.asarray(qt), np.asarray(qr))


def test_q8_dequant_add_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(4), (32, 128)) * 2.0
    u = jax.random.uniform(jax.random.PRNGKey(5), (32, 128))
    acc = jax.random.normal(jax.random.PRNGKey(6), (32, 128))
    q, s = q8_quantize_2d(x, u, block_rows=8)
    out = q8_dequant_add_2d(q, s, acc, block_rows=8)
    ref = q8_dequant_add_ref(q, s, acc, block=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    # quantization is tight: |dequant - x| <= one lattice step per tile
    err = np.abs(np.asarray(out - acc) - np.asarray(x))
    step = np.repeat(np.asarray(s)[:, 0], 8)[:, None]
    assert (err <= step + 1e-7).all()


def test_q8_quantize_unbiased():
    """Monte-Carlo unbiasedness of the stochastic rounding (the codec
    must stay a U(omega) member for the DIANA step-size theory)."""
    x = jnp.asarray([0.3, -1.7, 5.0, 0.011] * 32, jnp.float32).reshape(1, 128)
    outs = []
    for i in range(512):
        u = jax.random.uniform(jax.random.PRNGKey(i), x.shape)
        q, s = q8_quantize_2d(x, u, block_rows=1)
        outs.append(np.asarray(q, np.float32) * np.asarray(s)[0, 0])
    mean = np.mean(np.stack(outs), axis=0)
    np.testing.assert_allclose(mean, np.asarray(x), rtol=0.05, atol=0.01)


@pytest.mark.parametrize("shape", [(100,), (33, 7), (5, 4, 3, 2), (8192,),
                                   (), (1,)])
def test_fused_q8_codec_roundtrip_arbitrary_shapes(shape):
    """FusedQ8 decode(encode(x)) stays within one blockwise lattice step
    of x on any shape (incl. scalars) — and the payload is honest int8."""
    x = jax.random.normal(jax.random.PRNGKey(7), shape, jnp.float32) * 2.0
    c = FusedQ8()
    payload, meta = c.encode(jax.random.PRNGKey(8), x)
    assert payload["q"].dtype == jnp.int8
    assert not jax.tree_util.tree_leaves(meta)  # meta-free: may ride rings
    out = c.decode(payload, meta, jax.ShapeDtypeStruct(x.shape, x.dtype))
    assert out.shape == x.shape and out.dtype == x.dtype
    if x.size:
        bound = np.abs(np.asarray(x)).max() / 127.0 + 1e-6
        assert np.abs(np.asarray(out) - np.asarray(x)).max() <= bound


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,t,h,dk,dv,chunk", [
    (2, 64, 2, 64, 64, 32),
    (1, 128, 4, 64, 64, 128),
    (2, 96, 1, 32, 64, 32),      # rectangular K != V
    (1, 32, 2, 16, 16, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_matches_ref(b, t, h, dk, dv, chunk, dtype):
    keys = jax.random.split(jax.random.PRNGKey(4), 5)
    r = jax.random.normal(keys[0], (b, t, h, dk), jnp.float32).astype(dtype)
    k = jax.random.normal(keys[1], (b, t, h, dk), jnp.float32).astype(dtype)
    v = jax.random.normal(keys[2], (b, t, h, dv), jnp.float32).astype(dtype)
    # realistic decay range: w = exp(-exp(x)) in (0,1)
    w = jnp.exp(-jnp.exp(
        jax.random.normal(keys[3], (b, t, h, dk), jnp.float32)
    )).astype(dtype)
    u = jax.random.normal(keys[4], (h, dk), jnp.float32)

    y, s = wkv6(r, k, v, w, u, chunk=chunk)

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, t, x.shape[-1])
    ub = jnp.broadcast_to(u[None], (b, h, dk)).reshape(b * h, dk)
    y_ref, s_ref = wkv6_ref(to_bh(r), to_bh(k), to_bh(v), to_bh(w), ub)
    y_ref = y_ref.reshape(b, h, t, dv).transpose(0, 2, 1, 3)
    s_ref = s_ref.reshape(b, h, dk, dv)

    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=tol, atol=tol)


def test_wkv6_matches_model_scan():
    """Kernel == the model's wkv_scan (same math, different code path)."""
    b, t, h, d = 2, 64, 2, 64
    keys = jax.random.split(jax.random.PRNGKey(5), 5)
    r = jax.random.normal(keys[0], (b, t, h, d))
    k = jax.random.normal(keys[1], (b, t, h, d))
    v = jax.random.normal(keys[2], (b, t, h, d))
    w = jnp.exp(-jnp.exp(jax.random.normal(keys[3], (b, t, h, d))))
    u = jax.random.normal(keys[4], (h, d))
    y_kernel, s_kernel = wkv6(r, k, v, w, u, chunk=32)
    y_model, s_model = wkv_scan(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_kernel), np.asarray(s_model),
                               rtol=1e-4, atol=1e-4)


def test_wkv6_chunk_invariance():
    """Chunk size must not change the result (state carry across chunks)."""
    b, t, h, d = 1, 128, 2, 32
    keys = jax.random.split(jax.random.PRNGKey(6), 5)
    r = jax.random.normal(keys[0], (b, t, h, d))
    k = jax.random.normal(keys[1], (b, t, h, d))
    v = jax.random.normal(keys[2], (b, t, h, d))
    w = jnp.exp(-jnp.exp(jax.random.normal(keys[3], (b, t, h, d))))
    u = jax.random.normal(keys[4], (h, d))
    y1, s1 = wkv6(r, k, v, w, u, chunk=128)
    y2, s2 = wkv6(r, k, v, w, u, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-5)
