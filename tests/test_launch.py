"""Launch-layer integration: the production train step (all shift rules
and comm modes, routed through the Channel) trains a tiny LM on one
host; the EF21 comm mode also runs through the train CLI on 8 fake
devices."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import CompressionConfig, TrainConfig
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_host_mesh, n_workers
from repro.launch.train import build_train_step, init_state

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _train(comp: CompressionConfig, steps=100, lr=1e-2):
    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    tcfg = TrainConfig(learning_rate=lr, total_steps=steps, warmup_steps=2,
                       compression=comp)
    mesh = make_host_mesh()
    w = n_workers(mesh)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
    step = jax.jit(build_train_step(cfg, tcfg, mesh, w))
    stream = TokenStream(cfg, 64, 4)
    losses = []
    for i in range(steps):
        state, metrics = step(state, stream.batch(i))
        losses.append(float(metrics["loss"]))
    return losses, state


@pytest.mark.parametrize("rule", ["fixed", "diana", "rand_diana", "efbv"])
def test_train_step_rules_learn(rule):
    losses, state = _train(CompressionConfig(
        enabled=True, compressor="natural", shift_rule=rule))
    assert np.isfinite(losses).all(), losses[-5:]
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.02, (
        rule, losses[:3], losses[-3:])
    assert float(state.bits) > 0


def test_train_step_dense_baseline():
    losses, _ = _train(CompressionConfig(enabled=False))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.02


def test_vr_gdci_trains():
    """Algorithm 2 (compressed iterates) on the LM — the model-broadcast
    direction of the paper."""
    losses, state = _train(
        CompressionConfig(enabled=True, compressor="natural",
                          shift_rule="vr_gdci", shift_alpha=0.5,
                          gdci_eta=0.9),
        steps=150, lr=0.2,   # RAW SGD direction: needs SGD-scale gamma
    )
    assert np.isfinite(losses).all()
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.015, (
        losses[:3], losses[-3:])


def test_ef21_comm_mode_trains():
    """The ef21 comm mode (error feedback with a contractive TopK codec)
    learns on the LM; comm_mode alone selects the rule."""
    losses, state = _train(CompressionConfig(
        enabled=True, compressor="topk", compressor_kwargs=(("q", 0.25),),
        comm_mode="ef21"))
    assert np.isfinite(losses).all(), losses[-5:]
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.02, (
        losses[:3], losses[-3:])
    assert float(state.bits) > 0
    # shifts are live: EF21 integrates every message into h
    assert state.h is not None
    assert float(jnp.sum(jnp.abs(jax.tree_util.tree_leaves(state.h)[0]))) > 0


_EF21_CLI = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.launch.train import main
    state = main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "2",
                  "--batch", "8", "--seq", "32",
                  "--compressor", "topk", "--comm_mode", "ef21"])
    assert np.isfinite(float(state.bits)) and float(state.bits) > 0
    assert state.h is not None  # EF21 shift state allocated (8 workers)
    import jax
    assert jax.tree_util.tree_leaves(state.h)[0].shape[0] == 8
    print("EF21_CLI_OK")
""")


def test_train_cli_ef21_8dev_subprocess():
    """--comm_mode ef21 end-to-end through the train CLI on 8 fake
    devices (the acceptance path for the error-feedback comm mode)."""
    r = subprocess.run(
        [sys.executable, "-c", _EF21_CLI],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=_REPO_ROOT,
    )
    assert "EF21_CLI_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_diana_matches_dense_direction():
    """With an Identity compressor, DIANA's estimator equals the plain
    mean gradient (g_bar = h_bar + mean(g - h)) — the launch path must be
    EXACTLY dense-SGD-equivalent then."""
    losses_id, _ = _train(CompressionConfig(
        enabled=True, compressor="identity", shift_rule="diana"), steps=40)
    losses_dn, _ = _train(CompressionConfig(enabled=False), steps=40)
    # f32 reassociation drifts slowly; exact up to accumulated rounding
    np.testing.assert_allclose(losses_id, losses_dn, rtol=2e-3, atol=2e-3)
