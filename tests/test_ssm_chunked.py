"""Chunked vs sequential parity for the recurrent cores (§Perf-1):
the SSD matmul form and the unrolled-chunk WKV must be numerically
equivalent to the exact per-step scans."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import _ssd_chunked, _ssd_scan


def _inputs(b=2, t=256, h=4, p=16, n=8, seed=0, dt_scale=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, t, h, p), jnp.float32)
    bt = jax.random.normal(ks[1], (b, t, n), jnp.float32)
    ct = jax.random.normal(ks[2], (b, t, n), jnp.float32)
    dt = jax.nn.softplus(
        jax.random.normal(ks[3], (b, t, h), jnp.float32) * dt_scale - 2.0
    )
    a_log = jnp.log(jnp.linspace(1.0, 16.0, h))
    d_skip = jax.random.normal(ks[4], (h,), jnp.float32)
    s0 = jnp.zeros((b, h, n, p), jnp.float32)
    return x, bt, ct, dt, a_log, d_skip, s0


@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_ssd_chunked_matches_scan(chunk):
    args = _inputs()
    y_ref, s_ref = _ssd_scan(*args)
    y_c, s_c = _ssd_chunked(*args, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_extreme_decay_no_overflow():
    """Huge data-dependent dt (strong decay) must neither overflow nor
    lose parity — the clamped factored form's design constraint."""
    args = _inputs(dt_scale=8.0, seed=3)
    y_ref, s_ref = _ssd_scan(*args)
    y_c, s_c = _ssd_chunked(*args, chunk=64)
    assert np.isfinite(np.asarray(y_c)).all()
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)


def test_ssd_chunked_gradient_parity():
    args = _inputs(t=128)

    def loss_chunked(x):
        y, _ = _ssd_chunked(x, *args[1:], chunk=32)
        return jnp.sum(y**2)

    def loss_scan(x):
        y, _ = _ssd_scan(x, *args[1:])
        return jnp.sum(y**2)

    g_c = jax.grad(loss_chunked)(args[0])
    g_s = jax.grad(loss_scan)(args[0])
    np.testing.assert_allclose(np.asarray(g_c), np.asarray(g_s),
                               rtol=5e-3, atol=5e-3)
