"""Theorem-level integration tests: each convergence guarantee of the paper
is checked empirically on the ridge problem in the regime it covers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DCGDShift,
    DianaShift,
    FixedShift,
    GDCI,
    Identity,
    RandDianaShift,
    RandK,
    StarShift,
    TopK,
    VRGDCI,
    rand_diana_default_p,
    stepsize_dcgd_fixed,
    stepsize_dcgd_star,
    stepsize_diana,
    stepsize_gdci,
    stepsize_rand_diana,
    stepsize_vr_gdci,
)
from repro.core.simulate import run_dcgd_shift, run_gdci
from repro.data.problems import make_logreg, make_ridge


@pytest.fixture(scope="module")
def prob():
    # Conditioned for decisive theorem measurements (the paper-exact
    # instance lives in test_theorems):
    #  * noise=10 — non-interpolating regime; with noise=0, grad_i(x*)
    #    is lam-residual-only and the DCGD variance neighborhood that
    #    Theorem 1 measures collapses to the 1e-7 float32 knife edge.
    #  * lam=0.3 — at lam=1/m the self-noise coupling
    #    gamma*omega*L_bar^2/(2*mu*n) is ~0.57 at Theorem 1's max
    #    stepsize, so the neighborhood radius scales ~2x (not ~4x) when
    #    gamma drops 4x; a modestly larger mu restores the
    #    linear-in-gamma radius the gamma/4 assertion checks while
    #    keeping kappa ~150 (much larger lam over-conditions the
    #    problem and the exactness tests bottom out at the f32 floor
    #    before their "still contracting" windows sample).
    return make_ridge(lam=0.3, noise=10.0)


@pytest.fixture(scope="module")
def q():
    return RandK(0.25)


def test_uncompressed_gd_is_exact(prob):
    """Sanity: Q = Identity, zero shift == plain distributed GD."""
    tr = run_dcgd_shift(
        prob, DCGDShift(Identity(), FixedShift()), 1.0 / prob.L, 2000
    )
    assert tr.rel_err[-1] < 1e-9


def test_theorem1_dcgd_neighborhood(prob, q):
    """Thm 1: DCGD converges to a gamma-proportional neighborhood."""
    om = q.omega(prob.d)
    g = stepsize_dcgd_fixed(prob.L, prob.L_max, om, prob.n_workers)
    tr_full = run_dcgd_shift(prob, DCGDShift(q, FixedShift()), g, 4000, seed=1)
    tr_half = run_dcgd_shift(prob, DCGDShift(q, FixedShift()), g / 4, 16000, seed=1)
    tail_full = float(np.median(tr_full.rel_err[-500:]))
    tail_half = float(np.median(tr_half.rel_err[-500:]))
    assert tail_full > 1e-7  # genuinely stuck in a neighborhood
    # Thm 1: radius scales ~ gamma => gamma/4 shrinks it ~4x (allow slack 2x)
    assert tail_half < tail_full / 2.0


def test_theorem2_dcgd_star_exact(prob, q):
    """Thm 2: oracle shifts give exact linear convergence."""
    om = q.omega(prob.d)
    g = stepsize_dcgd_star(prob.L, prob.L_max, om, 0.0, prob.n_workers)
    tr = run_dcgd_shift(
        prob, DCGDShift(q, StarShift()), g, 6000, use_star=True, seed=2
    )
    assert tr.rel_err[-1] < 5e-5
    # linearity: log error decreases roughly monotonically (windowed)
    w = tr.rel_err[::500]
    assert all(w[i + 1] < w[i] for i in range(len(w) - 2))


def test_theorem2_star_with_biased_c(prob, q):
    """Thm 2 with contractive C_i (Top-K) in the shift update still exact."""
    om = q.omega(prob.d)
    c = TopK(0.5)
    g = stepsize_dcgd_star(prob.L, prob.L_max, om, c.delta(prob.d), prob.n_workers)
    tr = run_dcgd_shift(
        prob, DCGDShift(q, StarShift(c=c)), g, 6000, use_star=True, seed=3
    )
    assert tr.rel_err[-1] < 5e-4


def test_theorem3_diana_exact(prob, q):
    om = q.omega(prob.d)
    alpha, g = stepsize_diana(prob.L_max, om, 0.0, prob.n_workers)
    tr = run_dcgd_shift(prob, DCGDShift(q, DianaShift(alpha)), g, 12000, seed=4)
    assert tr.rel_err[-1] < 1e-4


def test_theorem3_generalized_diana_with_topk(prob, q):
    """Generalized DIANA: biased C_i in the shift update (eq. 10)."""
    om = q.omega(prob.d)
    c = TopK(0.5)
    alpha, g = stepsize_diana(prob.L_max, om, c.delta(prob.d), prob.n_workers)
    tr = run_dcgd_shift(
        prob, DCGDShift(q, DianaShift(alpha, c=c)), g, 12000, seed=5
    )
    assert tr.rel_err[-1] < 1e-4


def test_theorem4_rand_diana_exact(prob, q):
    om = q.omega(prob.d)
    p = rand_diana_default_p(om)
    _, g = stepsize_rand_diana(prob.L_max, om, prob.n_workers, p)
    tr = run_dcgd_shift(prob, DCGDShift(q, RandDianaShift(p)), g, 12000, seed=6)
    assert tr.rel_err[-1] < 1e-3
    # exactness: keeps contracting through late training (no variance floor)
    assert float(np.median(tr.rel_err[-1000:])) < float(
        np.median(tr.rel_err[5000:6000])
    )


def test_theorem5_gdci_neighborhood(prob, q):
    om = q.omega(prob.d)
    eta, gamma = stepsize_gdci(prob.L, prob.L_max, prob.mu, om, prob.n_workers)
    m = GDCI(q, gamma=gamma, eta=eta)
    tr = run_gdci(prob, m, 6000, seed=7)
    tail = float(np.median(tr.rel_err[-500:]))
    assert tail < 1e-1       # converged to the neighborhood...
    assert tail > 1e-9       # ...but not exactly (non-interpolation regime)


def test_theorem6_vr_gdci_exact(prob, q):
    om = q.omega(prob.d)
    alpha, eta, gamma = stepsize_vr_gdci(
        prob.L, prob.L_max, prob.mu, om, prob.n_workers
    )
    m = VRGDCI(q, gamma=gamma, eta=eta, alpha=alpha)
    tr = run_gdci(prob, m, 20000, seed=8)
    assert tr.rel_err[-1] < 1e-4
    # VR eliminates the GDCI neighborhood:
    eta_g, gamma_g = stepsize_gdci(prob.L, prob.L_max, prob.mu, om, prob.n_workers)
    tr_g = run_gdci(prob, GDCI(q, gamma=gamma_g, eta=eta_g), 20000, seed=8)
    assert tr.rel_err[-1] < float(np.median(tr_g.rel_err[-500:]))


def test_diana_beats_dcgd_in_bits():
    """The headline practical claim: shift learning reaches tighter
    tolerances than plain DCGD, which stalls at its variance radius.
    Uses a noisy (non-interpolating) problem and aggressive compression
    (Rand-K, q=0.05) so the DCGD radius is well above the float32 floor."""
    prob = make_ridge(noise=10.0, seed=5)
    q = RandK(0.05)
    om = q.omega(prob.d)
    alpha, g_d = stepsize_diana(prob.L_max, om, 0.0, prob.n_workers)
    g_f = stepsize_dcgd_fixed(prob.L, prob.L_max, om, prob.n_workers)
    tr_diana = run_dcgd_shift(prob, DCGDShift(q, DianaShift(alpha)), g_d, 20000)
    tr_dcgd = run_dcgd_shift(prob, DCGDShift(q, FixedShift()), g_f, 20000)
    dcgd_tail = float(np.median(tr_dcgd.rel_err[-2000:]))
    diana_tail = float(np.median(tr_diana.rel_err[-2000:]))
    assert dcgd_tail > 1e-7        # DCGD stuck in its neighborhood
    assert diana_tail < dcgd_tail  # DIANA breaks through it


def test_logreg_problem_wellformed():
    prob = make_logreg(m=200, d=40)
    g = prob.full_grad(prob.x_star)
    assert float(jnp.linalg.norm(g)) < 1e-5
    assert abs(prob.kappa - 100.0) < 5.0
    wg = prob.worker_grads(prob.x_star)
    assert wg.shape == (10, 40)
    np.testing.assert_allclose(
        np.asarray(jnp.mean(wg, axis=0)), np.asarray(g), atol=1e-5
    )


def test_rand_diana_on_logreg():
    prob = make_logreg(m=200, d=40)
    q = RandK(0.25)
    om = q.omega(prob.d)
    p = rand_diana_default_p(om)
    _, g = stepsize_rand_diana(prob.L_max, om, prob.n_workers, p)
    tr = run_dcgd_shift(prob, DCGDShift(q, RandDianaShift(p)), g, 15000, seed=9)
    assert tr.rel_err[-1] < 1e-2
