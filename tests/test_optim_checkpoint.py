"""Optimizer + checkpoint + data-pipeline tests."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.data.tokens import TokenStream, synth_batch
from repro.optim import adamw, cosine_schedule, make_optimizer, sgd


def _quad_params():
    return {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(5.0)}


def _quad_grad(p):
    return {"w": 2 * p["w"], "b": 2 * p["b"]}  # f = ||w||^2 + b^2


def test_sgd_converges():
    opt = sgd(lr=0.1)
    p = _quad_params()
    st = opt.init(p)
    for _ in range(100):
        p, st = opt.update(_quad_grad(p), st, p)
    assert float(jnp.abs(p["w"]).max()) < 1e-6
    assert abs(float(p["b"])) < 1e-6


def test_adamw_converges():
    opt = adamw(lr=0.05, weight_decay=0.0)
    p = _quad_params()
    st = opt.init(p)
    for _ in range(400):
        p, st = opt.update(_quad_grad(p), st, p)
    assert float(jnp.abs(p["w"]).max()) < 1e-2


def test_adamw_bf16_params_f32_moments():
    opt = adamw(lr=1e-3)
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = opt.init(p)
    assert st.m["w"].dtype == jnp.float32
    p2, st2 = opt.update({"w": jnp.ones((4,), jnp.bfloat16)}, st, p)
    assert p2["w"].dtype == jnp.bfloat16


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=110)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(lr(jnp.asarray(110))) <= 0.11
    # monotone decay after warmup
    vals = [float(lr(jnp.asarray(s))) for s in range(10, 111, 10)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


def test_checkpoint_roundtrip():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                   "c": jnp.asarray(7, jnp.int32)},
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        save(path, tree, step=42)
        like = jax.tree.map(lambda a: jnp.zeros_like(a), tree)
        out = restore(path, like)
    for k1, v in (("a", tree["a"]),):
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(v))
    np.testing.assert_array_equal(
        np.asarray(out["nested"]["b"], np.float32),
        np.asarray(tree["nested"]["b"], np.float32),
    )


def test_checkpoint_shape_mismatch_raises():
    tree = {"a": jnp.ones((2, 3))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        save(path, tree)
        like = {"a": jnp.ones((3, 2))}
        with pytest.raises(ValueError):
            restore(path, like)


def test_token_stream_deterministic_and_host_sharded():
    cfg = get_smoke_config("qwen3-0.6b")
    s1 = TokenStream(cfg, 32, 8, seed=1)
    s2 = TokenStream(cfg, 32, 8, seed=1)
    np.testing.assert_array_equal(
        np.asarray(s1.batch(3)["tokens"]), np.asarray(s2.batch(3)["tokens"])
    )
    # different steps differ
    assert not np.array_equal(
        np.asarray(s1.batch(0)["tokens"]), np.asarray(s1.batch(1)["tokens"])
    )
    # host sharding: two hosts cover the batch without coordination
    h0 = TokenStream(cfg, 32, 8, seed=1, host_index=0, host_count=2)
    h1 = TokenStream(cfg, 32, 8, seed=1, host_index=1, host_count=2)
    assert h0.local_batch == 4
    assert not np.array_equal(
        np.asarray(h0.batch(0)["tokens"]), np.asarray(h1.batch(0)["tokens"])
    )


def test_synth_batch_learnable_structure():
    """Tokens follow the Markov rule so a model CAN learn them."""
    cfg = get_smoke_config("qwen3-0.6b")
    b = synth_batch(jax.random.PRNGKey(0), cfg, 64, 4)
    toks = np.asarray(b["tokens"])
    v = cfg.vocab_size
    # next token is a deterministic-ish function of prev: verify the rule
    # x_{t+1} = (31 x_t + n_t) % v with n_t < 97
    diffs = (toks[:, 1:] - 31 * toks[:, :-1]) % v
    assert (diffs < 97).all()


def test_modality_stubs():
    vlm = get_smoke_config("llava-next-34b")
    b = synth_batch(jax.random.PRNGKey(0), vlm, 64, 2)
    assert b["prefix"].shape == (2, vlm.num_prefix_tokens, vlm.d_model)
    assert b["tokens"].shape[1] == 64 - vlm.num_prefix_tokens
    audio = get_smoke_config("seamless-m4t-large-v2")
    b = synth_batch(jax.random.PRNGKey(0), audio, 32, 2)
    assert b["frames"].shape == (2, 32, audio.d_model)
