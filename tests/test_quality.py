"""The theory observatory: measured omega / shift-residual probes, the
bench history ledger, and the regression gate.

Two kinds of pins live here:

* **theorem-style** — the measured ``omega_hat`` agrees with the
  analytic U(omega) certificate where the certificate is EXACT (RandK's
  ``d/K - 1`` is an equality in expectation for any input) and stays
  UNDER it where the certificate is a worst-case bound (int8
  stochastic rounding, natural compression); and the shift residual
  ``||g - h||^2`` decays under DIANA / EF-BV while plain DCGD keeps it
  pinned at the gradient norm — the paper's headline effect, observed
  on the observability surface instead of assumed.
* **plumbing** — the wire-level quality probe, the history ledger's
  sha x fingerprint keying, and the regress gate's per-class tolerance
  bands with their exit codes.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, tune
from repro.core.algorithms import DCGDShift
from repro.core.compressors import (
    Identity,
    Int8Stochastic,
    NaturalCompression,
    RandK,
)
from repro.core.shift_rules import (
    DianaShift,
    EFBVShift,
    FixedShift,
    residual_sq_diag,
)
from repro.data.problems import make_ridge
from repro.obs import history, regress
from repro.obs.quality import tree_distortion

tmap = jax.tree_util.tree_map


def _wtree_like(w=4, d=2000):
    return {"a": jax.ShapeDtypeStruct((w, d), jnp.float32),
            "b": jax.ShapeDtypeStruct((w, d // 2), jnp.float32)}


# ---------------------------------------------------------------------------
# omega_hat vs the analytic certificate (satellite: property tests)
# ---------------------------------------------------------------------------


def test_randk_omega_hat_matches_exact_certificate():
    """RandK's ``omega(d) = d/K - 1`` is an EQUALITY in expectation for
    any input, so the measured ratio must converge to it — the one codec
    where measured-vs-analytic is a tight property, not an inequality."""
    like = _wtree_like()
    q = RandK(0.05)
    analytic = tune.estimate_omega(q, like)
    m = tune.measure_omega(q, like, iters=4)
    assert m.source == "measured"
    assert m.omega_hat == pytest.approx(analytic, rel=0.1)
    # the global NMSE of an exact-variance sparsifier sits at the same
    # scale (it is the norm-weighted rather than d-weighted mean)
    assert m.nmse == pytest.approx(analytic, rel=0.15)


@pytest.mark.parametrize("codec", [Int8Stochastic(), NaturalCompression()])
def test_quantizer_omega_hat_within_certificate_bound(codec):
    """int8 / natural omegas are worst-case BOUNDS, not expectations:
    on Gaussian traffic the realized variance sits far below (int8:
    ~400x — the bound charges the max-scale corner).  The property is
    the certificate itself: ``0 < omega_hat <= omega``."""
    like = _wtree_like()
    bound = tune.estimate_omega(codec, like)
    m = tune.measure_omega(codec, like, iters=2)
    assert 0.0 < m.omega_hat <= bound
    assert 0.0 < m.nmse <= bound


def test_identity_omega_hat_is_zero():
    m = tune.measure_omega(Identity(), _wtree_like(), iters=1)
    assert m.omega_hat == 0.0 and m.nmse == 0.0


def test_tree_distortion_jits_and_rejects_empty():
    q = NaturalCompression()
    wtree = {"a": jax.random.normal(jax.random.PRNGKey(0), (3, 64))}
    fn = jax.jit(lambda k, t: tree_distortion(q, k, t))
    out = fn(jax.random.PRNGKey(1), wtree)
    assert float(out["omega_hat"]) > 0.0
    assert float(out["err_sq"]) > 0.0 and float(out["norm_sq"]) > 0.0
    with pytest.raises(ValueError, match="empty tree"):
        tree_distortion(q, jax.random.PRNGKey(0), {})


# ---------------------------------------------------------------------------
# The shift residual ||g - h||^2: decays under DIANA/EF-BV, flat under
# plain DCGD (theorem-style, on the ridge fixture)
# ---------------------------------------------------------------------------


def _residual_trajectory(rule, steps=400, gamma=None, seed=0):
    prob = make_ridge(lam=0.3, noise=10.0)
    q = RandK(0.25)
    gamma = gamma if gamma is not None else 0.25 / prob.L
    alg = DCGDShift(q, rule)
    x0 = jnp.zeros((prob.d,), prob.x_star.dtype)
    state0 = alg.init(prob.worker_grads(x0), seed=seed)

    def body(carry, _):
        x, st = carry
        wg = prob.worker_grads(x)
        diag = residual_sq_diag(wg, st.h)
        g, st = alg.estimate(st, wg)
        return (x - gamma * g, st), (diag["shift_residual_sq"],
                                     diag["grad_sq"])

    (_, _), (resid, grad) = jax.lax.scan(body, (x0, state0), None,
                                         length=steps)
    return np.asarray(resid), np.asarray(grad)


def test_shift_residual_decays_under_diana_and_efbv_flat_under_dcgd():
    omega = 80 / 20 - 1.0  # RandK(0.25) on d=80
    for rule in (DianaShift(alpha=1.0 / (1.0 + omega)),
                 EFBVShift(eta=1.0 / (1.0 + omega), nu=1.0)):
        resid, grad = _residual_trajectory(rule)
        # averaged tails beat single-draw noise from the sparsifier
        head = resid[:10].mean()
        tail = resid[-50:].mean()
        assert tail < 0.05 * head, f"{type(rule).__name__}: {tail} vs {head}"
        # the gradient norm itself does NOT vanish (noise=10 puts the
        # optimum away from interpolation) — the decay is the shift's
        assert grad[-50:].mean() > 10.0 * tail

    resid, grad = _residual_trajectory(FixedShift())
    # stateless rule: h is None, the wire carries g itself — the ratio
    # is pinned at exactly 1 every step
    np.testing.assert_allclose(resid, grad, rtol=1e-6)


# ---------------------------------------------------------------------------
# Wire-level probe + snapshot plumbing
# ---------------------------------------------------------------------------


def test_wire_codec_quality_and_snapshot_keys():
    from repro.comm import SimChannel, build_transport
    from repro.configs import get_smoke_config
    from repro.configs.base import CompressionConfig
    from repro.models import model as M

    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    shapes = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    comp = CompressionConfig(enabled=False, model_wire="q8", publish_every=2)
    transport = build_transport(comp, cfg, SimChannel(), params_like=shapes)
    wire = transport["model"]
    qual = wire.codec_quality()
    assert 0.0 < qual["nmse"] < 1.0          # int8 on normal data
    assert qual["omega_hat"] == qual["nmse"]  # single payload: coincide

    snap = transport.obs_snapshot()
    assert snap["model"]["omega_hat"] is None  # probe is opt-in
    snap_q = transport.obs_snapshot(quality=True)
    assert snap_q["model"]["omega_hat"] == pytest.approx(qual["omega_hat"])
    # record-ready for the run header, strict schema
    obs.validate_record(obs.run_record("t", wires=snap_q))

    # a traffic-free wire reports Nones, not zeros
    bare = build_transport(CompressionConfig(enabled=False, model_wire="q8"),
                           cfg, SimChannel())["model"]
    assert bare.codec_quality() == {"omega_hat": None, "nmse": None}


# ---------------------------------------------------------------------------
# History ledger: sha x fingerprint keying, schema-valid records
# ---------------------------------------------------------------------------


def _bench(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return str(p)


def test_history_ingest_fingerprint_and_schema(tmp_path):
    a = _bench(tmp_path, "BENCH_a.json",
               {"mode": "q8", "step_s": 0.5, "bytes_per_step": 1024,
                "loss": 1.25, "nested": {"bits": 99.0}})
    b = _bench(tmp_path, "BENCH_b.json", {"iters": [3, 4], "ok": True})
    out = str(tmp_path / "history.jsonl")

    recs = history.ingest([a, b], out, sha="cafe" * 10)
    assert len(recs) == 2
    for rec in recs:
        obs.validate_record(rec)           # ledger rides the obs schema
    n, errors = obs.check_jsonl(out)
    assert n == 2 and not errors

    d = recs[0]["data"]
    assert d["sha"] == "cafe" * 10
    assert d["metrics"]["step_s"] == 0.5
    assert d["metrics"]["nested.bits"] == 99.0
    assert d["metrics"]["bytes_per_step"] == 1024.0
    assert "ok" not in recs[1]["data"]["metrics"]       # bools are config
    assert recs[1]["data"]["metrics"]["iters[0]"] == 3.0

    # fingerprint: INSENSITIVE to metric values, sensitive to config
    # scalars and to the metric-name set
    base = json.loads(open(a).read())
    fp0 = history.config_fingerprint("BENCH_a.json", base)
    assert fp0 == history.config_fingerprint(
        "BENCH_a.json", {**base, "step_s": 99.0})
    assert fp0 != history.config_fingerprint(
        "BENCH_a.json", {**base, "mode": "dense"})
    assert fp0 != history.config_fingerprint(
        "BENCH_a.json", {**base, "extra_metric": 1.0})
    assert fp0 != history.config_fingerprint("BENCH_other.json", base)

    # append again: latest_by_artifact keeps the LAST entry per name
    history.ingest([a], out, sha="beef" * 10)
    latest = history.latest_by_artifact(history.load_history(out))
    assert latest["BENCH_a.json"]["data"]["sha"] == "beef" * 10
    assert set(latest) == {"BENCH_a.json", "BENCH_b.json"}


# ---------------------------------------------------------------------------
# Regression gate: tolerance classes, exit codes, --inject self-test
# ---------------------------------------------------------------------------


def test_classify_metric_classes():
    assert regress.classify("fused.step_s") == "timing"
    assert regress.classify("elapsed_total") == "timing"
    assert regress.classify("wall_seconds") == "timing"
    assert regress.classify("modes.q8.bytes_per_step") == "structural"
    assert regress.classify("uplink_bits") == "structural"
    assert regress.classify("suites[0].steps") == "structural"
    assert regress.classify("publishes") == "structural"
    assert regress.classify("loss") == "other"
    assert regress.classify("err_rel") == "other"
    assert regress.classify("omega_hat") == "other"


def test_regress_gate_exit_codes(tmp_path):
    art = _bench(tmp_path, "BENCH_g.json",
                 {"mode": "q8", "step_s": 0.5, "bytes_per_step": 1024.0,
                  "loss": 1.25})
    base_path = str(tmp_path / "baseline.json")

    # freeze strips timings by default; the committed baseline never
    # gates absolute times across machines
    assert regress.main(["--freeze", base_path, art]) == 0
    frozen = json.loads(open(base_path).read())
    assert frozen["version"] == regress.BASELINE_VERSION
    assert not frozen["timings_kept"]
    assert "step_s" not in frozen["artifacts"]["BENCH_g.json"]["metrics"]

    # clean pass against its own freeze
    assert regress.main(["--baseline", base_path, art]) == 0

    # the timing band: a same-run --keep-timings freeze plus --inject
    # MUST trip (the CI self-test), while inject within band passes
    base_t = str(tmp_path / "baseline_t.json")
    assert regress.main(["--freeze", base_t, "--keep-timings", art]) == 0
    assert regress.main(["--baseline", base_t, "--inject", "1.2", art]) == 1
    assert regress.main(["--baseline", base_t, "--inject", "1.1", art]) == 0
    # one-sided: getting FASTER never violates
    assert regress.main(["--baseline", base_t, "--inject", "0.5", art]) == 0

    # structural drift beyond 1% trips even when quality is unchanged
    payload = json.loads(open(art).read())
    payload["bytes_per_step"] = 1040.0          # +1.6%
    with open(art, "w") as f:
        json.dump(payload, f)
    assert regress.main(["--baseline", base_path, art]) == 1

    # usage errors exit 2 / argparse error paths
    assert regress.main(["--baseline", str(tmp_path / "nope.json"),
                         art]) == 2
    missing = str(tmp_path / "BENCH_missing.json")
    assert regress.main(["--baseline", base_path, missing]) == 2


def test_regress_compare_metrics_bands_and_zero_baseline():
    kw = dict(timing_rtol=0.15, structural_rtol=0.01, other_rtol=0.25)
    base = {"step_s": 1.0, "bytes_per_step": 100.0, "loss": 1.0,
            "resyncs": 0.0}

    assert regress.compare_metrics(dict(base), base, **kw) == []
    # other-class two-sided band: -30% trips, -20% doesn't
    v = regress.compare_metrics({**base, "loss": 0.7}, base, **kw)
    assert [x["metric"] for x in v] == ["loss"]
    assert regress.compare_metrics({**base, "loss": 0.8}, base, **kw) == []
    # a structural zero must STAY zero
    v = regress.compare_metrics({**base, "resyncs": 1.0}, base, **kw)
    assert v and v[0]["metric"] == "resyncs"
    # a disappeared metric is a violation only when the config matches
    cur = {k: v for k, v in base.items() if k != "loss"}
    v = regress.compare_metrics(cur, base, **kw)
    assert [x["why"] for x in v] == ["metric disappeared"]
    assert regress.compare_metrics(cur, base, require_all=False, **kw) == []


def test_regress_fingerprint_mismatch_intersects_only(tmp_path):
    """A config change makes runs incomparable point-to-point: the gate
    compares the INTERSECTING metrics, notes the mismatch, and a metric
    present only in the baseline is NOT a violation."""
    art = _bench(tmp_path, "BENCH_fp.json",
                 {"mode": "q8", "loss": 1.0, "gone": 5.0})
    base_path = str(tmp_path / "b.json")
    assert regress.main(["--freeze", base_path, art]) == 0
    # change a config scalar AND drop a metric
    with open(art, "w") as f:
        json.dump({"mode": "dense", "loss": 1.05}, f)
    result = regress.run_gate(regress.load_baseline(base_path), [art])
    assert result["violations"] == []
    assert any("fingerprint changed" in n for n in result["notes"])
    # but an intersecting metric outside its band still trips
    with open(art, "w") as f:
        json.dump({"mode": "dense", "loss": 2.0}, f)
    result = regress.run_gate(regress.load_baseline(base_path), [art])
    assert [v["metric"] for v in result["violations"]] == ["loss"]
