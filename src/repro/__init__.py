"""repro — shifted-compression distributed training & serving system.

Reproduction of "Shifted Compression Framework: Generalizations and
Improvements" grown toward a production-scale jax system; see ROADMAP.md.
"""

from repro import compat as _compat

_compat.install()
