"""Pytree checkpointing (npz-based, sharding-aware restore)."""

from repro.checkpoint.store import latest_step, restore, save
