"""Checkpointing: flat-key npz save/restore of arbitrary pytrees.

Sharding-aware restore: arrays are loaded host-side and device_put with
the provided shardings (if any), so a checkpoint written on one mesh can
be restored onto another.  Keys are '/'-joined pytree paths; a sidecar
'__treedef__' entry stores the structure fingerprint for validation.
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out[name] = leaf
    return out


def save(path: str, tree: Any, *, step: Optional[int] = None) -> None:
    named = _flatten_with_names(tree)

    def to_np(v):
        a = np.asarray(v)
        if a.dtype.name == "bfloat16":  # npz has no bf16: store as f32
            a = a.astype(np.float32)
        return a

    arrays = {k: to_np(v) for k, v in named.items()}
    meta = {"keys": sorted(arrays), "step": step}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, __meta__=np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8
        ), **arrays)
    os.replace(tmp, path)


def restore(path: str, like: Any, *, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optional shardings pytree."""
    with np.load(path) as z:
        names = _flatten_with_names(like)
        leaves_by_name = {}
        for name, ref in names.items():
            if name not in z:
                raise KeyError(f"checkpoint missing {name!r}")
            arr = z[name]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != expected {ref.shape}"
                )
            leaves_by_name[name] = arr.astype(ref.dtype)

    flat_like, treedef = jax.tree_util.tree_flatten(like)
    flat_names = list(_flatten_with_names(like))
    shard_flat = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: x is None or hasattr(x, "spec")
        )
        if shardings is not None
        else [None] * len(flat_like)
    )
    leaves = []
    for name, sh in zip(flat_names, shard_flat):
        arr = leaves_by_name[name]
        leaves.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(path: str) -> Optional[int]:
    try:
        with np.load(path) as z:
            meta = json.loads(bytes(z["__meta__"]).decode())
            return meta.get("step")
    except Exception:
        return None
