"""The paper's contribution: shifted compression operators + DCGD-SHIFT."""

from repro.core.compressors import (
    BernoulliP,
    Compressor,
    Contractive,
    Identity,
    Induced,
    Int8Stochastic,
    NaturalCompression,
    NaturalDithering,
    PackedBits,
    RandK,
    ScaledSign,
    TernGrad,
    TopK,
    Unbiased,
    Zero,
    make_compressor,
    shifted,
    tree_bits,
    tree_compress,
    tree_shifted_compress,
    tree_size,
    wire_bits,
)
from repro.core.shift_rules import (
    DianaShift,
    EF21Shift,
    FixedShift,
    RandDianaShift,
    ShiftRule,
    StarShift,
    make_shift_rule,
    worker_compress,
)
from repro.core.algorithms import (
    DCGDShift,
    DCGDState,
    rand_diana_default_p,
    stepsize_dcgd_fixed,
    stepsize_dcgd_star,
    stepsize_diana,
    stepsize_ef21,
    stepsize_rand_diana,
)
from repro.core.iterate_comp import (
    GDCI,
    VRGDCI,
    stepsize_gdci,
    stepsize_vr_gdci,
)
