"""Simulation driver: run any method of the framework on a convex Problem
and record the (relative error, cumulative bits) trajectory.

This is the engine behind every paper-fidelity experiment (Figures 1-4,
Table 1) and the theorem unit tests.  Runs the whole optimization as one
``lax.scan`` so even 10^4-step sweeps are fast on CPU.

Communication runs through the method's ``repro.comm.Channel`` (the
vmapped parameter-server ``SimChannel`` by default — construct
``DCGDShift(..., channel=...)`` / ``GDCI(..., channel=...)`` to swap the
transport); the recorded ``bits`` are the structural ``wire_bits`` of
the actual encoded payloads.

These reference runs drive the SAME phased rule engine
(``ShiftRule.round`` via ``Channel.shift_round``) as the production
``launch/train.py`` step — including the incremental ``h_bar``
tracking — which is what makes the cross-layer bit-exactness tests
(``tests/test_shift_engine.py``) possible.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import DCGDShift
from repro.core.iterate_comp import GDCI, VRGDCI
from repro.data.problems import Problem


@dataclass
class Trace:
    """Trajectory of one run."""
    name: str
    rel_err: np.ndarray   # ||x^k - x*||^2 / ||x^0 - x*||^2, per step
    bits: np.ndarray      # cumulative uplink bits, per step

    def bits_to_tol(self, tol: float) -> float:
        """Communicated bits needed to first reach rel_err <= tol."""
        idx = np.argmax(self.rel_err <= tol)
        if self.rel_err[idx] > tol:
            return float("inf")
        return float(self.bits[idx])

    def steps_to_tol(self, tol: float) -> float:
        idx = np.argmax(self.rel_err <= tol)
        if self.rel_err[idx] > tol:
            return float("inf")
        return float(idx)


def run_dcgd_shift(
    problem: Problem,
    method: DCGDShift,
    gamma: float,
    steps: int,
    *,
    x0: Optional[jax.Array] = None,
    seed: int = 0,
    use_star: bool = False,
    name: str = "dcgd-shift",
) -> Trace:
    """Run Algorithm 1 on ``problem`` with learning rate ``gamma``."""
    x0 = (
        jax.random.normal(jax.random.PRNGKey(100 + seed), (problem.d,))
        * jnp.sqrt(10.0)
        if x0 is None
        else x0
    )
    x0 = x0.astype(problem.x_star.dtype)
    wg0 = problem.worker_grads(x0)
    star = problem.star_grads() if use_star else None
    state0 = method.init(wg0, seed=seed, star=star)
    denom = jnp.sum((x0 - problem.x_star) ** 2)

    def body(carry, _):
        x, st = carry
        wg = problem.worker_grads(x)
        g, st = method.estimate(st, wg)
        x = x - gamma * g
        err = jnp.sum((x - problem.x_star) ** 2) / denom
        return (x, st), (err, st.bits)

    (_, _), (errs, bits) = jax.lax.scan(body, (x0, state0), None, length=steps)
    return Trace(name, np.asarray(errs), np.asarray(bits))


def run_gdci(
    problem: Problem,
    method: GDCI | VRGDCI,
    steps: int,
    *,
    x0: Optional[jax.Array] = None,
    seed: int = 0,
    name: str = "gdci",
) -> Trace:
    x0 = (
        jax.random.normal(jax.random.PRNGKey(100 + seed), (problem.d,))
        * jnp.sqrt(10.0)
        if x0 is None
        else x0
    )
    x0 = x0.astype(problem.x_star.dtype)
    if isinstance(method, VRGDCI):
        state0 = method.init_state(x0, problem.n_workers, seed=seed)
    else:
        state0 = method.init(x0, seed=seed)
    denom = jnp.sum((x0 - problem.x_star) ** 2)

    def body(carry, _):
        x, st = carry
        wg = problem.worker_grads(x)
        x, st = method.update(x, st, wg)
        err = jnp.sum((x - problem.x_star) ** 2) / denom
        return (x, st), (err, st.bits)

    (_, _), (errs, bits) = jax.lax.scan(body, (x0, state0), None, length=steps)
    return Trace(name, np.asarray(errs), np.asarray(bits))
