"""Compressed-iterate methods — Section 3.3 (GDCI) and Appendix B.7 (VR-GDCI).

These compress the *model* (downlink direction, the federated-learning
broadcast) rather than the gradient.  The paper's insight: GDCI is
DCGD-SHIFT in disguise with the shifted compressor
``Q~(z) = (1/gamma) [x - Q(x - gamma z)]  in  U(omega; x/gamma)``,
which is how the improved kappa (vs kappa^2) rate of Theorem 5 is proved.

Both methods consume stacked per-worker gradients like DCGDShift, so the
distributed mapping is identical.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm.channel import Channel
from repro.core.compressors import Compressor, Identity
from repro.core.shift_rules import _chan


class GDCIState(NamedTuple):
    key: jax.Array
    step: jax.Array
    bits: jax.Array


@dataclass(frozen=True)
class GDCI:
    """Distributed Gradient Descent with Compressed Iterates (eq. 13):

        x^{k+1} = (1-eta) x^k + eta * mean_i Q_i(x^k - gamma grad_i(x^k))

    Theorem 5: linear to a neighborhood ~ (2 omega eta / n) mean_i
    ||x* - gamma grad_i(x*)||^2; exact in the interpolation regime.
    """

    q: Compressor = field(default_factory=Identity)
    gamma: float = 0.1
    eta: float = 0.5
    channel: Optional[Channel] = None

    def init(self, params, *, seed: int = 0) -> GDCIState:
        return GDCIState(
            key=jax.random.PRNGKey(seed),
            step=jnp.zeros((), jnp.int32),
            bits=jnp.zeros((), jnp.float32),
        )

    def update(self, params, state: GDCIState, wgrads):
        ch = _chan(self.channel)
        key, sub, ka = jax.random.split(state.key, 3)
        # local iterate proposal per worker: x - gamma g_i  (broadcast x)
        prop = jax.tree_util.tree_map(
            lambda x, g: x[None] - self.gamma * g, params, wgrads
        )
        comp, bits = ch.uplink(self.q, sub, prop)
        mean = ch.reduce_mean(ka, comp)
        new_params = jax.tree_util.tree_map(
            lambda x, m: (1.0 - self.eta) * x + self.eta * m, params, mean
        )
        return new_params, GDCIState(
            key=key, step=state.step + 1, bits=state.bits + bits
        )


class VRGDCIState(NamedTuple):
    h: Any              # per-worker shifts on iterates, W-stacked
    h_bar: Any          # master aggregated shift (tracked incrementally:
                        # h_bar += alpha * delta_bar, so no dense mean of
                        # the W-stacked h ever materializes)
    key: jax.Array
    step: jax.Array
    bits: jax.Array


@dataclass(frozen=True)
class VRGDCI:
    """Algorithm 2 — Variance-Reduced GDCI.  Eliminates the neighborhood:

        delta_i = Q_i(x - gamma grad_i - h_i)
        h_i    += alpha delta_i
        x       = (1-eta) x + eta (mean_i delta_i + h_bar)

    Theorem 6 (improved): linear to the *exact* optimum at rate
    min{alpha/2, eta}, complexity max{2(omega+1), (1+6w/n) kappa} — same
    order as DIANA, improving Chraibi et al. (2019).

    Like the gradient-direction ``ShiftRule``s, the algebra is phased
    (``message`` / ``apply`` / ``round``) and the SAME object drives the
    reference simulator and the production trainer — ``launch/train.py``
    plumbs ``TrainState`` fields through ``round`` and contains no
    iterate-compression math of its own.
    """

    q: Compressor = field(default_factory=Identity)
    gamma: float = 0.1
    eta: float = 0.5
    alpha: float = 0.5
    channel: Optional[Channel] = None

    # -- trainer-facing state protocol (mirrors ShiftRule) ----------------

    stateful = True

    def init(self, wgrads_like):
        """Worker-stacked iterate shifts (arrays or ShapeDtypeStructs)."""
        return jax.tree_util.tree_map(
            lambda a: jnp.zeros(a.shape, a.dtype), wgrads_like
        )

    def init_bar(self, wgrads_like):
        return jax.tree_util.tree_map(
            lambda a: jnp.zeros(a.shape[1:], a.dtype), wgrads_like
        )

    # -- phases -----------------------------------------------------------

    def message(self, key, params, wgrads, h, channel=None):
        """The wire message: per-worker compressed iterate proposals
        delta_i = Q(x - gamma grad_i - h_i)."""
        ch = _chan(channel if channel is not None else self.channel)
        target = jax.tree_util.tree_map(
            lambda x, g, s: (x[None] - self.gamma * g.astype(x.dtype)) - s,
            params, wgrads, h,
        )
        return ch.uplink(self.q, key, target)

    def apply(self, params, delta, delta_bar, h, h_bar):
        """Iterate + shift update from the aggregated proposal.  The
        model mix runs in f32 and is cast back to the param dtype (a
        no-op in the f32 simulator, required for bf16 training)."""
        h_new = jax.tree_util.tree_map(
            lambda s, d: s + self.alpha * d, h, delta
        )
        new_params = jax.tree_util.tree_map(
            lambda x, db, hb: ((1.0 - self.eta) * x.astype(jnp.float32)
                               + self.eta * (db + hb).astype(jnp.float32)
                               ).astype(x.dtype),
            params, delta_bar, h_bar,
        )
        h_bar_new = jax.tree_util.tree_map(
            lambda hb, db: hb + self.alpha * db, h_bar, delta_bar
        )
        return new_params, h_new, h_bar_new

    def round(self, key, params, wgrads, h, h_bar, channel=None):
        """One full round: ``(new_params, h_new, h_bar_new, bits)``."""
        ch = _chan(channel if channel is not None else self.channel)
        k_msg, k_agg = jax.random.split(key)
        delta, bits = self.message(k_msg, params, wgrads, h, ch)
        delta_bar = ch.reduce_mean(k_agg, delta)
        new_params, h_new, hb_new = self.apply(
            params, delta, delta_bar, h, h_bar
        )
        return new_params, h_new, hb_new, bits

    # -- simulator driver --------------------------------------------------

    def init_state(self, params, n_workers: int, *, seed: int = 0) -> VRGDCIState:
        h = jax.tree_util.tree_map(
            lambda x: jnp.zeros((n_workers, *x.shape), x.dtype), params
        )
        return VRGDCIState(
            h=h,
            h_bar=jax.tree_util.tree_map(jnp.zeros_like, params),
            key=jax.random.PRNGKey(seed),
            step=jnp.zeros((), jnp.int32),
            bits=jnp.zeros((), jnp.float32),
        )

    def update(self, params, state: VRGDCIState, wgrads):
        key, sub = jax.random.split(state.key)
        new_params, h_new, hb_new, bits = self.round(
            sub, params, wgrads, state.h, state.h_bar, self.channel
        )
        return new_params, VRGDCIState(
            h=h_new, h_bar=hb_new, key=key, step=state.step + 1,
            bits=state.bits + bits,
        )


def stepsize_gdci(L, L_max, mu, omega, n):
    """Theorem 5 pair (eta, gamma)."""
    eta = 1.0 / (L / mu + (2.0 * omega / n) * (L_max / mu - 1.0))
    gamma = (1.0 + 2.0 * eta * omega / n) / (eta * (L + 2.0 * L_max * omega / n))
    return eta, gamma


def stepsize_vr_gdci(L, L_max, mu, omega, n):
    """Theorem 6 triple (alpha, eta, gamma)."""
    alpha = 1.0 / (omega + 1.0)
    eta = 1.0 / (L / mu + (6.0 * omega / n) * (L_max / mu - 1.0))
    gamma = (1.0 + 6.0 * omega * eta / n) / (eta * (L + 6.0 * L_max * omega / n))
    return alpha, eta, gamma
