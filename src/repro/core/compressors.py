"""Compression operators — the paper's Definitions 1-3 as wire codecs.

Two families:

  * ``Unbiased`` (class ``U(omega)``, Def. 2):   E C(x) = x,
        E ||C(x) - x||^2 <= omega ||x||^2.
  * ``Contractive`` (class ``B(delta)``, Def. 1): E ||C(x) - x||^2 <= (1-delta)||x||^2.

The paper's object of interest is the *compressed message* ``m_i =
Q(grad_i - h_i)`` that actually travels on the wire, so every operator
is an explicit codec:

  ``encode(key, x) -> (payload, meta)``
        ``payload`` is a pytree of arrays with honest wire dtypes (int8
        quantized values, packed indices, f32 scales).  ``meta`` carries
        side information the receiver derives from *shared* state (e.g.
        the correlated Rand-K pattern implied by a shared seed) — it is
        never charged to the wire.
  ``decode(payload, meta, shape_dtype) -> x_hat``
        reconstructs the dense message; ``shape_dtype`` is a
        ``jax.ShapeDtypeStruct`` for the original tensor.
  ``__call__(key, x)``
        the dense compress->decompress round trip the optimizer math
        sees — *derived* as ``decode(encode(key, x))``, never written by
        hand.
  ``wire_bits(payload)``
        bits on the wire for one payload, computed structurally from
        the payload's shapes/dtypes (``PackedBits`` leaves carry
        sub-dtype widths, e.g. 10-bit indices stored in an int32
        container).  Works on real arrays and on
        ``jax.eval_shape`` outputs alike.
  ``omega(d)`` / ``delta(d)``
        variance constants for step-size rules.

There is ONE accounting path: ``wire_bits`` on actual payloads for live
traffic, and the free function ``aot_wire_bits(q, shape)`` — the same
``wire_bits`` over the ``jax.eval_shape``'d payload — for ahead-of-time
cost quotes.  No analytic per-dimension formulas anywhere.

Every operator works on arrays of arbitrary shape (treated as flattened
vectors where ordering matters) and is a hashable frozen dataclass so it
can be closed over inside ``jax.jit``.

The transport of payloads (vmapped parameter server, shared-pattern
Rand-K aggregation, int8 ring all-reduce) lives in ``repro.comm`` and
``repro.dist.collectives`` — both are driven by these codecs; neither
re-derives payload formats.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

FLOAT_BITS = 32  # wire width of an uncompressed scalar

# ShapeDtypeStruct stand-in for a PRNG key, used by aot_wire_bits.
_KEY_SDS = jax.ShapeDtypeStruct((2,), jnp.uint32)


def _flat(x):
    return jnp.reshape(x, (-1,))


def _k_of(q: float, d: int) -> int:
    """Number of kept coordinates for a sparsifier with keep-fraction q."""
    return max(1, int(round(q * d)))


def _index_bits(d: int) -> int:
    """Bits to address one of d coordinates on the wire."""
    return math.ceil(math.log2(max(d, 2)))


def _numel(shape) -> int:
    return int(math.prod(shape)) if shape else 1


def _dtype_bits(dtype) -> int:
    return int(np.dtype(dtype).itemsize) * 8


@jax.tree_util.register_pytree_node_class
class PackedBits:
    """Payload leaf whose true wire width is ``width`` bits per element.

    JAX has no sub-byte array dtypes for e.g. 10-bit Rand-K indices or
    1-bit signs, so codecs store such fields in the smallest container
    dtype and declare the packed width here; ``wire_bits`` charges
    ``width * numel`` instead of the container width.  Registered as a
    pytree node so payloads remain ordinary pytrees under vmap /
    shard_map / ppermute.
    """

    __slots__ = ("data", "width")

    def __init__(self, data, width: int):
        self.data = data
        self.width = int(width)

    def tree_flatten(self):
        return (self.data,), self.width

    @classmethod
    def tree_unflatten(cls, width, children):
        return cls(children[0], width)

    def __repr__(self):
        return f"PackedBits({self.data!r}, width={self.width})"


def _is_packed(x) -> bool:
    return isinstance(x, PackedBits)


def wire_bits(payload) -> float:
    """Structural wire size of a payload pytree, in bits.

    Counts ``numel * dtype_bits`` per array leaf and ``numel * width``
    per ``PackedBits`` leaf.  Accepts concrete arrays or
    ``ShapeDtypeStruct`` leaves (so costs can be computed AOT via
    ``jax.eval_shape`` without running the codec).
    """
    total = 0
    for leaf in jax.tree_util.tree_leaves(payload, is_leaf=_is_packed):
        if _is_packed(leaf):
            total += _numel(leaf.data.shape) * leaf.width
        else:
            total += _numel(leaf.shape) * _dtype_bits(leaf.dtype)
    return float(total)


# --------------------------------------------------------------------------
# Base classes
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Compressor:
    """Base codec.  Subclasses are frozen dataclasses => hashable/static.

    Subclasses implement ``encode``/``decode`` (the wire protocol); the
    dense round trip ``__call__`` and the accounting (``wire_bits``)
    are derived here.
    """

    def encode(self, key: jax.Array, x: jax.Array) -> Tuple[Any, Any]:
        raise NotImplementedError

    def decode(self, payload, meta, shape_dtype) -> jax.Array:
        raise NotImplementedError

    def __call__(self, key: jax.Array, x: jax.Array) -> jax.Array:
        payload, meta = self.encode(key, x)
        return self.decode(
            payload, meta, jax.ShapeDtypeStruct(x.shape, x.dtype)
        )

    def wire_bits(self, payload) -> float:
        """Wire bits of one (possibly worker-stacked) payload.

        Default: the structural module-level ``wire_bits``.  Codecs
        whose payload size is itself a random variable (``BernoulliP``)
        override this with a traced, data-dependent count.
        """
        return wire_bits(payload)

    @property
    def stochastic(self) -> bool:
        return True


@dataclass(frozen=True)
class Unbiased(Compressor):
    """Marker base for the class U(omega)."""

    def omega(self, d: int) -> float:
        raise NotImplementedError


@dataclass(frozen=True)
class Contractive(Compressor):
    """Marker base for the class B(delta)."""

    def delta(self, d: int) -> float:
        raise NotImplementedError


# --------------------------------------------------------------------------
# Trivial operators
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Identity(Unbiased, Contractive):
    """I in U(0) and B(1): full-precision message."""

    def encode(self, key, x):
        return {"values": x}, {}

    def decode(self, payload, meta, shape_dtype):
        return jnp.reshape(payload["values"], shape_dtype.shape).astype(
            shape_dtype.dtype
        )

    def omega(self, d):
        return 0.0

    def delta(self, d):
        return 1.0

    @property
    def stochastic(self):
        return False


@dataclass(frozen=True)
class Zero(Compressor):
    """O — maps everything to zero; 'delta interpreted as 0' in the paper.

    Used as the C_i of plain DCGD (no shift learning) — the payload is
    empty: zero wire cost by construction.
    """

    def encode(self, key, x):
        return {}, {}

    def decode(self, payload, meta, shape_dtype):
        return jnp.zeros(shape_dtype.shape, shape_dtype.dtype)

    def delta(self, d):
        return 0.0

    @property
    def stochastic(self):
        return False


# --------------------------------------------------------------------------
# Unbiased operators  U(omega)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class RandK(Unbiased):
    """Random sparsification (eq. 2): keep a uniformly random K-subset,
    scale by d/K.  RandK(q) keeps K = round(q*d) coords; omega = d/K - 1.

    The K-subset is the prefix of a random permutation, so EXACTLY K
    coordinates survive for every draw (a threshold on uniform scores
    keeps more than K when float32 scores tie, and the d/K rescale then
    makes the operator biased — see the exact-K regression test).

    Payload: K values (input dtype) + K packed ceil(log2 d)-bit indices.
    ``shared_pattern`` marks that all workers use the same key for a
    given step (correlated sampling): the indices are implied by the
    shared seed, move to ``meta``, and are not charged to the wire —
    exploited by ``dist.collectives.randk_shared_mean``, where the
    aggregated message stays K-dimensional.
    """

    q: float = 0.1
    shared_pattern: bool = False

    def encode(self, key, x):
        xf = _flat(x)
        d = xf.shape[0]
        k = _k_of(self.q, d)
        idx = jax.random.permutation(key, d)[:k].astype(jnp.int32)
        values = xf[idx] * (d / k)
        if self.shared_pattern:
            return {"values": values}, {"indices": idx}
        return (
            {"values": values, "indices": PackedBits(idx, _index_bits(d))},
            {},
        )

    def decode(self, payload, meta, shape_dtype):
        d = _numel(shape_dtype.shape)
        idx = (
            meta["indices"] if self.shared_pattern
            else payload["indices"].data
        )
        out = (
            jnp.zeros((d,), shape_dtype.dtype)
            .at[idx]
            .set(payload["values"].astype(shape_dtype.dtype))
        )
        return jnp.reshape(out, shape_dtype.shape)

    def omega(self, d):
        return d / _k_of(self.q, d) - 1.0


@dataclass(frozen=True)
class BernoulliP(Unbiased):
    """B_p — full vector scaled 1/p with prob. p, else 0.  omega = 1/p - 1.

    The C_i of Rand-DIANA (Table 2): the shift is refreshed w.p. p.
    The payload size is a random variable (one flag bit always; the full
    vector only when it fires), so ``wire_bits`` is traced and ``bits``
    reports the expectation.
    """

    p: float = 0.1

    def encode(self, key, x):
        keep = jax.random.bernoulli(key, self.p)
        values = jnp.where(keep, x / self.p, jnp.zeros_like(x))
        return {"sent": keep, "values": values}, {}

    def decode(self, payload, meta, shape_dtype):
        return jnp.reshape(payload["values"], shape_dtype.shape).astype(
            shape_dtype.dtype
        )

    def wire_bits(self, payload):
        """Actual (traced) bits: flag + full vector iff it fired.

        Handles worker-stacked payloads (``sent`` shaped ``(W,)``) the
        same way: each message is charged independently.  On
        ``eval_shape`` payloads (AOT costing, ``aot_wire_bits``) the
        flag has no value, so the EXPECTATION p * full + flag is
        returned instead.
        """
        sent = payload["sent"]
        n_msg = _numel(sent.shape)
        per_msg = (
            _dtype_bits(payload["values"].dtype)
            * (_numel(payload["values"].shape) // n_msg)
        )
        if isinstance(sent, jax.ShapeDtypeStruct):  # AOT: expectation
            return self.p * per_msg * n_msg + float(n_msg)
        return jnp.sum(sent.astype(jnp.float32)) * per_msg + float(n_msg)

    def omega(self, d):
        return 1.0 / self.p - 1.0


@dataclass(frozen=True)
class NaturalDithering(Unbiased):
    """Natural dithering with s levels w.r.t. the l2 norm
    (Horváth et al., 2019a) — the 'ND' compressor of the paper's Fig. 1.

    Levels are the exponent lattice {2^0, 2^-1, ..., 2^-(s-1), 0} applied
    to |x|/||x||_2, with unbiased stochastic rounding between neighbouring
    levels.  omega <= 1/8 + 2^(1-s) * min(sqrt(d), 2^(1-s) d)  (their Thm 1).

    Payload per coordinate: a packed ceil(log2(s+1))-bit level code
    (0 = zero level, c >= 1 = 2^{-(c-1)}) + a 1-bit sign, plus one f32
    norm per message.
    """

    s: int = 8

    def encode(self, key, x):
        xf = x.astype(jnp.float32)
        norm = jnp.sqrt(jnp.sum(xf * xf))
        safe = jnp.maximum(norm, jnp.finfo(jnp.float32).tiny)
        y = jnp.abs(xf) / safe  # in [0, 1]
        # exponent index j: level_hi = 2^-j, level_lo = 2^-(j+1)
        j = jnp.clip(jnp.floor(-jnp.log2(jnp.maximum(y, 1e-38))), 0, self.s - 1)
        hi = jnp.exp2(-j)
        lo = jnp.where(j >= self.s - 1, 0.0, jnp.exp2(-(j + 1.0)))
        # Stochastic rounding between lo and hi, unbiased in y.
        p_hi = (y - lo) / jnp.maximum(hi - lo, 1e-38)
        take_hi = jax.random.uniform(key, x.shape) < p_hi
        code_lo = jnp.where(j >= self.s - 1, 0.0, j + 2.0)
        code = jnp.where(take_hi, j + 1.0, code_lo)
        code = jnp.where(y == 0.0, 0.0, code).astype(jnp.int8)
        sign = jnp.sign(xf).astype(jnp.int8)
        return (
            {
                "code": PackedBits(code, _index_bits(self.s + 1)),
                "sign": PackedBits(sign, 1),
                "norm": norm,
            },
            {},
        )

    def decode(self, payload, meta, shape_dtype):
        code = payload["code"].data.astype(jnp.float32)
        lvl = jnp.where(code > 0, jnp.exp2(-(code - 1.0)), 0.0)
        sign = payload["sign"].data.astype(jnp.float32)
        out = sign * payload["norm"] * lvl
        return jnp.reshape(out, shape_dtype.shape).astype(shape_dtype.dtype)

    def omega(self, d):
        t = 2.0 ** (1 - self.s)
        return 0.125 + t * min(math.sqrt(d), t * d)


@dataclass(frozen=True)
class NaturalCompression(Unbiased):
    """C_nat — stochastic rounding to the nearest powers of two.
    omega = 1/8; 9 bits/coordinate on the wire (1-bit sign + 8-bit
    exponent; x = 0 is signalled by sign 0)."""

    def encode(self, key, x):
        # elementwise and SHAPE-PRESERVING: never flattens, so sharded
        # gradient leaves stay sharded (no spurious all-gathers).
        xf = x.astype(jnp.float32)
        a = jnp.abs(xf)
        # floor at the min NORMAL f32 (2^-126): a subnormal floor would
        # flush to 0 under XLA's log2 and yield e = -inf -> int16 min,
        # escaping the declared 8-bit code range for exact-zero coords
        e = jnp.floor(jnp.log2(jnp.maximum(a, jnp.finfo(jnp.float32).tiny)))
        p_hi = a / jnp.exp2(e) - 1.0  # in [0,1): position within [2^e, 2^{e+1})
        up = jax.random.uniform(key, x.shape) < p_hi
        e_out = (e + up.astype(jnp.float32)).astype(jnp.int16)
        sign = jnp.sign(xf).astype(jnp.int8)
        # e_out spans [-126, 128]: 255 codes -> 8 wire bits (zero is
        # signalled by sign 0, not by an exponent code)
        return (
            {"exp": PackedBits(e_out, 8), "sign": PackedBits(sign, 1)},
            {},
        )

    def decode(self, payload, meta, shape_dtype):
        mag = jnp.exp2(payload["exp"].data.astype(jnp.float32))
        out = payload["sign"].data.astype(jnp.float32) * mag
        return jnp.reshape(out, shape_dtype.shape).astype(shape_dtype.dtype)

    def omega(self, d):
        return 0.125


@dataclass(frozen=True)
class TernGrad(Unbiased):
    """Ternary quantization (Wen et al., 2017): sign(x)*||x||_inf*Bern(|x|/||x||_inf).

    Unbiased; omega is data dependent, bounded by sqrt(d) for the worst case.
    Payload: one packed 2-bit ternary digit per coordinate + an f32 scale.
    """

    def encode(self, key, x):
        xf = x.astype(jnp.float32)
        m = jnp.maximum(jnp.max(jnp.abs(xf)), jnp.finfo(jnp.float32).tiny)
        b = jax.random.bernoulli(key, jnp.abs(xf) / m)
        t = (jnp.sign(xf) * b.astype(jnp.float32)).astype(jnp.int8)
        return {"tern": PackedBits(t, 2), "scale": m}, {}

    def decode(self, payload, meta, shape_dtype):
        out = payload["tern"].data.astype(jnp.float32) * payload["scale"]
        return jnp.reshape(out, shape_dtype.shape).astype(shape_dtype.dtype)

    def omega(self, d):
        return math.sqrt(d)  # worst-case bound


@dataclass(frozen=True)
class Int8Stochastic(Unbiased):
    """Linear int8 quantization with per-tensor max-scale and stochastic
    rounding (unbiased).  The codec of the q8 ring all-reduce: the ring
    forwards exactly this payload (int8 block + f32 scale) hop by hop.
    """

    levels: int = 127

    def encode(self, key, x):
        xf = x.astype(jnp.float32)
        # floor well above subnormal: tiny/levels would flush to zero -> NaN
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / self.levels
        y = xf / scale
        lo = jnp.floor(y)
        u = jax.random.uniform(key, x.shape)
        q = (lo + (u < (y - lo)).astype(jnp.float32)).astype(jnp.int8)
        return {"q": q, "scale": scale}, {}

    def decode(self, payload, meta, shape_dtype):
        out = payload["q"].astype(jnp.float32) * payload["scale"]
        return jnp.reshape(out, shape_dtype.shape).astype(shape_dtype.dtype)

    def omega(self, d):
        # ||C(x)-x||^2 <= d*scale^2/4 <= d * ||x||^2/(4*levels^2) elementwise bound
        return d / (4.0 * self.levels**2)


# --------------------------------------------------------------------------
# Contractive (biased) operators  B(delta)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class TopK(Contractive):
    """Greedy sparsification: keep the K = round(q*d) largest-magnitude
    coordinates.  TopK in B(K/d).

    Exactly K coordinates survive (``lax.top_k`` index order breaks
    magnitude ties).  Payload: K values + K packed indices, same wire
    format as Rand-K but the pattern is data dependent, so the indices
    always travel.
    """

    q: float = 0.1

    def encode(self, key, x):
        xf = _flat(x)
        d = xf.shape[0]
        k = _k_of(self.q, d)
        _, idx = jax.lax.top_k(jnp.abs(xf), k)
        idx = idx.astype(jnp.int32)
        return (
            {"values": xf[idx], "indices": PackedBits(idx, _index_bits(d))},
            {},
        )

    def decode(self, payload, meta, shape_dtype):
        d = _numel(shape_dtype.shape)
        out = (
            jnp.zeros((d,), shape_dtype.dtype)
            .at[payload["indices"].data]
            .set(payload["values"].astype(shape_dtype.dtype))
        )
        return jnp.reshape(out, shape_dtype.shape)

    def delta(self, d):
        return _k_of(self.q, d) / d

    @property
    def stochastic(self):
        return False


@dataclass(frozen=True)
class ScaledSign(Contractive):
    """(||x||_1 / d) * sign(x)  (Karimireddy et al.) in B(||x||_1^2/(d||x||_2^2)),
    worst-case delta = 1/d.

    Payload: one sign bit per coordinate + an f32 scale.  (Exact zeros —
    a measure-zero event — keep sign 0 so the round trip matches the
    operator definition; the canonical wire format still charges 1 bit.)
    """

    def encode(self, key, x):
        xf = x.astype(jnp.float32)
        s = jnp.mean(jnp.abs(xf))
        return {"sign": PackedBits(jnp.sign(xf).astype(jnp.int8), 1),
                "scale": s}, {}

    def decode(self, payload, meta, shape_dtype):
        out = payload["sign"].data.astype(jnp.float32) * payload["scale"]
        return jnp.reshape(out, shape_dtype.shape).astype(shape_dtype.dtype)

    def delta(self, d):
        return 1.0 / d

    @property
    def stochastic(self):
        return False


# --------------------------------------------------------------------------
# Induced compressor (Def. 4 / Lemma 3)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Induced(Unbiased):
    """C_ind(x) = C(x) + Q(x - C(x)) in U(omega*(1-delta)) for C in B(delta),
    Q in U(omega).  Turns a biased operator into an unbiased one with
    strictly smaller variance than Q alone (Horváth & Richtárik, 2021).

    The wire message is the CONCATENATION of both payloads; decode sums
    the two decoded parts.
    """

    c: Contractive = dataclasses.field(default_factory=lambda: TopK(0.1))
    q: Unbiased = dataclasses.field(default_factory=lambda: RandK(0.1))

    def encode(self, key, x):
        kc, kq = jax.random.split(key)
        cp, cm = self.c.encode(kc, x)
        cx = self.c.decode(cp, cm, jax.ShapeDtypeStruct(x.shape, x.dtype))
        qp, qm = self.q.encode(kq, x - cx)
        return {"c": cp, "q": qp}, {"c": cm, "q": qm}

    def decode(self, payload, meta, shape_dtype):
        return self.c.decode(payload["c"], meta["c"], shape_dtype) + self.q.decode(
            payload["q"], meta["q"], shape_dtype
        )

    def wire_bits(self, payload):
        # delegate so nested overrides (e.g. BernoulliP) stay honest
        return self.c.wire_bits(payload["c"]) + self.q.wire_bits(payload["q"])

    def omega(self, d):
        return self.q.omega(d) * (1.0 - self.c.delta(d))


# --------------------------------------------------------------------------
# Shifted compression (Def. 3 / Lemma 1)
# --------------------------------------------------------------------------


def shifted(q: Compressor, h: jax.Array, key: jax.Array, x: jax.Array) -> jax.Array:
    """Q_h(x) = h + Q(x - h): the shifted compressor of Definition 3.

    If Q in U(omega; 0) then the returned operator is in U(omega; h)
    (Lemma 1 with v = h).  This one-liner is the paper's core object.
    """
    return h + q(key, x - h)


def leaf_keys(key: jax.Array, tree) -> list:
    """Deterministic per-leaf keys: fold the leaf index into ``key``."""
    leaves = jax.tree_util.tree_leaves(tree)
    return [jax.random.fold_in(key, i) for i in range(len(leaves))]


def tree_compress(q: Compressor, key: jax.Array, tree):
    """Apply a compressor leaf-wise to a pytree with decorrelated keys."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = [jax.random.fold_in(key, i) for i in range(len(leaves))]
    out = [q(k, leaf) for k, leaf in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_shifted_compress(q: Compressor, key: jax.Array, tree, shift_tree):
    """Leaf-wise  h + Q(x - h)  over matching pytrees."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    hleaves, htreedef = jax.tree_util.tree_flatten(shift_tree)
    if htreedef != treedef:
        raise ValueError(
            "tree_shifted_compress: shift_tree structure does not match "
            f"tree (shifts would mis-pair with leaves): tree={treedef}, "
            f"shift_tree={htreedef}"
        )
    keys = [jax.random.fold_in(key, i) for i in range(len(leaves))]
    out = [shifted(q, h, k, x) for k, x, h in zip(keys, leaves, hleaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def aot_wire_bits(q: Compressor, shape, dtype=jnp.float32) -> float:
    """Structural wire bits of ONE compressed message, ahead of time.

    ``jax.eval_shape`` of the codec's own ``encode`` over a
    ``ShapeDtypeStruct`` — the exact payload shapes of the live wire,
    with zero FLOPs.  ``shape`` may be an int ``d`` (a flat d-vector) or
    a full shape tuple.  Codecs whose payload size is a random variable
    (``BernoulliP``) report their expectation, as documented on their
    ``wire_bits`` override.
    """
    if isinstance(shape, int):
        shape = (shape,)
    payload, _ = jax.eval_shape(
        q.encode, _KEY_SDS, jax.ShapeDtypeStruct(tuple(shape), dtype)
    )
    return float(q.wire_bits(payload))


def tree_bits(q: Compressor, tree) -> float:
    """Total AOT wire bits for one compressed message of this pytree:
    ``aot_wire_bits`` summed over the leaves (flattened, f32 — the wire
    treats each leaf as a flat message; see ``repro.comm`` for the live
    structural accounting on actual payloads)."""
    return float(
        sum(aot_wire_bits(q, int(leaf.size))
            for leaf in jax.tree_util.tree_leaves(tree))
    )


def tree_size(tree) -> int:
    return int(sum(leaf.size for leaf in jax.tree_util.tree_leaves(tree)))


# --------------------------------------------------------------------------
# Registry used by configs / CLI flags.
# --------------------------------------------------------------------------


def _induced_topk_randk(q: float = 0.1) -> "Induced":
    return Induced(c=TopK(q), q=RandK(q))


def _induced_topk_natural(q: float = 0.1) -> "Induced":
    return Induced(c=TopK(q), q=NaturalCompression())


def _fused_q8(**kw) -> Compressor:
    # lazy: the Pallas-fused blockwise-int8 codec lives with its kernel
    from repro.kernels.q8ring.ops import FusedQ8

    return FusedQ8(**kw)


def make_compressor(name: str, **kw) -> Compressor:
    table = {
        "identity": Identity,
        "zero": Zero,
        "randk": RandK,
        "bernoulli": BernoulliP,
        "natural_dithering": NaturalDithering,
        "natural": NaturalCompression,
        "terngrad": TernGrad,
        "int8": Int8Stochastic,
        "q8_block": _fused_q8,
        "topk": TopK,
        "sign": ScaledSign,
        "induced": Induced,
        # convenience instances of the induced compressor (Lemma 3):
        # biased TopK wrapped unbiased by RandK / natural compression.
        # Plain signatures (no **kwargs sink) so unknown arguments raise
        # just like the dataclass constructors do.
        "induced_topk_randk": _induced_topk_randk,
        "induced_topk_natural": _induced_topk_natural,
    }
    if name not in table:
        raise ValueError(f"unknown compressor {name!r}; have {sorted(table)}")
    return table[name](**kw)
