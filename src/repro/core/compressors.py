"""Compression operators — the paper's Definitions 1-3 as composable JAX objects.

Two families:

  * ``Unbiased`` (class ``U(omega)``, Def. 2):   E C(x) = x,
        E ||C(x) - x||^2 <= omega ||x||^2.
  * ``Contractive`` (class ``B(delta)``, Def. 1): E ||C(x) - x||^2 <= (1-delta)||x||^2.

Every operator works on arrays of arbitrary shape (treated as flattened
vectors where ordering matters) and is a hashable frozen dataclass so it
can be closed over inside ``jax.jit``.  Each operator reports the number
of *bits on the wire* for one message (``bits(d)``) so algorithms can be
compared in communicated-bits space, as in the paper's experiments.

Operators expose:

  ``__call__(key, x)``      dense compress->decompress round trip (what the
                            optimizer math sees).
  ``omega(d)`` / ``delta(d)``  variance constants for step-size rules.
  ``bits(d)``               wire size of one compressed d-vector message.

The payload-reducing structured forms (values-only Rand-K with a shared
pattern, int8 blocks for the quantized ring all-reduce) live in
``repro.dist.collectives`` — here we keep the operator algebra.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

FLOAT_BITS = 32  # wire width of an uncompressed scalar


def _flat(x):
    return jnp.reshape(x, (-1,))


def _k_of(q: float, d: int) -> int:
    """Number of kept coordinates for a sparsifier with keep-fraction q."""
    return max(1, int(round(q * d)))


# --------------------------------------------------------------------------
# Base classes
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Compressor:
    """Base class.  Subclasses are frozen dataclasses => hashable/static."""

    def __call__(self, key: jax.Array, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def bits(self, d: int) -> float:
        raise NotImplementedError

    @property
    def stochastic(self) -> bool:
        return True


@dataclass(frozen=True)
class Unbiased(Compressor):
    """Marker base for the class U(omega)."""

    def omega(self, d: int) -> float:
        raise NotImplementedError


@dataclass(frozen=True)
class Contractive(Compressor):
    """Marker base for the class B(delta)."""

    def delta(self, d: int) -> float:
        raise NotImplementedError


# --------------------------------------------------------------------------
# Trivial operators
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Identity(Unbiased, Contractive):
    """I in U(0) and B(1): full-precision message."""

    def __call__(self, key, x):
        return x

    def omega(self, d):
        return 0.0

    def delta(self, d):
        return 1.0

    def bits(self, d):
        return FLOAT_BITS * d

    @property
    def stochastic(self):
        return False


@dataclass(frozen=True)
class Zero(Compressor):
    """O — maps everything to zero; 'delta interpreted as 0' in the paper.

    Used as the C_i of plain DCGD (no shift learning) — zero wire cost.
    """

    def __call__(self, key, x):
        return jnp.zeros_like(x)

    def delta(self, d):
        return 0.0

    def bits(self, d):
        return 0.0

    @property
    def stochastic(self):
        return False


# --------------------------------------------------------------------------
# Unbiased operators  U(omega)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class RandK(Unbiased):
    """Random sparsification (eq. 2): keep a uniformly random K-subset,
    scale by d/K.  RandK(q) keeps K = round(q*d) coords; omega = d/K - 1.

    ``shared_pattern`` marks that all workers use the same key for a given
    step (correlated sampling).  It does not change the operator law on a
    single input, but it makes the *aggregated* message K-dimensional —
    exploited by ``dist.collectives.randk_shared_mean``.
    """

    q: float = 0.1
    shared_pattern: bool = False

    def __call__(self, key, x):
        shape = x.shape
        xf = _flat(x)
        d = xf.shape[0]
        k = _k_of(self.q, d)
        # Uniform K-subset via random permutation ranks.
        scores = jax.random.uniform(key, (d,))
        thresh = jnp.sort(scores)[k - 1]
        mask = (scores <= thresh).astype(x.dtype)
        out = xf * mask * (d / k)
        return jnp.reshape(out, shape)

    def omega(self, d):
        return d / _k_of(self.q, d) - 1.0

    def bits(self, d):
        k = _k_of(self.q, d)
        if self.shared_pattern:
            return FLOAT_BITS * k  # indices implied by shared seed
        return k * (FLOAT_BITS + math.ceil(math.log2(max(d, 2))))


@dataclass(frozen=True)
class BernoulliP(Unbiased):
    """B_p — full vector scaled 1/p with prob. p, else 0.  omega = 1/p - 1.

    The C_i of Rand-DIANA (Table 2): the shift is refreshed w.p. p.
    """

    p: float = 0.1

    def __call__(self, key, x):
        keep = jax.random.bernoulli(key, self.p)
        return jnp.where(keep, x / self.p, jnp.zeros_like(x))

    def omega(self, d):
        return 1.0 / self.p - 1.0

    def bits(self, d):
        return self.p * FLOAT_BITS * d  # expected bits


@dataclass(frozen=True)
class NaturalDithering(Unbiased):
    """Natural dithering with s levels w.r.t. the l2 norm
    (Horváth et al., 2019a) — the 'ND' compressor of the paper's Fig. 1.

    Levels are the exponent lattice {2^0, 2^-1, ..., 2^-(s-1), 0} applied
    to |x|/||x||_2, with unbiased stochastic rounding between neighbouring
    levels.  omega <= 1/8 + 2^(1-s) * min(sqrt(d), 2^(1-s) d)  (their Thm 1).
    """

    s: int = 8

    def __call__(self, key, x):
        xf = x.astype(jnp.float32)
        norm = jnp.sqrt(jnp.sum(xf * xf))
        safe = jnp.maximum(norm, jnp.finfo(jnp.float32).tiny)
        y = jnp.abs(xf) / safe  # in [0, 1]
        # exponent index j: level_hi = 2^-j, level_lo = 2^-(j+1)
        j = jnp.clip(jnp.floor(-jnp.log2(jnp.maximum(y, 1e-38))), 0, self.s - 1)
        hi = jnp.exp2(-j)
        lo = jnp.where(j >= self.s - 1, 0.0, jnp.exp2(-(j + 1.0)))
        # Stochastic rounding between lo and hi, unbiased in y.
        p_hi = (y - lo) / jnp.maximum(hi - lo, 1e-38)
        u = jax.random.uniform(key, x.shape)
        lvl = jnp.where(u < p_hi, hi, lo)
        lvl = jnp.where(y == 0.0, 0.0, lvl)
        return (jnp.sign(xf) * norm * lvl).astype(x.dtype)

    def omega(self, d):
        t = 2.0 ** (1 - self.s)
        return 0.125 + t * min(math.sqrt(d), t * d)

    def bits(self, d):
        # sign + level index per coordinate, one f32 norm.
        return d * (1 + math.ceil(math.log2(self.s + 1))) + FLOAT_BITS


@dataclass(frozen=True)
class NaturalCompression(Unbiased):
    """C_nat — stochastic rounding to the nearest powers of two.
    omega = 1/8; ~9 bits/coordinate (sign + 8-bit exponent)."""

    def __call__(self, key, x):
        # elementwise and SHAPE-PRESERVING: never flattens, so sharded
        # gradient leaves stay sharded (no spurious all-gathers).
        xf = x.astype(jnp.float32)
        a = jnp.abs(xf)
        e = jnp.floor(jnp.log2(jnp.maximum(a, 1e-38)))
        lo = jnp.exp2(e)
        p_hi = a / lo - 1.0  # in [0,1): distance to 2^e within [2^e, 2^{e+1})
        u = jax.random.uniform(key, x.shape)
        out = jnp.where(u < p_hi, 2.0 * lo, lo)
        out = jnp.where(a == 0.0, 0.0, out) * jnp.sign(xf)
        return out.astype(x.dtype)

    def omega(self, d):
        return 0.125

    def bits(self, d):
        return 9 * d


@dataclass(frozen=True)
class TernGrad(Unbiased):
    """Ternary quantization (Wen et al., 2017): sign(x)*||x||_inf*Bern(|x|/||x||_inf).

    Unbiased; omega is data dependent, bounded by sqrt(d) for the worst case.
    """

    def __call__(self, key, x):
        xf = x.astype(jnp.float32)
        m = jnp.maximum(jnp.max(jnp.abs(xf)), jnp.finfo(jnp.float32).tiny)
        p = jnp.abs(xf) / m
        b = jax.random.bernoulli(key, p).astype(jnp.float32)
        return (jnp.sign(xf) * m * b).astype(x.dtype)

    def omega(self, d):
        return math.sqrt(d)  # worst-case bound

    def bits(self, d):
        return 2 * d + FLOAT_BITS  # {-1,0,1} per coord + scale


@dataclass(frozen=True)
class Int8Stochastic(Unbiased):
    """Linear int8 quantization with per-tensor max-scale and stochastic
    rounding (unbiased).  The operator of the q8 ring all-reduce."""

    levels: int = 127

    def __call__(self, key, x):
        xf = x.astype(jnp.float32)
        # floor well above subnormal: tiny/levels would flush to zero -> NaN
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / self.levels
        y = xf / scale
        lo = jnp.floor(y)
        u = jax.random.uniform(key, x.shape)
        q = lo + (u < (y - lo)).astype(jnp.float32)
        return (q * scale).astype(x.dtype)

    def omega(self, d):
        # ||C(x)-x||^2 <= d*scale^2/4 <= d * ||x||^2/(4*levels^2) elementwise bound
        return d / (4.0 * self.levels**2)

    def bits(self, d):
        return 8 * d + FLOAT_BITS


# --------------------------------------------------------------------------
# Contractive (biased) operators  B(delta)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class TopK(Contractive):
    """Greedy sparsification: keep the K = round(q*d) largest-magnitude
    coordinates.  TopK in B(K/d)."""

    q: float = 0.1

    def __call__(self, key, x):
        shape = x.shape
        xf = _flat(x)
        d = xf.shape[0]
        k = _k_of(self.q, d)
        a = jnp.abs(xf)
        thresh = jax.lax.top_k(a, k)[0][-1]
        mask = (a >= thresh).astype(x.dtype)
        # Tie-break: top_k keeps exactly k, the mask may keep more on ties.
        # Acceptable for a contractive operator (keeps >= k coords).
        return jnp.reshape(xf * mask, shape)

    def delta(self, d):
        return _k_of(self.q, d) / d

    def bits(self, d):
        k = _k_of(self.q, d)
        return k * (FLOAT_BITS + math.ceil(math.log2(max(d, 2))))

    @property
    def stochastic(self):
        return False


@dataclass(frozen=True)
class ScaledSign(Contractive):
    """(||x||_1 / d) * sign(x)  (Karimireddy et al.) in B(||x||_1^2/(d||x||_2^2)),
    worst-case delta = 1/d."""

    def __call__(self, key, x):
        s = jnp.mean(jnp.abs(x.astype(jnp.float32)))
        return (s * jnp.sign(x.astype(jnp.float32))).astype(x.dtype)

    def delta(self, d):
        return 1.0 / d

    def bits(self, d):
        return d + FLOAT_BITS

    @property
    def stochastic(self):
        return False


# --------------------------------------------------------------------------
# Induced compressor (Def. 4 / Lemma 3)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Induced(Unbiased):
    """C_ind(x) = C(x) + Q(x - C(x)) in U(omega*(1-delta)) for C in B(delta),
    Q in U(omega).  Turns a biased operator into an unbiased one with
    strictly smaller variance than Q alone (Horváth & Richtárik, 2021)."""

    c: Contractive = dataclasses.field(default_factory=lambda: TopK(0.1))
    q: Unbiased = dataclasses.field(default_factory=lambda: RandK(0.1))

    def __call__(self, key, x):
        kc, kq = jax.random.split(key)
        cx = self.c(kc, x)
        return cx + self.q(kq, x - cx)

    def omega(self, d):
        return self.q.omega(d) * (1.0 - self.c.delta(d))

    def bits(self, d):
        return self.c.bits(d) + self.q.bits(d)


# --------------------------------------------------------------------------
# Shifted compression (Def. 3 / Lemma 1)
# --------------------------------------------------------------------------


def shifted(q: Compressor, h: jax.Array, key: jax.Array, x: jax.Array) -> jax.Array:
    """Q_h(x) = h + Q(x - h): the shifted compressor of Definition 3.

    If Q in U(omega; 0) then the returned operator is in U(omega; h)
    (Lemma 1 with v = h).  This one-liner is the paper's core object.
    """
    return h + q(key, x - h)


def leaf_keys(key: jax.Array, tree) -> list:
    """Deterministic per-leaf keys: fold the leaf index into ``key``."""
    leaves = jax.tree_util.tree_leaves(tree)
    return [jax.random.fold_in(key, i) for i in range(len(leaves))]


def tree_compress(q: Compressor, key: jax.Array, tree):
    """Apply a compressor leaf-wise to a pytree with decorrelated keys."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = [jax.random.fold_in(key, i) for i in range(len(leaves))]
    out = [q(k, leaf) for k, leaf in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_shifted_compress(q: Compressor, key: jax.Array, tree, shift_tree):
    """Leaf-wise  h + Q(x - h)  over matching pytrees."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    hleaves = jax.tree_util.tree_leaves(shift_tree)
    keys = [jax.random.fold_in(key, i) for i in range(len(leaves))]
    out = [shifted(q, h, k, x) for k, x, h in zip(keys, leaves, hleaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_bits(q: Compressor, tree) -> float:
    """Total wire bits for one compressed message of this pytree."""
    return float(
        sum(q.bits(int(leaf.size)) for leaf in jax.tree_util.tree_leaves(tree))
    )


def tree_size(tree) -> int:
    return int(sum(leaf.size for leaf in jax.tree_util.tree_leaves(tree)))


# Registry used by configs / CLI flags.
def make_compressor(name: str, **kw) -> Compressor:
    table = {
        "identity": Identity,
        "zero": Zero,
        "randk": RandK,
        "bernoulli": BernoulliP,
        "natural_dithering": NaturalDithering,
        "natural": NaturalCompression,
        "terngrad": TernGrad,
        "int8": Int8Stochastic,
        "topk": TopK,
        "sign": ScaledSign,
        "induced": Induced,
        # convenience instances of the induced compressor (Lemma 3):
        # biased TopK wrapped unbiased by RandK / natural compression
        "induced_topk_randk": lambda q=0.1, **k2: Induced(
            c=TopK(q), q=RandK(q)),
        "induced_topk_natural": lambda q=0.1, **k2: Induced(
            c=TopK(q), q=NaturalCompression()),
    }
    if name not in table:
        raise ValueError(f"unknown compressor {name!r}; have {sorted(table)}")
    return table[name](**kw)
