"""Shift update rules — Section 3 of the paper.

A *shift rule* owns everything the meta-algorithm DCGD-SHIFT leaves open
(the coloured line of Alg. 1): how the per-worker shifts ``h_i`` start,
how the worker messages are formed from the shifted gradients, and how
``h_i^{k+1}`` is produced.  Rules are frozen dataclasses (static under
jit); their mutable state is the stacked shift pytree ``h`` with leading
worker axis ``W`` plus a bits counter.

All communication goes through a ``repro.comm.Channel``: the rule calls
``channel.uplink`` (codec encode -> wire -> decode, with STRUCTURAL bits
accounting from the actual payloads) and ``channel.reduce_mean`` (the
master-side aggregation in the channel's wire format).  The default
``SimChannel`` is the paper's vmapped parameter server; the production
``MeshChannel`` swaps in transparently.

All rules implement::

    init(wgrads_like)                        -> h0        (W-stacked pytree)
    step(q, key, wgrads, h, channel=None)    -> (g_bar, h_new, bits)

where ``wgrads`` is the stacked per-worker gradient pytree (leaves shaped
``(W, *param.shape)``), ``g_bar`` is the master's gradient estimator (no
worker axis), and ``bits`` is the total uplink wire cost of the step (a
traced scalar — Rand-DIANA's cost is a random variable).

DIANA-like rules couple the estimator and the shift update (they reuse
the same compressed message), which is why the rule computes both.
``EF21Shift`` is the error-feedback member of the family: its message is
a CONTRACTIVE compression of the residual, integrated into the shift.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.comm.channel import Channel, SimChannel
from repro.core.compressors import FLOAT_BITS, Compressor, Zero

tmap = jax.tree_util.tree_map


def _tree_mean_w(tree):
    """Mean over the leading worker axis, leaf-wise."""
    return tmap(lambda a: jnp.mean(a, axis=0), tree)


def worker_compress(q: Compressor, key: jax.Array, wtree):
    """Compress each worker's slice of a W-stacked pytree independently.

    Compatibility wrapper over ``SimChannel.uplink`` (same key
    derivation: per-leaf fold-in, then per-worker split unless the codec
    declares a shared pattern or is deterministic).  Prefer the channel
    when wire-bit accounting is also needed.
    """
    m, _ = SimChannel().uplink(q, key, wtree)
    return m


def stack_like(tree, w: int):
    """Zeros with a leading worker axis mirroring ``tree``."""
    return tmap(lambda a: jnp.zeros((w, *a.shape), a.dtype), tree)


def _chan(channel: Optional[Channel]) -> Channel:
    return channel if channel is not None else SimChannel()


# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ShiftRule:
    def init(self, wgrads_like):
        raise NotImplementedError

    def step(self, q: Compressor, key, wgrads, h, channel: Optional[Channel] = None):
        raise NotImplementedError


@dataclass(frozen=True)
class FixedShift(ShiftRule):
    """DCGD-SHIFT with constant shifts (eq. 6).  ``h0 = 0`` gives plain
    DCGD (Khirirat et al., 2018).  Theorem 1: linear to a neighborhood
    proportional to mean_i ||grad_i(x*) - h_i||^2."""

    def init(self, wgrads_like):
        return tmap(jnp.zeros_like, wgrads_like)

    def step(self, q, key, wgrads, h, channel=None):
        ch = _chan(channel)
        ku, ka = jax.random.split(key)
        diff = tmap(lambda g, s: g - s, wgrads, h)
        m, bits = ch.uplink(q, ku, diff)
        g_bar = ch.reduce_mean(ka, tmap(lambda s, mm: s + mm, h, m))
        return g_bar, h, bits


@dataclass(frozen=True)
class StarShift(ShiftRule):
    """DCGD-STAR (eq. 8): oracle shifts around grad_i(x*), optionally
    compressed by a contractive C.  Theorem 2: exact linear convergence.

    Impractical by construction (needs the optimum) — included as the
    theoretical reference point, exactly as in the paper.
    """

    c: Compressor = field(default_factory=Zero)

    def init_with_star(self, wgrads_star):
        """State carries the oracle gradients; h starts there too."""
        return {"h": wgrads_star, "star": wgrads_star}

    def init(self, wgrads_like):  # pragma: no cover - guarded
        raise ValueError("StarShift requires init_with_star(grads_at_optimum)")

    def step(self, q, key, wgrads, state, channel=None):
        ch = _chan(channel)
        h, star = state["h"], state["star"]
        kq, kc, ka = jax.random.split(key, 3)
        diff = tmap(lambda g, s: g - s, wgrads, h)
        m, bits_q = ch.uplink(q, kq, diff)
        g_bar = ch.reduce_mean(ka, tmap(lambda s, mm: s + mm, h, m))
        # h_i^{k+1} = g*_i + C(grad_i - g*_i)
        dstar = tmap(lambda g, s: g - s, wgrads, star)
        chm, bits_c = ch.uplink(self.c, kc, dstar)
        h_new = tmap(lambda s, cc: s + cc, star, chm)
        return g_bar, {"h": h_new, "star": star}, bits_q + bits_c


@dataclass(frozen=True)
class DianaShift(ShiftRule):
    """Generalized DIANA (eq. 10): h_i += alpha * Q_ind(grad_i - h_i) with
    Q_ind(x) = C(x) + Q(x - C(x)) the induced compressor; C = Zero recovers
    classic DIANA (eq. 11, Mishchenko et al. 2019).

    The *same* message is used for the gradient estimator and the shift
    update (Section 3.2.1), so with C = Zero nothing extra is ever sent.
    Theorem 3 rate: max{kappa(1 + omega(1-delta)/n), omega(1-delta)}.
    """

    alpha: float = 0.1
    c: Compressor = field(default_factory=Zero)

    def init(self, wgrads_like):
        return tmap(jnp.zeros_like, wgrads_like)

    def step(self, q, key, wgrads, h, channel=None):
        ch = _chan(channel)
        kc, kq, ka = jax.random.split(key, 3)
        diff = tmap(lambda g, s: g - s, wgrads, h)
        cmsg, bits_c = ch.uplink(self.c, kc, diff)
        resid = tmap(lambda d, cc: d - cc, diff, cmsg)
        qmsg, bits_q = ch.uplink(q, kq, resid)
        # m_full = Q_ind(grad - h) = c + Q(grad - h - c)
        m_full = tmap(lambda cc, mm: cc + mm, cmsg, qmsg)
        g_bar = ch.reduce_mean(ka, tmap(lambda s, mf: s + mf, h, m_full))
        h_new = tmap(lambda s, mf: s + self.alpha * mf, h, m_full)
        return g_bar, h_new, bits_c + bits_q


@dataclass(frozen=True)
class RandDianaShift(ShiftRule):
    """Rand-DIANA (eq. 12, *new in the paper*): the shift is the gradient
    at a lazily-refreshed reference point, h_i = grad_i(w_i), where w_i is
    reset to x^k with probability p_i (Loopless-SVRG style).

    Because the refresh happens at the current point, h_i^{k+1} is exactly
    the gradient the worker just computed — no extra gradient evaluation —
    but the refresh message is a *full* d-vector, sent rarely (expected
    p*32d bits/step).  Theorem 4: max{kappa(1 + omega/n), 1/p} with a
    dramatically simpler analysis than DIANA.
    """

    p: float = 0.1

    def init(self, wgrads_like):
        return tmap(jnp.zeros_like, wgrads_like)

    def step(self, q, key, wgrads, h, channel=None):
        ch = _chan(channel)
        kq, kb, ka = jax.random.split(key, 3)
        diff = tmap(lambda g, s: g - s, wgrads, h)
        m, bits = ch.uplink(q, kq, diff)
        g_bar = ch.reduce_mean(ka, tmap(lambda s, mm: s + mm, h, m))
        w = jax.tree_util.tree_leaves(wgrads)[0].shape[0]
        refresh = jax.random.bernoulli(kb, self.p, (w,))

        def upd(s, g):
            mask = refresh.reshape((w,) + (1,) * (g.ndim - 1))
            return jnp.where(mask, g, s)

        h_new = tmap(upd, h, wgrads)
        # refresh messages are uncompressed f32 vectors (structurally
        # FLOAT_BITS per scalar), sent only by the workers that fired
        one = tmap(lambda a: a[0], wgrads)
        d = sum(int(l.size) for l in jax.tree_util.tree_leaves(one))
        bits = bits + jnp.sum(refresh) * float(FLOAT_BITS * d)
        return g_bar, h_new, bits


@dataclass(frozen=True)
class EF21Shift(ShiftRule):
    """EF21 error feedback (Richtárik, Sokolov & Fatkhullin, 2021) in the
    shifted-compression template.

    The wire message is the CONTRACTIVE compression of the gradient-shift
    residual, and the shift integrates it::

        c_i     = C(grad_i - h_i)           (the payload on the wire)
        g^k     = mean_i (h_i + c_i)        (master estimator)
        h_i^{k+1} = h_i + c_i               (worker-local, no extra comm)

    Because h_i tracks grad_i at the contraction rate delta, biased
    operators (TopK, ScaledSign) converge EXACTLY where plain DCGD with
    the same operator stalls at a bias floor — the error-feedback
    mechanism the ROADMAP's ``ef21`` comm mode ships.  The master's
    aggregated shift is tracked incrementally (h_bar += mean_i c_i) just
    like DIANA's, so no uncompressed collective ever materializes.
    """

    def init(self, wgrads_like):
        return tmap(jnp.zeros_like, wgrads_like)

    def step(self, q, key, wgrads, h, channel=None):
        ch = _chan(channel)
        ku, ka = jax.random.split(key)
        diff = tmap(lambda g, s: g - s, wgrads, h)
        c, bits = ch.uplink(q, ku, diff)
        g_bar = ch.reduce_mean(ka, tmap(lambda s, cc: s + cc, h, c))
        h_new = tmap(lambda s, cc: s + cc, h, c)
        return g_bar, h_new, bits


def make_shift_rule(name: str, **kw) -> ShiftRule:
    table = {
        "fixed": FixedShift,
        "dcgd": FixedShift,
        "star": StarShift,
        "diana": DianaShift,
        "rand_diana": RandDianaShift,
        "ef21": EF21Shift,
    }
    if name not in table:
        raise ValueError(f"unknown shift rule {name!r}; have {sorted(table)}")
    return table[name](**kw)
