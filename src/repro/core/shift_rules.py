"""Shift update rules — Section 3 of the paper, as ONE phased engine.

A *shift rule* owns everything the meta-algorithm DCGD-SHIFT leaves open
(the coloured line of Alg. 1): how the per-worker shifts ``h_i`` start,
what message goes on the wire, and how ``h_i^{k+1}`` is produced.  Rules
are frozen dataclasses (static under jit); their mutable state is the
stacked shift pytree ``h`` (leading worker axis ``W``) plus the master's
aggregated shift ``h_bar`` — tracked INCREMENTALLY, so no uncompressed
collective over ``h`` ever materializes (Alg. 1 line 14, as the paper
notes for DIANA: ``h^{k+1} = h^k + alpha * m_bar^k``).  Over LOSSY
aggregation formats (the q8 rings, shared Rand-K) the incremental
``h_bar`` carries the per-step aggregation noise as a zero-mean random
walk relative to ``mean_i h_i`` — inherent to the tracking, unbiased,
and absent on dense/sim aggregation; see the ARCHITECTURE.md
"Algorithm layer" footnote.

Every rule implements the same PHASED protocol, and the same rule object
drives all three transports (the vmapped parameter-server ``SimChannel``,
the production ``MeshChannel``, and the bucketed overlapped
``AsyncChannel``) — the trainer contains no per-rule update math::

    init(wgrads_like)            -> h       worker-stacked state (None if
                                            the rule is stateless)
    init_bar(wgrads_like)        -> h_bar   master aggregated shift
    message_leaf(q, key, g, h)   -> (m, bits)
                                            ONE leaf's wire message; the
                                            key is already folded to the
                                            leaf's GLOBAL tree position,
                                            so any bucket partition of
                                            the tree reproduces it
                                            bit-exactly
    message(q, key, wgrads, h)   -> (m, bits)
                                            derived: message_leaf mapped
                                            over the tree
    aux(key, wgrads, h)          -> (aux, extra_bits)
                                            tree-level extras that are
                                            not per-leaf wire messages
                                            (Rand-DIANA's refresh draw
                                            and its dense refresh cost)
    apply(wgrads, m, m_bar, h, h_bar, aux)
                                 -> (g_bar, h_new, h_bar_new)
                                            estimator + shift update
                                            from the AGGREGATED message
    round(q, key, wgrads, h, h_bar, channel=None)
                                 -> (g_bar, h_new, h_bar_new, bits)
                                            one full communication round,
                                            scheduled by the channel
                                            (``Channel.shift_round``);
                                            the AsyncChannel interleaves
                                            message/reduce per bucket

``wgrads`` is the stacked per-worker gradient pytree (leaves shaped
``(W, *param.shape)``), ``g_bar`` the master's gradient estimator (no
worker axis), and ``bits`` the total uplink wire cost of the round — a
traced scalar computed STRUCTURALLY from the actual payloads
(``Compressor.wire_bits``); there are no hand-written bit formulas here.

DIANA-like rules couple the estimator and the shift update (they reuse
the same compressed message), which is why ``apply`` computes both.
``EF21Shift`` is the error-feedback member of the family (contractive
message integrated into the shift); ``EFBVShift`` generalizes it with
the EF-BV ``eta``/``nu`` knobs (Condat, Li & Richtárik, 2022), covering
EF21 (``eta = nu = 1``) and DIANA (unbiased Q, ``eta = 1/(1+omega)``,
``nu = 1``) as special cases.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.comm.channel import Channel, SimChannel
from repro.comm.wire import (
    encode_decode_workers,
    encode_workers,
    leaf_key,
    worker_keys,
)
from repro.core.compressors import Compressor, Zero, wire_bits

tmap = jax.tree_util.tree_map

#: PRNG keys are raw (2,) uint32 throughout the repo
_KEY_SDS = jax.ShapeDtypeStruct((2,), jnp.uint32)


def _tree_mean_w(tree):
    """Mean over the leading worker axis, leaf-wise."""
    return tmap(lambda a: jnp.mean(a, axis=0), tree)


def worker_compress(q: Compressor, key: jax.Array, wtree):
    """Compress each worker's slice of a W-stacked pytree independently.

    Compatibility wrapper over ``SimChannel.uplink`` (same key
    derivation: per-leaf fold-in, then per-worker split unless the codec
    declares a shared pattern or is deterministic).  Prefer the channel
    when wire-bit accounting is also needed.
    """
    m, _ = SimChannel().uplink(q, key, wtree)
    return m


def stack_like(tree, w: int):
    """Zeros with a leading worker axis mirroring ``tree``."""
    return tmap(lambda a: jnp.zeros((w, *a.shape), a.dtype), tree)


def _chan(channel: Optional[Channel]) -> Channel:
    return channel if channel is not None else SimChannel()


def _zeros(tree):
    """Zeros matching a tree of arrays OR ``ShapeDtypeStruct`` leaves
    (rule state is initializable AOT, e.g. from ``jax.eval_shape``)."""
    return tmap(lambda a: jnp.zeros(a.shape, a.dtype), tree)


def residual_sq_diag(wgrads, h):
    """The paper's headline probe: how small has shifting made the
    compressed vector?  Returns f32 scalars

    * ``grad_sq``           = ``mean_i ||g_i||^2``
    * ``shift_residual_sq`` = ``mean_i ||g_i - h_i||^2``

    over the worker axis.  ``h is None`` (stateless rules — plain DCGD)
    means the wire carries ``g_i`` itself, so the residual IS the
    gradient norm and the ratio stays pinned at 1; DIANA / EF-BV drive
    it toward 0 as ``h_i -> grad f_i(x^*)``.  Pure jnp — safe inside a
    jitted ``diag=True`` step (no state, no extra randomness).
    """
    leaves = jax.tree_util.tree_leaves(wgrads)
    w = leaves[0].shape[0]

    def _sq(t):
        return sum(
            jnp.sum(jnp.square(a.astype(jnp.float32)))
            for a in jax.tree_util.tree_leaves(t)
        )

    grad_sq = _sq(wgrads) / w
    if h is None:
        resid_sq = grad_sq
    else:
        resid_sq = _sq(tmap(lambda g, hh: g - hh, wgrads, h)) / w
    return {"grad_sq": grad_sq, "shift_residual_sq": resid_sq}


def dense_message_bits(wgrads_like) -> float:
    """STRUCTURAL wire cost of one worker's uncompressed (dense) message:
    the ``wire_bits`` of the identity payload — per-leaf inner numel at
    the leaf's true dtype width, never a hand-written ``32 * d``."""
    return float(
        sum(
            wire_bits(jax.ShapeDtypeStruct(a.shape[1:], a.dtype))
            for a in jax.tree_util.tree_leaves(wgrads_like)
        )
    )


# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ShiftRule:
    """Base of the phased protocol (see module docstring).

    The default ``message_leaf`` compresses the gradient-shift residual
    ``g - h`` with the round's codec ``q`` — the shifted-compression
    message every rule in the paper sends; rules whose message differs
    (generalized DIANA's induced two-part message) override it.
    """

    #: rules with ``stateful = False`` keep ``h``/``h_bar`` as ``None``
    #: (the trainer then allocates no shift tensors at all)
    stateful: bool = field(default=True, init=False, repr=False)

    #: ``fusible = True`` means the rule's ``apply`` consumes only the
    #: per-worker MESSAGES (never the dense ``wgrads``) and its round
    #: follows the standard message -> aux -> reduce -> apply schedule,
    #: so the fused-backward path (``repro.comm.fused_vjp``) can emit
    #: the messages as the cotangents themselves and the dense gradients
    #: never materialize.  Rules that read ``wgrads`` in ``apply``
    #: (Rand-DIANA's refresh) or override the round wholesale (StarShift)
    #: set this to ``False`` and are rejected by ``check_fusible``.
    fusible: bool = field(default=True, init=False, repr=False)

    # -- state ------------------------------------------------------------

    def init(self, wgrads_like):
        """Worker-stacked shift state (``None`` for stateless rules).
        Accepts arrays or ``ShapeDtypeStruct`` leaves."""
        return _zeros(wgrads_like) if self.stateful else None

    def init_bar(self, wgrads_like):
        """The master's aggregated shift ``h_bar`` (no worker axis)."""
        if not self.stateful:
            return None
        return tmap(lambda a: jnp.zeros(a.shape[1:], a.dtype), wgrads_like)

    # -- phases -----------------------------------------------------------

    def message_leaf(self, q: Compressor, key, g, h):
        """One leaf's wire message: ``Q(g - h)`` encoded per worker.

        ``key`` must already be folded to the leaf's GLOBAL tree
        position — the invariant that makes any bucket partition of the
        tree (the overlap runtime) bit-exact with the whole-tree round.
        Returns ``(decoded W-stacked message, structural wire bits)``.
        """
        diff = g if h is None else g - h
        payload, m = encode_decode_workers(q, key, diff)
        return m, q.wire_bits(payload)

    def message(self, q: Compressor, key, wgrads, h):
        """``message_leaf`` mapped over the tree with global-position
        key folding (identical derivation to ``Channel.uplink``)."""
        leaves, treedef = jax.tree_util.tree_flatten(wgrads)
        h_leaves = (
            [None] * len(leaves) if h is None else jax.tree_util.tree_leaves(h)
        )
        out = []
        bits = jnp.zeros((), jnp.float32)
        for i, (g, hl) in enumerate(zip(leaves, h_leaves)):
            m, b = self.message_leaf(q, leaf_key(key, i), g, hl)
            out.append(m)
            bits = bits + b
        return jax.tree_util.tree_unflatten(treedef, out), bits

    # -- fused-backward decomposition of message_leaf ----------------------
    #
    # ``message_leaf`` = vmap(message_leaf_worker) over the keys that
    # ``message_keys`` derives — the SAME primitives under the same vmap
    # batching, so the fused-VJP path (which runs message_leaf_worker
    # inside each worker's backward pass, under the per-worker vmap of
    # ``dist.worker_grads``) is bit-exact with the post-hoc encode.
    # ``message_bits_aot`` is the leaf's structural wire cost computed
    # from shapes alone (no payload materialized): the fused round's
    # accounting, equal to message_leaf's bits for every codec whose
    # wire_bits is structural (all registered CLI compressors; the
    # data-dependent BernoulliP is the documented exception).

    def message_keys(self, q: Compressor, key, w: int):
        """The per-worker key pytree ``message_leaf`` consumes for one
        leaf, stacked on a leading ``(W,)`` axis: row ``i`` fed to
        ``message_leaf_worker`` reproduces worker ``i``'s slice of
        ``message_leaf`` bitwise.  ``key`` is the leaf-folded round key."""
        return worker_keys(q, key, w)

    def message_leaf_worker(self, q: Compressor, wkey, g, h):
        """ONE worker's slice of ``message_leaf``: the per-row body of
        ``encode_decode_workers`` on that worker's dense gradient ``g``
        and shift ``h`` (both WITHOUT the worker axis).  ``wkey`` is one
        row of ``message_keys``.  Returns the decoded message only —
        bits are accounted structurally via ``message_bits_aot``."""
        diff = g if h is None else g - h
        payload, meta = q.encode(wkey, diff)
        return q.decode(payload, meta,
                        jax.ShapeDtypeStruct(diff.shape, diff.dtype))

    def message_bits_aot(self, q: Compressor, wleaf_like) -> float:
        """Structural wire bits of one leaf's W-stacked message, from
        shapes alone (``jax.eval_shape`` of the encode)."""
        sds = jax.ShapeDtypeStruct(tuple(wleaf_like.shape), wleaf_like.dtype)
        payload, _ = jax.eval_shape(
            lambda k, leaf: encode_workers(q, k, leaf), _KEY_SDS, sds
        )
        return float(q.wire_bits(payload))

    def aux(self, key, wgrads, h):
        """Tree-level extras: ``(aux carried to apply, extra wire bits)``."""
        return None, jnp.zeros((), jnp.float32)

    def apply(self, wgrads, m, m_bar, h, h_bar, aux):
        """Estimator + shift update from the aggregated message."""
        raise NotImplementedError

    # -- the composed round -----------------------------------------------

    def round(self, q: Compressor, key, wgrads, h, h_bar,
              channel: Optional[Channel] = None):
        """One full communication round, scheduled by the channel.

        ``Channel.shift_round`` runs message -> aux -> reduce -> apply;
        the overlapped ``AsyncChannel`` overrides the schedule (per
        bucket: message then issue the reduction) without touching the
        math.  Returns ``(g_bar, h_new, h_bar_new, bits)``.
        """
        return _chan(channel).shift_round(self, q, key, wgrads, h, h_bar)

    def step(self, q: Compressor, key, wgrads, h,
             channel: Optional[Channel] = None):
        """DEPRECATED single-state entry: ``(g_bar, h_new, bits)``.

        Kept for callers that track only ``h``; ``h_bar`` is recomputed
        as the exact worker mean each call, which the incremental
        tracking of ``round`` makes unnecessary.  Prefer ``round``.
        """
        h_bar = None if h is None else _tree_mean_w(h)
        g_bar, h_new, _, bits = self.round(q, key, wgrads, h, h_bar,
                                           channel=channel)
        return g_bar, h_new, bits


@dataclass(frozen=True)
class FixedShift(ShiftRule):
    """DCGD-SHIFT with constant shifts (eq. 6).  ``h = 0`` (the stateless
    default) gives plain DCGD (Khirirat et al., 2018).  Theorem 1:
    linear to a neighborhood proportional to
    mean_i ||grad_i(x*) - h_i||^2.  Nonzero fixed shifts still work:
    pass an ``h``/``h_bar`` pair and ``apply`` leaves them untouched."""

    stateful: bool = field(default=False, init=False, repr=False)

    def apply(self, wgrads, m, m_bar, h, h_bar, aux):
        g_bar = m_bar if h_bar is None else tmap(
            lambda hb, mb: hb + mb, h_bar, m_bar
        )
        return g_bar, h, h_bar


@dataclass(frozen=True)
class StarShift(ShiftRule):
    """DCGD-STAR (eq. 8): oracle shifts around grad_i(x*), optionally
    compressed by a contractive C.  Theorem 2: exact linear convergence.

    Impractical by construction (needs the optimum) — included as the
    theoretical reference point, exactly as in the paper.  Its state is
    the dict ``{"h", "star"}`` and its message has a second (oracle
    refresh) part, so it overrides ``round`` wholesale; it runs on the
    reference ``SimChannel`` only and never rides the mesh or the
    overlap runtime.
    """

    #: overrides the round schedule wholesale -> no fused-backward path
    fusible: bool = field(default=False, init=False, repr=False)

    c: Compressor = field(default_factory=Zero)

    def init_with_star(self, wgrads_star):
        """State carries the oracle gradients; h starts there too."""
        return {"h": wgrads_star, "star": wgrads_star}

    def init(self, wgrads_like):  # pragma: no cover - guarded
        raise ValueError("StarShift requires init_with_star(grads_at_optimum)")

    def init_bar(self, wgrads_like):
        return None

    def round(self, q, key, wgrads, state, h_bar, channel=None):
        ch = _chan(channel)
        h, star = state["h"], state["star"]
        kq, kc, ka = jax.random.split(key, 3)
        diff = tmap(lambda g, s: g - s, wgrads, h)
        m, bits_q = ch.uplink(q, kq, diff)
        g_bar = ch.reduce_mean(ka, tmap(lambda s, mm: s + mm, h, m))
        # h_i^{k+1} = g*_i + C(grad_i - g*_i)
        dstar = tmap(lambda g, s: g - s, wgrads, star)
        chm, bits_c = ch.uplink(self.c, kc, dstar)
        h_new = tmap(lambda s, cc: s + cc, star, chm)
        return g_bar, {"h": h_new, "star": star}, None, bits_q + bits_c

    def step(self, q, key, wgrads, state, channel=None):
        g_bar, state_new, _, bits = self.round(q, key, wgrads, state, None,
                                               channel=channel)
        return g_bar, state_new, bits


@dataclass(frozen=True)
class DianaShift(ShiftRule):
    """Generalized DIANA (eq. 10): h_i += alpha * Q_ind(grad_i - h_i) with
    Q_ind(x) = C(x) + Q(x - C(x)) the induced compressor; C = Zero recovers
    classic DIANA (eq. 11, Mishchenko et al. 2019).

    The *same* message is used for the gradient estimator and the shift
    update (Section 3.2.1), so with C = Zero nothing extra is ever sent.
    Theorem 3 rate: max{kappa(1 + omega(1-delta)/n), omega(1-delta)}.
    """

    alpha: float = 0.1
    c: Compressor = field(default_factory=Zero)

    def message_leaf(self, q, key, g, h):
        # the induced two-part message, still leaf-local: C picks the
        # contractive part, Q the unbiased remainder of the residual
        diff = g if h is None else g - h
        kc, kq = jax.random.split(key)
        cpay, cm = encode_decode_workers(self.c, kc, diff)
        qpay, qm = encode_decode_workers(q, kq, diff - cm)
        return cm + qm, self.c.wire_bits(cpay) + q.wire_bits(qpay)

    def message_keys(self, q, key, w):
        # same split as message_leaf, then each part's worker derivation
        kc, kq = jax.random.split(key)
        return {"c": worker_keys(self.c, kc, w),
                "q": worker_keys(q, kq, w)}

    def message_leaf_worker(self, q, wkey, g, h):
        diff = g if h is None else g - h
        sds = jax.ShapeDtypeStruct(diff.shape, diff.dtype)
        cpay, cmeta = self.c.encode(wkey["c"], diff)
        cm = self.c.decode(cpay, cmeta, sds)
        qpay, qmeta = q.encode(wkey["q"], diff - cm)
        return cm + q.decode(qpay, qmeta, sds)

    def message_bits_aot(self, q, wleaf_like):
        sds = jax.ShapeDtypeStruct(tuple(wleaf_like.shape), wleaf_like.dtype)
        cpay, _ = jax.eval_shape(
            lambda k, leaf: encode_workers(self.c, k, leaf), _KEY_SDS, sds
        )
        qpay, _ = jax.eval_shape(
            lambda k, leaf: encode_workers(q, k, leaf), _KEY_SDS, sds
        )
        return float(self.c.wire_bits(cpay)) + float(q.wire_bits(qpay))

    def apply(self, wgrads, m, m_bar, h, h_bar, aux):
        a = self.alpha
        g_bar = tmap(lambda hb, mb: hb + mb, h_bar, m_bar)
        h_new = tmap(lambda s, mm: s + a * mm, h, m)
        h_bar_new = tmap(lambda hb, mb: hb + a * mb, h_bar, m_bar)
        return g_bar, h_new, h_bar_new


@dataclass(frozen=True)
class RandDianaShift(ShiftRule):
    """Rand-DIANA (eq. 12, *new in the paper*): the shift is the gradient
    at a lazily-refreshed reference point, h_i = grad_i(w_i), where w_i is
    reset to x^k with probability p_i (Loopless-SVRG style).

    Because the refresh happens at the current point, h_i^{k+1} is exactly
    the gradient the worker just computed — no extra gradient evaluation —
    but the refresh message is a *full* dense vector, sent rarely
    (expected ``p *`` one dense message per step, charged structurally at
    the leaves' true dtype widths).  Theorem 4: max{kappa(1 + omega/n),
    1/p} with a dramatically simpler analysis than DIANA.
    """

    #: ``apply`` refreshes shifts from the DENSE wgrads, which never
    #: materialize on the fused-backward path
    fusible: bool = field(default=False, init=False, repr=False)

    p: float = 0.1

    def aux(self, key, wgrads, h):
        w = jax.tree_util.tree_leaves(wgrads)[0].shape[0]
        refresh = jax.random.bernoulli(key, self.p, (w,))
        # refresh messages are uncompressed dense vectors, sent only by
        # the workers that fired — structural wire_bits, not 32*d
        extra = jnp.sum(refresh) * dense_message_bits(wgrads)
        return refresh, extra

    def apply(self, wgrads, m, m_bar, h, h_bar, refresh):
        g_bar = tmap(lambda hb, mb: hb + mb, h_bar, m_bar)
        w = refresh.shape[0]

        def upd(s, g):
            mask = refresh.reshape((w,) + (1,) * (g.ndim - 1))
            return jnp.where(mask, g, s)

        h_new = tmap(upd, h, wgrads)
        h_bar_new = tmap(
            lambda hb, s, n: hb + jnp.mean(n - s, axis=0), h_bar, h, h_new
        )
        return g_bar, h_new, h_bar_new


@dataclass(frozen=True)
class EF21Shift(ShiftRule):
    """EF21 error feedback (Richtárik, Sokolov & Fatkhullin, 2021) in the
    shifted-compression template.

    The wire message is the CONTRACTIVE compression of the gradient-shift
    residual, and the shift integrates it::

        c_i     = C(grad_i - h_i)           (the payload on the wire)
        g^k     = mean_i (h_i + c_i)        (master estimator)
        h_i^{k+1} = h_i + c_i               (worker-local, no extra comm)

    Because h_i tracks grad_i at the contraction rate delta, biased
    operators (TopK, ScaledSign) converge EXACTLY where plain DCGD with
    the same operator stalls at a bias floor — the error-feedback
    mechanism the ROADMAP's ``ef21`` comm mode ships.  The master's
    aggregated shift is tracked incrementally (h_bar += mean_i c_i) just
    like DIANA's, so no uncompressed collective ever materializes.
    """

    def apply(self, wgrads, m, m_bar, h, h_bar, aux):
        g_bar = tmap(lambda hb, mb: hb + mb, h_bar, m_bar)
        h_new = tmap(lambda s, mm: s + mm, h, m)
        h_bar_new = tmap(lambda hb, mb: hb + mb, h_bar, m_bar)
        return g_bar, h_new, h_bar_new


@dataclass(frozen=True)
class EFBVShift(ShiftRule):
    """EF-BV (Condat, Li & Richtárik, 2022): the unified error-feedback /
    variance-reduction mechanism for Biased *and* unbiased compressors,
    the recursive variance-reduced generalization of EF21::

        m_i       = C(grad_i - h_i)          (the wire message)
        h_i^{k+1} = h_i + eta * m_i          (shift integration, rate eta)
        g^k       = h_bar + nu * m_bar       (estimator mixing nu)
        h_bar^{k+1} = h_bar + eta * m_bar

    ``eta`` (the paper's lambda) damps the shift recursion so the shift
    error contracts even for NON-contractive unbiased operators —
    E||e - eta*C(e)||^2 <= (1 - 2 eta + eta^2 (1+omega)) ||e||^2, which
    is minimized (to omega/(1+omega)) at eta = 1/(1+omega).  ``nu``
    scales the correction in the estimator, trading bias for variance.
    Special cases: ``eta = nu = 1`` is EXACTLY EF21 (bitwise — the
    trajectory test pins it); an unbiased Q with ``eta = 1/(1+omega)``,
    ``nu = 1`` is DIANA with its optimal alpha.
    """

    eta: float = 1.0
    nu: float = 1.0

    def apply(self, wgrads, m, m_bar, h, h_bar, aux):
        g_bar = tmap(lambda hb, mb: hb + self.nu * mb, h_bar, m_bar)
        h_new = tmap(lambda s, mm: s + self.eta * mm, h, m)
        h_bar_new = tmap(lambda hb, mb: hb + self.eta * mb, h_bar, m_bar)
        return g_bar, h_new, h_bar_new


#: the rules the registry accepts (error messages quote this)
SHIFT_RULES = ("fixed", "dcgd", "star", "diana", "rand_diana", "ef21",
               "efbv")


def make_shift_rule(name: str, **kw) -> ShiftRule:
    table = {
        "fixed": FixedShift,
        "dcgd": FixedShift,
        "star": StarShift,
        "diana": DianaShift,
        "rand_diana": RandDianaShift,
        "ef21": EF21Shift,
        "efbv": EFBVShift,
    }
    if name not in table:
        raise ValueError(
            f"unknown shift rule {name!r}; have shift rules "
            f"{SHIFT_RULES}"
        )
    return table[name](**kw)
