"""Shift update rules — Section 3 of the paper.

A *shift rule* owns everything the meta-algorithm DCGD-SHIFT leaves open
(the coloured line of Alg. 1): how the per-worker shifts ``h_i`` start,
how the worker messages are formed from the shifted gradients, and how
``h_i^{k+1}`` is produced.  Rules are frozen dataclasses (static under
jit); their mutable state is the stacked shift pytree ``h`` with leading
worker axis ``W`` plus a bits counter.

All rules implement::

    init(wgrads_like)                  -> h0            (W-stacked pytree)
    step(q, key, wgrads, h)            -> (g_bar, h_new, bits)

where ``wgrads`` is the stacked per-worker gradient pytree (leaves shaped
``(W, *param.shape)``), ``g_bar`` is the master's unbiased gradient
estimator (no worker axis), and ``bits`` is the total uplink wire cost of
the step (a traced scalar — Rand-DIANA's cost is a random variable).

DIANA-like rules couple the estimator and the shift update (they reuse
the same compressed message), which is why the rule computes both.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.compressors import (
    Compressor,
    Contractive,
    Unbiased,
    Zero,
    tree_bits,
)


def _tree_mean_w(tree):
    """Mean over the leading worker axis, leaf-wise."""
    return jax.tree_util.tree_map(lambda a: jnp.mean(a, axis=0), tree)


def worker_compress(q: Compressor, key: jax.Array, wtree):
    """Compress each worker's slice of a W-stacked pytree independently.

    Workers get decorrelated keys unless the operator declares a shared
    pattern (correlated Rand-K), in which case every worker samples the
    same sparsity mask — the property the payload-shrinking collective
    relies on.
    """
    leaves, treedef = jax.tree_util.tree_flatten(wtree)
    shared = bool(getattr(q, "shared_pattern", False))
    out = []
    for i, leaf in enumerate(leaves):
        lk = jax.random.fold_in(key, i)
        w = leaf.shape[0]
        if shared or not q.stochastic:
            keys = jnp.broadcast_to(lk, (w, *lk.shape))
        else:
            keys = jax.random.split(lk, w)
        out.append(jax.vmap(q)(keys, leaf))
    return jax.tree_util.tree_unflatten(treedef, out)


def stack_like(tree, w: int):
    """Zeros with a leading worker axis mirroring ``tree``."""
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros((w, *a.shape), a.dtype), tree
    )


# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ShiftRule:
    def init(self, wgrads_like):
        raise NotImplementedError

    def step(self, q: Unbiased, key, wgrads, h):
        raise NotImplementedError


@dataclass(frozen=True)
class FixedShift(ShiftRule):
    """DCGD-SHIFT with constant shifts (eq. 6).  ``h0 = 0`` gives plain
    DCGD (Khirirat et al., 2018).  Theorem 1: linear to a neighborhood
    proportional to mean_i ||grad_i(x*) - h_i||^2."""

    def init(self, wgrads_like):
        return jax.tree_util.tree_map(jnp.zeros_like, wgrads_like)

    def step(self, q, key, wgrads, h):
        diff = jax.tree_util.tree_map(lambda g, s: g - s, wgrads, h)
        m = worker_compress(q, key, diff)
        g_bar = _tree_mean_w(
            jax.tree_util.tree_map(lambda s, mm: s + mm, h, m)
        )
        w = jax.tree_util.tree_leaves(wgrads)[0].shape[0]
        bits = w * tree_bits(q, jax.tree_util.tree_map(lambda a: a[0], wgrads))
        return g_bar, h, jnp.asarray(bits, jnp.float32)


@dataclass(frozen=True)
class StarShift(ShiftRule):
    """DCGD-STAR (eq. 8): oracle shifts around grad_i(x*), optionally
    compressed by a contractive C.  Theorem 2: exact linear convergence.

    Impractical by construction (needs the optimum) — included as the
    theoretical reference point, exactly as in the paper.
    """

    c: Compressor = field(default_factory=Zero)

    def init_with_star(self, wgrads_star):
        """State carries the oracle gradients; h starts there too."""
        return {"h": wgrads_star, "star": wgrads_star}

    def init(self, wgrads_like):  # pragma: no cover - guarded
        raise ValueError("StarShift requires init_with_star(grads_at_optimum)")

    def step(self, q, key, wgrads, state):
        h, star = state["h"], state["star"]
        kq, kc = jax.random.split(key)
        diff = jax.tree_util.tree_map(lambda g, s: g - s, wgrads, h)
        m = worker_compress(q, kq, diff)
        g_bar = _tree_mean_w(
            jax.tree_util.tree_map(lambda s, mm: s + mm, h, m)
        )
        # h_i^{k+1} = g*_i + C(grad_i - g*_i)
        dstar = jax.tree_util.tree_map(lambda g, s: g - s, wgrads, star)
        ch = worker_compress(self.c, kc, dstar)
        h_new = jax.tree_util.tree_map(lambda s, cc: s + cc, star, ch)
        one = jax.tree_util.tree_map(lambda a: a[0], wgrads)
        w = jax.tree_util.tree_leaves(wgrads)[0].shape[0]
        bits = w * (tree_bits(q, one) + tree_bits(self.c, one))
        return g_bar, {"h": h_new, "star": star}, jnp.asarray(bits, jnp.float32)


@dataclass(frozen=True)
class DianaShift(ShiftRule):
    """Generalized DIANA (eq. 10): h_i += alpha * Q_ind(grad_i - h_i) with
    Q_ind(x) = C(x) + Q(x - C(x)) the induced compressor; C = Zero recovers
    classic DIANA (eq. 11, Mishchenko et al. 2019).

    The *same* message is used for the gradient estimator and the shift
    update (Section 3.2.1), so with C = Zero nothing extra is ever sent.
    Theorem 3 rate: max{kappa(1 + omega(1-delta)/n), omega(1-delta)}.
    """

    alpha: float = 0.1
    c: Compressor = field(default_factory=Zero)

    def init(self, wgrads_like):
        return jax.tree_util.tree_map(jnp.zeros_like, wgrads_like)

    def step(self, q, key, wgrads, h):
        kc, kq = jax.random.split(key)
        diff = jax.tree_util.tree_map(lambda g, s: g - s, wgrads, h)
        cmsg = worker_compress(self.c, kc, diff)
        resid = jax.tree_util.tree_map(lambda d, cc: d - cc, diff, cmsg)
        qmsg = worker_compress(q, kq, resid)
        # m_full = Q_ind(grad - h) = c + Q(grad - h - c)
        m_full = jax.tree_util.tree_map(lambda cc, mm: cc + mm, cmsg, qmsg)
        g_bar = _tree_mean_w(
            jax.tree_util.tree_map(lambda s, mf: s + mf, h, m_full)
        )
        h_new = jax.tree_util.tree_map(
            lambda s, mf: s + self.alpha * mf, h, m_full
        )
        one = jax.tree_util.tree_map(lambda a: a[0], wgrads)
        w = jax.tree_util.tree_leaves(wgrads)[0].shape[0]
        bits = w * (tree_bits(q, one) + tree_bits(self.c, one))
        return g_bar, h_new, jnp.asarray(bits, jnp.float32)


@dataclass(frozen=True)
class RandDianaShift(ShiftRule):
    """Rand-DIANA (eq. 12, *new in the paper*): the shift is the gradient
    at a lazily-refreshed reference point, h_i = grad_i(w_i), where w_i is
    reset to x^k with probability p_i (Loopless-SVRG style).

    Because the refresh happens at the current point, h_i^{k+1} is exactly
    the gradient the worker just computed — no extra gradient evaluation —
    but the refresh message is a *full* d-vector, sent rarely (expected
    p*32d bits/step).  Theorem 4: max{kappa(1 + omega/n), 1/p} with a
    dramatically simpler analysis than DIANA.
    """

    p: float = 0.1

    def init(self, wgrads_like):
        return jax.tree_util.tree_map(jnp.zeros_like, wgrads_like)

    def step(self, q, key, wgrads, h):
        kq, kb = jax.random.split(key)
        diff = jax.tree_util.tree_map(lambda g, s: g - s, wgrads, h)
        m = worker_compress(q, kq, diff)
        g_bar = _tree_mean_w(
            jax.tree_util.tree_map(lambda s, mm: s + mm, h, m)
        )
        w = jax.tree_util.tree_leaves(wgrads)[0].shape[0]
        refresh = jax.random.bernoulli(kb, self.p, (w,))
        def upd(s, g):
            mask = refresh.reshape((w,) + (1,) * (g.ndim - 1))
            return jnp.where(mask, g, s)
        h_new = jax.tree_util.tree_map(upd, h, wgrads)
        one = jax.tree_util.tree_map(lambda a: a[0], wgrads)
        d = sum(int(l.size) for l in jax.tree_util.tree_leaves(one))
        bits = w * tree_bits(q, one) + jnp.sum(refresh) * 32.0 * d
        return g_bar, h_new, jnp.asarray(bits, jnp.float32)


def make_shift_rule(name: str, **kw) -> ShiftRule:
    table = {
        "fixed": FixedShift,
        "dcgd": FixedShift,
        "star": StarShift,
        "diana": DianaShift,
        "rand_diana": RandDianaShift,
    }
    if name not in table:
        raise ValueError(f"unknown shift rule {name!r}; have {sorted(table)}")
    return table[name](**kw)
