"""DCGD-SHIFT — the paper's Algorithm 1 as a functional JAX optimizer.

The meta-algorithm is expressed as an optax-style gradient transformation
over *stacked per-worker gradients*: leaves shaped ``(W, *param.shape)``.
On a single host this is literally the paper's parameter-server loop
(vmapped); on the production mesh the same function runs under pjit with
the worker axis sharded over ``("pod","data")`` — see
``repro.dist.worker_grads`` — so the mean over workers lowers to the
compressed all-reduce.

Also provides the theoretical step sizes of Theorems 1-4 so experiments
can run exactly in the regime the theory covers.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm.channel import Channel
from repro.core.compressors import Compressor, Identity
from repro.core.shift_rules import FixedShift, ShiftRule


class DCGDState(NamedTuple):
    h: Any              # shift state (rule-specific pytree, worker-stacked)
    h_bar: Any          # master aggregated shift (no worker axis; tracked
                        # incrementally — None for stateless/oracle rules)
    key: jax.Array      # PRNG state for the compressors
    step: jax.Array     # iteration counter
    bits: jax.Array     # cumulative uplink bits (f32 scalar)


@dataclass(frozen=True)
class DCGDShift:
    """Distributed Compressed Gradient Descent with Shift (Alg. 1).

    ``q``       — per-worker compressor Q_i (unbiased U(omega) for the
                  DIANA family; contractive B(delta) for EF21/EF-BV)
    ``rule``    — the shift update mechanism (Section 3), a phased
                  ``ShiftRule`` (message/apply engine)
    ``channel`` — the message transport; ``None`` means the vmapped
                  parameter-server ``SimChannel`` (the paper's setting)

    This is the REFERENCE consumer of the shift-rule engine: the
    production ``launch/train.py`` step runs the *same*
    ``rule.round(...)`` over the same channel abstraction, which the
    cross-layer bit-exactness tests pin.
    """

    q: Compressor = field(default_factory=Identity)
    rule: ShiftRule = field(default_factory=FixedShift)
    channel: Optional[Channel] = None

    def init(self, wgrads_like, *, seed: int = 0, star: Any = None) -> DCGDState:
        if star is not None:
            h = self.rule.init_with_star(star)  # type: ignore[attr-defined]
            h_bar = None
        else:
            h = self.rule.init(wgrads_like)
            h_bar = self.rule.init_bar(wgrads_like)
        return DCGDState(
            h=h,
            h_bar=h_bar,
            key=jax.random.PRNGKey(seed),
            step=jnp.zeros((), jnp.int32),
            bits=jnp.zeros((), jnp.float32),
        )

    def estimate(self, state: DCGDState, wgrads):
        """One round: compress shifted worker grads, aggregate, update shifts.

        Returns ``(g_bar, new_state)`` where ``g_bar`` is the master's
        unbiased estimator of the full gradient (no worker axis).
        """
        key, sub = jax.random.split(state.key)
        g_bar, h_new, hb_new, bits = self.rule.round(
            self.q, sub, wgrads, state.h, state.h_bar, channel=self.channel
        )
        return g_bar, DCGDState(
            h=h_new, h_bar=hb_new, key=key, step=state.step + 1,
            bits=state.bits + bits,
        )


# --------------------------------------------------------------------------
# Theoretical step sizes (used by the fidelity experiments)
# --------------------------------------------------------------------------


def stepsize_dcgd_fixed(L, L_max, omega, n):
    """Theorem 1: gamma <= 1 / (L + 2 max_i(L_i omega_i)/n)."""
    return 1.0 / (L + 2.0 * L_max * omega / n)


def stepsize_dcgd_star(L, L_max, omega, delta, n):
    """Theorem 2: gamma <= 1 / (L + max_i(L_i omega_i (1-delta_i))/n)."""
    return 1.0 / (L + L_max * omega * (1.0 - delta) / n)


def stepsize_diana(L_max, omega, delta, n, M_mult: float = 4.0):
    """Theorem 3 pair (alpha, gamma) with M = M_mult/(n*alpha) > 2/(n*alpha)."""
    om = omega * (1.0 - delta)
    alpha = 1.0 / (1.0 + om)
    M = M_mult / (n * alpha)
    gamma = 1.0 / ((2.0 / n) * omega * L_max + (1.0 + alpha * M) * L_max)
    return alpha, gamma


def stepsize_rand_diana(L_max, omega, n, p, M_mult: float = 2.0):
    """Theorem 4: M = M_mult * 2*omega/(n*p); gamma <= 1/((1+2w/n)Lmax + M max_i p_i L_i).

    The paper's recommended choice is M = 4*omega/(n*p)  (M_mult = 2).
    """
    M = M_mult * 2.0 * omega / (n * p) if omega > 0 else 0.0
    gamma = 1.0 / ((1.0 + 2.0 * omega / n) * L_max + M * p * L_max)
    return M, gamma


def rand_diana_default_p(omega: float) -> float:
    """p = 1/(omega+1) — matches DIANA's iteration complexity (Sec. 3.2.2)."""
    return 1.0 / (omega + 1.0)


def stepsize_ef21(L, L_max, delta):
    """EF21 (Richtárik, Sokolov & Fatkhullin, 2021, Thm 1): with a
    delta-contractive C, theta = 1 - sqrt(1-delta), beta = (1-delta)/theta,
    gamma <= 1 / (L + L_tilde sqrt(beta/theta)); we bound L_tilde =
    sqrt(mean_i L_i^2) by L_max.  delta = 1 (Identity) recovers 1/L."""
    return stepsize_efbv(L, L_max, delta=delta)


def _efbv_contraction(eta: float, delta: float, omega) -> float:
    """Per-step contraction r^2 of the EF-BV shift error e = grad - h
    under h <- h + eta * C(e): the best of the available certificates.

      contractive (C in B(delta)):
          ||e - eta C(e)|| <= ((1-eta) + eta sqrt(1-delta)) ||e||
          (triangle inequality on (1-eta) e + eta (e - C(e)))
      unbiased (C in U(omega), pass ``omega``; None = not unbiased):
          E||e - eta C(e)||^2 = (1 - 2 eta + eta^2 (1+omega)) ||e||^2
          (exact — the cross term uses E C(e) = e)
    """
    r2 = ((1.0 - eta) + eta * math.sqrt(max(1.0 - delta, 0.0))) ** 2
    if omega is not None:
        r2 = min(r2, 1.0 - 2.0 * eta + eta * eta * (1.0 + omega))
    return max(r2, 0.0)


def stepsize_efbv(L, L_max, delta: float = 0.0, omega=None,
                  eta: float = 1.0, nu: float = 1.0):
    """EF-BV (Condat, Li & Richtárik, 2022) step size, generalizing
    ``stepsize_ef21`` to the damped shift recursion h += eta * C(e).

    With r^2 the shift-error contraction (``_efbv_contraction``),
    theta = 1 - r and beta = r^2 / theta, the EF21-shaped bound is

        gamma <= 1 / (L + nu * L_max * sqrt(beta / theta)).

    It reduces EXACTLY to ``stepsize_ef21`` at eta = nu = 1 with a
    delta-contractive C, and for an unbiased C at the optimal
    eta = 1/(1+omega) it lands in DIANA's stepsize regime.  Returns 0
    when no certificate contracts (r >= 1): no safe step exists —
    e.g. eta = 1 with a non-contractive unbiased operator, the exact
    failure mode EF-BV's damping is for.
    """
    r2 = _efbv_contraction(eta, delta, omega)
    theta = 1.0 - math.sqrt(r2)
    if theta <= 0.0:
        return 0.0  # the shift recursion does not contract
    beta = r2 / theta
    return 1.0 / (L + nu * L_max * math.sqrt(beta / theta))


def efbv_params(delta: float = 0.0, omega=None):
    """Recommended EF-BV ``(eta, nu)`` for a compressor with contraction
    ``delta`` (B-class) and/or unbiased variance ``omega`` (U-class;
    ``None`` = not unbiased).

    The unbiased certificate is exactly minimized at eta = 1/(1+omega)
    (DIANA's optimal alpha); the contractive certificate is decreasing
    in eta on (0, 1], so its optimum is eta = 1 (EF21).  The better of
    the two is chosen by comparing contractions.  nu = 1 keeps the
    estimator's correction unscaled — unbiased when C is, and the EF21
    choice when C is contractive.
    """
    eta_c = 1.0
    best = (_efbv_contraction(eta_c, delta, None), eta_c)
    if omega is not None:
        eta_u = 1.0 / (1.0 + omega)
        best = min(best, (_efbv_contraction(eta_u, delta, omega), eta_u))
    return best[1], 1.0
