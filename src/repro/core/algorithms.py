"""DCGD-SHIFT — the paper's Algorithm 1 as a functional JAX optimizer.

The meta-algorithm is expressed as an optax-style gradient transformation
over *stacked per-worker gradients*: leaves shaped ``(W, *param.shape)``.
On a single host this is literally the paper's parameter-server loop
(vmapped); on the production mesh the same function runs under pjit with
the worker axis sharded over ``("pod","data")`` — see
``repro.dist.worker_grads`` — so the mean over workers lowers to the
compressed all-reduce.

Also provides the theoretical step sizes of Theorems 1-4 so experiments
can run exactly in the regime the theory covers.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm.channel import Channel
from repro.core.compressors import Compressor, Identity
from repro.core.shift_rules import FixedShift, ShiftRule, stack_like


class DCGDState(NamedTuple):
    h: Any              # shift state (rule-specific pytree, worker-stacked)
    key: jax.Array      # PRNG state for the compressors
    step: jax.Array     # iteration counter
    bits: jax.Array     # cumulative uplink bits (f32 scalar)


@dataclass(frozen=True)
class DCGDShift:
    """Distributed Compressed Gradient Descent with Shift (Alg. 1).

    ``q``       — per-worker compressor Q_i (unbiased U(omega) for the
                  DIANA family; contractive B(delta) for EF21)
    ``rule``    — the shift update mechanism (Section 3)
    ``channel`` — the message transport; ``None`` means the vmapped
                  parameter-server ``SimChannel`` (the paper's setting)
    """

    q: Compressor = field(default_factory=Identity)
    rule: ShiftRule = field(default_factory=FixedShift)
    channel: Optional[Channel] = None

    def init(self, wgrads_like, *, seed: int = 0, star: Any = None) -> DCGDState:
        if star is not None:
            h = self.rule.init_with_star(star)  # type: ignore[attr-defined]
        else:
            h = self.rule.init(wgrads_like)
        return DCGDState(
            h=h,
            key=jax.random.PRNGKey(seed),
            step=jnp.zeros((), jnp.int32),
            bits=jnp.zeros((), jnp.float32),
        )

    def estimate(self, state: DCGDState, wgrads):
        """One round: compress shifted worker grads, aggregate, update shifts.

        Returns ``(g_bar, new_state)`` where ``g_bar`` is the master's
        unbiased estimator of the full gradient (no worker axis).
        """
        key, sub = jax.random.split(state.key)
        g_bar, h_new, bits = self.rule.step(
            self.q, sub, wgrads, state.h, channel=self.channel
        )
        return g_bar, DCGDState(
            h=h_new, key=key, step=state.step + 1, bits=state.bits + bits
        )


# --------------------------------------------------------------------------
# Theoretical step sizes (used by the fidelity experiments)
# --------------------------------------------------------------------------


def stepsize_dcgd_fixed(L, L_max, omega, n):
    """Theorem 1: gamma <= 1 / (L + 2 max_i(L_i omega_i)/n)."""
    return 1.0 / (L + 2.0 * L_max * omega / n)


def stepsize_dcgd_star(L, L_max, omega, delta, n):
    """Theorem 2: gamma <= 1 / (L + max_i(L_i omega_i (1-delta_i))/n)."""
    return 1.0 / (L + L_max * omega * (1.0 - delta) / n)


def stepsize_diana(L_max, omega, delta, n, M_mult: float = 4.0):
    """Theorem 3 pair (alpha, gamma) with M = M_mult/(n*alpha) > 2/(n*alpha)."""
    om = omega * (1.0 - delta)
    alpha = 1.0 / (1.0 + om)
    M = M_mult / (n * alpha)
    gamma = 1.0 / ((2.0 / n) * omega * L_max + (1.0 + alpha * M) * L_max)
    return alpha, gamma


def stepsize_rand_diana(L_max, omega, n, p, M_mult: float = 2.0):
    """Theorem 4: M = M_mult * 2*omega/(n*p); gamma <= 1/((1+2w/n)Lmax + M max_i p_i L_i).

    The paper's recommended choice is M = 4*omega/(n*p)  (M_mult = 2).
    """
    M = M_mult * 2.0 * omega / (n * p) if omega > 0 else 0.0
    gamma = 1.0 / ((1.0 + 2.0 * omega / n) * L_max + M * p * L_max)
    return M, gamma


def rand_diana_default_p(omega: float) -> float:
    """p = 1/(omega+1) — matches DIANA's iteration complexity (Sec. 3.2.2)."""
    return 1.0 / (omega + 1.0)


def stepsize_ef21(L, L_max, delta):
    """EF21 (Richtárik, Sokolov & Fatkhullin, 2021, Thm 1): with a
    delta-contractive C, theta = 1 - sqrt(1-delta), beta = (1-delta)/theta,
    gamma <= 1 / (L + L_tilde sqrt(beta/theta)); we bound L_tilde =
    sqrt(mean_i L_i^2) by L_max.  delta = 1 (Identity) recovers 1/L."""
    theta = 1.0 - math.sqrt(max(1.0 - delta, 0.0))
    if theta <= 0.0:
        return 0.0  # delta == 0: the compressor makes no progress
    beta = (1.0 - delta) / theta
    return 1.0 / (L + L_max * math.sqrt(beta / theta))
