"""repro.comm — the unified Channel for compressed communication.

See ``repro.comm.channel`` for the abstraction; ``SimChannel`` is the
vmapped parameter server used by the reference algebra in ``repro.core``,
``MeshChannel`` wraps the codec-driven collectives in ``repro.dist``.
"""

from repro.comm.channel import (
    AGGREGATION_MODES,
    Channel,
    MeshChannel,
    SimChannel,
    aggregation_mode_of,
    collective_payload_scale,
    make_channel,
)

__all__ = [
    "AGGREGATION_MODES",
    "Channel",
    "MeshChannel",
    "SimChannel",
    "aggregation_mode_of",
    "collective_payload_scale",
    "make_channel",
]
