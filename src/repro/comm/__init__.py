"""repro.comm — the unified Channel for compressed communication.

See ``repro.comm.channel`` for the abstraction; ``SimChannel`` is the
vmapped parameter server used by the reference algebra in ``repro.core``,
``MeshChannel`` wraps the codec-driven collectives in ``repro.dist``,
and ``AsyncChannel`` (``repro.comm.overlap``) is the bucketed,
pipelined overlapped runtime on top of them.  ``repro.comm.wire``
holds the per-worker encode helpers shared by all of them;
``repro.comm.fused_vjp`` is the fused-backward encode path (wire
messages emitted as cotangents, no standalone encode stage).
"""

from repro.comm.channel import (
    AGGREGATION_MODES,
    CHANNEL_MODES,
    FUSED_VJP_MODES,
    Channel,
    MeshChannel,
    SimChannel,
    aggregation_mode_of,
    collective_payload_scale,
    make_channel,
    resync_h_bar,
)
from repro.comm.fused_vjp import (
    check_fusible,
    encode_on_backward,
    fused_message_bits,
    message_tag,
    round_message_keys,
)
from repro.comm.overlap import (
    DEFAULT_BUCKET_BYTES,
    AsyncChannel,
    Bucket,
    BucketPlan,
    plan_buckets,
)
from repro.comm.transport import (
    WIRE_CODEC_FLAGS,
    WIRE_TOPOLOGIES,
    Transport,
    Wire,
    aggregation_wire_codec,
    build_transport,
    wire_flag_codec,
    wire_stream,
)
from repro.comm.wire import (
    encode_decode_workers,
    encode_meta_free,
    encode_workers,
    worker_keys,
)

__all__ = [
    "AGGREGATION_MODES",
    "CHANNEL_MODES",
    "DEFAULT_BUCKET_BYTES",
    "FUSED_VJP_MODES",
    "WIRE_CODEC_FLAGS",
    "WIRE_TOPOLOGIES",
    "AsyncChannel",
    "Bucket",
    "BucketPlan",
    "Channel",
    "MeshChannel",
    "SimChannel",
    "Transport",
    "Wire",
    "aggregation_mode_of",
    "aggregation_wire_codec",
    "build_transport",
    "check_fusible",
    "collective_payload_scale",
    "encode_decode_workers",
    "encode_meta_free",
    "encode_on_backward",
    "encode_workers",
    "fused_message_bits",
    "make_channel",
    "message_tag",
    "plan_buckets",
    "resync_h_bar",
    "round_message_keys",
    "wire_flag_codec",
    "wire_stream",
    "worker_keys",
]
