"""Shared per-worker encode plumbing for the wire layer.

One home for the helpers that were duplicated between the Channel
uplink (``repro.comm.channel``) and the codec-driven collectives
(``repro.dist.collectives``): worker key derivation, the vmapped
per-worker encode, and the meta-free guard for forwarded-payload
transports.  Imports only jax — safe for both sides of the
comm <-> dist boundary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def leaf_key(key: jax.Array, leaf_index: int) -> jax.Array:
    """THE per-leaf key derivation of the whole wire layer.

    Every consumer — ``Channel.uplink``/``broadcast``,
    ``ShiftRule.message``, the bucketed loops in ``comm.overlap``, and
    the codec-driven collectives — folds the leaf's GLOBAL tree
    position through this one function.  That shared derivation is what
    makes any re-schedule (bucket partition, interleaved
    message/reduce) bit-exact with the whole-tree round; change it here
    or nowhere.
    """
    return jax.random.fold_in(key, leaf_index)


def worker_keys(codec, key: jax.Array, w: int) -> jax.Array:
    """Per-worker encode keys for ONE leaf, stacked (w, *key.shape).

    Every worker samples the SAME key when the codec declares a shared
    pattern (correlated Rand-K) or is deterministic — the property the
    payload-shrinking collectives rely on; decorrelated split keys
    otherwise.
    """
    if getattr(codec, "shared_pattern", False) or not codec.stochastic:
        return jnp.broadcast_to(key, (w, *key.shape))
    return jax.random.split(key, w)


def encode_workers(codec, key: jax.Array, leaf: jax.Array):
    """Encode each worker row of a worker-stacked leaf.

    Returns the worker-stacked ``(payload, meta)`` pytrees (leaves gain
    a leading W axis; for shared-pattern codecs every row is encoded
    with the same key, so meta rows are identical).
    """
    return jax.vmap(codec.encode)(worker_keys(codec, key, leaf.shape[0]), leaf)


def encode_decode_workers(codec, key: jax.Array, leaf: jax.Array):
    """One uplink leaf: encode then decode each worker row.

    Returns ``(stacked payload, stacked decoded messages)`` — the
    decoded tensor is what the master-side aggregation sees, the payload
    is what wire accounting charges.
    """
    sds = jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)

    def enc_dec(k, row):
        payload, meta = codec.encode(k, row)
        return payload, codec.decode(payload, meta, sds)

    return jax.vmap(enc_dec)(worker_keys(codec, key, leaf.shape[0]), leaf)


def encode_meta_free(codec, key: jax.Array, block: jax.Array):
    """Encode for forwarded-payload transports (ring hops, the pod psum
    stage): the decoder sees ONLY the payload, so shared-seed side
    information in ``meta`` cannot travel — reject codecs that need it.
    """
    payload, meta = codec.encode(key, block)
    if jax.tree_util.tree_leaves(meta):
        raise ValueError(
            f"{type(codec).__name__} carries decoder state in meta; "
            "quantized ring/tree stages forward payloads only "
            "(meta must be empty)"
        )
    return payload
