"""Fused backward-pass encode: wire messages AS cotangents.

The post-hoc rounds (``Channel.shift_round`` and the bucketed
``AsyncChannel.shift_round``) first materialize every worker's full
dense gradient tree to HBM, then run a separate encode stage over it
(``ShiftRule.message``).  This module deletes that stage: each param
leaf is wrapped in an identity ``jax.custom_vjp`` whose BACKWARD
replaces the dense cotangent with the decoded shifted-compressed
message — ``jax.grad`` of the wrapped loss then emits the message tree
directly, layer by layer as backprop produces each cotangent, and the
dense gradient tree never exists as a step output.  The dataflow is

    cotangent g_i  ->  shift (g_i - h_i)  ->  quantize/encode+decode
                   ->  per-leaf ring reduction (AsyncChannel, per_leaf)

with the encode running INSIDE the backward pass (same XLA program as
the producing matmuls) instead of as a post-hoc pass re-reading every
dense leaf from HBM.

Bit-exactness contract (pinned in tests/test_fused_vjp.py): the fused
path reproduces the post-hoc path BITWISE, per shift rule x channel.
The three invariants that make it hold:

* KEYS — ``round_message_keys`` derives per-leaf per-worker keys from
  the round key exactly as ``Channel.shift_round`` does: the round
  key's first 3-split row (``k_msg``), folded to each leaf's GLOBAL
  tree position (``leaf_key``), then ``ShiftRule.message_keys`` (the
  codec's shared/split worker derivation).
* VALUES — the tag's backward runs ``ShiftRule.message_leaf_worker``
  (the exact per-row body of ``encode_decode_workers``) under the SAME
  per-worker vmap ``dist.worker_grads`` already applies, so XLA lowers
  the identical batched encode as the post-hoc ``message_leaf``.
* BITS — the fused rounds accumulate each leaf's STRUCTURAL
  ``message_bits_aot`` (a python float equal to the post-hoc payload's
  ``wire_bits``) in the same order the post-hoc rounds do, so even the
  f32 bits counter matches bitwise.  (Codecs with data-dependent
  ``wire_bits`` — BernoulliP — get the structural expectation instead;
  every registered CLI compressor is structural.)

Only rules whose ``apply`` never touches the dense gradients are
fusible (``ShiftRule.fusible``): fixed/dcgd, diana, ef21, efbv.
``check_fusible`` rejects the rest (rand_diana, star, vr_gdci) with a
clear error instead of silently wrong math.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.wire import leaf_key

tree = jax.tree_util


def check_fusible(rule) -> None:
    """Reject rules whose round cannot run on the fused-backward path."""
    if not getattr(rule, "fusible", False):
        raise ValueError(
            f"shift rule {type(rule).__name__} is not fusible: its round "
            "consumes the dense per-worker gradients (or overrides the "
            "round schedule), which never materialize when messages are "
            "emitted as cotangents.  Fusible rules: fixed/dcgd, diana, "
            "ef21, efbv."
        )


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def message_tag(rule, q, x, keys, h):
    """Identity on ``x`` whose backward emits the wire message.

    ``x`` is one param leaf (per worker — this runs under the
    ``dist.worker_grads`` vmap), ``keys`` one row-stackable key pytree
    from ``round_message_keys``, ``h`` the worker's shift for this leaf
    (or None for stateless rules).  Forward is exact identity; backward
    maps the dense cotangent ``g`` to
    ``rule.message_leaf_worker(q, keys, g, h)`` — decoded
    ``Q(g - h)`` — which then propagates as THE gradient of this leaf.
    """
    del rule, q, keys, h
    return x


def _tag_fwd(rule, q, x, keys, h):
    del rule, q
    return x, (keys, h)


def _tag_bwd(rule, q, res, g):
    keys, h = res
    m = rule.message_leaf_worker(q, keys, g, h)
    # keys are uint32 — their cotangent is the symbolic-zero float0;
    # h gets real zeros (it is a residual input, not a trained leaf)
    dkeys = tree.tree_map(
        lambda k: np.zeros(np.shape(k), jax.dtypes.float0), keys
    )
    dh = None if h is None else jnp.zeros_like(h)
    return m, dkeys, dh


message_tag.defvjp(_tag_fwd, _tag_bwd)


def round_message_keys(rule, q, key, params_like, w: int):
    """Per-leaf message-key pytrees for one round, as a tuple aligned
    with ``tree_flatten(params_like)`` order.

    Reproduces the post-hoc derivation bitwise: ``Channel.shift_round``
    splits the round key 3 ways and hands the first (``k_msg``) to
    ``rule.message``, which folds it to each leaf's global position.
    Each tuple entry is ``rule.message_keys`` at that leaf — every
    array leaf has a leading ``(w,)`` axis, so the tuple can ride the
    worker-batched input dict straight into the per-worker vmap.
    """
    k_msg = jax.random.split(key, 3)[0]
    n = len(tree.tree_leaves(params_like))
    return tuple(
        rule.message_keys(q, leaf_key(k_msg, i), w) for i in range(n)
    )


def encode_on_backward(rule, q, params, keys, h):
    """Wrap every param leaf in ``message_tag``.

    ``keys`` is one worker's row of ``round_message_keys`` (or the full
    stacked tuple when called under the worker vmap), ``h`` that
    worker's shift tree (or None).  Returns params unchanged in value;
    ``jax.grad`` of a loss on the result yields the MESSAGE tree — the
    fused round's ``msgs`` input — instead of dense gradients.
    """
    check_fusible(rule)
    leaves, treedef = tree.tree_flatten(params)
    if len(keys) != len(leaves):
        raise ValueError(
            f"round_message_keys carries {len(keys)} leaf key trees but "
            f"params has {len(leaves)} leaves — keys must be derived "
            "from the same tree"
        )
    h_leaves = [None] * len(leaves) if h is None else tree.tree_leaves(h)
    tagged = [
        message_tag(rule, q, x, k, hl)
        for x, k, hl in zip(leaves, keys, h_leaves)
    ]
    return tree.tree_unflatten(treedef, tagged)


def fused_message_bits(rule, q, wgrads_like) -> float:
    """Total structural uplink bits of one fused round's messages —
    the sum the fused rounds accumulate leaf-wise (python float)."""
    return float(
        sum(
            rule.message_bits_aot(q, leaf)
            for leaf in tree.tree_leaves(wgrads_like)
        )
    )
