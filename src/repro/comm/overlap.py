"""Overlapped communication runtime: the bucketed, pipelined Channel.

The trainer's wall-clock problem is SERIALIZATION, not just payload
size: ``MeshChannel.reduce_mean`` hands the whole gradient tree to one
collective call, so the first ring hop waits for the full backward pass
and every leaf's ring waits for the previous leaf's.  This module splits
the tree into wire-sized units and pipelines them:

  ``plan_buckets``   flattens the worker-stacked tree into fixed
        byte-budget buckets in REVERSE-layer order (gradients arrive
        last-layer-first during backward, so bucket 0 — the tail of the
        tree — is ready while earlier layers are still differentiating;
        the reverse order is what makes compute/comm overlap possible at
        all).  Buckets group whole leaves: concatenating leaf data would
        move quantization chunk boundaries and silently change the wire
        format — grouping keeps every leaf's payload bit-identical to
        the unbucketed channel, which is the contract below.
  ``AsyncChannel``   a ``Channel`` whose aggregation is issued bucket by
        bucket through explicit ``reduce_start`` / ``finish`` handles.
        ``push_mean`` interleaves the pipeline: bucket i's reduction is
        issued BEFORE bucket i+1's encode, and consecutive buckets share
        no data dependency.  Under ``jit`` the handles delimit
        independent collective computations (one shard_map per bucket
        instead of one for the whole tree) — exactly the freedom XLA's
        latency-hiding scheduler needs to run ring hops concurrently
        with encode and backward compute.

THE CONTRACT (tested): drained synchronously, ``AsyncChannel`` is
bit-exact with ``MeshChannel`` in the same aggregation mode.  Per-leaf
keys are folded from GLOBAL tree positions (``leaf_indices``), so a
bucket subtree reduces to exactly the arrays the full-tree call
produces, in any bucket partition, in any finish order.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm.channel import AGGREGATION_MODES, Channel
from repro.comm.wire import encode_decode_workers, leaf_key

tmap = jax.tree_util.tree_map

#: default per-bucket budget in UNCOMPRESSED per-worker message bytes
#: (inner numel x dense dtype width — the codec's wire payload is
#: smaller, e.g. ~4x for int8): 4 MiB, ~ PyTorch DDP's 25 MB default
#: scaled to the compressed-wire regime
DEFAULT_BUCKET_BYTES = 4 << 20


@dataclass(frozen=True)
class Bucket:
    """One pipeline unit: GLOBAL leaf positions (reverse-layer order)
    plus the per-worker message bytes they carry."""

    indices: Tuple[int, ...]
    nbytes: int


@dataclass(frozen=True)
class BucketPlan:
    buckets: Tuple[Bucket, ...]
    n_leaves: int

    def __len__(self) -> int:
        return len(self.buckets)


def plan_buckets(wtree, bucket_bytes: int = DEFAULT_BUCKET_BYTES, *,
                 per_leaf: bool = False) -> BucketPlan:
    """Partition a worker-stacked pytree into reverse-layer buckets.

    Walks leaves LAST first, accumulating per-worker message bytes
    (inner numel x dtype width — the uplink unit), and closes a bucket
    when adding the next leaf would exceed ``bucket_bytes``.  A single
    leaf above the budget gets its own bucket (leaves are never split —
    see the module docstring).  Works on concrete arrays and
    ``ShapeDtypeStruct`` trees alike, so plans can be built AOT.

    ``per_leaf=True`` ignores the byte budget and emits ONE bucket per
    leaf (still reverse-layer order): the fused-VJP schedule, where each
    layer's message is already encoded the moment backprop produces its
    cotangent, so the natural pipeline unit is the layer itself.
    """
    if bucket_bytes <= 0:
        raise ValueError(f"bucket_bytes must be positive, got {bucket_bytes}")
    leaves = jax.tree_util.tree_leaves(wtree)
    buckets: List[Bucket] = []
    cur: List[int] = []
    cur_bytes = 0
    for i in reversed(range(len(leaves))):
        leaf = leaves[i]
        n_inner = 1
        for s in leaf.shape[1:]:
            n_inner *= s
        b = n_inner * np.dtype(leaf.dtype).itemsize
        if per_leaf:
            buckets.append(Bucket((i,), b))
            continue
        if cur and cur_bytes + b > bucket_bytes:
            buckets.append(Bucket(tuple(cur), cur_bytes))
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += b
    if cur:
        buckets.append(Bucket(tuple(cur), cur_bytes))
    return BucketPlan(tuple(buckets), len(leaves))


class Handle(NamedTuple):
    """An in-flight bucket reduction: ``values`` are the issued (traced)
    per-leaf results, ``bucket`` says where they land in the tree."""

    bucket: Bucket
    values: Tuple[Any, ...]


class Inflight(NamedTuple):
    """Everything ``reduce_start`` issued; pass to ``finish`` to drain.
    Handles may also be consumed individually, in any order."""

    treedef: Any
    n_leaves: int
    handles: Tuple[Handle, ...]


@dataclass(frozen=True, eq=False)
class AsyncChannel(Channel):
    """Bucketed overlapped Channel (see module docstring).

    ``mode`` is an aggregation wire format (``AGGREGATION_MODES``);
    ``bucket_bytes`` is the per-bucket budget in UNCOMPRESSED per-worker
    message bytes (see ``plan_buckets``).
    """

    mode: str = "q8_ring_fused"
    mesh: Any = None
    randk_q: float = 0.05
    wspecs: Any = None
    bucket_bytes: int = DEFAULT_BUCKET_BYTES
    q8_block_rows: Optional[int] = None  # fused-q8 scale block (None=default)
    per_leaf: bool = False               # one bucket per leaf (the fused-VJP
    #                                      schedule: payloads arrive layer by
    #                                      layer during backprop)
    obs: Any = None                      # optional StampRecorder: stamps the
    #                                      reduce_start/finish call windows
    #                                      (host side only; no effect on the
    #                                      traced computation)

    def __post_init__(self):
        if self.mode not in AGGREGATION_MODES:
            raise ValueError(
                f"unknown aggregation mode {self.mode!r}; "
                f"have {AGGREGATION_MODES}"
            )
        if self.bucket_bytes <= 0:
            raise ValueError(
                f"bucket_bytes must be positive, got {self.bucket_bytes}"
            )

    # -- plumbing ----------------------------------------------------------

    def _plan(self, wtree) -> BucketPlan:
        return plan_buckets(wtree, self.bucket_bytes, per_leaf=self.per_leaf)

    def _spec_leaves(self, wtree) -> Optional[list]:
        """Worker-stacked PartitionSpecs flattened in leaf order (specs
        are tuple subclasses, so pair against the VALUE tree first)."""
        if self.wspecs is None:
            return None
        paired = tmap(lambda _, sp: sp, wtree, self.wspecs)
        return jax.tree_util.tree_leaves(
            paired, is_leaf=lambda x: isinstance(x, P)
        )

    def _reduce_bucket(self, key, leaves, bucket: Bucket,
                       spec_leaves) -> Handle:
        from repro.dist.collectives import compressed_tree_mean

        sub = [leaves[i] for i in bucket.indices]
        sub_specs = (
            [spec_leaves[i] for i in bucket.indices] if spec_leaves else None
        )
        outs = compressed_tree_mean(
            sub, self.mode, key, self.mesh,
            randk_q=self.randk_q, wspecs=sub_specs,
            leaf_indices=bucket.indices,
            q8_block_rows=self.q8_block_rows,
        )
        return Handle(bucket, tuple(outs))

    def _uplink_bucket(self, q, key, leaves, bucket: Bucket):
        """Encode+decode one bucket's leaves (keys folded from GLOBAL
        leaf positions — bit-exact with the unbucketed uplink)."""
        decoded, bits = [], []
        for i in bucket.indices:
            payload, dec = encode_decode_workers(
                q, leaf_key(key, i), leaves[i]
            )
            decoded.append(dec)
            bits.append(q.wire_bits(payload))
        return decoded, bits

    # -- explicit start/finish API ----------------------------------------

    def reduce_start(self, key, wtree) -> Inflight:
        """Issue every bucket's aggregation; returns handles without
        assembling the tree (callers overlap other work, then
        ``finish``).  With an ``obs`` StampRecorder attached the call
        window is stamped ``"reduce_start"`` — the measured-overlap
        probe (``repro.tune.measure.measure_overlap_hide``) reads these
        stamps off the SAME handles the runtime schedules."""
        if self.obs is not None:
            with self.obs.stamp("reduce_start"):
                return self._reduce_start(key, wtree)
        return self._reduce_start(key, wtree)

    def _reduce_start(self, key, wtree) -> Inflight:
        leaves, treedef = jax.tree_util.tree_flatten(wtree)
        spec_leaves = self._spec_leaves(wtree)
        plan = self._plan(wtree)
        handles = tuple(
            self._reduce_bucket(key, leaves, b, spec_leaves)
            for b in plan.buckets
        )
        return Inflight(treedef, plan.n_leaves, handles)

    def finish(self, inflight: Inflight):
        """Drain all handles back into the aggregated tree (the call
        window is stamped ``"finish"`` when ``obs`` is attached)."""
        if self.obs is not None:
            with self.obs.stamp("finish"):
                return self._finish(inflight)
        return self._finish(inflight)

    def _finish(self, inflight: Inflight):
        out: list = [None] * inflight.n_leaves
        seen = 0
        for h in inflight.handles:
            for j, i in enumerate(h.bucket.indices):
                out[i] = h.values[j]
                seen += 1
        if seen != inflight.n_leaves or any(o is None for o in out):
            raise ValueError(
                f"finish: handles cover {seen} of {inflight.n_leaves} leaves"
            )
        return jax.tree_util.tree_unflatten(inflight.treedef, out)

    # -- Channel interface -------------------------------------------------
    # uplink is inherited: encoding alone has no reductions to overlap
    # with, so bucket order would be a no-op there — only push_mean
    # interleaves (and its per-bucket encodes stay bit-exact with the
    # inherited uplink because keys fold global leaf positions).

    def reduce_mean(self, key, wtree):
        """The synchronous drain: start everything, finish everything —
        bit-exact with ``MeshChannel(mode=...)`` (the contract test)."""
        return self.finish(self.reduce_start(key, wtree))

    def shift_round(self, rule, q, key, wgrads, h, h_bar):
        """The overlapped SHIFT-RULE round: bucket i's message is formed
        (``rule.message_leaf`` with keys folded from GLOBAL leaf
        positions) and its reduction issued BEFORE bucket i+1's message
        — the same interleave as ``push_mean``, but for any rule of the
        phased protocol, so shifted modes (DIANA, EF21, EF-BV, ...) ride
        the overlap runtime instead of being silently serialized.

        Scheduling only: drained synchronously this is bit-exact with
        the default ``Channel.shift_round`` over this channel's
        ``reduce_mean`` (the engine contract test), because both fold
        the same global leaf positions into the message and reduction
        keys.  ``rule.apply`` — the math — is untouched.
        """
        k_msg, k_aux, k_agg = jax.random.split(key, 3)
        g_leaves, treedef = jax.tree_util.tree_flatten(wgrads)
        n = len(g_leaves)
        h_leaves = [None] * n if h is None else jax.tree_util.tree_leaves(h)
        plan = self._plan(wgrads)
        spec_leaves = self._spec_leaves(wgrads)
        msgs: list = [None] * n
        reduced: list = [None] * n
        bits = jnp.zeros((), jnp.float32)

        for b in plan.buckets:
            for i in b.indices:
                m, bl = rule.message_leaf(
                    q, leaf_key(k_msg, i), g_leaves[i], h_leaves[i]
                )
                msgs[i] = m
                bits = bits + bl
            hd = self._reduce_bucket(k_agg, msgs, b, spec_leaves)
            for j, i in enumerate(hd.bucket.indices):
                reduced[i] = hd.values[j]

        m_tree = jax.tree_util.tree_unflatten(treedef, msgs)
        m_bar = jax.tree_util.tree_unflatten(treedef, reduced)
        aux, extra = rule.aux(k_aux, wgrads, h)
        g_bar, h_new, hb_new = rule.apply(wgrads, m_tree, m_bar, h, h_bar, aux)
        return g_bar, h_new, hb_new, bits + extra

    def fused_round(self, rule, q, key, msgs, h, h_bar):
        """``shift_round`` for PRE-ENCODED messages (the fused-VJP path:
        backprop already emitted each leaf's decoded message as its
        cotangent, so there is no message phase here — only the
        bucket-by-bucket reductions).  With ``per_leaf=True`` (the
        ``q8_ring_fused_vjp`` channel) every leaf is its own pipeline
        unit, matching the layer-by-layer arrival order of the fused
        backward.

        Bit-exact with ``shift_round`` on the same round key: the
        message keys were pre-derived from this key's ``k_msg`` split
        (``fused_vjp.round_message_keys``), the reductions fold the
        same GLOBAL leaf indices, and the structural per-leaf bits are
        accumulated in the same reverse-layer order.
        """
        from repro.comm.fused_vjp import check_fusible

        check_fusible(rule)
        _k_msg, k_aux, k_agg = jax.random.split(key, 3)
        leaves, treedef = jax.tree_util.tree_flatten(msgs)
        plan = self._plan(msgs)
        spec_leaves = self._spec_leaves(msgs)
        reduced: list = [None] * len(leaves)
        bits = jnp.zeros((), jnp.float32)

        for b in plan.buckets:
            for i in b.indices:
                bits = bits + rule.message_bits_aot(q, leaves[i])
            hd = self._reduce_bucket(k_agg, leaves, b, spec_leaves)
            for j, i in enumerate(hd.bucket.indices):
                reduced[i] = hd.values[j]

        m_bar = jax.tree_util.tree_unflatten(treedef, reduced)
        aux, extra = rule.aux(k_aux, msgs, h)
        g_bar, h_new, hb_new = rule.apply(msgs, msgs, m_bar, h, h_bar, aux)
        return g_bar, h_new, hb_new, bits + extra

    def push_mean(self, q, key, wtree):
        """The overlapped round: each bucket's reduction is issued right
        after its encode and BEFORE the next bucket's encode
        (reverse-layer order) — consecutive buckets share no data
        dependency, so under jit the tail buckets' ring hops can run
        while XLA still has later encodes (and, in the full train step,
        earlier backward) to schedule."""
        k1, k2 = jax.random.split(key)
        leaves, treedef = jax.tree_util.tree_flatten(wtree)
        plan = self._plan(wtree)
        spec_leaves = self._spec_leaves(wtree)
        msgs: list = [None] * len(leaves)
        reduced: list = [None] * len(leaves)
        bits_by_leaf: list = [None] * len(leaves)

        for b in plan.buckets:
            decoded, bits = self._uplink_bucket(q, k1, leaves, b)
            for j, i in enumerate(b.indices):
                msgs[i] = decoded[j]
                bits_by_leaf[i] = bits[j]
            h = self._reduce_bucket(k2, msgs, b, spec_leaves)
            for j, i in enumerate(h.bucket.indices):
                reduced[i] = h.values[j]

        total = jnp.zeros((), jnp.float32)
        for b_leaf in bits_by_leaf:
            total = total + b_leaf
        return (
            jax.tree_util.tree_unflatten(treedef, msgs),
            jax.tree_util.tree_unflatten(treedef, reduced),
            total,
        )
