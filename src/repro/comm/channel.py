"""The Channel: one transport abstraction for compressed messages.

Algorithm 1 has two communication directions, and the framework now
routes BOTH through a single interface instead of ad-hoc call sites:

  ``uplink(q, key, wtree)``
        workers encode their (shifted) gradients with codec ``q`` and
        send the payloads to the master.  Returns the decoded
        worker-stacked messages plus the TOTAL wire bits, computed
        structurally from the actual payloads (``q.wire_bits``) — no
        analytic ``bits(d)`` formulas on any live path.
  ``reduce_mean(key, wtree)``
        master-side aggregation of (already decoded) worker messages in
        the channel's aggregation wire format.
  ``push_mean(q, key, wtree)``
        the composed round: uplink then aggregate.
  ``broadcast(q, key, tree)``
        the downlink (model-broadcast) direction: one encoded message
        from the master, decoded by every worker.

Three interchangeable implementations:

  ``SimChannel``   the vmapped parameter-server of ``core.simulate`` /
        ``core.shift_rules``: the master receives every decoded message
        exactly (aggregation = exact mean over the worker axis).
  ``MeshChannel``  the production path: uplink is identical (messages
        live on their worker's device slice), aggregation wraps
        ``dist.collectives`` — dense psum, shared-pattern Rand-K, or the
        int8 ring/tree all-reduce, all driven by the same codecs.
  ``AsyncChannel`` (``repro.comm.overlap``) the overlapped runtime:
        reverse-layer byte-budget buckets with explicit start/finish
        handles and an interleaved encode/reduce pipeline; drained
        synchronously it is bit-exact with ``MeshChannel``.

``make_channel`` builds the right one from a ``CompressionConfig`` (or a
comm-mode string), replacing the string dispatch that used to live in
``launch/train.py``.  The ``ef21``/``efbv`` comm modes aggregate
densely — the messages themselves are the contractive-compressed
error-feedback increments — and the overlap modes (``q8_ring_overlap``,
``efbv_overlap``) select the AsyncChannel over the Pallas-fused
``q8_ring_fused`` aggregation format.

``Channel.shift_round`` is the engine entry: one shift-rule round
(message -> aux -> reduce -> apply) scheduled by the channel.  All
three channels run the SAME rule algebra (``repro.core.shift_rules``);
the AsyncChannel merely re-schedules it bucket by bucket.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm.wire import encode_decode_workers, encode_meta_free, leaf_key

if TYPE_CHECKING:  # import cycle: core.shift_rules routes through Channel
    from repro.core.compressors import Compressor

tmap = jax.tree_util.tree_map

#: aggregation formats a MeshChannel supports (ef21/efbv/disabled map to
#: dense)
AGGREGATION_MODES = ("dense", "randk_shared", "q8_ring", "q8_ring_fused")

#: every comm-mode string make_channel accepts (config/CLI surface):
#: aggregation formats plus the channel-selecting aliases
CHANNEL_MODES = AGGREGATION_MODES + (
    "sim", "ef21", "efbv", "q8_ring_overlap", "efbv_overlap"
)

#: comm modes served by the bucketed overlapped AsyncChannel
OVERLAP_MODES = ("q8_ring_overlap", "efbv_overlap")

#: comm modes whose wire messages are emitted by the backward pass
#: itself (``repro.comm.fused_vjp``): the AsyncChannel consumes the
#: pre-encoded per-leaf messages with NO standalone encode stage, one
#: bucket per leaf (true per-layer granularity)
FUSED_VJP_MODES = ("q8_ring_fused_vjp",)

CHANNEL_MODES = CHANNEL_MODES + FUSED_VJP_MODES


class Channel:
    """Transport for compressed messages between workers and master."""

    def uplink(self, q: Compressor, key: jax.Array, wtree) -> Tuple[Any, jax.Array]:
        """Encode+decode each worker's slice of a W-stacked pytree.

        Workers get decorrelated keys unless the codec declares a shared
        pattern (correlated Rand-K) or is deterministic, in which case
        every worker samples the same key — the property the
        payload-shrinking collective relies on.  Returns
        ``(decoded W-stacked messages, total wire bits)``; bits are
        structural (summed ``q.wire_bits`` over the actual payloads).
        """
        leaves, treedef = jax.tree_util.tree_flatten(wtree)
        out = []
        bits = jnp.zeros((), jnp.float32)
        for i, leaf in enumerate(leaves):
            lk = leaf_key(key, i)
            payload, decoded = encode_decode_workers(q, lk, leaf)
            bits = bits + q.wire_bits(payload)
            out.append(decoded)
        return jax.tree_util.tree_unflatten(treedef, out), bits

    def reduce_mean(self, key: jax.Array, wtree):
        raise NotImplementedError

    def push_mean(self, q: Compressor, key: jax.Array, wtree):
        """One uplink round: ``(messages, mean over workers, wire bits)``."""
        k1, k2 = jax.random.split(key)
        m, bits = self.uplink(q, k1, wtree)
        return m, self.reduce_mean(k2, m), bits

    def shift_round(self, rule, q: Compressor, key: jax.Array,
                    wgrads, h, h_bar):
        """One shift-rule round, scheduled by this channel.

        The DEFAULT schedule: the rule's whole-tree message, its
        tree-level aux draw, ONE aggregation of the message tree, then
        the rule's ``apply``.  Subclasses that pipeline (the bucketed
        ``AsyncChannel``) override the SCHEDULE only — the per-leaf key
        folding (global tree positions) keeps any re-schedule bit-exact
        with this one.  Returns ``(g_bar, h_new, h_bar_new, bits)``.
        """
        k_msg, k_aux, k_agg = jax.random.split(key, 3)
        m, bits = rule.message(q, k_msg, wgrads, h)
        aux, extra = rule.aux(k_aux, wgrads, h)
        m_bar = self.reduce_mean(k_agg, m)
        g_bar, h_new, hb_new = rule.apply(wgrads, m, m_bar, h, h_bar, aux)
        return g_bar, h_new, hb_new, bits + extra

    def fused_round(self, rule, q: Compressor, key: jax.Array,
                    msgs, h, h_bar):
        """The shift-round tail for PRE-ENCODED messages.

        ``msgs`` is the already decoded W-stacked message tree the
        fused-backward path emitted as cotangents
        (``repro.comm.fused_vjp``: the keys were derived from THIS
        round key's ``k_msg`` split, so ``k_msg`` is consumed here by
        discarding it).  The schedule is ``shift_round`` minus its
        message phase: aux draw, one aggregation, ``apply`` — with the
        rule's ``msgs`` standing in for the dense gradients its
        fusibility contract says it never reads.  Bits are the per-leaf
        STRUCTURAL ``message_bits_aot``, accumulated in the same leaf
        order as ``rule.message`` so the counter matches the post-hoc
        round bitwise.  Returns ``(g_bar, h_new, h_bar_new, bits)``.
        """
        from repro.comm.fused_vjp import check_fusible

        check_fusible(rule)
        _k_msg, k_aux, k_agg = jax.random.split(key, 3)
        bits = jnp.zeros((), jnp.float32)
        for leaf in jax.tree_util.tree_leaves(msgs):
            bits = bits + rule.message_bits_aot(q, leaf)
        aux, extra = rule.aux(k_aux, msgs, h)
        m_bar = self.reduce_mean(k_agg, msgs)
        g_bar, h_new, hb_new = rule.apply(msgs, msgs, m_bar, h, h_bar, aux)
        return g_bar, h_new, hb_new, bits + extra

    def all_to_all(self, q: Compressor, key: jax.Array, x: jax.Array):
        """Forwarded-payload transport for the non-allreduce wires
        (MoE dispatch/combine, pipeline-boundary activations).

        Encodes ``x`` with codec ``q`` and returns the receiver-side
        decode.  The receiver sees ONLY the payload — meta-carrying
        codecs are rejected (``encode_meta_free``), the same contract as
        the quantized ring hops.  Under GSPMD the surrounding dispatch
        einsums lower to the actual all-to-all; what this method pins is
        that the tensor crossing it is the codec's wire format.  Shared
        by all channels (the math is placement-independent); the
        structural accounting for these payloads lives on the ``Wire``
        (``repro.comm.transport``), not here.
        """
        payload = encode_meta_free(q, key, x)
        return q.decode(payload, {}, jax.ShapeDtypeStruct(x.shape, x.dtype))

    def broadcast(self, q: Compressor, key: jax.Array, tree) -> Tuple[Any, jax.Array]:
        """Downlink (model-broadcast): one encoded message per leaf."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        out = []
        bits = jnp.zeros((), jnp.float32)
        for i, leaf in enumerate(leaves):
            lk = leaf_key(key, i)
            payload, meta = q.encode(lk, leaf)
            bits = bits + q.wire_bits(payload)
            out.append(
                q.decode(payload, meta,
                         jax.ShapeDtypeStruct(leaf.shape, leaf.dtype))
            )
        return jax.tree_util.tree_unflatten(treedef, out), bits


@dataclass(frozen=True, eq=False)
class SimChannel(Channel):
    """Vmapped parameter server: the master sees every decoded message
    exactly, so aggregation is the exact mean over the worker axis."""

    def reduce_mean(self, key, wtree):
        return tmap(lambda a: jnp.mean(a, axis=0), wtree)


@dataclass(frozen=True, eq=False)
class MeshChannel(Channel):
    """Production channel on a device mesh.

    ``mode`` picks the aggregation wire format (see ``AGGREGATION_MODES``);
    ``wspecs`` optionally carries worker-stacked PartitionSpecs so the
    q8 ring's shard_map preserves inner-dim sharding.
    """

    mode: str = "dense"
    mesh: Any = None
    randk_q: float = 0.05
    wspecs: Any = None
    q8_block_rows: Optional[int] = None  # fused-q8 scale block (None=default)

    def __post_init__(self):
        if self.mode not in AGGREGATION_MODES:
            raise ValueError(
                f"unknown aggregation mode {self.mode!r}; "
                f"have {AGGREGATION_MODES}"
            )

    def reduce_mean(self, key, wtree):
        from repro.dist.collectives import compressed_tree_mean

        return compressed_tree_mean(
            wtree, self.mode, key, self.mesh,
            randk_q=self.randk_q, wspecs=self.wspecs,
            q8_block_rows=self.q8_block_rows,
        )


def aggregation_mode_of(mode_or_cfg) -> str:
    """Normalize a comm-mode string / CompressionConfig to an aggregation
    format: disabled configs and the ``ef21``/``efbv`` modes aggregate
    densely (their wire savings are in the per-worker contractive
    messages); the overlap modes aggregate in the Pallas-fused
    ``q8_ring_fused`` wire format."""
    if hasattr(mode_or_cfg, "aggregation_mode"):  # CompressionConfig
        return mode_or_cfg.aggregation_mode
    if mode_or_cfg in ("ef21", "efbv"):
        return "dense"
    if mode_or_cfg in OVERLAP_MODES + FUSED_VJP_MODES:
        return "q8_ring_fused"
    return mode_or_cfg


def make_channel(mode_or_cfg="dense", mesh=None, *, randk_q: float = 0.05,
                 wspecs=None, bucket_bytes: Optional[int] = None,
                 q8_block_rows: Optional[int] = None) -> Channel:
    """Build a Channel from a comm-mode string or a CompressionConfig.

    ``"sim"`` gives the parameter-server SimChannel; the overlap modes
    (``q8_ring_overlap``, ``efbv_overlap``) the bucketed AsyncChannel
    over the fused q8 ring (``bucket_bytes`` sets its per-bucket budget
    in uncompressed per-worker message bytes, and is rejected for every
    other mode); ``q8_ring_fused_vjp`` the same AsyncChannel in per-leaf
    bucket mode, consuming messages the backward pass itself emitted
    (``repro.comm.fused_vjp`` — no standalone encode stage); everything
    else a MeshChannel in the corresponding aggregation format.  Unknown modes raise, naming every accepted
    mode — a typo'd mode must fail HERE, not as a confusing shape/key
    error deep in a collective.
    """
    comm_mode = getattr(mode_or_cfg, "comm_mode", mode_or_cfg)
    if comm_mode == "auto":
        if getattr(mode_or_cfg, "enabled", True):
            raise ValueError(
                "comm_mode 'auto' is a tuner sentinel, not a transport: "
                "resolve it to a concrete mode first (repro.tune.autotune "
                "+ apply_plan, or `train.py --comm_mode auto` which does "
                "both)"
            )
        # a DISABLED config never resolves: its transport is the dense
        # mean, exactly as CompressionConfig.aggregation_mode reports
        comm_mode = "dense"
    if isinstance(comm_mode, str) and comm_mode not in CHANNEL_MODES:
        raise ValueError(
            f"unknown comm mode {comm_mode!r}; have channel modes "
            f"{CHANNEL_MODES} (aggregation formats: {AGGREGATION_MODES})"
        )
    if (bucket_bytes is not None
            and comm_mode not in OVERLAP_MODES + FUSED_VJP_MODES):
        raise ValueError(
            f"bucket_bytes only applies to the overlap channels "
            f"{OVERLAP_MODES + FUSED_VJP_MODES}, not {comm_mode!r} (it "
            f"would be silently ignored)"
        )
    if comm_mode == "sim":  # uniform: string or config comm_mode
        return SimChannel()
    if hasattr(mode_or_cfg, "comm_mode"):
        randk_q = mode_or_cfg.randk_q
        if bucket_bytes is None:
            bucket_bytes = getattr(mode_or_cfg, "overlap_bucket_bytes", None)
        if q8_block_rows is None:
            q8_block_rows = getattr(mode_or_cfg, "q8_block_rows", None)
    mode = aggregation_mode_of(mode_or_cfg)
    if comm_mode in OVERLAP_MODES + FUSED_VJP_MODES:
        from repro.comm.overlap import DEFAULT_BUCKET_BYTES, AsyncChannel

        return AsyncChannel(
            mode=mode, mesh=mesh, randk_q=randk_q, wspecs=wspecs,
            bucket_bytes=(DEFAULT_BUCKET_BYTES if bucket_bytes is None
                          else bucket_bytes),
            q8_block_rows=q8_block_rows,
            # fused-VJP: payloads arrive leaf by leaf during backprop,
            # so the plan is one bucket per leaf (per-layer granularity)
            per_leaf=comm_mode in FUSED_VJP_MODES,
        )
    return MeshChannel(mode=mode, mesh=mesh, randk_q=randk_q, wspecs=wspecs,
                       q8_block_rows=q8_block_rows)


def resync_h_bar(h, h_bar, step, every: int):
    """Bound the shift-tracking drift of lossy aggregation.

    Stateful rules track the master shift INCREMENTALLY
    (``h_bar += eta * m_bar``), so lossy aggregation formats
    (``randk_shared``, the q8 rings) make ``h_bar - mean_i h_i`` a
    zero-mean random walk of the per-step aggregation noise (see the
    ARCHITECTURE.md "Algorithm layer" footnote).  Every ``every`` rounds
    — on steps where ``step % every == every - 1`` — this replaces
    ``h_bar`` with the DENSE reduce (exact worker mean) of the current
    shifts, resetting the walk to zero at the cost of one uncompressed
    collective per window.  ``every <= 0`` (the config default) and
    stateless rules (``h``/``h_bar`` None) are no-ops; ``lax.cond``
    keeps the dense reduce off the non-firing steps.
    """
    if every <= 0 or h is None or h_bar is None:
        return h_bar
    from repro.dist.collectives import dense_mean

    fire = (step % every) == (every - 1)
    return jax.lax.cond(fire, lambda: dense_mean(h), lambda: h_bar)


def collective_payload_scale(cfg, d_nominal: int = 1_000_000) -> dict:
    """Per-collective-kind wire fraction for the HLO payload cost model.

    Only aggregation formats whose HLO lowering is DENSE while the
    protocol payload is compressed need a scale.  The codec-driven
    collectives are structurally honest on their own: the q8 ring's s8
    payloads and the shared-pattern Rand-K's K-sized value mean both
    appear at true wire size in the HLO text (scale 1 — the ROADMAP's
    "wire randk_shared payload accounting into the HLO cost model" item
    is satisfied by the lowering itself).  EF21 is the remaining dense
    lowering: its aggregation is an exact mean of DECODED sparse
    messages, so the all-reduce is full-width in HLO while the wire
    carries the contractive codec's payload — scale by that codec's
    wire fraction, derived structurally (``aot_wire_bits``), not from an
    analytic formula.  The same holds for ``efbv`` (EF-BV shares EF21's
    dense aggregation of decoded messages).  Apply it to the
    GRADIENT-MESSAGE share only
    (``hlo_cost.apply_gradient_payload_model``): activation all-reduces
    under model parallelism are genuine dense traffic.
    """
    if not getattr(cfg, "enabled", True):
        return {}
    if getattr(cfg, "comm_mode", "dense") in ("ef21", "efbv"):
        from repro.core.compressors import aot_wire_bits, make_compressor

        q = make_compressor(cfg.compressor, **dict(cfg.compressor_kwargs))
        return {"all-reduce": aot_wire_bits(q, d_nominal) / (32.0 * d_nominal)}
    return {}
