"""The Transport layer: every wire in the system as one registry.

The paper's shifted-compression framework applies to ANY exchanged
vector, not just gradients.  After the Channel unification the repo
still had exactly one consumer (the gradient all-reduce); this module
makes "a thing that moves compressed tensors" a first-class object so
MoE expert dispatch/combine, pipeline-boundary activations — and later
arcs (elastic workers, serving deltas) — are a REGISTRATION, not a new
subsystem:

  ``Wire``       one named traffic stream: a topology
        (``allreduce | all_to_all | p2p``), the codec whose payload
        rides it, an optional shift rule + Channel (allreduce wires),
        and its declared per-step traffic for structural accounting.
  ``Transport``  the per-step registry of every Wire.  ``per_wire_bits``
        is the accounting surface the dryrun table, the tune predictor
        and ``BENCH_moe_wire.json`` all read.
  ``build_transport``  constructs the standard registry from a
        ``CompressionConfig`` + ``ModelConfig``: the grad wire always,
        the ``moe`` / ``act`` wires when their config flags are set.

Keying rule (pinned by tests):

  * The GRAD wire passes its round key VERBATIM to
    ``rule.round(...)`` — bit-exact with the pre-refactor
    ``Channel.shift_round`` by construction.
  * Every OTHER wire derives its key stream with ``wire_stream(key,
    name)`` (fold a stable hash of the wire name), so no two wires —
    and no wire and the grad path — ever share an encode key stream.
  * Error-feedback state is PER WIRE and per step: ``Wire.send``
    threads a shift ``e`` (zeroed at step start) along the wire's send
    stream (MoE groups, pipeline layers), so compression noise on one
    wire never biases another.

``Wire.send`` is the forwarded-payload hop: encode with the wire's
codec (meta-free — the receiver sees only the payload), decode on the
receiving side, STRAIGHT-THROUGH on the backward pass (the decode is
treated as identity by the gradient), classic error feedback when a
shift is threaded: ``d = Dec(Enc(x + e));  e' = x + e - d``.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm.channel import FUSED_VJP_MODES, OVERLAP_MODES
from repro.comm.wire import encode_meta_free, encode_workers

#: wire topologies the Transport understands.  ``allreduce`` wires run
#: the shift-rule engine through a Channel; ``all_to_all`` and ``p2p``
#: wires forward codec payloads point to point (``Wire.send``);
#: ``broadcast`` wires fan one sender's payload out to every subscriber
#: (``Wire.broadcast`` — the trainer->serving-fleet model downlink).
WIRE_TOPOLOGIES = ("allreduce", "all_to_all", "p2p", "broadcast")

#: per-wire codec flags the config/CLI surface accepts (``--moe_wire``,
#: ``--act_wire``, ``--model_wire``); "none" disables the wire, "dense"
#: moves full-width payloads through the transport (bitwise-identical
#: math, real accounting)
WIRE_CODEC_FLAGS = ("none", "dense", "q8", "randk", "topk", "sign",
                    "natural")

_KEY_SDS = jax.ShapeDtypeStruct((2,), jnp.uint32)


def wire_stream(key: jax.Array, name: str) -> jax.Array:
    """THE per-wire key derivation: fold a stable hash of the wire name.

    Every non-grad wire derives its keys here, so no two wires share an
    encode key stream and adding a wire never perturbs another wire's
    randomness.  The grad wire deliberately does NOT use this — its
    round key passes verbatim to the rule engine, which is what keeps
    the refactored grad path bit-exact with ``Channel.shift_round``.
    """
    return jax.random.fold_in(key, zlib.crc32(name.encode("utf-8")) & 0x7FFFFFFF)


def wire_flag_codec(flag: str, *, randk_q: float = 0.05):
    """Codec for one per-wire config flag (``None`` for ``"none"``).

    Every codec here is META-FREE (decoder state travels in the payload)
    because forwarded-payload wires cannot ship shared-seed side
    information — ``encode_meta_free`` enforces it again at send time.
    """
    from repro.core.compressors import (
        Identity,
        Int8Stochastic,
        NaturalCompression,
        RandK,
        ScaledSign,
        TopK,
    )

    table = {
        "none": lambda: None,
        "dense": Identity,
        "q8": Int8Stochastic,
        "randk": lambda: RandK(q=randk_q),
        "topk": lambda: TopK(q=randk_q),
        "sign": ScaledSign,
        "natural": NaturalCompression,
    }
    if flag not in table:
        raise ValueError(
            f"unknown wire codec {flag!r}; have {WIRE_CODEC_FLAGS}"
        )
    return table[flag]()


def aggregation_wire_codec(comp):
    """The codec whose payload defines a grad-wire round's bytes-on-wire.

    Accepts anything with ``comm_mode`` / ``randk_q`` / ``q8_block_rows``
    / ``compressor`` attributes (a ``CompressionConfig`` or a tune
    ``Candidate``) — the ONE mode->codec map shared by the transport's
    accounting and the tune predictor, so the two cannot drift.
    Aggregation-format modes are charged their aggregation codec (that
    payload rides the collective); the error-feedback modes aggregate
    densely in HLO but their protocol wire is the configured
    contractive message (see ``collective_payload_scale``).
    """
    from repro.core.compressors import (
        Identity,
        Int8Stochastic,
        RandK,
        make_compressor,
    )

    if not getattr(comp, "enabled", True):
        return Identity()
    mode = comp.comm_mode
    if mode in ("dense", "sim"):  # sim: the exact-mean parameter server
        return Identity()         # forwards dense messages

    if mode == "randk_shared":
        return RandK(q=comp.randk_q, shared_pattern=True)
    if mode == "q8_ring":
        return Int8Stochastic()
    if mode in ("q8_ring_fused",) + OVERLAP_MODES + FUSED_VJP_MODES:
        from repro.kernels.q8ring.ops import FusedQ8

        return FusedQ8(block_rows=comp.q8_block_rows)
    if mode in ("ef21", "efbv"):
        return make_compressor(comp.compressor,
                               **dict(comp.compressor_kwargs))
    raise ValueError(f"no wire codec for comm mode {mode!r}")


def _aot_payload_shapes(codec, sds, topology: str):
    """The payload pytree (as ShapeDtypeStructs) of ONE send of ``sds``
    through ``codec`` — the same encode path the live traffic runs."""
    if topology == "allreduce":
        payload, _ = jax.eval_shape(
            lambda k, l: encode_workers(codec, k, l), _KEY_SDS, sds
        )
    else:
        payload = jax.eval_shape(
            lambda k, l: encode_meta_free(codec, k, l), _KEY_SDS, sds
        )
    return payload


def _aot_payload_bits(codec, sds, topology: str) -> float:
    """Structural bits of ONE payload of ``sds`` through ``codec``, AOT.

    Allreduce traffic is worker-stacked and runs the SAME
    ``encode_workers`` path as the live uplink; forwarded topologies run
    the same meta-free encode as ``Wire.send`` — either way the number
    cannot drift from the wire protocol without the accounting tests
    catching it.
    """
    return float(codec.wire_bits(_aot_payload_shapes(codec, sds, topology)))


def _aot_payload_nbytes(codec, sds, topology: str) -> float:
    """ACTUAL buffer bytes of one send's payload tree, AOT — the
    container-width number (an int8 payload leaf counts 1 byte/elem)
    next to the structural ``wire_bits`` (the protocol-width number);
    the two differ exactly where a codec's wire format packs below its
    buffer dtype."""
    import numpy as np

    payload = _aot_payload_shapes(codec, sds, topology)
    return float(sum(
        int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        for leaf in jax.tree_util.tree_leaves(payload)
    ))


@dataclass(eq=False)
class Wire:
    """One named traffic stream owned by the Transport.

    ``traffic`` declares the per-STEP payload tensors as
    ``((ShapeDtypeStruct, count), ...)`` — counts fold repeated sends
    (scan groups, layers, workers) so accounting stays static instead of
    accumulating traced bits through ``lax.scan``.
    """

    name: str
    topology: str
    codec: Any                       # accounting / forwarded-hop codec
    channel: Any = None
    rule: Any = None                 # allreduce: the phased ShiftRule
    msg_codec: Any = None            # allreduce: the rule's message compressor
    traffic: Tuple = ()              # ((sds, count), ...)
    overlap_hidden: float = 0.0      # fraction of comm hidden under compute
    fused: bool = False              # encode runs INSIDE the backward pass
    #                                  (repro.comm.fused_vjp): no standalone
    #                                  encode launches on this wire

    def __post_init__(self):
        if self.topology not in WIRE_TOPOLOGIES:
            raise ValueError(
                f"unknown wire topology {self.topology!r}; "
                f"have {WIRE_TOPOLOGIES}"
            )

    # -- allreduce wires: the shift-rule engine, key passed VERBATIM ----

    def reduce_mean(self, key, wtree):
        return self.channel.reduce_mean(key, wtree)

    def shift_round(self, key, wgrads, h, h_bar):
        """One gradient round.  The key goes to ``rule.round`` verbatim
        — bit-exact with the pre-refactor ``Channel.shift_round`` call
        (pinned in tests/test_transport.py)."""
        return self.rule.round(self.msg_codec, key, wgrads, h, h_bar,
                               self.channel)

    def fused_round(self, key, msgs, h, h_bar):
        """The fused-backward round tail: ``msgs`` are the decoded wire
        messages backprop already emitted as cotangents
        (``repro.comm.fused_vjp`` — keys pre-derived from THIS round
        key, so the same verbatim-key contract as ``shift_round``
        holds).  Returns ``(g_bar, h_new, h_bar_new, bits)``."""
        return self.channel.fused_round(self.rule, self.msg_codec, key,
                                        msgs, h, h_bar)

    def iterate_round(self, key, params, wgrads, h, h_bar):
        """Algorithm 2 (VR-GDCI): compressed-iterate round."""
        return self.rule.round(key, params, wgrads, h, h_bar, self.channel)

    # -- forwarded-payload wires: one compressed hop --------------------

    def send(self, key, x, e=None):
        """One compressed hop of ``x``: ``(y, e_new)``.

        Forward value is the DECODED payload; the backward pass is
        straight-through (decode treated as identity, so gradients flow
        to ``x`` uncompressed).  With a threaded shift ``e`` this is
        classic within-step error feedback: the error-compensated signal
        ``x + e`` is what rides the wire, and the residual becomes the
        next send's shift — routing/quantization noise averages out
        along the wire's send stream instead of biasing it.
        """
        target = x if e is None else x + e.astype(x.dtype)
        decoded = self.channel.all_to_all(self.codec, key, target)
        y = x + jax.lax.stop_gradient(decoded - x)
        e_new = None if e is None else jax.lax.stop_gradient(target - decoded)
        return y, e_new

    def broadcast(self, key, tree):
        """One downlink fan-out of a whole pytree: ``(decoded, bits)``.

        The sender encodes each leaf once with the wire's codec and
        every subscriber decodes the same payload — bits are counted
        once (a broadcast tree sends each byte per LINK, not per
        subscriber).  This is the model-delta hop of
        ``repro.serving.delta``; the accounting codec is the same object
        ``wire_bits`` charges.
        """
        return self.channel.broadcast(self.codec, key, tree)

    # -- accounting ------------------------------------------------------

    def wire_bits(self) -> float:
        """Per-step wire bits of this wire's declared traffic, AOT."""
        total = 0.0
        cache: Dict[Tuple, float] = {}
        for sds, count in self.traffic:
            sig = (tuple(sds.shape), str(jnp.dtype(sds.dtype)))
            if sig not in cache:
                cache[sig] = _aot_payload_bits(self.codec, sds, self.topology)
            total += count * cache[sig]
        return total

    def payload_nbytes(self) -> float:
        """Per-step ACTUAL payload buffer bytes of the declared traffic
        (see ``_aot_payload_nbytes`` — the obs layer reports this next
        to the structural ``wire_bits``)."""
        total = 0.0
        cache: Dict[Tuple, float] = {}
        for sds, count in self.traffic:
            sig = (tuple(sds.shape), str(jnp.dtype(sds.dtype)))
            if sig not in cache:
                cache[sig] = _aot_payload_nbytes(self.codec, sds,
                                                 self.topology)
            total += count * cache[sig]
        return total

    def codec_timings(self, key: Optional[jax.Array] = None, *,
                      iters: int = 2,
                      cap_bytes: int = 1 << 20) -> Dict[str, Optional[float]]:
        """Measured ``{"encode_s", "decode_s"}`` of ONE payload of this
        wire's traffic through its codec (jitted, median wall clock).

        Times the largest declared shape within ``cap_bytes`` (falling
        back to the smallest — a micro-measurement must stay micro).
        Returns Nones when the wire declares no traffic.  ``decode_s``
        is the encode+decode round trip minus the encode (clamped >= 0:
        short CPU timings are noisy).

        A FUSED wire reports exact zeros without timing anything: its
        encode and decode run inside the backward pass itself (the
        cotangent is consumed as it is produced), so there is no
        standalone codec launch to measure — the deleted stage the obs
        snapshot pins (tests/test_obs.py).
        """
        if self.fused:
            return {"encode_s": 0.0, "decode_s": 0.0}
        if not self.traffic:
            return {"encode_s": None, "decode_s": None}
        import numpy as np

        from repro.tune.measure import time_fn

        def _nbytes(sds):
            return int(np.prod(sds.shape)) * np.dtype(sds.dtype).itemsize

        within = [sds for sds, _ in self.traffic if _nbytes(sds) <= cap_bytes]
        sds = (max(within, key=_nbytes) if within
               else min((s for s, _ in self.traffic), key=_nbytes))
        key = jax.random.PRNGKey(0) if key is None else key
        data = jax.random.normal(key, sds.shape, jnp.float32).astype(sds.dtype)
        codec = self.codec

        if self.topology == "allreduce":
            from repro.comm.wire import encode_decode_workers

            enc = jax.jit(lambda k, l: encode_workers(codec, k, l))
            enc_dec = jax.jit(lambda k, l: encode_decode_workers(codec, k, l))
        else:
            inner = jax.ShapeDtypeStruct(tuple(sds.shape), sds.dtype)

            def _enc(k, l):
                return codec.encode(k, l)

            def _enc_dec(k, l):
                payload, meta = codec.encode(k, l)
                return codec.decode(payload, meta, inner)

            enc = jax.jit(_enc)
            enc_dec = jax.jit(_enc_dec)
        t_enc = time_fn(enc, key, data, iters=iters)
        t_round = time_fn(enc_dec, key, data, iters=iters)
        return {"encode_s": float(t_enc),
                "decode_s": float(max(0.0, t_round - t_enc))}

    def codec_quality(self, key: Optional[jax.Array] = None, *,
                      cap_bytes: int = 1 << 18
                      ) -> Dict[str, Optional[float]]:
        """Measured ``{"omega_hat", "nmse"}`` of ONE payload of this
        wire's traffic through its codec (``repro.obs.quality``).

        Shape selection mirrors ``codec_timings``: the largest declared
        shape within ``cap_bytes``, falling back to the smallest.  The
        probe runs the wire's REAL encode path per topology (allreduce
        → per-worker ``encode_decode_workers`` rows, everything else →
        whole-block encode/decode).  Unlike timings, a FUSED wire is
        probed too — fusing deletes the standalone launch, not the
        distortion.  Returns Nones when no traffic is declared.
        """
        if not self.traffic:
            return {"omega_hat": None, "nmse": None}
        import numpy as np

        from repro.obs.quality import array_distortion

        def _nbytes(sds):
            return int(np.prod(sds.shape)) * np.dtype(sds.dtype).itemsize

        within = [sds for sds, _ in self.traffic if _nbytes(sds) <= cap_bytes]
        sds = (max(within, key=_nbytes) if within
               else min((s for s, _ in self.traffic), key=_nbytes))
        key = jax.random.PRNGKey(0) if key is None else key
        data = jax.random.normal(key, sds.shape, jnp.float32).astype(sds.dtype)
        codec = self.codec
        topology = self.topology
        out = jax.jit(
            lambda k, l: array_distortion(codec, k, l, topology=topology)
        )(key, data)
        err = float(out["err_sq"])
        norm = float(out["norm_sq"])
        nmse = err / norm if norm > 0.0 else 0.0
        return {"omega_hat": nmse, "nmse": nmse}


class Transport:
    """Per-step registry of every Wire.  Dict-like: ``transport["grad"]``,
    ``"moe" in transport``, ``transport.get("act")``."""

    def __init__(self, wires=()):
        self._wires: Dict[str, Wire] = {}
        for wire in wires:
            self.register(wire)

    def register(self, wire: Wire) -> Wire:
        if wire.name in self._wires:
            raise ValueError(
                f"wire {wire.name!r} already registered "
                f"(have {sorted(self._wires)})"
            )
        self._wires[wire.name] = wire
        return wire

    def __contains__(self, name) -> bool:
        return name in self._wires

    def __getitem__(self, name) -> Wire:
        if name not in self._wires:
            raise KeyError(
                f"no wire {name!r} registered; have {sorted(self._wires)}"
            )
        return self._wires[name]

    def get(self, name, default=None) -> Optional[Wire]:
        return self._wires.get(name, default)

    def __iter__(self):
        return iter(self._wires.values())

    def __len__(self) -> int:
        return len(self._wires)

    def names(self):
        return tuple(self._wires)

    def per_wire_bits(self) -> Dict[str, float]:
        """{wire name: per-step wire bits} — the accounting table the
        dryrun, tune predictor and moe_wire bench all surface."""
        return {name: wire.wire_bits() for name, wire in self._wires.items()}

    def obs_snapshot(self, *, timed: bool = False,
                     quality: bool = False) -> Dict[str, dict]:
        """Per-wire telemetry dict for the obs run header: topology,
        codec, structural ``wire_bits`` AND actual ``payload_bytes`` per
        step, plus (with ``timed``) measured encode/decode seconds and
        (with ``quality``) measured ``omega_hat``/``nmse`` of one
        payload.  Keys match what ``repro.obs.export`` renders."""
        snap: Dict[str, dict] = {}
        for name, wire in self._wires.items():
            timings = (wire.codec_timings() if timed
                       else {"encode_s": None, "decode_s": None})
            qual = (wire.codec_quality() if quality
                    else {"omega_hat": None, "nmse": None})
            snap[name] = {
                "topology": wire.topology,
                "codec": type(wire.codec).__name__,
                "wire_bits": wire.wire_bits(),
                "payload_bytes": wire.payload_nbytes(),
                "fused": wire.fused,
                **timings,
                **qual,
            }
        return snap

    def extra_traffic(self) -> Dict[str, Tuple]:
        """Declared traffic of every NON-grad wire, keyed by name — the
        ``wire_traffic`` dict the tune predictor charges."""
        return {
            name: wire.traffic
            for name, wire in self._wires.items()
            if name != "grad" and wire.traffic
        }


def build_transport(comp, cfg, channel, *, rule=None, msg_codec=None,
                    w: int = 1, params_like=None,
                    tokens_per_worker: int = 0) -> Transport:
    """The standard per-step Transport for one run.

    Registers the ``grad`` wire always (its accounting codec is the
    aggregation wire codec of ``comp.comm_mode`` — the same convention
    the tune predictor charges; its engine objects ``rule``/``msg_codec``
    come from ``comp.make()`` and may be None for accounting-only
    transports such as the dryrun's).  The ``moe`` and ``act`` wires are
    registered when their config flags are set, with declared traffic
    when ``tokens_per_worker`` is known:

      * ``moe``  — ``all_to_all``: 2 sends (dispatch + combine) of the
        ``(E, C, D)`` expert buffers per GShard group per MoE layer per
        worker (``repro.models.moe.moe_wire_traffic``).
      * ``act``  — ``p2p``: one ``(tokens, d_model)`` pipeline-boundary
        send per scanned layer per worker.
      * ``model`` — ``broadcast``: the trainer->serving-fleet model-delta
        downlink (``repro.serving.delta``).  One params-shaped payload
        per publish; declared traffic is scaled by ``1/publish_every``
        so ``per_wire_bits`` stays per-STEP like every other wire.

    ``params_like`` (unstacked parameter tree) declares the grad wire's
    traffic as worker-stacked leaves (and the model wire's as unstacked
    leaves); omit it for transports that never read ``per_wire_bits``
    for those wires.
    """
    wires = []
    hidden = 0.0
    if (getattr(comp, "enabled", False)
            and comp.comm_mode in OVERLAP_MODES + FUSED_VJP_MODES):
        from repro.tune.model import OVERLAP_HIDE

        hidden = OVERLAP_HIDE
    grad_traffic = ()
    if params_like is not None:
        grad_traffic = tuple(
            (jax.ShapeDtypeStruct((w, *leaf.shape), leaf.dtype), 1)
            for leaf in jax.tree_util.tree_leaves(params_like)
        )
    wires.append(Wire(
        name="grad", topology="allreduce",
        codec=aggregation_wire_codec(comp), channel=channel,
        rule=rule, msg_codec=msg_codec, traffic=grad_traffic,
        overlap_hidden=hidden,
        fused=(getattr(comp, "enabled", False)
               and comp.comm_mode in FUSED_VJP_MODES),
    ))

    moe_flag = getattr(comp, "moe_wire", "none")
    if moe_flag != "none":
        if not cfg.is_moe:
            raise ValueError(
                f"moe_wire {moe_flag!r} needs a MoE architecture; "
                f"{cfg.name!r} has n_experts={cfg.n_experts}"
            )
        from repro.models.moe import moe_wire_traffic

        traffic = ()
        if tokens_per_worker > 0:
            n_moe_layers = cfg.n_layers - cfg.first_dense_layers
            traffic = tuple(
                (sds, count * n_moe_layers * w)
                for sds, count in moe_wire_traffic(cfg, tokens_per_worker)
            )
        wires.append(Wire(
            name="moe", topology="all_to_all",
            codec=wire_flag_codec(moe_flag, randk_q=comp.randk_q),
            channel=channel, traffic=traffic,
        ))

    act_flag = getattr(comp, "act_wire", "none")
    if act_flag != "none":
        if cfg.arch_type not in ("dense", "vlm", "moe"):
            raise ValueError(
                f"act_wire {act_flag!r} supports arch_type dense|vlm|moe "
                f"(scanned residual-stream blocks); {cfg.name!r} is "
                f"{cfg.arch_type!r}"
            )
        traffic = ()
        if tokens_per_worker > 0:
            sds = jax.ShapeDtypeStruct(
                (tokens_per_worker, cfg.d_model), jnp.dtype(cfg.dtype)
            )
            traffic = ((sds, cfg.n_layers * w),)
        wires.append(Wire(
            name="act", topology="p2p",
            codec=wire_flag_codec(act_flag, randk_q=comp.randk_q),
            channel=channel, traffic=traffic,
        ))

    model_flag = getattr(comp, "model_wire", "none")
    if model_flag != "none":
        traffic = ()
        if params_like is not None:
            every = max(1, int(getattr(comp, "publish_every", 1)))
            traffic = tuple(
                (jax.ShapeDtypeStruct(leaf.shape, leaf.dtype), 1.0 / every)
                for leaf in jax.tree_util.tree_leaves(params_like)
            )
        wires.append(Wire(
            name="model", topology="broadcast",
            codec=wire_flag_codec(model_flag, randk_q=comp.randk_q),
            channel=channel, traffic=traffic,
        ))
    return Transport(wires)
