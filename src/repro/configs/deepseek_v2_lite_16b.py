"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434].
27L, d_model=2048, 16H, MLA kv_lora=512, 64 routed experts top-6 +
2 shared, expert d_ff=1408, first layer dense, vocab=102400.

Note: the assignment bracket mentions "160 routed" which is the *full*
DeepSeek-V2 configuration; the headline spec (64e top-6) matches
DeepSeek-V2-Lite and is what we implement.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,            # dense-FFN width of the first (non-MoE) layer
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    head_dim=192,          # qk_nope + qk_rope
    n_experts=64,
    n_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    source="arXiv:2405.04434 (DeepSeek-V2-Lite)",
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
                        d_ff=256, vocab_size=512, kv_lora_rank=32,
                        qk_rope_dim=16, qk_nope_dim=32, v_head_dim=32,
                        head_dim=48, n_experts=4, experts_per_token=2,
                        n_shared_experts=1, moe_d_ff=64, first_dense_layers=1)
