"""qwen1.5-32b — dense MHA-kv (kv=40 == heads: full MHA) with QKV bias
[hf:Qwen/Qwen1.5-0.5B family].  64L, d_model=5120, 40H (kv=40),
d_ff=27392, vocab=152064."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    arch_type="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-32B (bias per Qwen1.5-0.5B card)",
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                        d_ff=256, vocab_size=512)
