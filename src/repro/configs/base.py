"""Config system: architecture + input-shape + run configuration.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs`` (exact spec from the assignment, source cited).  Each
also provides a ``smoke()`` reduced variant (<=2 layers, d_model<=512,
<=4 experts) used by CPU tests; the full configs are exercised only via
the AOT dry-run.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0        # 0 = full attention; >0 = window size
    attn_q_chunk: int = 512        # query-chunked (flash-style) attention

    # MLA (DeepSeek-V2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0    # leading dense-FFN layers (DeepSeek)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    moe_group_size: int = 4096     # GShard token-group size (see §Perf-4)

    # SSM / RWKV / hybrid
    ssm_state: int = 0             # mamba2 state size
    rwkv_head_dim: int = 64
    attn_every: int = 0            # zamba2: shared attn block period
    conv_kernel: int = 4           # mamba conv1d width

    # encoder-decoder (audio)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0

    # modality frontends (stubs per the brief)
    modality: str = "text"         # text | vision_prefix | audio_frames
    num_prefix_tokens: int = 576   # vlm: patch embeddings per image

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"        # activation/param dtype
    source: str = ""               # citation for the config

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.arch_type == "ssm"

    def supports_long_decode(self) -> bool:
        """long_500k policy (see DESIGN.md §Arch-applicability): native for
        ssm/hybrid; dense archs only via the sliding-window variant; the
        audio enc-dec is skipped (500k source frames is out of domain)."""
        return self.arch_type in ("ssm", "hybrid") or self.sliding_window > 0

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic total parameter count (for 6ND roofline math)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class CompressionConfig:
    """How the DCGD-SHIFT layer is wired into the training step.

    ``comm_mode`` selects the Channel (see ``repro.comm``): ``dense`` /
    ``randk_shared`` / ``q8_ring`` pick the uplink aggregation wire
    format; ``ef21`` / ``efbv`` select the error-feedback modes
    (contractive messages integrated into the shifts, aggregated
    densely) and override ``shift_rule``; ``q8_ring_overlap`` /
    ``efbv_overlap`` select the bucketed overlapped AsyncChannel over
    the Pallas-fused q8 ring (``overlap_bucket_bytes`` sets its
    per-bucket budget, in uncompressed per-worker message bytes);
    ``q8_ring_fused_vjp`` fuses the message encode into the backward
    pass itself (``repro.comm.fused_vjp``): each layer's cotangent is
    shifted and quantized as it is produced, the AsyncChannel consumes
    the pre-encoded per-leaf payloads with no standalone encode stage;
    ``auto`` is the TUNER sentinel — ``repro.tune.autotune`` resolves
    it to a concrete mode (and sets ``overlap_bucket_bytes`` /
    ``randk_q`` / ``q8_block_rows`` / ``efbv_eta``/``efbv_nu``) from a
    calibrated cost model before any channel is built.

    ``drift_resync_every`` bounds the shift-tracking drift of stateful
    rules over LOSSY aggregation: every N rounds the trainer replaces
    the incrementally-tracked ``h_bar`` with a dense reduce of the
    worker shifts (``repro.comm.resync_h_bar``); 0 disables.

    ``moe_wire`` / ``act_wire`` compress the NON-gradient wires through
    the same codec transport (``repro.comm.transport``): the MoE expert
    dispatch/combine all-to-all and the pipeline-boundary activations
    respectively.  Values are ``repro.comm.WIRE_CODEC_FLAGS``
    (``none | dense | q8 | randk | topk | sign | natural``); ``none``
    leaves the wire out of the transport entirely, ``dense`` routes it
    through the transport at full width (bitwise-identical math, real
    accounting).  Both run straight-through on the backward pass with a
    per-wire, per-step error-feedback shift (see the Transport-layer
    section of ARCHITECTURE.md).

    ``model_wire`` is the trainer->serving-fleet model-delta DOWNLINK
    (``repro.serving.delta``): every ``publish_every`` steps the
    publisher ships a shifted-compressed params delta through
    ``Wire("model", broadcast, ...)``.  Same flag vocabulary; ``dense``
    is the LOSSLESS stream (integer bit-pattern deltas — exact
    reconstruction, full width), the lossy flags ride the EF-BV shift
    recursion over params.  ``publish_every`` scales the wire's declared
    per-step traffic, so ``per_wire_bits`` and the tune predictor charge
    the amortized downlink.
    """
    enabled: bool = True
    compressor: str = "natural"    # see core.compressors.make_compressor
    compressor_kwargs: tuple = ()  # tuple of (key, value) pairs (hashable)
    shift_rule: str = "diana"      # fixed | diana | rand_diana | vr_gdci
                                   # | ef21 | efbv
    shift_alpha: float = 0.125     # DIANA / VR-GDCI alpha
    shift_p: float = 0.05          # Rand-DIANA refresh probability
    gdci_eta: float = 0.5          # VR-GDCI model-mixing rate
    efbv_eta: float = 1.0          # EF-BV shift integration rate (lambda);
                                   # 1.0 with nu=1.0 is exactly EF21
    efbv_nu: float = 1.0           # EF-BV estimator mixing
    comm_mode: str = "dense"       # dense | q8_ring | randk_shared | ef21
                                   # | efbv | q8_ring_overlap | efbv_overlap
                                   # | q8_ring_fused_vjp (backward-fused)
                                   # | auto (tuner-resolved; see repro.tune)
    randk_q: float = 0.05          # keep-fraction for randk_shared
    overlap_bucket_bytes: int = 4 << 20  # AsyncChannel bucket budget
    q8_block_rows: int = 64        # fused-q8 scale-block rows (autotuned)
    drift_resync_every: int = 0    # dense h_bar resync period (0 = off)
    moe_wire: str = "none"         # MoE dispatch/combine wire codec flag
    act_wire: str = "none"         # pipeline-boundary activation wire flag
    model_wire: str = "none"       # trainer->fleet model-delta downlink flag
    publish_every: int = 1         # trainer steps between delta publishes

    @property
    def effective_shift_rule(self) -> str:
        """The update rule actually run (the ``ef21``/``efbv`` comm
        modes imply their rule)."""
        if self.comm_mode == "ef21":
            return "ef21"
        if self.comm_mode in ("efbv", "efbv_overlap"):
            return "efbv"
        return self.shift_rule

    @property
    def aggregation_mode(self) -> str:
        """Wire format of the master-side aggregation: disabled configs
        and EF21 aggregate densely (EF21's savings are in the
        per-worker contractive messages)."""
        if not self.enabled:
            return "dense"
        if self.comm_mode == "auto":
            raise ValueError(
                "comm_mode 'auto' has no aggregation format until the "
                "tuner resolves it (repro.tune.autotune + apply_plan)"
            )
        from repro.comm.channel import aggregation_mode_of

        return aggregation_mode_of(self.comm_mode)

    def make(self, learning_rate: Optional[float] = None):
        """Build the ``(compressor, rule)`` pair this config describes.

        The rule is the ONE engine object every consumer runs
        (reference simulator, production trainer, overlap runtime).
        ``vr_gdci`` — Algorithm 2, compressed iterates — needs the
        outer ``learning_rate`` as its gradient-mapping gamma, so the
        trainer passes it; the others ignore it.  Unknown rules fail
        here, naming the accepted ones.
        """
        from repro.core import make_compressor, make_shift_rule
        q = make_compressor(self.compressor, **dict(self.compressor_kwargs))
        rule_name = self.effective_shift_rule
        if rule_name == "vr_gdci":
            from repro.core.iterate_comp import VRGDCI
            if learning_rate is None:
                raise ValueError(
                    "shift_rule 'vr_gdci' needs learning_rate (its "
                    "gradient-mapping gamma); pass make(learning_rate=...)"
                )
            return q, VRGDCI(q=q, gamma=learning_rate, eta=self.gdci_eta,
                             alpha=self.shift_alpha)
        rule_kwargs = {
            "fixed": {},
            "dcgd": {},
            "diana": dict(alpha=self.shift_alpha),
            "rand_diana": dict(p=self.shift_p),
            "ef21": {},
            "efbv": dict(eta=self.efbv_eta, nu=self.efbv_nu),
        }
        if rule_name not in rule_kwargs:
            raise ValueError(
                f"unknown shift rule {rule_name!r}; have trainer rules "
                f"{tuple(sorted(rule_kwargs)) + ('vr_gdci',)}"
            )
        return q, make_shift_rule(rule_name, **rule_kwargs[rule_name])


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1000
    optimizer: str = "adamw"       # adamw | sgd
    train_attn_chunk: int = 256    # key-chunk for TRAIN attention (<=0:
                                   # keep the arch default; 256 cuts the
                                   # collective term ~27-29%% on the 32B
                                   # trains — §Perf-5; prefill keeps 512)
    remat: bool = True
    zero_opt_state: bool = True    # ZeRO-1: shard optimizer state over data
    fsdp_params: bool = False      # also shard params over data (FSDP)
    compression: CompressionConfig = field(default_factory=CompressionConfig)
