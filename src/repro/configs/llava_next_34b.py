"""llava-next-34b — VLM: anyres vision tiling feeding a dense GQA decoder
[hf:llava-hf/llava-v1.6-mistral-7b-hf, scaled per assignment].
Backbone only: 60L, d_model=7168, 56H (kv=8), d_ff=20480, vocab=64000.
Vision frontend is a stub: input_specs() provides projected patch
embeddings (B, num_prefix_tokens, d_model)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    arch_type="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    modality="vision_prefix",
    num_prefix_tokens=576,     # one 24x24 anyres tile
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (34B assignment scale)",
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                        d_ff=256, vocab_size=512, num_prefix_tokens=16)
