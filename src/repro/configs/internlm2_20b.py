"""internlm2-20b — dense GQA [arXiv:2403.17297].
48L, d_model=6144, 48H (kv=8), d_ff=16384, vocab=92544."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    arch_type="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    rope_theta=1_000_000.0,
    source="arXiv:2403.17297 (InternLM2 20B)",
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                        d_ff=256, vocab_size=512)
