"""seamless-m4t-large-v2 — encoder-decoder multimodal translation backbone
[arXiv:2308.11596].  24L decoder + 24L encoder, d_model=1024, 16H (kv=16),
d_ff=8192, vocab=256206.  The mel-spectrogram/conformer feature frontend is
a stub: input_specs() provides frame embeddings (B, S_src, d_model).

long_500k is SKIPPED for this arch (500k source frames would require a
quadratic full-attention encoder pass and is far outside the model's
training domain) — see DESIGN.md §Arch-applicability."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    is_encoder_decoder=True,
    n_enc_layers=24,
    modality="audio_frames",
    source="arXiv:2308.11596 (SeamlessM4T-Large v2)",
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, n_enc_layers=2, d_model=128, n_heads=4,
                        n_kv_heads=4, d_ff=256, vocab_size=512)
