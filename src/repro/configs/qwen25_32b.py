"""qwen2.5-32b — dense GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B family].
64L, d_model=5120, 40H (kv=8), d_ff=27648, vocab=152064."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    arch_type="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-32B (per assignment; bias per Qwen2.5-0.5B card)",
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                        d_ff=256, vocab_size=512)
