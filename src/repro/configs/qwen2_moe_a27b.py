"""qwen2-moe-a2.7b — MoE with shared experts [hf:Qwen/Qwen1.5-MoE-A2.7B].
24L, d_model=2048, 16H (kv=16), 60 routed experts top-4 + 4 shared,
expert d_ff=1408, vocab=151936."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5632,              # shared-expert fused width (4 x 1408)
    vocab_size=151936,
    qkv_bias=True,
    n_experts=60,
    n_shared_experts=4,
    experts_per_token=4,
    moe_d_ff=1408,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                        d_ff=128, vocab_size=512, n_experts=4,
                        experts_per_token=2, n_shared_experts=1, moe_d_ff=64)
