"""qwen3-0.6b — dense GQA with QK-norm [hf:Qwen/Qwen3-8B family].
28L, d_model=1024, 16H (kv=8), d_ff=3072, vocab=151936, head_dim=128."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    arch_type="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,              # decoupled head_dim per Qwen3 card
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-0.6B (qk_norm per Qwen3-8B card)",
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                        d_ff=256, vocab_size=512, head_dim=32)
