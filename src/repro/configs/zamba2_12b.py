"""zamba2-1.2b — hybrid: Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].  38 Mamba2 layers, d_model=2048, shared attn block
(32H MHA) applied every 6 layers, d_ff=8192, ssm_state=64, vocab=32000."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    attn_every=6,
    rwkv_head_dim=64,       # mamba2 head dim
    source="arXiv:2411.15242 (Zamba2-1.2B)",
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
                        d_ff=256, vocab_size=512, ssm_state=16, attn_every=2)
