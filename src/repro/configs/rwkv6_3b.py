"""rwkv6-3b — Finch: attention-free RNN with data-dependent decay
[arXiv:2404.05892].  32L, d_model=2560, d_ff=8960, vocab=65536."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # 2560 / 64 WKV heads
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    rwkv_head_dim=64,
    source="arXiv:2404.05892 (RWKV-6 Finch 3B)",
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
                        d_ff=256, vocab_size=512)
