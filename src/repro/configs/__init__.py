"""Architecture registry: ``--arch <id>`` resolves here."""

from repro.configs.base import (
    INPUT_SHAPES,
    CompressionConfig,
    InputShape,
    ModelConfig,
    TrainConfig,
)

from repro.configs import (
    deepseek_v2_lite_16b,
    internlm2_20b,
    llava_next_34b,
    qwen15_32b,
    qwen25_32b,
    qwen2_moe_a27b,
    qwen3_06b,
    rwkv6_3b,
    seamless_m4t_large_v2,
    zamba2_12b,
)

_MODULES = {
    "rwkv6-3b": rwkv6_3b,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "llava-next-34b": llava_next_34b,
    "qwen2.5-32b": qwen25_32b,
    "internlm2-20b": internlm2_20b,
    "qwen3-0.6b": qwen3_06b,
    "qwen1.5-32b": qwen15_32b,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "qwen2-moe-a2.7b": qwen2_moe_a27b,
    "zamba2-1.2b": zamba2_12b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; have {list(_MODULES)}")
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; have {list(_MODULES)}")
    return _MODULES[arch].smoke()
