"""JAX version-compatibility polyfills.

The launch layer (and the sharded subprocess tests) use the modern
``jax.sharding.set_mesh`` context to establish the ambient mesh.  On
older jaxlibs (< 0.5) that symbol does not exist; the legacy
``with mesh:`` global-mesh context provides the equivalent scoping for
everything this codebase needs (input shardings drive GSPMD; the
best-effort ``shard_hint`` constraints already no-op gracefully).

``install()`` is idempotent and called from ``repro.__init__`` so any
``import repro.*`` makes the API available.
"""

from __future__ import annotations

import contextlib

import jax


def install() -> None:
    if not hasattr(jax.sharding, "set_mesh"):

        @contextlib.contextmanager
        def set_mesh(mesh):
            with mesh:
                yield mesh

        jax.sharding.set_mesh = set_mesh
