"""Data pipeline: convex problems (paper fidelity) + synthetic token
streams (LM substrate)."""

from repro.data.problems import Problem, make_logreg, make_ridge
from repro.data.tokens import TokenStream, make_batch_specs
