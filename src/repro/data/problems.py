"""Convex distributed problems for paper-fidelity experiments (Section 4).

Ridge regression matches the paper's setup: ``make_regression``-style
synthetic data (m=100, d=80), lambda = 1/m, uniformly split among n=10
workers.  Logistic regression stands in for the w2a LibSVM experiment
(Appendix C) with synthetic separable-ish data and lambda tuned so the
condition number of f is ~100, as in the paper.

All problems expose the quantities the theory needs: per-worker gradient
oracles, smoothness constants L_i / L, strong convexity mu, and the exact
optimum x* (closed form for ridge, high-precision solver for logreg).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Problem:
    name: str
    d: int
    n_workers: int
    worker_grads: Callable  # x (d,) -> (W, d) stacked per-worker gradients
    full_grad: Callable     # x (d,) -> (d,)
    loss: Callable          # x (d,) -> scalar
    x_star: jax.Array
    L: float
    L_max: float
    mu: float

    @property
    def kappa(self) -> float:
        return self.L / self.mu

    def star_grads(self) -> jax.Array:
        """grad_i(x*) for all i — the DCGD-STAR oracle."""
        return self.worker_grads(self.x_star)


def _make_regression(m: int, d: int, seed: int, noise: float = 10.0):
    """sklearn.datasets.make_regression equivalent (default params):
    standard normal A, dense ground-truth coefficients in [0,100],
    additive Gaussian noise of scale ``noise`` (sklearn default is 0; the
    paper uses default parameters => noise=0, but we keep a knob)."""
    rng = np.random.RandomState(seed)
    a = rng.randn(m, d)
    coef = rng.uniform(0.0, 100.0, size=d)
    y = a @ coef
    if noise > 0:
        y = y + rng.normal(scale=noise, size=m)
    return a.astype(np.float64), y.astype(np.float64)


def make_ridge(
    m: int = 100, d: int = 80, n_workers: int = 10,
    lam: float | None = None, seed: int = 0, noise: float = 0.0,
) -> Problem:
    """f(x) = (1/2)||Ax-y||^2 + (lam/2)||x||^2, rows split evenly so that
    f = (1/n) sum f_i with f_i = (n/2)||A_i x - y_i||^2 + (lam/2)||x||^2."""
    assert m % n_workers == 0
    lam = 1.0 / m if lam is None else lam
    a_np, y_np = _make_regression(m, d, seed, noise)
    x_star_np = np.linalg.solve(a_np.T @ a_np + lam * np.eye(d), a_np.T @ y_np)

    a = jnp.asarray(a_np, jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    y = jnp.asarray(y_np, a.dtype)
    rows = m // n_workers
    a_w = a.reshape(n_workers, rows, d)
    y_w = y.reshape(n_workers, rows)
    n = n_workers

    def worker_grads(x):
        def one(ai, yi):
            return n * ai.T @ (ai @ x - yi) + lam * x
        return jax.vmap(one)(a_w, y_w)

    def full_grad(x):
        return a.T @ (a @ x - y) + lam * x

    def loss(x):
        r = a @ x - y
        return 0.5 * jnp.sum(r**2) + 0.5 * lam * jnp.sum(x**2)

    evals = np.linalg.eigvalsh(a_np.T @ a_np)
    l_is = [
        n * np.linalg.eigvalsh(np.asarray(a_w[i]).T @ np.asarray(a_w[i]))[-1] + lam
        for i in range(n_workers)
    ]
    return Problem(
        name="ridge",
        d=d,
        n_workers=n_workers,
        worker_grads=worker_grads,
        full_grad=full_grad,
        loss=loss,
        x_star=jnp.asarray(x_star_np, a.dtype),
        L=float(evals[-1] + lam),
        L_max=float(max(l_is)),
        mu=float(evals[0] + lam),
    )


def make_logreg(
    m: int = 300, d: int = 60, n_workers: int = 10,
    kappa_target: float = 100.0, seed: int = 1,
) -> Problem:
    """l2-regularized logistic regression on synthetic data; lam chosen so
    that cond(f) ~= kappa_target (paper's Appendix C protocol).  x* found
    by damped Newton to ||grad||^2 <= 1e-28."""
    assert m % n_workers == 0
    rng = np.random.RandomState(seed)
    a_np = rng.randn(m, d) / np.sqrt(d)
    w_true = rng.randn(d)
    logits = a_np @ w_true
    b_np = np.where(rng.rand(m) < 1.0 / (1.0 + np.exp(-logits)), 1.0, -1.0)

    # L_logistic = lmax(A^T A)/(4m); pick lam so (L_log + lam)/lam = kappa.
    l_data = float(np.linalg.eigvalsh(a_np.T @ a_np)[-1]) / (4.0 * m)
    lam = l_data / (kappa_target - 1.0)

    dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    a = jnp.asarray(a_np, dtype)
    b = jnp.asarray(b_np, dtype)
    rows = m // n_workers
    a_w = a.reshape(n_workers, rows, d)
    b_w = b.reshape(n_workers, rows)

    def _grad(ai, bi, x):
        z = (ai @ x) * bi
        s = jax.nn.sigmoid(-z)  # = 1 - sigma(z)
        return -(ai.T @ (s * bi)) / ai.shape[0] + lam * x

    def worker_grads(x):
        return jax.vmap(lambda ai, bi: _grad(ai, bi, x))(a_w, b_w)

    def full_grad(x):
        return _grad(a, b, x)

    def loss(x):
        z = (a @ x) * b
        return jnp.mean(jnp.log1p(jnp.exp(-z))) + 0.5 * lam * jnp.sum(x**2)

    # High-precision optimum by damped Newton (numpy, float64).
    x = np.zeros(d)
    for _ in range(200):
        z = (a_np @ x) * b_np
        s = 1.0 / (1.0 + np.exp(z))  # sigma(-z)
        g = -(a_np.T @ (s * b_np)) / m + lam * x
        if g @ g < 1e-28:
            break
        w = s * (1.0 - s)
        hess = (a_np.T * w) @ a_np / m + lam * np.eye(d)
        x = x - np.linalg.solve(hess, g)

    l_i = [
        float(np.linalg.eigvalsh(np.asarray(a_w[i]).T @ np.asarray(a_w[i]))[-1])
        / (4.0 * rows) + lam
        for i in range(n_workers)
    ]
    return Problem(
        name="logreg",
        d=d,
        n_workers=n_workers,
        worker_grads=worker_grads,
        full_grad=full_grad,
        loss=loss,
        x_star=jnp.asarray(x, dtype),
        L=l_data + lam,
        L_max=float(max(l_i)),
        mu=lam,
    )
