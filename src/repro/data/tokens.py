"""Deterministic synthetic token streams for LM training/serving.

A ``TokenStream`` yields batches derived purely from (seed, step) so every
host in a multi-host launch can materialize ITS shard of the global batch
without any coordination — the standard trick for data-parallel input
pipelines without a distributed filesystem.

The stream is a Zipf-ish unigram mixture with short-range structure
(Markov-flavoured: token_{t+1} depends on token_t) so the ~100M example
model has something learnable; purely uniform tokens would give a flat
loss.  Modality extras (VLM patch / audio frame embeddings) are Gaussian
stubs per the brief.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig


@dataclass(frozen=True)
class TokenStream:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count

    def batch(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(
            jax.random.PRNGKey(self.seed), step * self.host_count + self.host_index
        )
        return synth_batch(key, self.cfg, self.seq_len, self.local_batch)


def synth_batch(key, cfg: ModelConfig, seq_len: int, batch: int):
    """One batch of learnable synthetic tokens (+ modality stubs)."""
    k1, k2, k3 = jax.random.split(key, 3)
    v = cfg.vocab_size
    text_len = seq_len
    if cfg.modality == "vision_prefix":
        text_len = max(2, seq_len - cfg.num_prefix_tokens)

    # Markov-ish stream: x_{t+1} = (a * x_t + b_t) mod V with sparse resets.
    a = 6364136223846793005 % v or 1
    x0 = jax.random.randint(k1, (batch,), 0, v, jnp.int32)
    noise = jax.random.randint(k2, (batch, text_len), 0, 97, jnp.int32)

    def step(x, n):
        nxt = (x * 31 + n) % v
        return nxt, nxt

    _, toks = jax.lax.scan(step, x0, noise.T)
    out = {"tokens": toks.T.astype(jnp.int32)}

    if cfg.modality == "vision_prefix":
        out["prefix"] = jax.random.normal(
            k3, (batch, cfg.num_prefix_tokens, cfg.d_model), jnp.float32
        ) * 0.02
    if cfg.is_encoder_decoder:
        out["frames"] = jax.random.normal(
            k3, (batch, seq_len, cfg.d_model), jnp.float32
        ) * 0.02
    return out


def make_batch_specs(cfg: ModelConfig, shape: InputShape,
                     dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a TRAIN batch —
    the dry-run path (no allocation).  Decode specs live in launch.serve."""
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.modality == "vision_prefix":
        specs["tokens"] = jax.ShapeDtypeStruct(
            (b, max(2, s - cfg.num_prefix_tokens)), jnp.int32
        )
        specs["prefix"] = jax.ShapeDtypeStruct(
            (b, cfg.num_prefix_tokens, cfg.d_model), jnp.float32
        )
    if cfg.is_encoder_decoder:
        specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.float32)
    return specs
