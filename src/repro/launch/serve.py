"""Serving: batched single-token decode against sharded caches.

``build_serve_step`` returns the pure decode function; ``decode_specs``
builds ShapeDtypeStruct stand-ins for (params, state, tok, pos) used by
the dry-run.  ``broadcast_params`` routes the model-broadcast (the
downlink direction of the framework) through the same ``repro.comm``
Channel the trainer uses for its uplink, so a quantized weight
broadcast (int8 / natural) shares the codec and its structural wire
accounting with the rest of the system.  KV caches are sharded batch-over-("pod","data") and
SEQUENCE-over-"model": with GQA kv-head counts (8) below the model-axis
size (16), head sharding cannot absorb the model axis — sequence sharding
keeps per-device cache bytes ~C/256 and lowers the softmax over the
sharded key dim to small all-reduces (max + sum), which is the standard
TPU serving layout.

Decode-shape policy (DESIGN.md §Arch-applicability): decode_32k uses the
full-length cache; long_500k uses the native O(1) state for ssm, and a
sliding-window (8192) rolling cache for every attention-bearing arch;
the audio enc-dec skips long_500k.
"""

from __future__ import annotations

import argparse
import functools
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ModelConfig
from repro.dist import params_pspecs, validate_pspecs
from repro.launch.mesh import make_host_mesh
from repro.models import model as M

tmap = jax.tree_util.tree_map

LONG_WINDOW = 8192


def serving_config(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Arch variant actually served for a given decode shape."""
    if shape_name == "long_500k" and cfg.arch_type != "ssm":
        if cfg.arch_type == "audio":
            raise ValueError("long_500k is skipped for the audio enc-dec "
                             "(see DESIGN.md)")
        return cfg.with_(sliding_window=LONG_WINDOW)
    return cfg


def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window > 0:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def build_serve_step(cfg: ModelConfig):
    def serve_step(params, state, tok, pos):
        logits, state = M.decode_step(params, cfg, tok, state, pos)
        return logits, state
    return serve_step


def broadcast_params(params, compressor: str = "identity", *,
                     key: Optional[jax.Array] = None, channel=None,
                     comm_mode: str = "sim"):
    """Model-broadcast through the Channel downlink.

    The params pytree is encoded leaf-wise with the named codec and
    decoded on the receiving side — ``identity`` is the exact (f32)
    broadcast, ``int8`` / ``natural`` give a quantized weight broadcast
    at 8-9 bits/scalar.  Returns ``(params_received, wire_bits)`` with
    bits computed structurally from the actual payloads.

    ``comm_mode`` builds the channel when none is passed — through
    ``make_channel``, so an unresolved ``"auto"`` sentinel or a typo'd
    mode fails HERE with the same named-accepted-modes error every
    other channel boundary raises, not as a confusing shape error
    downstream.
    """
    from repro.comm import make_channel
    from repro.core.compressors import make_compressor

    channel = channel if channel is not None else make_channel(comm_mode)
    q = make_compressor(compressor)
    key = jax.random.PRNGKey(0) if key is None else key
    return channel.broadcast(q, key, params)


# ---------------------------------------------------------------------------
# Sharding
# ---------------------------------------------------------------------------


def decode_state_pspecs(state_shapes, mesh):
    """Cache sharding: batch over data axes, sequence over 'model'.

    Leaf conventions (see models.model.make_decode_state):
      attention k/v        (L, B, C, KV, Dh) -> P(None, data, 'model', None, None)
      mla ckv/kr           (L, B, C, r)      -> P(None, data, 'model', None)
      kpos                 (L, C)            -> replicated
      ssm / rwkv states    (L, B, ...)       -> batch over data
      cross-attn xkv       (L, B, S_src, KV, Dh) -> seq over 'model'
    """
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    data = data_axes if data_axes else None

    def one(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        last = names[-1]
        if last == "kpos":
            return P()
        if last in ("k", "v", "ckv", "kr"):
            # (L, B, C, ...) — cache: seq (axis 2) over model
            dims = [None, data, "model"] + [None] * (leaf.ndim - 3)
            return P(*dims[: leaf.ndim])
        # recurrent states / conv tails: (L, B, ...)
        dims = [None, data] + [None] * (leaf.ndim - 2)
        return P(*dims[: leaf.ndim])

    specs = jax.tree_util.tree_map_with_path(one, state_shapes)
    return validate_pspecs(state_shapes, specs, mesh)


def decode_specs(cfg: ModelConfig, seq_len: int, global_batch: int,
                 dtype_params=None):
    """ShapeDtypeStructs for (params, state, tok, pos) — dry-run inputs."""
    cache_len = cache_len_for(cfg, seq_len)
    enc_len = seq_len if cfg.is_encoder_decoder else 0
    params = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    state = jax.eval_shape(
        lambda: M.make_decode_state(cfg, global_batch, cache_len, enc_len)
    )
    tok = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return params, state, tok, pos


# ---------------------------------------------------------------------------
# CLI: serve a smoke model with batched requests on the host
# ---------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--broadcast-compressor", "--broadcast_compressor",
                    dest="broadcast_compressor", default="identity",
                    help="codec for the model-broadcast downlink "
                         "(identity = exact, int8/natural = quantized)")
    ap.add_argument("--serve_fleet", "--serve-fleet", dest="serve_fleet",
                    type=int, default=0,
                    help="N > 0: run the trainer->fleet delta-stream demo "
                         "with N continuous-batching replicas instead of "
                         "the single-host greedy loop")
    ap.add_argument("--model_wire", "--model-wire", dest="model_wire",
                    default="q8",
                    help="model-downlink codec flag for the fleet demo "
                         "(dense = lossless bit-delta, q8/natural/topk/...)")
    ap.add_argument("--publish_every", "--publish-every",
                    dest="publish_every", type=int, default=2,
                    help="trainer steps between delta publishes")
    ap.add_argument("--stale_k", "--stale-k", dest="stale_k", type=int,
                    default=4, help="staleness bound K (steps behind the "
                                    "trainer) before a dense resync")
    ap.add_argument("--trainer_steps", "--trainer-steps",
                    dest="trainer_steps", type=int, default=6,
                    help="trainer steps to run in the fleet demo")
    args = ap.parse_args(argv)

    if args.serve_fleet > 0:
        import json

        from repro.serving import run_fleet_demo

        stats = run_fleet_demo(
            args.arch, n_replicas=args.serve_fleet,
            model_wire=args.model_wire, publish_every=args.publish_every,
            stale_k=args.stale_k, steps=args.trainer_steps,
            n_requests=2 * args.serve_fleet, gen_len=args.gen_len,
        )
        print(json.dumps(stats, indent=2, default=float))
        print(f"fleet[{args.serve_fleet}x {args.arch}] wire={args.model_wire}:"
              f" {stats['bytes_fraction']:.3f} of dense bytes/publish,"
              f" max staleness {stats['max_staleness']} (K={args.stale_k}),"
              f" {stats['resyncs']} resyncs,"
              f" {stats['tokens_served']} tokens served")
        return stats

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = cfg.with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    params, bcast_bits = broadcast_params(
        params, args.broadcast_compressor, key=jax.random.PRNGKey(17)
    )
    print(f"model broadcast [{args.broadcast_compressor}]: "
          f"{float(bcast_bits) / 8e6:.2f} MB on the wire")
    cache_len = args.prompt_len + args.gen_len
    enc_len = args.prompt_len if cfg.is_encoder_decoder else 0
    state = M.make_decode_state(cfg, args.batch, cache_len, enc_len)

    step = jax.jit(build_serve_step(cfg))
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (args.batch, 1), 0, cfg.vocab_size)
    t0 = time.time()
    out = []
    for t in range(args.prompt_len + args.gen_len):
        logits, state = step(params, state, toks, jnp.int32(t))
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(toks[:, 0])
    dt = time.time() - t0
    total = args.batch * (args.prompt_len + args.gen_len)
    print(f"{args.arch}: {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s batched greedy)")
    return jnp.stack(out, 1)


if __name__ == "__main__":
    main()
