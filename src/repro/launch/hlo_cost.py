"""Loop-aware HLO-text cost model.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
program built around ``lax.scan`` (layer stacks, key-chunk attention,
MoE token groups, recurrent SSM scans) under-reports FLOPs/bytes by the
trip count — 64x for a 64-layer scanned transformer.  This module walks
the optimized HLO text instead and multiplies through loop nests:

  flops        2 * numel(result) * prod(contraction dims)  per dot
  bytes        operands + result per compute instruction (one-pass
               fusion model, ~ XLA's "bytes accessed")
  collectives  result-shape bytes per collective, bucketed by kind

Trip counts come from each while-condition's compare-against-constant
(the lax.scan pattern); anything unrecognized falls back to 1 and is
reported in ``unresolved_whiles``.

The numbers are per-device: SPMD-partitioned modules are the per-device
program.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "c64": 8, "c128": 16,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-get-and-update-state",
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shapes_of(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    return [
        (dt, tuple(int(d) for d in dims.split(",") if d))
        for dt, dims in _SHAPE_RE.findall(text)
    ]


def _numel(dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _bytes_of(shapes) -> int:
    return sum(_numel(dims) * _DTYPE_BYTES.get(dt, 4) for dt, dims in shapes)


@dataclass
class Instr:
    name: str
    op: str
    result_text: str          # the "f32[8,16]{1,0}" (or tuple) part
    line: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # %name -> result_text


_OP_WORD = re.compile(r"\b([a-z][a-z0-9\-]*)\(")


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        if not raw.strip():
            continue
        if not raw.startswith(" "):
            m = re.match(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(", raw)
            if m and raw.rstrip().endswith("{"):
                cur = Computation(m.group(1))
                comps[cur.name] = comps.get(m.group(1)) or cur
                comps[cur.name] = cur
                if raw.startswith("ENTRY"):
                    comps["__entry__"] = cur
            elif raw.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(raw)
        if not m:
            continue
        name, rest = m.groups()
        # result_text is everything up to the op word
        mo = _OP_WORD.search(rest)
        if not mo:
            continue
        op = mo.group(1)
        result_text = rest[: mo.start()]
        # operand names: %refs inside the first parens after op
        tail = rest[mo.end() - 1:]
        operands = re.findall(r"%([\w.\-]+)", tail.split(")")[0])
        ins = Instr(name=name, op=op, result_text=result_text, line=rest,
                    operands=operands)
        cur.instrs.append(ins)
        cur.symbols[name] = result_text if result_text.strip() else rest
    return comps


def _trip_count(cond: Computation) -> Optional[int]:
    """lax.scan pattern: compare(counter, constant(N)), LT, start 0."""
    consts = []
    direction = None
    for ins in cond.instrs:
        consts += [int(c) for c in _CONST_RE.findall(ins.line)]
        dm = re.search(r"direction=(\w+)", ins.line)
        if dm:
            direction = dm.group(1)
    # nested fused compare: constants may live in the fused computation too
    if not consts:
        return None
    n = max(consts)
    if direction == "LE":
        n += 1
    return max(n, 1)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    transcendentals: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.transcendentals += mult * other.transcendentals
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + mult * v


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = parse_computations(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self.unresolved_whiles: List[str] = []
        self.while_trips: Dict[str, int] = {}

    # -- per-instruction primitive costs ------------------------------------

    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        out_shapes = _shapes_of(ins.result_text)
        if not out_shapes:
            return 0.0
        out_n = sum(_numel(d) for _, d in out_shapes)
        k = 1
        mc = _LHS_C_RE.search(ins.line)
        if mc and ins.operands:
            lhs = comp.symbols.get(ins.operands[0], "")
            lhs_shapes = _shapes_of(lhs)
            if lhs_shapes:
                dims = lhs_shapes[0][1]
                for ci in (int(x) for x in mc.group(1).split(",") if x):
                    if ci < len(dims):
                        k *= dims[ci]
        return 2.0 * out_n * k

    def _instr_bytes(self, comp: Computation, ins: Instr) -> float:
        """HBM traffic of one instruction.

        Slice-family ops only touch the sliced/updated REGION, not the
        whole operand — counting full operands would charge a 64-layer
        scan 64x the stacked parameter bytes per step (observed: a
        phantom 28 TB/step).  dynamic-slice/gather ~ 2x result;
        dynamic-update-slice/scatter ~ 3x update (read+write region +
        update read).
        """
        op = ins.op
        res = _bytes_of(_shapes_of(ins.result_text))
        if op in ("dynamic-slice", "gather", "slice"):
            return 2.0 * res
        if op in ("dynamic-update-slice", "scatter"):
            upd = 0
            if len(ins.operands) >= 2:
                upd = _bytes_of(_shapes_of(comp.symbols.get(ins.operands[1], "")))
            return 3.0 * (upd or res)
        total = res
        for opn in ins.operands:
            total += _bytes_of(_shapes_of(comp.symbols.get(opn, "")))
        return float(total)

    def _sliced_params(self, called_name: str) -> Dict[int, float]:
        """Parameter indices of a fused computation that are only read
        through a slice/gather (or written through dynamic-update-slice),
        mapped to the bytes actually touched.  A fused dynamic-slice of a
        stacked 64-layer parameter tensor reads ONE layer per call, not
        the whole stack."""
        called = self.comps.get(called_name)
        if called is None:
            return {}
        param_idx: Dict[str, int] = {}
        for ins in called.instrs:
            if ins.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", ins.line)
                if m:
                    param_idx[ins.name] = int(m.group(1))
        touched: Dict[int, float] = {}
        direct_reads: Dict[str, int] = {n: 0 for n in param_idx}
        for ins in called.instrs:
            for i, opn in enumerate(ins.operands):
                if opn not in param_idx:
                    continue
                if ins.op in ("dynamic-slice", "gather", "slice") and i == 0:
                    b = 2.0 * _bytes_of(_shapes_of(ins.result_text))
                    pi = param_idx[opn]
                    touched[pi] = touched.get(pi, 0.0) + b
                elif ins.op == "dynamic-update-slice" and i == 0:
                    upd = _bytes_of(_shapes_of(
                        called.symbols.get(ins.operands[1], "")
                    )) if len(ins.operands) > 1 else 0
                    pi = param_idx[opn]
                    touched[pi] = touched.get(pi, 0.0) + 3.0 * upd
                else:
                    direct_reads[opn] += 1
        # a param read directly anywhere is NOT slice-only
        return {
            pi: b for pi, b in touched.items()
            if all(direct_reads.get(n, 0) == 0
                   for n, j in param_idx.items() if j == pi)
        }

    def _fusion_bytes(self, comp: Computation, ins: Instr,
                      called_name: str) -> float:
        sliced = self._sliced_params(called_name)
        total = _bytes_of(_shapes_of(ins.result_text))
        for i, opn in enumerate(ins.operands):
            if i in sliced:
                total += sliced[i]
            else:
                total += _bytes_of(_shapes_of(comp.symbols.get(opn, "")))
        return float(total)

    # -- recursive computation cost ------------------------------------------

    def cost_of(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        c = Cost()
        self._memo[name] = c  # guards recursion
        if comp is None:
            return c
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                mb = _BODY_RE.search(ins.line)
                mc = _COND_RE.search(ins.line)
                trip = None
                if mc and mc.group(1) in self.comps:
                    trip = _trip_count(self.comps[mc.group(1)])
                if trip is None:
                    trip = 1
                    self.unresolved_whiles.append(ins.name)
                self.while_trips[ins.name] = trip
                if mb:
                    c.add(self.cost_of(mb.group(1)), trip)
                continue
            if op == "conditional":
                mbr = _BRANCH_RE.search(ins.line)
                if mbr:
                    branch_costs = [
                        self.cost_of(b.strip().lstrip("%"))
                        for b in mbr.group(1).split(",")
                    ]
                    if branch_costs:
                        # expected cost: average of branches
                        avg = Cost()
                        for bc in branch_costs:
                            avg.add(bc, 1.0 / len(branch_costs))
                        c.add(avg)
                continue
            if op in ("fusion", "call", "map", "custom-call", "reduce",
                      "reduce-window", "sort", "scatter", "select-and-scatter"):
                # A fusion is ONE pass over its operands/result: count
                # call-site bytes only; inner instructions contribute
                # flops/transcendentals/collectives but NOT bytes (their
                # intermediates live in registers/VMEM, not HBM).
                inner_name = None
                mcall = _CALLS_RE.search(ins.line)
                if mcall:
                    inner_name = mcall.group(1)
                else:
                    mto = re.search(r"to_apply=%([\w.\-]+)", ins.line)
                    if mto:
                        inner_name = mto.group(1)
                if inner_name:
                    inner = self.cost_of(inner_name)
                    c.flops += inner.flops
                    c.transcendentals += inner.transcendentals
                    for k, v in inner.coll.items():
                        c.coll[k] = c.coll.get(k, 0.0) + v
                    c.bytes += self._fusion_bytes(comp, ins, inner_name)
                else:
                    c.bytes += self._instr_bytes(comp, ins)
                continue
            kind = next((k for k in _COLLECTIVES if op.startswith(k)), None)
            if kind is not None:
                if op.endswith("-done"):
                    continue
                b = _bytes_of(_shapes_of(ins.result_text))
                c.coll[kind] = c.coll.get(kind, 0.0) + b
                c.bytes += self._instr_bytes(comp, ins)
                continue
            if op in _SKIP_OPS:
                continue
            if op == "dot":
                c.flops += self._dot_flops(comp, ins)
                c.bytes += self._instr_bytes(comp, ins)
                continue
            if op in ("convolution",):
                # not used by this framework; count as a dot-like pass
                c.bytes += self._instr_bytes(comp, ins)
                continue
            if op in ("exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                      "logistic", "sine", "cosine"):
                c.transcendentals += sum(
                    _numel(d) for _, d in _shapes_of(ins.result_text)
                )
                c.bytes += self._instr_bytes(comp, ins)
                continue
            # generic elementwise / data movement: 1 flop per output element
            out_n = sum(_numel(d) for _, d in _shapes_of(ins.result_text))
            if op in ("add", "subtract", "multiply", "divide", "maximum",
                      "minimum", "compare", "select", "and", "or", "xor",
                      "negate", "abs", "floor", "ceil", "clamp",
                      "convert", "exponential-minus-one"):
                c.flops += out_n
            c.bytes += self._instr_bytes(comp, ins)
        return c

    def entry_cost(self) -> Cost:
        entry = self.comps.get("__entry__")
        if entry is None:
            # fall back: biggest computation
            name = max(self.comps, key=lambda n: len(self.comps[n].instrs))
            return self.cost_of(name)
        return self.cost_of(entry.name)


def apply_gradient_payload_model(corrected: Dict[str, object], kind: str,
                                 message_bytes: float,
                                 wire_fraction: float) -> Dict[str, object]:
    """Re-charge the GRADIENT-AGGREGATION share of one collective kind
    at the codec's wire fraction, leaving the rest structural.

    For comm modes whose aggregation lowers to a dense collective while
    the protocol payload is compressed (EF21: an exact mean of DECODED
    sparse messages), only the gradient-message bytes — one per-device
    param-tree share, ``message_bytes`` — ride the compressed uplink;
    model-parallel activation all-reduces and loss reductions of the
    same HLO kind are genuine dense traffic and must keep their
    structural count.
    """
    coll = dict(corrected["collective_bytes_by_kind"])
    total = float(coll.get(kind, 0.0))
    grad = min(float(message_bytes), total)
    coll[kind] = (total - grad) + grad * wire_fraction
    out = dict(corrected)
    out["collective_bytes_by_kind"] = coll
    out["collective_bytes"] = sum(coll.values())
    out["payload_model"] = {
        "kind": kind,
        "gradient_message_bytes": grad,
        "wire_fraction": wire_fraction,
    }
    return out


def analyze(hlo_text: str,
            collective_scale: Optional[Dict[str, float]] = None
            ) -> Dict[str, object]:
    """Loop-aware cost analysis of an HLO module text.

    ``collective_scale`` applies a Channel payload model uniformly to a
    whole collective kind — appropriate only when EVERY instruction of
    that kind carries the compressed payload.  When compressed gradient
    aggregation shares an HLO kind with dense traffic (activation
    all-reduces under model parallelism), use
    ``apply_gradient_payload_model`` on the result instead.  Kinds
    absent from the dict keep their structural count (the int8 ring's
    s8 payloads and the shared-pattern Rand-K's K-sized value mean are
    already honest in the HLO).
    """
    model = HloCostModel(hlo_text)
    c = model.entry_cost()
    coll = dict(c.coll)
    if collective_scale:
        for kind, scale in collective_scale.items():
            if kind in coll:
                coll[kind] *= scale
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "transcendentals": c.transcendentals,
        "collective_bytes_by_kind": coll,
        "collective_bytes": sum(coll.values()),
        "collective_bytes_structural": sum(c.coll.values()),
        "collective_scale": dict(collective_scale or {}),
        "while_trips": model.while_trips,
        "unresolved_whiles": model.unresolved_whiles,
    }
