"""Distributed training step with first-class shifted compression.

This is Algorithm 1 (DCGD-SHIFT) mapped onto the TPU mesh:

  * "worker i" = one (pod, data) slice; per-worker gradients come from a
    vmap over the worker axis (``dist.worker_grads``), sharded
    P(("pod","data"), ...).
  * ALL algorithm math lives in the ONE phased rule engine
    (``repro.core.shift_rules`` for the gradient direction,
    ``repro.core.iterate_comp.VRGDCI`` for compressed iterates): the
    step below only plumbs ``TrainState`` fields through
    ``rule.round(...)``.  There is NO per-rule update math in this
    module — a rule lands once in ``repro.core`` and runs everywhere
    (reference simulator, this mesh step, the overlap runtime), which
    the cross-layer bit-exactness tests in ``tests/test_shift_engine.py``
    pin.
  * ALL communication goes through one ``repro.comm.Channel``
    (``MeshChannel`` here, ``AsyncChannel`` for the overlap modes):
    wire bits are accounted STRUCTURALLY from the actual payloads and
    aggregation runs in the configured wire format (dense psum /
    shared-pattern Rand-K / int8 ring) — no comm-mode string dispatch
    lives here either.
  * The master's aggregated shift h^k is tracked INCREMENTALLY by the
    rules (Alg. 1 line 14 as the paper notes: h^{k+1} = h^k + alpha*m^k
    for DIANA) so no uncompressed collective ever materializes for it.

CLI:  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
          [--comm_mode dense|randk_shared|q8_ring|q8_ring_overlap|ef21|\
           efbv|efbv_overlap|q8_ring_fused_vjp|auto] [--autotune] \
          [--tune_plan PLAN.json] ...

``--comm_mode auto`` resolves through ``repro.tune``: fingerprint the
(model x mesh x world-size x compressor) workload, reuse the cached
``TunePlan`` on a hit, otherwise calibrate an alpha-beta link model by
timed micro-reduces of the real leaf shapes, rank every candidate plan
by predicted step time, verify the top few by measurement, and persist
the winner (strict JSON under ``--tune_cache``).  ``--autotune`` forces
a fresh search even on a hit; ``--tune_plan`` applies an explicit plan
file; ``--tune_modes`` restricts the candidate grid (CI keeps measured
candidates tiny — interpret-mode Pallas is slow on CPU).

``q8_ring_overlap`` / ``efbv_overlap`` route the round through
``comm.AsyncChannel``: reverse-layer byte-budget buckets over the
Pallas-fused int8 ring, each bucket's message formed and its reduction
issued before the next bucket's message (``AsyncChannel.shift_round``),
so XLA can overlap ring hops with encode and backward compute — for
EVERY rule of the engine, shifted ones included.

``q8_ring_fused_vjp`` goes one step further and deletes the standalone
encode stage entirely (``repro.comm.fused_vjp``): every param leaf is
wrapped in an identity ``custom_vjp`` whose backward applies the
rule's ``message_leaf`` shift+encode, so the backward pass EMITS the
decoded wire messages as its cotangents and the AsyncChannel (per-leaf
buckets) only runs the reduce/apply tail — bit-exact with the post-hoc
rounds per shift rule (tests/test_fused_vjp.py).
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import (
    CHANNEL_MODES,
    FUSED_VJP_MODES,
    WIRE_CODEC_FLAGS,
    build_transport,
    make_channel,
    resync_h_bar,
    wire_stream,
)
from repro.configs import get_config, get_smoke_config
from repro.configs.base import CompressionConfig, ModelConfig, TrainConfig
from repro.core import SHIFT_RULES
from repro.core.iterate_comp import VRGDCI
from repro.core.shift_rules import residual_sq_diag
from repro.dist import (
    params_pspecs,
    per_worker_grads,
    split_batch,
    validate_pspecs,
    worker_stacked_pspec,
)
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_host_mesh, n_workers
from repro.models import model as M
from repro.optim import make_optimizer

tmap = jax.tree_util.tree_map

#: CLI comm modes — DERIVED from the channel registry (minus the
#: reference-only parameter server) so the two surfaces cannot drift
COMM_MODES = tuple(m for m in CHANNEL_MODES if m != "sim")

#: CLI shift rules — the engine registry minus the oracle rule (which
#: needs grads at the optimum) plus the iterate-compression Algorithm 2
SHIFT_RULE_CHOICES = tuple(
    r for r in SHIFT_RULES if r != "star"
) + ("vr_gdci",)


class TrainState(NamedTuple):
    params: Any
    opt: Any
    h: Any            # worker-stacked shifts (None for stateless rules)
    h_bar: Any        # master aggregated shift (params-like; None if zero)
    key: jax.Array
    step: jax.Array
    bits: jax.Array   # cumulative uplink bits (model-size units, f32)


def init_state(key, cfg: ModelConfig, tcfg: TrainConfig, w: int) -> TrainState:
    kp, kk = jax.random.split(key)
    params = M.init_params(kp, cfg)
    opt = make_optimizer(tcfg).init(params)
    comp = tcfg.compression
    if comp.enabled:
        # the rule decides its own state: stateless rules (fixed/dcgd)
        # allocate nothing; stateful ones get worker-stacked shifts in
        # the gradient dtype (bf16 at scale — a full f32 copy per worker
        # would dominate HBM for the 32B archs) plus the master h_bar
        _, rule = comp.make(learning_rate=tcfg.learning_rate)
        wlike = tmap(
            lambda p: jax.ShapeDtypeStruct((w, *p.shape), p.dtype), params
        )
        h = rule.init(wlike)
        h_bar = rule.init_bar(wlike)
    else:
        h = None
        h_bar = None
    return TrainState(params, opt, h, h_bar, kk,
                      jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))


def build_channel(comp: CompressionConfig, cfg: ModelConfig, mesh, w: int):
    """The MeshChannel for this run, with worker-stacked specs when the
    aggregation runs a shard_map (q8 ring / shared Rand-K)."""
    wspecs = None
    if (
        comp.enabled
        and comp.aggregation_mode in ("q8_ring", "q8_ring_fused",
                                      "randk_shared")
        and mesh is not None
    ):
        # worker-stacked grad specs so the ring's shard_map keeps the
        # model-axis sharding of inner dims (no whole-leaf gathers)
        params_shapes = jax.eval_shape(
            lambda k: M.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
        )
        inner = validate_pspecs(params_shapes, params_pspecs(params_shapes), mesh)
        wspecs = tmap(lambda sp: worker_stacked_pspec(mesh, sp), inner,
                      is_leaf=lambda x: isinstance(x, P))
        wshapes = tmap(lambda p: jax.ShapeDtypeStruct((w, *p.shape), p.dtype),
                       params_shapes)
        wspecs = validate_pspecs(wshapes, wspecs, mesh)
    return make_channel(comp, mesh, wspecs=wspecs)


def _tree_dist(a, b) -> jax.Array:
    """Global l2 distance ``||a - b||`` over two pytrees (f32)."""
    sq = jnp.zeros((), jnp.float32)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        d = la.astype(jnp.float32) - lb.astype(jnp.float32)
        sq = sq + jnp.sum(d * d)
    return jnp.sqrt(sq)


def build_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh, w: int,
                     diag: bool = False):
    """Returns train_step(state, batch) -> (state, metrics) — pure, jittable.

    The step is RULE PLUMBING ONLY: per-worker gradients in, one
    ``rule.round`` (the engine: message -> aggregate -> apply, scheduled
    by the channel), optimizer out.  Iterate-compression rules
    (``VRGDCI``) update the params inside their round, so the optimizer
    is bypassed for them — the paper's gradient mapping is plain SGD.

    ``diag=True`` adds shift-rule diagnostics to the METRICS dict only —
    ``h_bar_drift`` (||h_bar - mean_i h_i||, the lossy-aggregation
    tracking error ``resync_h_bar`` bounds) and ``ef_err_norm``
    (||g_bar - mean_i g_i||, the compression error of the round).  The
    returned STATE is bit-exact with ``diag=False`` (pinned in
    tests/test_obs.py): diagnostics consume no randomness and feed
    nothing back.  Phases are annotated with ``repro.obs.span`` — pure
    trace metadata, no runtime ops, no extra compilations.
    """
    from repro.obs import span
    if getattr(tcfg, "train_attn_chunk", 0) and tcfg.train_attn_chunk > 0:
        cfg = cfg.with_(attn_q_chunk=tcfg.train_attn_chunk)
    comp = tcfg.compression
    optimizer = make_optimizer(tcfg)
    channel = build_channel(comp, cfg, mesh, w)
    if comp.enabled:
        q, rule = comp.make(learning_rate=tcfg.learning_rate)
        iterate_rule = isinstance(rule, VRGDCI)
    else:
        q, rule, iterate_rule = None, None, False
    fused = comp.enabled and comp.comm_mode in FUSED_VJP_MODES
    if fused:
        from repro.comm import fused_vjp

        if iterate_rule:
            raise ValueError(
                "comm_mode 'q8_ring_fused_vjp' fuses GRADIENT-message "
                "encode into the backward pass; the iterate-compression "
                "rule 'vr_gdci' has no gradient message to fuse"
            )
        fused_vjp.check_fusible(rule)
    # ALL of this step's traffic is registered on the transport: the
    # grad wire wraps the channel+rule above (bit-exact — Wire passes
    # the round key through verbatim), and any configured moe/act wires
    # ride into the forward pass
    transport = build_transport(comp, cfg, channel, rule=rule, msg_codec=q,
                                w=w)
    grad_wire = transport["grad"]
    wired = ("moe" in transport) or ("act" in transport)

    def loss_fn(params, batch):
        if fused or wired:
            batch = dict(batch)
        tap = None
        if fused:
            # the fused-backward encode: wrap every param leaf so its
            # dense cotangent is replaced by the decoded shifted-
            # compressed message the moment backprop produces it —
            # jax.grad of this loss then EMITS the wire message tree
            # directly, and the dense gradient tree never materializes
            keys = batch.pop("fused_keys")
            fh = batch.pop("fused_h", None)
            tap = lambda p: fused_vjp.encode_on_backward(  # noqa: E731
                rule, q, p, keys, fh
            )
        if wired:
            wire_key = batch.pop("wire_key")
            return M.train_loss(params, cfg, batch, wires=transport,
                                wire_key=wire_key, param_tap=tap)
        return M.train_loss(params, cfg, batch, param_tap=tap)

    def train_step(state: TrainState, batch):
        wbatch = split_batch(batch, w)
        # the round key is split BEFORE the backward pass (the fused
        # path derives its message keys from ``sub``); the split is
        # pure, so every mode's trajectory is bitwise unchanged
        key, sub = jax.random.split(state.key)
        if wired:
            # per-worker wire keys, derived from a stream disjoint from
            # the round key (which stays byte-identical to the unwired
            # step)
            kw = wire_stream(state.key, "transport")
            wbatch = dict(wbatch, wire_key=jax.random.split(kw, w))
        if fused:
            # per-leaf per-worker message keys, pre-derived from the
            # round key exactly as the post-hoc rounds derive them
            # (Channel.shift_round's k_msg split + global leaf fold);
            # every array leaf is (w, ...)-stacked so the tuple rides
            # the worker vmap with the rest of the batch
            wbatch = dict(wbatch, fused_keys=fused_vjp.round_message_keys(
                rule, q, sub, state.params, w
            ))
            if state.h is not None:
                wbatch = dict(wbatch, fused_h=state.h)
        with span("train/grads"):
            grads, loss, metrics = per_worker_grads(
                loss_fn, state.params, wbatch
            )

        extra = {}
        if not comp.enabled:
            with span("train/reduce"):
                g_bar = grad_wire.reduce_mean(sub, grads)
            with span("train/apply"):
                new_params, opt = optimizer.update(
                    g_bar, state.opt, state.params
                )
            h, h_bar, bits = state.h, state.h_bar, state.bits
        elif iterate_rule:
            # Algorithm 2: the round returns the mixed iterate directly
            with span("train/round"):
                new_params, h, h_bar, step_bits = grad_wire.iterate_round(
                    sub, state.params, grads, state.h, state.h_bar
                )
            opt = state.opt
            bits = state.bits + step_bits
        else:
            with span("train/round"):
                if fused:
                    # ``grads`` here ARE the decoded wire messages (the
                    # fused backward emitted them as cotangents): the
                    # round is its reduce/apply tail, no encode stage
                    g_bar, h, h_bar, step_bits = grad_wire.fused_round(
                        sub, grads, state.h, state.h_bar
                    )
                else:
                    g_bar, h, h_bar, step_bits = grad_wire.shift_round(
                        sub, grads, state.h, state.h_bar
                    )
                # bound the shift-tracking drift of lossy aggregation:
                # every N rounds h_bar resyncs to the exact worker mean
                h_bar = resync_h_bar(h, h_bar, state.step,
                                     comp.drift_resync_every)
            with span("train/apply"):
                new_params, opt = optimizer.update(
                    g_bar, state.opt, state.params
                )
            bits = state.bits + step_bits
            if diag:
                if not fused:
                    # fused mode has no dense per-worker gradients to
                    # compare against — that deletion is the point
                    g_mean = tmap(
                        lambda g: jnp.mean(g.astype(jnp.float32), axis=0),
                        grads,
                    )
                    extra["ef_err_norm"] = _tree_dist(g_bar, g_mean)
                    # the paper's headline probe: ||g - h||^2 vs ||g||^2
                    # against the PRE-round shift (what the wire carried)
                    extra.update(residual_sq_diag(grads, state.h))
                if h is not None and h_bar is not None:
                    h_mean = tmap(
                        lambda x: jnp.mean(x.astype(jnp.float32), axis=0), h
                    )
                    extra["h_bar_drift"] = _tree_dist(h_bar, h_mean)

        new_state = TrainState(new_params, opt, h, h_bar, key,
                               state.step + 1, bits)
        return new_state, {**metrics, "loss": loss, "bits": bits, **extra}

    return train_step


# ---------------------------------------------------------------------------
# Sharding assembly for the production mesh
# ---------------------------------------------------------------------------


def state_pspecs(state_shapes, mesh, tcfg: TrainConfig):
    """PartitionSpecs for a TrainState, validated against the mesh."""
    fsdp = tcfg.fsdp_params
    p_specs = params_pspecs(state_shapes.params, fsdp=fsdp)
    p_specs = validate_pspecs(state_shapes.params, p_specs, mesh)
    opt_data = tcfg.zero_opt_state
    m_specs = params_pspecs(state_shapes.opt.m, fsdp=opt_data)
    m_specs = validate_pspecs(state_shapes.opt.m, m_specs, mesh)
    v_specs = params_pspecs(state_shapes.opt.v, fsdp=opt_data)
    v_specs = validate_pspecs(state_shapes.opt.v, v_specs, mesh)

    if state_shapes.h is not None:
        inner = params_pspecs(state_shapes.params, fsdp=False)
        h_specs = tmap(lambda sp: worker_stacked_pspec(mesh, sp), inner,
                       is_leaf=lambda x: isinstance(x, P))
        h_specs = validate_pspecs(state_shapes.h, h_specs, mesh)
        hb_specs = params_pspecs(state_shapes.h_bar, fsdp=True)
        hb_specs = validate_pspecs(state_shapes.h_bar, hb_specs, mesh)
    else:
        h_specs = None
        hb_specs = None

    return TrainState(
        params=p_specs,
        opt=type(state_shapes.opt)(step=P(), m=m_specs, v=v_specs),
        h=h_specs,
        h_bar=hb_specs,
        key=P(),
        step=P(),
        bits=P(),
    )


def batch_pspecs(batch_shapes, mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return tmap(lambda _: P(axes), batch_shapes)


# ---------------------------------------------------------------------------
# CLI driver (host-scale): trains a reduced/smoke or small full config
# ---------------------------------------------------------------------------


def dense_step_analysis(cfg: ModelConfig, mesh, w: int, lr: float,
                        batch: int, seq: int):
    """Loop-aware HLO cost of THIS run's train step with compression
    disabled — the compute/memory time every tuner candidate shares, so
    the overlap candidates' hide credit is charged against the real
    backward pass (without it, compute_s is 0 and bucketed overlap can
    never beat its own launch overhead).  Returns None (with a warning)
    if the step cannot be lowered here — the search then ranks by comm
    alone, exactly the pre-analysis behavior."""
    from repro.launch import hlo_cost

    try:
        tcfg = TrainConfig(learning_rate=lr,
                           compression=CompressionConfig(enabled=False))
        step = build_train_step(cfg, tcfg, mesh, w)
        state_shapes = jax.eval_shape(
            lambda k: init_state(k, cfg, tcfg, w),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
        batch_shapes = tmap(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            TokenStream(cfg, seq, batch).batch(0),
        )
        hlo = jax.jit(step).lower(state_shapes, batch_shapes).compile().as_text()
        return hlo_cost.analyze(hlo)
    except Exception as e:  # noqa: BLE001 — tuning must not kill training
        print(f"tune: WARNING: dense-step HLO analysis failed "
              f"({type(e).__name__}: {e}); ranking candidates by comm time "
              f"only (overlap modes get no compute-hide credit)")
        return None


def resolve_comm_auto(comp: CompressionConfig, cfg: ModelConfig, mesh, w: int,
                      *, plan_path=None, cache_dir=None, force=False,
                      tune_modes=None, lr: float = 3e-4, batch: int = 8,
                      seq: int = 128, obs_sink=None):
    """Resolve ``comm_mode='auto'`` (or an explicit ``--tune_plan`` /
    ``--autotune`` request) via ``repro.tune``, printing what happened —
    the fingerprint, whether the plan came from the cache, and the
    chosen knobs.  Returns ``(resolved CompressionConfig, TunePlan)`` —
    the plan carries the predicted step time the obs layer logs next to
    every measured step.  ``obs_sink`` receives the search's structured
    warning events (e.g. ``omega_unavailable``)."""
    from repro import tune
    from repro.core.compressors import make_compressor

    if plan_path:
        plan = tune.load_plan(plan_path)
        source = f"plan file {plan_path}"
    else:
        params_shapes = jax.eval_shape(
            lambda k: M.init_params(k, cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
        modes = (
            tuple(m for m in tune_modes.split(",") if m)
            if tune_modes else None
        )
        wlike = tmap(
            lambda p: jax.ShapeDtypeStruct((w, *p.shape), p.dtype),
            params_shapes,
        )
        codec = make_compressor(comp.compressor,
                                **dict(comp.compressor_kwargs))
        plan, hit = tune.autotune(
            comp, params_shapes, mesh, w,
            cache_dir=(cache_dir or tune.DEFAULT_CACHE_DIR),
            force=force, modes=modes,
            # evaluated LAZILY on a cache miss only: the HLO analysis
            # (one dense-step lower+compile), rate calibration, the
            # MEASURED overlap hide fraction (three timed phases through
            # the real AsyncChannel handles), and the MEASURED compressor
            # variance (obs.quality distortion over the real leaf shapes)
            # replace nominal/analytic constants
            analysis_fn=lambda: dense_step_analysis(
                cfg, mesh, w, lr, batch, seq
            ),
            rates_fn=tune.calibrate_rates,
            hide_fn=lambda: tune.measure_overlap_hide(
                mesh, wlike, cap_bytes=1 << 20, iters=2
            ),
            omega_fn=lambda: (tune.measure_omega(
                codec, wlike, mesh=mesh, cap_bytes=1 << 20, iters=2
            ) if hasattr(codec, "omega") else None),
            obs_sink=obs_sink,
        )
        source = "cache hit" if hit else "searched"
    resolved = tune.apply_plan(comp, plan)
    measured = (f"{plan.measured_step_s:.3e}s"
                if plan.measured_step_s is not None else "n/a")
    hide = (f"{plan.hide_fraction:.2f} ({plan.hide_source})"
            if plan.hide_fraction is not None else plan.hide_source)
    omega = (f"{plan.omega:.3g} ({plan.omega_source})"
             if plan.omega is not None else plan.omega_source)
    print(f"tune: {source}  fingerprint={plan.fingerprint[:12]}  "
          f"-> comm_mode={resolved.comm_mode} "
          f"bucket={resolved.overlap_bucket_bytes} "
          f"randk_q={resolved.randk_q:g} "
          f"q8_block={resolved.q8_block_rows} "
          f"(predicted {plan.predicted_step_s:.3e}s, measured {measured}, "
          f"hide {hide}, omega {omega})")
    return resolved, plan


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke variant of the arch")
    ap.add_argument("--compressor", default="natural")
    ap.add_argument("--shift-rule", "--shift_rule", dest="shift_rule",
                    default="diana", choices=list(SHIFT_RULE_CHOICES))
    ap.add_argument("--comm-mode", "--comm_mode", dest="comm_mode",
                    default="dense", choices=list(COMM_MODES) + ["auto"],
                    help="Channel aggregation format; ef21/efbv select "
                         "the error-feedback modes (implying their rule); "
                         "the *_overlap modes run the bucketed "
                         "AsyncChannel over the Pallas-fused q8 ring; "
                         "q8_ring_fused_vjp fuses the encode into the "
                         "backward pass itself (messages emitted as "
                         "cotangents, per-leaf buckets, no standalone "
                         "encode stage); 'auto' resolves through the "
                         "repro.tune cost-model search (cached by "
                         "fingerprint)")
    ap.add_argument("--autotune", action="store_true",
                    help="force a fresh tune search even when a cached "
                         "plan matches this workload's fingerprint")
    ap.add_argument("--tune-plan", "--tune_plan", dest="tune_plan",
                    default=None,
                    help="apply an explicit TunePlan JSON (skips the "
                         "search and the cache)")
    ap.add_argument("--tune-cache", "--tune_cache", dest="tune_cache",
                    default=None,
                    help="plan-cache directory (default experiments/tune)")
    ap.add_argument("--tune-modes", "--tune_modes", dest="tune_modes",
                    default=None,
                    help="comma-separated subset of tunable comm modes to "
                         "search (keeps measured candidates tiny in CI)")
    ap.add_argument("--moe-wire", "--moe_wire", dest="moe_wire",
                    default="none", choices=list(WIRE_CODEC_FLAGS),
                    help="codec for the MoE dispatch/combine all-to-all "
                         "wire ('none' leaves it off the transport; "
                         "'dense' routes it uncompressed)")
    ap.add_argument("--act-wire", "--act_wire", dest="act_wire",
                    default="none", choices=list(WIRE_CODEC_FLAGS),
                    help="codec for the pipeline-boundary activation "
                         "wire (block-boundary residuals, straight-"
                         "through backward)")
    ap.add_argument("--model-wire", "--model_wire", dest="model_wire",
                    default="none", choices=list(WIRE_CODEC_FLAGS),
                    help="codec for the trainer->serving model-delta "
                         "downlink ('none' leaves it off the transport; "
                         "'dense' is the lossless bit-pattern delta "
                         "stream)")
    ap.add_argument("--publish_every", "--publish-every",
                    dest="publish_every", type=int, default=1,
                    help="trainer steps between model-delta publishes on "
                         "the downlink")
    ap.add_argument("--serve_fleet", "--serve-fleet", dest="serve_fleet",
                    type=int, default=0,
                    help="N > 0: co-run N continuous-batching serving "
                         "replicas off the model-delta stream while "
                         "training")
    ap.add_argument("--stale_k", "--stale-k", dest="stale_k", type=int,
                    default=4,
                    help="fleet staleness bound K (trainer steps behind) "
                         "before a dense resync")
    ap.add_argument("--drift-resync-every", "--drift_resync_every",
                    dest="drift_resync_every", type=int, default=0,
                    help="every N rounds resync h_bar from a dense reduce "
                         "of the worker shifts (bounds shift-tracking "
                         "drift over lossy aggregation; 0 = off)")
    ap.add_argument("--efbv-eta", "--efbv_eta", dest="efbv_eta",
                    type=float, default=1.0,
                    help="EF-BV shift integration rate (1.0 = EF21)")
    ap.add_argument("--efbv-nu", "--efbv_nu", dest="efbv_nu",
                    type=float, default=1.0,
                    help="EF-BV estimator mixing")
    ap.add_argument("--no-compression", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--metrics_out", "--metrics-out", dest="metrics_out",
                    default=None,
                    help="write per-step obs records (strict JSONL, "
                         "rotated) here; enables shift-rule diagnostics "
                         "(h_bar drift, EF error norm) in the metrics "
                         "dict — the returned train STATE stays "
                         "bit-exact with the uninstrumented run")
    ap.add_argument("--trace", action="store_true",
                    help="record host wall-clock spans per phase "
                         "(encode/reduce/apply) and include the span "
                         "table in the run summary")
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = cfg.with_(dtype="float32")
    comp = CompressionConfig(
        enabled=not args.no_compression,
        compressor=args.compressor,
        shift_rule=args.shift_rule,
        comm_mode=args.comm_mode,
        efbv_eta=args.efbv_eta,
        efbv_nu=args.efbv_nu,
        drift_resync_every=args.drift_resync_every,
        moe_wire=args.moe_wire,
        act_wire=args.act_wire,
        model_wire=args.model_wire,
        publish_every=args.publish_every,
    )
    if args.serve_fleet > 0 and args.model_wire == "none":
        raise SystemExit("--serve_fleet needs a model downlink; pass "
                         "--model_wire (dense/q8/natural/...)")
    mesh = make_host_mesh()
    w = n_workers(mesh)
    if args.batch % w:
        raise SystemExit(f"--batch must be divisible by {w} workers")

    if (args.autotune or args.tune_plan) and args.comm_mode != "auto":
        # an explicit concrete --comm_mode would be SILENTLY replaced by
        # the plan — make overriding it an explicit opt-in
        raise SystemExit(
            "--autotune/--tune_plan replace the communication plan; they "
            "require --comm_mode auto (you passed "
            f"--comm_mode {args.comm_mode})"
        )
    # the sink exists BEFORE plan resolution so the tune search's
    # structured warning events (omega_unavailable) land in --metrics_out
    obs_on = args.metrics_out is not None
    sink = None
    recorder = None
    if obs_on or args.trace:
        from repro import obs

        if obs_on:
            sink = obs.JsonlSink(args.metrics_out)
        if args.trace:
            recorder = obs.SpanRecorder()

    plan = None
    if comp.enabled and comp.comm_mode == "auto":
        comp, plan = resolve_comm_auto(
            comp, cfg, mesh, w,
            plan_path=args.tune_plan, cache_dir=args.tune_cache,
            force=args.autotune, tune_modes=args.tune_modes,
            lr=args.lr, batch=args.batch, seq=args.seq,
            obs_sink=sink,
        )
        # an explicit CLI wire flag beats the plan's (plans searched
        # with the default grids pin both wires to 'none')
        if args.moe_wire != "none":
            comp = dataclasses.replace(comp, moe_wire=args.moe_wire)
        if args.act_wire != "none":
            comp = dataclasses.replace(comp, act_wire=args.act_wire)
        if args.model_wire != "none":
            comp = dataclasses.replace(comp, model_wire=args.model_wire)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 10),
                       compression=comp)

    state = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
    step_fn = jax.jit(build_train_step(cfg, tcfg, mesh, w, diag=obs_on))
    stream = TokenStream(cfg, args.seq, args.batch)

    predicted_step_s = None
    if obs_on:
        from repro import tune
        from repro.comm import SimChannel, build_transport

        # predicted step time for the measured-vs-predicted ledger: the
        # plan's number when the tuner picked the mode, a nominal
        # comm-only prediction otherwise (no analysis lowered — the gap
        # is the point, not a problem)
        if plan is not None:
            predicted_step_s = plan.predicted_step_s
        elif comp.enabled and comp.comm_mode in tune.TUNABLE_MODES:
            params_shapes = jax.eval_shape(
                lambda k: M.init_params(k, cfg),
                jax.ShapeDtypeStruct((2,), jnp.uint32),
            )
            wlike = tmap(
                lambda p: jax.ShapeDtypeStruct((w, *p.shape), p.dtype),
                params_shapes,
            )
            cand = tune.Candidate(
                comp.comm_mode,
                bucket_bytes=comp.overlap_bucket_bytes,
                randk_q=comp.randk_q,
                q8_block_rows=comp.q8_block_rows or 64,
                efbv_eta=comp.efbv_eta, efbv_nu=comp.efbv_nu,
                compressor=comp.compressor,
                compressor_kwargs=tuple(comp.compressor_kwargs),
            )
            predicted_step_s = tune.predict_step(
                cand, wlike, tune.LinkModel.nominal(), w
            ).step_s

        # run header: per-wire telemetry (structural bits AND payload
        # bytes, measured codec timings) + the measured overlap hide
        params_shapes = jax.eval_shape(
            lambda k: M.init_params(k, cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
        acct = build_transport(
            comp, cfg, SimChannel(), w=w, params_like=params_shapes,
            tokens_per_worker=(args.batch // w) * args.seq,
        )
        wlike = tmap(
            lambda p: jax.ShapeDtypeStruct((w, *p.shape), p.dtype),
            params_shapes,
        )
        if plan is not None and plan.hide_fraction is not None:
            hide_fraction, hide_source = plan.hide_fraction, plan.hide_source
        else:
            m = tune.measure_overlap_hide(mesh, wlike, cap_bytes=1 << 20,
                                          iters=2)
            hide_fraction, hide_source = m.hide_fraction, m.source
        sink.emit(obs.run_record(
            "train",
            arch=args.arch,
            workers=w,
            comm_mode=comp.comm_mode,
            shift_rule=comp.effective_shift_rule if comp.enabled else None,
            steps=args.steps,
            wires=acct.obs_snapshot(timed=True, quality=True),
            hide_fraction=hide_fraction,
            hide_source=hide_source,
            omega=plan.omega if plan is not None else None,
            omega_source=(plan.omega_source if plan is not None
                          else "analytic"),
            predicted_step_s=predicted_step_s,
        ))

    bridge = None
    if args.serve_fleet > 0:
        from repro.comm import SimChannel, build_transport
        from repro.serving import TrainerFleetBridge

        params_shapes = jax.eval_shape(
            lambda k: M.init_params(k, cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
        downlink = build_transport(comp, cfg, SimChannel(), w=w,
                                   params_like=params_shapes)
        bridge = TrainerFleetBridge(
            cfg, state.params, downlink["model"],
            n_replicas=args.serve_fleet, publish_every=comp.publish_every,
            stale_k=args.stale_k, key=jax.random.PRNGKey(1),
            obs=sink,
        )

    print(f"arch={args.arch} params={M.count_params_analytic(cfg):,} "
          f"workers={w} compression={comp.enabled} "
          f"rule={comp.effective_shift_rule} comm={comp.comm_mode} "
          f"moe_wire={comp.moe_wire} act_wire={comp.act_wire} "
          f"model_wire={comp.model_wire}")

    from contextlib import nullcontext

    every = comp.drift_resync_every if comp.enabled else 0
    if recorder is not None:
        from repro.obs import recording

        loop_ctx = recording(recorder)
    else:
        loop_ctx = nullcontext()
    # host-side span around the step dispatch (+ readback when timing):
    # inert without a recorder, and obs is only imported when one exists
    step_ctx = ((lambda: obs.span("host/step"))
                if recorder is not None else nullcontext)
    t0 = time.time()
    with loop_ctx:
        for i in range(args.steps):
            ts = time.perf_counter()
            with step_ctx():
                state, metrics = step_fn(state, stream.batch(i))
                if sink is not None or recorder is not None:
                    jax.block_until_ready(state.params)
            step_s = time.perf_counter() - ts
            if bridge is not None:
                bridge.on_step(state.params, i + 1)
            if sink is not None:
                sink.emit(obs.step_record(
                    i,
                    loss=float(metrics["loss"]),
                    bits=float(metrics["bits"]),
                    step_s=step_s,
                    predicted_step_s=predicted_step_s,
                    h_bar_drift=(float(metrics["h_bar_drift"])
                                 if "h_bar_drift" in metrics else None),
                    ef_err_norm=(float(metrics["ef_err_norm"])
                                 if "ef_err_norm" in metrics else None),
                    grad_sq=(float(metrics["grad_sq"])
                             if "grad_sq" in metrics else None),
                    shift_residual_sq=(
                        float(metrics["shift_residual_sq"])
                        if "shift_residual_sq" in metrics else None),
                ))
                # resync_h_bar fires inside jit at (step % N) == N-1;
                # mirror the event host-side from the same arithmetic
                if every and (i % every) == every - 1:
                    sink.emit(obs.event_record(
                        "drift_resync", i, every=every,
                    ))
            if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                      f"bits {float(metrics['bits']):.3e}  "
                      f"({time.time()-t0:.1f}s)")
    if bridge is not None:
        bridge.drain()
        s = bridge.stats()
        print(f"fleet[{args.serve_fleet}] wire={comp.model_wire}: "
              f"{s['publishes']} publishes, {s['resyncs']} resyncs, "
              f"{s['bytes_fraction']:.3f} of dense bytes/publish, "
              f"max staleness {s['max_staleness']} (K={args.stale_k}), "
              f"{s['tokens_served']} tokens served")
    if sink is not None:
        from repro import obs

        spans = recorder.snapshot() if recorder is not None else None
        sink.emit(obs.summary_record("train", spans=spans))
        sink.close()
        print(obs.summary_table(obs.read_jsonl(args.metrics_out),
                                name=args.arch))
        if spans:
            rows = [(n, s["count"], f"{s['mean_s']:.3e}s")
                    for n, s in sorted(spans.items())]
            print(obs.format_table("host spans", ["span", "count", "mean"],
                                   rows))
    elif recorder is not None:
        rows = [(n, s["count"], f"{s['mean_s']:.3e}s")
                for n, s in sorted(recorder.snapshot().items())]
        from repro import obs

        print(obs.format_table("host spans", ["span", "count", "mean"], rows))
    return state


if __name__ == "__main__":
    main()
