"""Distributed training step with first-class shifted compression.

This is Algorithm 1 (DCGD-SHIFT) mapped onto the TPU mesh:

  * "worker i" = one (pod, data) slice; per-worker gradients come from a
    vmap over the worker axis (``dist.worker_grads``), sharded
    P(("pod","data"), ...).
  * ALL communication goes through one ``repro.comm.Channel``
    (``MeshChannel`` here): ``channel.uplink`` encodes each worker's
    shifted gradient with the configured codec (wire bits accounted
    STRUCTURALLY from the actual payloads) and ``channel.reduce_mean``
    aggregates in the configured wire format (dense psum /
    shared-pattern Rand-K / int8 ring) — no comm-mode string dispatch
    lives here anymore.
  * The master's aggregated shift h^k is tracked INCREMENTALLY
    (Alg. 1 line 14 as the paper notes: h^{k+1} = h^k + alpha*m^k for
    DIANA) so no uncompressed collective ever materializes for it.

Shift-rule updates implemented here (production path; the reference
parameter-server algebra lives in ``repro.core``):

  fixed       h_i^k = h_i^0 (=0)  — plain DCGD
  diana       h_i += alpha * m_i ;  h_bar += alpha * m_bar
  rand_diana  h_i = grad_i w.p. p (worker-local refresh); the h_bar
              correction is a dense mean of the sparse refresh deltas
              (expected p * full message — noted in EXPERIMENTS.md).
  ef21        error feedback (Richtárik et al., 2021): the message is
              the CONTRACTIVE compression c_i = C(grad_i - h_i);
              h_i += c_i; h_bar += c_bar; g_bar = h_bar + c_bar.
              Selected by shift_rule="ef21" OR comm_mode="ef21".
  vr_gdci     Algorithm 2 — compressed ITERATES (the model-broadcast
              direction): delta_i = Q(x - gamma*SGD_dir_i - h_i);
              h_i += alpha*delta_i; x = (1-eta)x + eta(delta_bar+h_bar).
              Uses the plain SGD direction per worker (the paper's
              gradient mapping); the AdamW/momentum path does not apply
              to iterate compression.

CLI:  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
          [--comm_mode dense|randk_shared|q8_ring|q8_ring_overlap|ef21] ...

``q8_ring_overlap`` routes aggregation through ``comm.AsyncChannel``:
reverse-layer byte-budget buckets over the Pallas-fused int8 ring, one
independent collective per bucket so XLA can overlap ring hops with
encode and backward compute.
"""

from __future__ import annotations

import argparse
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import make_channel
from repro.configs import get_config, get_smoke_config
from repro.configs.base import CompressionConfig, ModelConfig, TrainConfig
from repro.core.compressors import make_compressor
from repro.dist import (
    params_pspecs,
    per_worker_grads,
    split_batch,
    validate_pspecs,
    worker_stacked_pspec,
)
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_host_mesh, n_workers
from repro.models import model as M
from repro.optim import make_optimizer

tmap = jax.tree_util.tree_map

COMM_MODES = ("dense", "randk_shared", "q8_ring", "q8_ring_overlap", "ef21")


class TrainState(NamedTuple):
    params: Any
    opt: Any
    h: Any            # worker-stacked shifts (or None when disabled/fixed-0)
    h_bar: Any        # master aggregated shift (params-like; None if zero)
    key: jax.Array
    step: jax.Array
    bits: jax.Array   # cumulative uplink bits (model-size units, f32)


def init_state(key, cfg: ModelConfig, tcfg: TrainConfig, w: int) -> TrainState:
    kp, kk = jax.random.split(key)
    params = M.init_params(kp, cfg)
    opt = make_optimizer(tcfg).init(params)
    comp = tcfg.compression
    if comp.enabled and comp.effective_shift_rule in (
        "diana", "rand_diana", "vr_gdci", "ef21"
    ):
        # shift state in the gradient dtype (bf16 at scale) — a full f32
        # copy per worker would dominate HBM for the 32B archs
        h = tmap(lambda p: jnp.zeros((w, *p.shape), p.dtype), params)
        h_bar = tmap(lambda p: jnp.zeros(p.shape, p.dtype), params)
    else:
        h = None
        h_bar = None
    return TrainState(params, opt, h, h_bar, kk,
                      jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))


def build_channel(comp: CompressionConfig, cfg: ModelConfig, mesh, w: int):
    """The MeshChannel for this run, with worker-stacked specs when the
    aggregation runs a shard_map (q8 ring / shared Rand-K)."""
    wspecs = None
    if (
        comp.enabled
        and comp.aggregation_mode in ("q8_ring", "q8_ring_fused",
                                      "randk_shared")
        and mesh is not None
    ):
        # worker-stacked grad specs so the ring's shard_map keeps the
        # model-axis sharding of inner dims (no whole-leaf gathers)
        params_shapes = jax.eval_shape(
            lambda k: M.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
        )
        inner = validate_pspecs(params_shapes, params_pspecs(params_shapes), mesh)
        wspecs = tmap(lambda sp: worker_stacked_pspec(mesh, sp), inner,
                      is_leaf=lambda x: isinstance(x, P))
        wshapes = tmap(lambda p: jax.ShapeDtypeStruct((w, *p.shape), p.dtype),
                       params_shapes)
        wspecs = validate_pspecs(wshapes, wspecs, mesh)
    return make_channel(comp, mesh, wspecs=wspecs)


def build_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh, w: int):
    """Returns train_step(state, batch) -> (state, metrics) — pure, jittable."""
    if getattr(tcfg, "train_attn_chunk", 0) and tcfg.train_attn_chunk > 0:
        cfg = cfg.with_(attn_q_chunk=tcfg.train_attn_chunk)
    comp = tcfg.compression
    optimizer = make_optimizer(tcfg)
    q = make_compressor(comp.compressor, **dict(comp.compressor_kwargs)) if comp.enabled else None
    rule = comp.effective_shift_rule
    channel = build_channel(comp, cfg, mesh, w)

    def loss_fn(params, batch):
        return M.train_loss(params, cfg, batch)

    def vr_gdci_step(state: TrainState, batch):
        """Algorithm 2 (VR-GDCI) on the LM: compressed-iterate exchange.
        x' = (1-eta) x + eta * mean_i [h_i + Q(T_i(x) - h_i)] with
        T_i(x) = x - gamma * grad_i, h_i += alpha * Q(...)."""
        wbatch = split_batch(batch, w)
        grads, loss, metrics = per_worker_grads(loss_fn, state.params, wbatch)
        key, k1, k2 = jax.random.split(state.key, 3)
        gamma = tcfg.learning_rate
        eta, alpha = comp.gdci_eta, comp.shift_alpha
        target = tmap(
            lambda x, g, s: (x[None] - gamma * g.astype(x.dtype)) - s,
            state.params, grads, state.h,
        )
        delta, step_bits = channel.uplink(q, k1, target)
        h = tmap(lambda s, d: s + alpha * d, state.h, delta)
        delta_bar = channel.reduce_mean(k2, delta)
        new_params = tmap(
            lambda x, db, hb: ((1.0 - eta) * x.astype(jnp.float32)
                               + eta * (db + hb).astype(jnp.float32)
                               ).astype(x.dtype),
            state.params, delta_bar, state.h_bar,
        )
        h_bar = tmap(lambda hb, db: hb + alpha * db, state.h_bar, delta_bar)
        bits = state.bits + step_bits
        new_state = TrainState(new_params, state.opt, h, h_bar, key,
                               state.step + 1, bits)
        return new_state, {**metrics, "loss": loss, "bits": bits}

    def train_step(state: TrainState, batch):
        if comp.enabled and rule == "vr_gdci":
            return vr_gdci_step(state, batch)
        wbatch = split_batch(batch, w)
        grads, loss, metrics = per_worker_grads(loss_fn, state.params, wbatch)
        key, k1, k2, k3 = jax.random.split(state.key, 4)
        bits = state.bits

        if not comp.enabled:
            g_bar = channel.reduce_mean(k1, grads)
            h, h_bar = state.h, state.h_bar
        else:
            if state.h is not None:
                diff = tmap(lambda g, s: g - s, grads, state.h)
            else:
                diff = grads
            m, step_bits = channel.uplink(q, k1, diff)
            m_bar = channel.reduce_mean(k2, m)
            h, h_bar = state.h, state.h_bar
            if rule in ("fixed", "dcgd"):
                g_bar = m_bar                     # h == 0
            elif rule == "diana":
                g_bar = tmap(lambda hb, mb: hb + mb, h_bar, m_bar)
                a = comp.shift_alpha
                h = tmap(lambda s, mm: s + a * mm, h, m)
                h_bar = tmap(lambda hb, mb: hb + a * mb, h_bar, m_bar)
            elif rule == "ef21":
                # error feedback: integrate the contractive message
                g_bar = tmap(lambda hb, mb: hb + mb, h_bar, m_bar)
                h = tmap(lambda s, mm: s + mm, h, m)
                h_bar = tmap(lambda hb, mb: hb + mb, h_bar, m_bar)
            elif rule == "rand_diana":
                g_bar = tmap(lambda hb, mb: hb + mb, h_bar, m_bar)
                refresh = jax.random.bernoulli(k3, comp.shift_p, (w,))
                def upd(s, g):
                    mask = refresh.reshape((w,) + (1,) * (g.ndim - 1))
                    return jnp.where(mask, g, s)
                delta = tmap(lambda s, g: upd(s, g) - s, h, grads)
                h = tmap(lambda s, d: s + d, h, delta)
                h_bar = tmap(
                    lambda hb, d: hb + jnp.mean(d, axis=0), h_bar, delta
                )
                # the rare refresh uplink is a full uncompressed message
                d_total = sum(
                    int(l.size) // w for l in jax.tree_util.tree_leaves(grads)
                )
                step_bits = step_bits + jnp.sum(refresh) * float(32 * d_total)
            else:
                raise ValueError(rule)
            bits = bits + step_bits

        new_params, opt = optimizer.update(g_bar, state.opt, state.params)
        new_state = TrainState(new_params, opt, h, h_bar, key,
                               state.step + 1, bits)
        metrics = {**metrics, "loss": loss, "bits": bits}
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Sharding assembly for the production mesh
# ---------------------------------------------------------------------------


def state_pspecs(state_shapes, mesh, tcfg: TrainConfig):
    """PartitionSpecs for a TrainState, validated against the mesh."""
    fsdp = tcfg.fsdp_params
    p_specs = params_pspecs(state_shapes.params, fsdp=fsdp)
    p_specs = validate_pspecs(state_shapes.params, p_specs, mesh)
    opt_data = tcfg.zero_opt_state
    m_specs = params_pspecs(state_shapes.opt.m, fsdp=opt_data)
    m_specs = validate_pspecs(state_shapes.opt.m, m_specs, mesh)
    v_specs = params_pspecs(state_shapes.opt.v, fsdp=opt_data)
    v_specs = validate_pspecs(state_shapes.opt.v, v_specs, mesh)

    if state_shapes.h is not None:
        inner = params_pspecs(state_shapes.params, fsdp=False)
        h_specs = tmap(lambda sp: worker_stacked_pspec(mesh, sp), inner,
                       is_leaf=lambda x: isinstance(x, P))
        h_specs = validate_pspecs(state_shapes.h, h_specs, mesh)
        hb_specs = params_pspecs(state_shapes.h_bar, fsdp=True)
        hb_specs = validate_pspecs(state_shapes.h_bar, hb_specs, mesh)
    else:
        h_specs = None
        hb_specs = None

    return TrainState(
        params=p_specs,
        opt=type(state_shapes.opt)(step=P(), m=m_specs, v=v_specs),
        h=h_specs,
        h_bar=hb_specs,
        key=P(),
        step=P(),
        bits=P(),
    )


def batch_pspecs(batch_shapes, mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return tmap(lambda _: P(axes), batch_shapes)


# ---------------------------------------------------------------------------
# CLI driver (host-scale): trains a reduced/smoke or small full config
# ---------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke variant of the arch")
    ap.add_argument("--compressor", default="natural")
    ap.add_argument("--shift-rule", "--shift_rule", dest="shift_rule",
                    default="diana",
                    choices=["fixed", "dcgd", "diana", "rand_diana",
                             "vr_gdci", "ef21"])
    ap.add_argument("--comm-mode", "--comm_mode", dest="comm_mode",
                    default="dense", choices=list(COMM_MODES),
                    help="Channel aggregation format; ef21 selects the "
                         "error-feedback mode (implies the ef21 rule)")
    ap.add_argument("--no-compression", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = cfg.with_(dtype="float32")
    comp = CompressionConfig(
        enabled=not args.no_compression,
        compressor=args.compressor,
        shift_rule=args.shift_rule,
        comm_mode=args.comm_mode,
    )
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 10),
                       compression=comp)
    mesh = make_host_mesh()
    w = n_workers(mesh)
    if args.batch % w:
        raise SystemExit(f"--batch must be divisible by {w} workers")

    state = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
    step_fn = jax.jit(build_train_step(cfg, tcfg, mesh, w))
    stream = TokenStream(cfg, args.seq, args.batch)

    print(f"arch={args.arch} params={M.count_params_analytic(cfg):,} "
          f"workers={w} compression={comp.enabled} "
          f"rule={comp.effective_shift_rule} comm={comp.comm_mode}")
    t0 = time.time()
    for i in range(args.steps):
        state, metrics = step_fn(state, stream.batch(i))
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"bits {float(metrics['bits']):.3e}  "
                  f"({time.time()-t0:.1f}s)")
    return state


if __name__ == "__main__":
    main()
