"""Roofline accounting from compiled (AOT) artifacts.

``collective_bytes`` parses StableHLO/HLO text and sums the result-shape
bytes of every collective op, bucketed by kind.  The result shape is the
per-device tensor the op produces — a consistent proxy for wire bytes
(exact for all-reduce/all-to-all/collective-permute; the gathered size
for all-gather, i.e. an upper bound on what one device receives).

``roofline`` combines cost_analysis with the TPU v5e constants from the
brief: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from typing import Any, Dict

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link (conservative 1-link model)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# result shape right after '=' e.g.:  %x = f32[8,128]{1,0} all-reduce(
_INSTR_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\b("
    + "|".join(_COLLECTIVES)
    + r")(?:-start|-done)?\(",
)
# tuple-result form: %x = (f32[4,8], f32[4,8]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-kind result-shape bytes of collectives in an HLO module text."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "-done(" in stripped:
            continue  # started ops already counted at -start
        m = _INSTR_RE.search(stripped)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            counts[kind] += 1
            continue
        m = _TUPLE_RE.search(stripped)
        if m:
            shapes, kind = m.groups()
            for dt, dm in _SHAPE_RE.findall(shapes):
                out[kind] += _shape_bytes(dt, dm)
            counts[kind] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    return out


def roofline(corrected: Dict[str, Any], raw_cost: Dict[str, Any],
             model_flops_global: float, n_chips: int) -> Dict[str, Any]:
    """Three roofline terms (seconds, per chip).

    ``corrected`` is the loop-aware HLO cost model output
    (``repro.launch.hlo_cost.analyze``); ``raw_cost`` is XLA's own
    ``cost_analysis()`` (kept for reference — it counts while bodies
    once, so scanned-layer programs under-report there).
    """
    flops = float(corrected["flops"])
    bytes_hbm = float(corrected["bytes"])
    cbytes = float(corrected["collective_bytes"])
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_hbm / HBM_BW
    t_coll = cbytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    model_flops_chip = model_flops_global / n_chips
    return {
        **terms,
        "dominant": dom,
        "hlo_flops": flops,
        "hlo_bytes": bytes_hbm,
        "collective_bytes": cbytes,
        "collective_by_kind": corrected["collective_bytes_by_kind"],
        "raw_cost_analysis_flops": float(raw_cost.get("flops", 0.0) or 0.0),
        "raw_cost_analysis_bytes": float(
            raw_cost.get("bytes accessed", 0.0)
            or raw_cost.get("bytes_accessed", 0.0) or 0.0
        ),
        "model_flops_global": model_flops_global,
        "model_flops_per_chip": model_flops_chip,
        "useful_flops_frac": (model_flops_chip / flops) if flops else 0.0,
        "unresolved_whiles": corrected["unresolved_whiles"],
    }
