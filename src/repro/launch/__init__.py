"""Launch layer: production mesh, train/serve steps, AOT dry-run."""
