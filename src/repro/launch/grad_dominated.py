import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf-2b: the paper's technique measured on its OWN regime.

At train_4k (global batch 256 x 4096) activation collectives dwarf the
once-per-step gradient reduce, so compressed gradient exchange cannot
move the wire needle.  The paper's setting is the opposite: many workers,
SMALL per-worker batches (federated / cross-DC).  This script lowers the
qwen2.5-32b train step at global_batch=16 (ONE sequence of 512 per
worker) where the gradient exchange dominates, and compares the lowered
collective bytes across aggregation modes:

    dense          f32/bf16 all-reduce mean         (DCGD baseline wire)
    randk_shared   shared-pattern Rand-K (q=0.05)   (values-only payload)
    q8_ring        int8 ring all-reduce (ppermute)  (per-hop quantization)

Usage: PYTHONPATH=src python -m repro.launch.grad_dominated
"""

import json

import jax

from repro.configs import get_config
from repro.configs.base import CompressionConfig, InputShape, TrainConfig
from repro.launch import hlo_cost
from repro.launch.dryrun import lower_train
from repro.launch.mesh import make_production_mesh

SHAPE = InputShape("grad_dom", 512, 16, "train")


def run(comm_mode: str, arch: str = "qwen2.5-32b"):
    cfg = get_config(arch)
    tcfg = TrainConfig(compression=CompressionConfig(
        compressor="natural", shift_rule="diana", comm_mode=comm_mode,
        randk_q=0.05,
    ))
    mesh = make_production_mesh()
    lowered = lower_train(cfg, SHAPE, mesh, tcfg)
    hlo = lowered.compile().as_text()
    c = hlo_cost.analyze(hlo)
    return c


def main():
    rows = {}
    for mode in ("dense", "randk_shared", "q8_ring"):
        try:
            c = run(mode)
            rows[mode] = {
                "collective_bytes": c["collective_bytes"],
                "by_kind": c["collective_bytes_by_kind"],
                "hlo_bytes": c["bytes"],
            }
            print(f"{mode:14s} collective "
                  f"{c['collective_bytes']/1e9:8.2f} GB   "
                  + ", ".join(f"{k} {v/1e9:.2f}"
                              for k, v in c["collective_bytes_by_kind"].items()
                              if v > 1e8))
        except Exception as e:
            rows[mode] = {"error": f"{type(e).__name__}: {e}"[:300]}
            print(f"{mode:14s} ERROR {rows[mode]['error'][:150]}")
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/grad_dominated.json", "w") as f:
        json.dump(rows, f, indent=2)
    if all("collective_bytes" in r for r in rows.values()):
        d = rows["dense"]["collective_bytes"]
        for m in ("randk_shared", "q8_ring"):
            r = rows[m]["collective_bytes"]
            print(f"{m}: {d/max(r,1):.2f}x fewer collective bytes than dense")


if __name__ == "__main__":
    main()
