"""Production mesh construction.

Functions, not module-level constants — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod ("data","model"); multi_pod prepends a
    2-way "pod" axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has — used by CPU smoke tests and examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def n_workers(mesh) -> int:
    """DCGD worker count = product of data-like axes (pod x data)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1) * sizes.get("data", 1)
