import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod AOT dry-run: lower + compile every (architecture x input
shape x mesh) combination against 512 placeholder devices; record
memory_analysis, cost_analysis and the collective-bytes HLO parse for
the roofline table (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

Outputs one JSON per combination under experiments/dryrun/.
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.configs.base import CompressionConfig, InputShape, ModelConfig, TrainConfig
from repro.data.tokens import make_batch_specs
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh, n_workers
from repro.launch.serve import decode_specs, decode_state_pspecs, serving_config
from repro.launch.train import (
    COMM_MODES,
    batch_pspecs,
    build_train_step,
    init_state,
    state_pspecs,
)
from repro.models import model as M

tmap = jax.tree_util.tree_map


def _named(mesh, specs):
    return tmap(
        lambda sp: NamedSharding(mesh, sp),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def skip_reason(arch: str, shape: InputShape) -> str | None:
    cfg = get_config(arch)
    if shape.name == "long_500k" and cfg.arch_type == "audio":
        return "long_500k skipped for audio enc-dec (DESIGN.md §Arch-applicability)"
    return None


def tune_preview(cfg: ModelConfig, comp: CompressionConfig, mesh,
                 analysis: Dict[str, Any], top: int = 5,
                 wire_traffic=None) -> Dict[str, Any]:
    """Predicted-vs-chosen comm plans for this (arch x mesh) workload.

    AOT-only: the tuner's predictor runs off this dry-run's loop-aware
    HLO analysis, nominal TPU link/device rates, and structural wire
    bits (``verify_top=0`` — nothing is timed on the dry-run host).
    The full measured search belongs to ``--comm_mode auto`` at launch;
    this preview shows what it WOULD choose next to what is configured.
    With registered non-grad wires (``wire_traffic``) the grid also
    crosses each configured wire flag against ``"none"`` so the preview
    shows whether compressing that wire pays off.
    """
    from repro import tune
    from repro.launch.mesh import n_workers

    w = n_workers(mesh)
    params_shapes = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    wlike = tmap(
        lambda p: jax.ShapeDtypeStruct((w, *p.shape), p.dtype), params_shapes
    )
    grids = {}
    if comp.moe_wire != "none":
        grids["moe_wire_grid"] = tuple(dict.fromkeys(("none", comp.moe_wire)))
    if comp.act_wire != "none":
        grids["act_wire_grid"] = tuple(dict.fromkeys(("none", comp.act_wire)))
    if comp.model_wire != "none":
        grids["model_wire_grid"] = tuple(
            dict.fromkeys(("none", comp.model_wire))
        )
    plan = tune.search_plan(
        comp, wlike, mesh, w, fingerprint="preview", analysis=analysis,
        link=tune.LinkModel.nominal(), rates=tune.DeviceRates.nominal(),
        verify_top=0, wire_traffic=wire_traffic, **grids,
    )
    return {
        "configured_comm_mode": comp.comm_mode,
        "predicted_choice": plan.comm_mode,
        "predicted_moe_wire": plan.moe_wire,
        "predicted_act_wire": plan.act_wire,
        "predicted_model_wire": plan.model_wire,
        "predicted_step_s": plan.predicted_step_s,
        # which overlap-hide fed the composition: "nominal" here (AOT
        # preview — nothing is measured); a launch-time search records
        # the measured fraction in its TunePlan and the obs run header
        "hide_fraction": plan.hide_fraction,
        "hide_source": plan.hide_source,
        # likewise the compressor variance: "analytic" here (the AOT
        # preview never runs traffic); a launch-time measured probe
        # records omega_source="measured" instead
        "omega": plan.omega,
        "omega_source": plan.omega_source,
        "candidates": list(plan.candidates[:top]),
    }


def accounting_transport(cfg: ModelConfig, comp: CompressionConfig, mesh,
                         shape: InputShape):
    """The Transport this run registers, channel-free (accounting only):
    grad traffic from the parameter tree, moe/act traffic from the input
    shape's per-worker token count."""
    from repro.comm import build_transport

    w = n_workers(mesh)
    params_shapes = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    return build_transport(
        comp, cfg, None, w=w, params_like=params_shapes,
        tokens_per_worker=shape.global_batch * shape.seq_len // max(w, 1),
    )


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """6*N*D for training, 2*N*D forward-only; N = active params."""
    n = M.count_params_analytic(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens


def lower_train(cfg: ModelConfig, shape: InputShape, mesh,
                tcfg: TrainConfig):
    w = n_workers(mesh)
    step = build_train_step(cfg, tcfg, mesh, w)
    state_shapes = jax.eval_shape(
        lambda k: init_state(k, cfg, tcfg, w), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    st_specs = state_pspecs(state_shapes, mesh, tcfg)
    batch_shapes = make_batch_specs(cfg, shape)
    b_specs = batch_pspecs(batch_shapes, mesh)
    with jax.sharding.set_mesh(mesh):
        jfn = jax.jit(
            step,
            in_shardings=(_named(mesh, st_specs), _named(mesh, b_specs)),
            out_shardings=(_named(mesh, st_specs), None),
            donate_argnums=(0,),
        )
        return jfn.lower(state_shapes, batch_shapes)


def lower_eval(cfg: ModelConfig, shape: InputShape, mesh):
    """Prefill = forward pass over the full sequence (logits only)."""
    from repro.dist import params_pspecs, validate_pspecs

    def eval_step(params, batch):
        logits, _ = M.forward_train(params, cfg, batch)
        return logits[:, -1]

    params_shapes = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    p_specs = validate_pspecs(
        params_shapes, params_pspecs(params_shapes), mesh
    )
    batch_shapes = make_batch_specs(cfg, shape)
    b_specs = batch_pspecs(batch_shapes, mesh)
    with jax.sharding.set_mesh(mesh):
        jfn = jax.jit(
            eval_step,
            in_shardings=(_named(mesh, p_specs), _named(mesh, b_specs)),
            out_shardings=None,
        )
        return jfn.lower(params_shapes, batch_shapes)


def lower_decode(cfg: ModelConfig, shape: InputShape, mesh):
    from repro.dist import params_pspecs, validate_pspecs
    from repro.launch.serve import build_serve_step

    scfg = serving_config(cfg, shape.name)
    params_shapes, state_shapes, tok, pos = decode_specs(
        scfg, shape.seq_len, shape.global_batch
    )
    p_specs = validate_pspecs(params_shapes, params_pspecs(params_shapes), mesh)
    s_specs = decode_state_pspecs(state_shapes, mesh)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tok_spec = P(data_axes)
    # downgrade tok batch spec if indivisible (long_500k B=1)
    nshards = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in data_axes:
        nshards *= sizes[a]
    if tok.shape[0] % nshards:
        tok_spec = P()
    step = build_serve_step(scfg)
    with jax.sharding.set_mesh(mesh):
        jfn = jax.jit(
            step,
            in_shardings=(
                _named(mesh, p_specs),
                _named(mesh, s_specs),
                NamedSharding(mesh, tok_spec),
                NamedSharding(mesh, P()),
            ),
            out_shardings=(None, _named(mesh, s_specs)),
        )
        return jfn.lower(params_shapes, state_shapes, tok, pos)


def run_one(arch: str, shape_name: str, multi_pod: bool,
            tcfg: TrainConfig, out_dir: str, save_hlo: bool = False,
            probe_quality: bool = False) -> Dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    mesh_tag = "pod512" if multi_pod else "pod256"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "kind": shape.kind,
    }
    reason = skip_reason(arch, shape)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    cfg = get_config(arch)
    # per-arch wire sanitization: under --all a moe/act wire flag only
    # applies to the archs that have that wire (a dense model has no
    # expert all-to-all) — drop it rather than failing the combination
    comp = tcfg.compression
    drop = {}
    if comp.moe_wire != "none" and not cfg.is_moe:
        drop["moe_wire"] = "none"
    if comp.act_wire != "none" and cfg.arch_type not in ("dense", "vlm",
                                                         "moe"):
        drop["act_wire"] = "none"
    if drop:
        tcfg = dataclasses.replace(
            tcfg, compression=dataclasses.replace(comp, **drop)
        )
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        if shape.kind == "train":
            lowered = lower_train(cfg, shape, mesh, tcfg)
        elif shape.kind == "prefill":
            lowered = lower_eval(cfg, shape, mesh)
        else:
            lowered = lower_decode(cfg, shape, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # jaxlib < 0.5 returns [dict]
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        from repro.comm import collective_payload_scale
        from repro.launch import hlo_cost
        corrected = hlo_cost.analyze(hlo)
        scale = (
            collective_payload_scale(tcfg.compression)
            if shape.kind == "train" else {}
        )
        if scale:
            # re-charge only the gradient-mean share of the all-reduce
            # bytes at the codec wire fraction; activation collectives
            # stay structural.  The per-DEVICE gradient message is the
            # param tree sharded over the model axis only (the data/pod
            # reduction replicates over those axes), so divide by the
            # model-axis size, not the chip count.
            import numpy as np
            params_shapes = jax.eval_shape(
                lambda k: M.init_params(k, cfg),
                jax.ShapeDtypeStruct((2,), jnp.uint32),
            )
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            msg_bytes = sum(
                int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
                for l in jax.tree_util.tree_leaves(params_shapes)
            ) / sizes.get("model", 1)
            corrected = hlo_cost.apply_gradient_payload_model(
                corrected, "all-reduce", msg_bytes, scale["all-reduce"]
            )
        coll = hlo_stats.collective_bytes(hlo)  # static instruction counts
        mf = model_flops(
            serving_config(cfg, shape_name) if shape.kind == "decode" else cfg,
            shape,
        )
        n_chips = 512 if multi_pod else 256
        roof = hlo_stats.roofline(corrected, cost, mf, n_chips)

        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            # cost-model blind spots MUST be visible: a while whose trip
            # count fell back to 1 silently under-counts that loop in
            # every roofline/tuner number derived from this analysis
            "cost_model": {
                "unresolved_whiles": list(corrected["unresolved_whiles"]),
                "unresolved_while_count":
                    len(corrected["unresolved_whiles"]),
                "while_trips": dict(corrected["while_trips"]),
            },
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            },
            "roofline": roof,
            "collective_counts": coll.get("_counts"),
        })
        if shape.kind == "train":
            transport = accounting_transport(cfg, tcfg.compression, mesh,
                                             shape)
            rec["wires"] = [
                {
                    "name": wire.name,
                    "topology": wire.topology,
                    "codec": type(wire.codec).__name__,
                    "bytes_per_step": wire.wire_bits() / 8.0,
                    "overlap_hidden": wire.overlap_hidden,
                    # measured distortion is opt-in on the dry-run host:
                    # encoding a synthetic payload per wire is cheap for
                    # the rank/quant codecs but interpret-mode fused
                    # codecs pay real time — dash in the table until run
                    **(wire.codec_quality() if probe_quality
                       else {"omega_hat": None, "nmse": None}),
                }
                for wire in transport
            ]
            if tcfg.compression.enabled:
                rec["tune_preview"] = tune_preview(
                    cfg, tcfg.compression, mesh, corrected,
                    wire_traffic=transport.extra_traffic(),
                )
        if save_hlo:
            with open(os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_tag}.hlo"), "w") as f:
                f.write(hlo)
    except Exception as e:  # record failures — they are bugs to fix
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--comm-mode", "--comm_mode", dest="comm_mode",
                    default="dense", choices=list(COMM_MODES))
    ap.add_argument("--compressor", default="natural")
    ap.add_argument("--shift-rule", "--shift_rule", dest="shift_rule",
                    default="diana")
    from repro.comm import WIRE_CODEC_FLAGS
    ap.add_argument("--moe-wire", "--moe_wire", dest="moe_wire",
                    default="none", choices=list(WIRE_CODEC_FLAGS))
    ap.add_argument("--act-wire", "--act_wire", dest="act_wire",
                    default="none", choices=list(WIRE_CODEC_FLAGS))
    ap.add_argument("--model-wire", "--model_wire", dest="model_wire",
                    default="none", choices=list(WIRE_CODEC_FLAGS),
                    help="trainer->serving model-delta downlink codec")
    ap.add_argument("--publish_every", "--publish-every",
                    dest="publish_every", type=int, default=1,
                    help="steps between downlink publishes (amortizes "
                         "the model wire's bytes/step)")
    ap.add_argument("--no-compression", action="store_true")
    ap.add_argument("--probe-quality", "--probe_quality",
                    dest="probe_quality", action="store_true",
                    help="run the measured omega_hat/NMSE distortion "
                         "probe on each wire's codec (off by default: "
                         "the per-wire table shows a dash)")
    ap.add_argument("--metrics_out", "--metrics-out", dest="metrics_out",
                    default=None,
                    help="emit one obs event per combination (status, "
                         "unresolved-while count) as strict JSONL")
    args = ap.parse_args(argv)

    sink = None
    if args.metrics_out:
        from repro import obs

        sink = obs.JsonlSink(args.metrics_out)
        sink.emit(obs.run_record("dryrun", comm_mode=args.comm_mode))

    os.makedirs(args.out, exist_ok=True)
    tcfg = TrainConfig(
        compression=CompressionConfig(
            enabled=not args.no_compression,
            compressor=args.compressor,
            shift_rule=args.shift_rule,
            comm_mode=args.comm_mode,
            moe_wire=args.moe_wire,
            act_wire=args.act_wire,
            model_wire=args.model_wire,
            publish_every=args.publish_every,
        )
    )

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'512' if mp else '256'}"
                print(f"=== {tag} ...", flush=True)
                rec = run_one(arch, shape, mp, tcfg, args.out,
                              save_hlo=args.save_hlo,
                              probe_quality=args.probe_quality)
                results.append(rec)
                fname = os.path.join(
                    args.out,
                    f"{arch}_{shape}_{'pod512' if mp else 'pod256'}"
                    f"_{tcfg.compression.comm_mode}.json",
                )
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=2)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']} "
                             f"c={r['compute_s']:.3f}s m={r['memory_s']:.3f}s "
                             f"coll={r['collective_s']:.3f}s")
                elif status == "error":
                    extra = " " + rec["error"][:200]
                print(f"=== {tag}: {status}{extra}", flush=True)
                unresolved = (rec.get("cost_model") or {}).get(
                    "unresolved_whiles") or []
                if sink is not None:
                    from repro import obs

                    sink.emit(obs.event_record(
                        "dryrun_combination", len(results) - 1,
                        arch=arch, shape=shape, status=status,
                        unresolved_while_count=len(unresolved),
                    ))
                if unresolved:
                    print(f"    WARNING: {len(unresolved)} while loop(s) "
                          f"with unresolved trip counts (fell back to 1): "
                          f"{', '.join(unresolved[:4])}"
                          f"{' ...' if len(unresolved) > 4 else ''} — "
                          f"flops/bytes and tuner predictions under-count "
                          f"these loops", flush=True)
                for wrow in rec.get("wires") or ():
                    oh = wrow.get("omega_hat")
                    nm = wrow.get("nmse")
                    print(f"    wire {wrow['name']:<5} "
                          f"{wrow['topology']:<10} {wrow['codec']:<18} "
                          f"{wrow['bytes_per_step']:.3e} B/step  "
                          f"hidden={wrow['overlap_hidden']:.0%}  "
                          f"omega_hat="
                          f"{'-' if oh is None else format(oh, '.3g')}  "
                          f"nmse="
                          f"{'-' if nm is None else format(nm, '.3g')}",
                          flush=True)
                tp = rec.get("tune_preview")
                if tp:
                    mark = ("  (matches configured)"
                            if tp["predicted_choice"]
                            == tp["configured_comm_mode"] else
                            f"  (configured: {tp['configured_comm_mode']})")
                    om = tp.get("omega")
                    print(f"    tune preview: predicted choice "
                          f"{tp['predicted_choice']} "
                          f"@ {tp['predicted_step_s']:.3e}s/step{mark}  "
                          f"[hide: {tp['hide_source']}, omega: "
                          f"{'-' if om is None else format(om, '.3g')} "
                          f"({tp['omega_source']})]",
                          flush=True)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\nDRY-RUN SUMMARY: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if sink is not None:
        from repro import obs

        sink.emit(obs.summary_record("dryrun", ok=n_ok, skipped=n_skip,
                                     errors=n_err))
        sink.close()
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
