"""repro.tune — the cost-model-driven communication autotuner.

The right compression scheme is workload-dependent (the paper's whole
point: shift rule x compressor variance x wire width vs. link speed),
so this layer picks the communication plan instead of asking the user
to hardcode one:

  ``measure``   alpha-beta link model calibrated by timed micro-reduces
                of the REAL leaf shapes, plus device compute rates.
  ``model``     the step-time predictor: ``launch/hlo_cost`` loop-aware
                entry cost + structural ``wire_bits`` from each comm
                mode's own codec + ``plan_buckets`` launch counts.
  ``search``    predict every candidate in {comm mode x bucket grid x
                codec params (Rand-K keep-fraction, q8 scale block,
                EF-BV eta/nu from estimated omega)}, verify the top few
                by measurement, pick the measured winner.
  ``plan``      the frozen ``TunePlan``: strict-JSON persistence and a
                fingerprint cache keyed on model leaves x mesh x
                world size x compressor.

``autotune`` is the one-call entry ``launch/train.py`` uses for
``--comm_mode auto``: fingerprint, cache lookup, search on miss, save.
"""

from __future__ import annotations

import os
from typing import Optional, Sequence, Tuple

import jax

from repro.tune.measure import (
    DEFAULT_MEASURE_BYTES_CAP,
    DeviceRates,
    LinkModel,
    OmegaMeasurement,
    OverlapMeasurement,
    calibrate_link,
    calibrate_rates,
    measure_omega,
    measure_overlap_hide,
    measure_subtree,
    synth_wtree,
    time_fn,
)
from repro.tune.model import (
    Candidate,
    OVERLAP_HIDE,
    StepPrediction,
    TUNABLE_MODES,
    compose_step_s,
    comm_time_s,
    compute_time_s,
    extra_wire_bits,
    predict_step,
    predicted_wire_bits,
    wire_codec,
)
from repro.tune.plan import (
    PLAN_VERSION,
    TunePlan,
    apply_plan,
    cache_path,
    load_cached_plan,
    load_plan,
    plan_fingerprint,
    save_plan,
)
from repro.tune.search import (
    DEFAULT_ACT_WIRE_GRID,
    DEFAULT_BUCKET_GRID,
    DEFAULT_MODEL_WIRE_GRID,
    DEFAULT_MOE_WIRE_GRID,
    DEFAULT_RANDK_GRID,
    default_candidates,
    estimate_delta,
    estimate_omega,
    measure_candidate,
    search_plan,
)

tmap = jax.tree_util.tree_map

#: default on-disk home of the fingerprint cache
DEFAULT_CACHE_DIR = os.path.join("experiments", "tune")


def autotune(
    comp,
    params_like,
    mesh,
    w: int,
    *,
    cache_dir: str = DEFAULT_CACHE_DIR,
    force: bool = False,
    modes: Optional[Sequence[str]] = None,
    verify_top: int = 2,
    analysis: Optional[dict] = None,
    analysis_fn=None,
    link: Optional[LinkModel] = None,
    rates: Optional[DeviceRates] = None,
    rates_fn=None,
    cap_bytes: int = DEFAULT_MEASURE_BYTES_CAP,
    measure_iters: int = 3,
    hide: Optional[float] = None,
    hide_fn=None,
    omega: Optional[float] = None,
    omega_fn=None,
    obs_sink=None,
    **search_kw,
) -> Tuple[TunePlan, bool]:
    """Resolve one workload to a ``TunePlan``: ``(plan, cache_hit)``.

    ``params_like`` is the (unstacked) parameter tree — arrays or
    ``ShapeDtypeStruct`` leaves; everything structural runs AOT off the
    shapes, only calibration and top-candidate verification touch
    devices.  ``force=True`` re-searches even on a fingerprint hit (the
    ``--autotune`` CLI flag); a fresh plan always overwrites the cache
    entry for its fingerprint.  ``analysis_fn``/``rates_fn``/``hide_fn``
    are LAZY suppliers of the HLO step analysis, device rates, and the
    measured overlap hide fraction, called only on a cache miss — a hit
    must stay free of lower/compile/measure work.  ``hide_fn`` returns
    an ``OverlapMeasurement`` (or a bare float); like calibration it is
    only invoked when ``verify_top > 0`` (the measuring path).
    ``omega_fn`` is the same lazy shape for the MEASURED compressor
    variance: it returns an ``OmegaMeasurement`` (or a bare float, or
    ``None`` to decline), and on a measuring-path cache miss its
    ``omega_hat`` replaces the analytic ``estimate_omega`` in the EF-BV
    eta/nu derivation (plan records ``omega``/``omega_source``).
    ``obs_sink`` receives the search's structured warning events (e.g.
    ``omega_unavailable``).
    """
    # the search space is part of the cache key: a plan from a narrowed
    # --tune_modes/grid run must MISS a later full-grid lookup
    search_sig = {
        "modes": "all" if modes is None else tuple(sorted(modes)),
        "verify_top": verify_top,
        **{k: search_kw[k] for k in
           ("bucket_grid", "randk_grid", "q8_block_grid",
            "moe_wire_grid", "act_wire_grid", "model_wire_grid")
           if k in search_kw},
    }
    fp = plan_fingerprint(params_like, mesh, w, comp.compressor,
                          comp.compressor_kwargs, search=search_sig)
    if not force:
        cached = load_cached_plan(cache_dir, fp)
        if cached is not None:
            return cached, True
    if analysis is None and analysis_fn is not None:
        analysis = analysis_fn()
    if rates is None and rates_fn is not None and analysis is not None:
        rates = rates_fn()
    hide_source = None if hide is None else "measured"
    if hide is None and hide_fn is not None and verify_top > 0:
        m = hide_fn()
        hide = getattr(m, "hide_fraction", m)
        hide_source = getattr(m, "source", "measured")
    omega_source = None if omega is None else "measured"
    if omega is None and omega_fn is not None and verify_top > 0:
        m = omega_fn()
        if m is not None:
            omega = getattr(m, "omega_hat", m)
            omega_source = getattr(m, "source", "measured")
    wlike = tmap(
        lambda p: jax.ShapeDtypeStruct((w, *p.shape), p.dtype), params_like
    )
    plan = search_plan(
        comp, wlike, mesh, w, fingerprint=fp, analysis=analysis, link=link,
        rates=rates, modes=modes, verify_top=verify_top,
        measure_iters=measure_iters, cap_bytes=cap_bytes,
        hide=hide, hide_source=hide_source,
        omega=omega, omega_source=omega_source, obs_sink=obs_sink,
        **search_kw,
    )
    save_plan(plan, cache_path(cache_dir, fp))
    return plan, False


__all__ = [
    "Candidate",
    "DEFAULT_ACT_WIRE_GRID",
    "DEFAULT_BUCKET_GRID",
    "DEFAULT_CACHE_DIR",
    "DEFAULT_MEASURE_BYTES_CAP",
    "DEFAULT_MODEL_WIRE_GRID",
    "DEFAULT_MOE_WIRE_GRID",
    "DEFAULT_RANDK_GRID",
    "DeviceRates",
    "LinkModel",
    "OVERLAP_HIDE",
    "OmegaMeasurement",
    "OverlapMeasurement",
    "PLAN_VERSION",
    "StepPrediction",
    "TUNABLE_MODES",
    "TunePlan",
    "apply_plan",
    "autotune",
    "cache_path",
    "calibrate_link",
    "calibrate_rates",
    "comm_time_s",
    "compose_step_s",
    "compute_time_s",
    "default_candidates",
    "estimate_delta",
    "estimate_omega",
    "extra_wire_bits",
    "load_cached_plan",
    "load_plan",
    "measure_candidate",
    "measure_omega",
    "measure_overlap_hide",
    "measure_subtree",
    "plan_fingerprint",
    "predict_step",
    "predicted_wire_bits",
    "save_plan",
    "search_plan",
    "synth_wtree",
    "time_fn",
    "wire_codec",
]
