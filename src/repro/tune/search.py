"""The plan search: predict every candidate, measure the top few.

The grid covers {comm mode} x {bucket-byte budgets for the overlap
modes} x {codec parameters}: Rand-K keep-fractions, the fused q8 ring's
scale-block rows, and EF-BV ``(eta, nu)`` derived from the configured
compressor's ESTIMATED variance (``estimate_omega``: size-weighted
``omega(d)`` over the real leaf dimensions — the quantity EF-BV's
optimal damping ``eta = 1/(1+omega)`` needs, which the user previously
had to guess).

Ranking is two-stage, mirroring how autotuners earn trust: the
alpha-beta predictor (``repro.tune.model``) orders ALL candidates
cheaply and structurally; the top ``verify_top`` are then VERIFIED by
timed micro-reduces of the real leaf shapes through the real channels
(``measure_candidate`` jits ``Channel.reduce_mean`` — the overlap
modes' measured number is therefore the drained pipeline; their
predicted overlap credit comes from the composition model, and both
numbers are recorded in the plan so the gap stays visible).  The
measured winner becomes the ``TunePlan``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import jax

from repro.comm import make_channel
from repro.core.algorithms import efbv_params
from repro.core.compressors import make_compressor
from repro.tune.measure import (
    DEFAULT_MEASURE_BYTES_CAP,
    DeviceRates,
    LinkModel,
    calibrate_link,
    measure_subtree,
    synth_wtree,
    time_fn,
)
from repro.tune.model import (
    Candidate,
    TUNABLE_MODES,
    compose_step_s,
    predict_step,
)
from repro.tune.plan import TunePlan

tmap = jax.tree_util.tree_map

#: overlap bucket budgets searched by default (uncompressed per-worker
#: message bytes — the plan_buckets unit)
DEFAULT_BUCKET_GRID = (1 << 20, 4 << 20, 16 << 20)
DEFAULT_RANDK_GRID = (0.01, 0.05, 0.1)
DEFAULT_Q8_BLOCK_GRID = (64,)
#: per-wire codec-flag grids — ("none",) keeps non-grad wires out of the
#: search (and the grid size unchanged) unless the caller has registered
#: wire traffic to trade against
DEFAULT_MOE_WIRE_GRID = ("none",)
DEFAULT_ACT_WIRE_GRID = ("none",)
DEFAULT_MODEL_WIRE_GRID = ("none",)


def _leaf_d(leaf) -> int:
    n = 1
    for s in leaf.shape[1:]:
        n *= s
    return n


def estimate_omega(codec, wtree_like) -> Optional[float]:
    """Size-weighted unbiased variance ``omega`` of a codec over the
    REAL leaf dimensions (per-leaf messages see per-leaf d, so a single
    ``omega(total_d)`` would be wrong for sparsifiers).  ``None`` when
    the codec has no unbiased certificate."""
    if not hasattr(codec, "omega"):
        return None
    total, acc = 0, 0.0
    for leaf in jax.tree_util.tree_leaves(wtree_like):
        d = _leaf_d(leaf)
        try:
            acc += codec.omega(d) * d
        except NotImplementedError:
            return None
        total += d
    return acc / total if total else None


def estimate_delta(codec, wtree_like) -> Optional[float]:
    """Size-weighted contraction ``delta`` (B-class certificate)."""
    if not hasattr(codec, "delta"):
        return None
    total, acc = 0, 0.0
    for leaf in jax.tree_util.tree_leaves(wtree_like):
        d = _leaf_d(leaf)
        try:
            acc += codec.delta(d) * d
        except NotImplementedError:
            return None
        total += d
    return acc / total if total else None


def default_candidates(
    comp,
    wtree_like,
    *,
    modes: Optional[Sequence[str]] = None,
    bucket_grid: Sequence[int] = DEFAULT_BUCKET_GRID,
    randk_grid: Sequence[float] = DEFAULT_RANDK_GRID,
    q8_block_grid: Sequence[int] = DEFAULT_Q8_BLOCK_GRID,
    moe_wire_grid: Sequence[str] = DEFAULT_MOE_WIRE_GRID,
    act_wire_grid: Sequence[str] = DEFAULT_ACT_WIRE_GRID,
    model_wire_grid: Sequence[str] = DEFAULT_MODEL_WIRE_GRID,
    omega: Optional[float] = None,
) -> Tuple[Candidate, ...]:
    """The search grid for one ``CompressionConfig`` (module docstring).

    ``modes`` restricts the grid to a subset of ``TUNABLE_MODES`` —
    the knob CI uses to keep measured candidates tiny (interpret-mode
    Pallas is slow per grid step on CPU).  ``moe_wire_grid`` /
    ``act_wire_grid`` / ``model_wire_grid`` cross every mode candidate
    with per-wire codec flags (``WIRE_CODEC_FLAGS``), letting the
    search pick a DIFFERENT codec per registered wire (the model wire
    is the trainer->serving downlink).  ``omega`` overrides the analytic
    ``estimate_omega`` in the EF-BV eta/nu derivation — pass
    ``tune.measure_omega(...).omega_hat`` so the damping runs on the
    variance REALIZED on this traffic, not the certificate.
    """
    allowed = set(TUNABLE_MODES if modes is None else modes)
    unknown = allowed - set(TUNABLE_MODES)
    if unknown:
        raise ValueError(
            f"unknown tune modes {sorted(unknown)}; have {TUNABLE_MODES}"
        )
    base = dict(compressor=comp.compressor,
                compressor_kwargs=tuple(comp.compressor_kwargs))
    q = make_compressor(comp.compressor, **dict(comp.compressor_kwargs))
    if omega is None:
        omega = estimate_omega(q, wtree_like)
    delta = estimate_delta(q, wtree_like)
    eta, nu = efbv_params(delta=delta or 0.0, omega=omega)

    out = []
    if "dense" in allowed:
        out.append(Candidate("dense", **base))
    if "randk_shared" in allowed:
        for rq in dict.fromkeys(tuple(randk_grid) + (comp.randk_q,)):
            out.append(Candidate("randk_shared", randk_q=rq, **base))
    if "q8_ring" in allowed:
        out.append(Candidate("q8_ring", **base))
    if "q8_ring_fused" in allowed:
        for br in q8_block_grid:
            out.append(Candidate("q8_ring_fused", q8_block_rows=br, **base))
    if "q8_ring_overlap" in allowed:
        for bb in bucket_grid:
            for br in q8_block_grid:
                out.append(Candidate("q8_ring_overlap", bucket_bytes=bb,
                                     q8_block_rows=br, **base))
    if "q8_ring_fused_vjp" in allowed:
        # Per-leaf buckets by construction — no bucket-byte axis.
        for br in q8_block_grid:
            out.append(Candidate("q8_ring_fused_vjp",
                                 q8_block_rows=br, **base))
    if "ef21" in allowed and delta is not None and delta > 0.0:
        out.append(Candidate("ef21", **base))
    if "efbv" in allowed:
        out.append(Candidate("efbv", efbv_eta=eta, efbv_nu=nu, **base))
    if "efbv_overlap" in allowed:
        for bb in bucket_grid:
            out.append(Candidate("efbv_overlap", bucket_bytes=bb,
                                 efbv_eta=eta, efbv_nu=nu, **base))
    wire_points = [
        (mw, aw, dw)
        for mw in dict.fromkeys(moe_wire_grid)
        for aw in dict.fromkeys(act_wire_grid)
        for dw in dict.fromkeys(model_wire_grid)
    ]
    if wire_points != [("none", "none", "none")]:
        out = [
            dataclasses.replace(c, moe_wire=mw, act_wire=aw, model_wire=dw)
            for c in out
            for mw, aw, dw in wire_points
        ]
    return tuple(out)


def measure_candidate(cand: Candidate, mesh, wtree, key, *,
                      iters: int = 3) -> float:
    """Median seconds of one drained aggregation round through the REAL
    channel this candidate configures (micro-reduce of the given
    worker-stacked data)."""
    kw = {}
    if cand.overlap:
        kw["bucket_bytes"] = cand.bucket_bytes
    ch = make_channel(cand.comm_mode, mesh, randk_q=cand.randk_q,
                      q8_block_rows=cand.q8_block_rows, **kw)
    return time_fn(jax.jit(ch.reduce_mean), key, wtree, iters=iters)


def search_plan(
    comp,
    wtree_like,
    mesh,
    w: int,
    *,
    fingerprint: str = "",
    analysis: Optional[dict] = None,
    link: Optional[LinkModel] = None,
    rates: Optional[DeviceRates] = None,
    modes: Optional[Sequence[str]] = None,
    bucket_grid: Sequence[int] = DEFAULT_BUCKET_GRID,
    randk_grid: Sequence[float] = DEFAULT_RANDK_GRID,
    q8_block_grid: Sequence[int] = DEFAULT_Q8_BLOCK_GRID,
    moe_wire_grid: Sequence[str] = DEFAULT_MOE_WIRE_GRID,
    act_wire_grid: Sequence[str] = DEFAULT_ACT_WIRE_GRID,
    model_wire_grid: Sequence[str] = DEFAULT_MODEL_WIRE_GRID,
    wire_traffic=None,
    verify_top: int = 2,
    measure_iters: int = 3,
    cap_bytes: int = DEFAULT_MEASURE_BYTES_CAP,
    measure_fn: Optional[Callable] = None,
    key: Optional[jax.Array] = None,
    hide: Optional[float] = None,
    hide_source: Optional[str] = None,
    omega: Optional[float] = None,
    omega_source: Optional[str] = None,
    obs_sink=None,
) -> TunePlan:
    """Predict-all, measure-top-``verify_top``, pick the measured winner.

    ``measure_fn(candidate, wtree_data, key) -> comm_seconds`` is
    injectable for tests; the default times the real channel.  With
    ``verify_top=0`` the predicted ranking alone decides (the dryrun
    preview path — nothing is timed).  ``wire_traffic`` is
    ``Transport.extra_traffic()`` — the predictor charges every
    registered non-grad wire under each candidate's wire flags.
    ``hide`` replaces the nominal overlap-hide constant in BOTH the
    predicted and the measured composition (pass
    ``measure_overlap_hide(...).hide_fraction`` for the measured
    accounting the obs layer reports); the plan records it with its
    ``hide_source``.  ``omega`` does the same for the compressor
    variance (pass ``measure_omega(...).omega_hat``): it replaces the
    analytic ``estimate_omega`` in the EF-BV eta/nu derivation, and the
    plan records ``omega``/``omega_source``.  A codec with NO variance
    certificate at all gets ``omega_source="none"`` plus a structured
    ``omega_unavailable`` warning event on ``obs_sink`` (stdout when no
    sink) — previously that information was silently dropped and the
    search proceeded on ``delta or 0.0`` with no trace.
    """
    key = jax.random.PRNGKey(0) if key is None else key
    q = make_compressor(comp.compressor, **dict(comp.compressor_kwargs))
    if omega is not None:
        omega = float(omega)
        omega_source = omega_source or "measured"
    else:
        omega = estimate_omega(q, wtree_like)
        if omega is not None:
            omega_source = omega_source or "analytic"
        else:
            omega_source = "none"
            codec_name = type(q).__name__
            if obs_sink is not None:
                from repro.obs.metrics import event_record

                obs_sink.emit(event_record(
                    "omega_unavailable", 0, codec=codec_name,
                    compressor=comp.compressor,
                    fallback="efbv eta/nu from delta or 0.0",
                ))
            else:
                print(
                    f"tune: WARNING: codec {codec_name} has no unbiased "
                    "variance certificate (.omega); EF-BV eta/nu fall "
                    "back to the contraction delta or 0.0 "
                    "(omega_source='none')"
                )
    candidates = default_candidates(
        comp, wtree_like, modes=modes, bucket_grid=bucket_grid,
        randk_grid=randk_grid, q8_block_grid=q8_block_grid,
        moe_wire_grid=moe_wire_grid, act_wire_grid=act_wire_grid,
        model_wire_grid=model_wire_grid, omega=omega,
    )
    if not candidates:
        raise ValueError("empty candidate grid (modes filtered everything)")
    if link is None:
        link = (calibrate_link(mesh, wtree_like, cap_bytes=cap_bytes,
                               iters=measure_iters)
                if verify_top > 0 else LinkModel.nominal())
    preds = [predict_step(c, wtree_like, link, w, analysis=analysis,
                          rates=rates, wire_traffic=wire_traffic, hide=hide)
             for c in candidates]
    order = sorted(range(len(candidates)), key=lambda i: preds[i].step_s)

    measured_step = {}
    measured_comm = {}
    if verify_top > 0:
        sub = measure_subtree(wtree_like, cap_bytes)
        data = synth_wtree(key, sub, mesh)
        if measure_fn is None:
            measure_fn = lambda c, t, k: measure_candidate(  # noqa: E731
                c, mesh, t, k, iters=measure_iters
            )
        for i in order[:verify_top]:
            comm_s = float(measure_fn(candidates[i], data, key))
            measured_comm[i] = comm_s
            measured_step[i] = compose_step_s(
                preds[i].compute_s, comm_s, candidates[i].overlap, hide
            ) + preds[i].encode_s
        chosen_i = min(measured_step, key=lambda i: measured_step[i])
    else:
        chosen_i = order[0]

    rows = []
    for rank, i in enumerate(order):
        p = preds[i]
        rows.append({
            "label": candidates[i].label,
            "comm_mode": candidates[i].comm_mode,
            "moe_wire": candidates[i].moe_wire,
            "act_wire": candidates[i].act_wire,
            "model_wire": candidates[i].model_wire,
            "rank": rank,
            "predicted_step_s": p.step_s,
            "predicted_comm_s": p.comm_s,
            "compute_s": p.compute_s,
            "wire_bytes": p.wire_bytes,
            "n_buckets": p.n_buckets,
            "encode_s": p.encode_s,
            "measured_comm_s": measured_comm.get(i),
            "measured_step_s": measured_step.get(i),
            "chosen": i == chosen_i,
        })
    c = candidates[chosen_i]
    return TunePlan(
        fingerprint=fingerprint,
        comm_mode=c.comm_mode,
        overlap_bucket_bytes=c.bucket_bytes,
        randk_q=c.randk_q,
        q8_block_rows=c.q8_block_rows,
        efbv_eta=c.efbv_eta,
        efbv_nu=c.efbv_nu,
        moe_wire=c.moe_wire,
        act_wire=c.act_wire,
        model_wire=c.model_wire,
        predicted_step_s=preds[chosen_i].step_s,
        measured_step_s=measured_step.get(chosen_i),
        hide_fraction=hide,
        hide_source=(hide_source or
                     ("nominal" if hide is None else "measured")),
        omega=omega,
        omega_source=omega_source,
        candidates=tuple(rows),
    )
