"""The step-time predictor: structural wire accounting x alpha-beta link.

One candidate plan's predicted step time combines three structural
sources — no hand-written byte formulas anywhere:

  * the COMPUTE half comes from ``launch/hlo_cost.analyze``'s loop-aware
    entry cost (flops / bytes of the lowered train step, while trip
    counts multiplied through) divided by calibrated device rates;
  * the WIRE half is each comm mode's per-round payload, computed AOT
    from the mode's own codec via ``jax.eval_shape`` of the SAME
    ``encode_workers`` path the live uplink runs — the accounting the
    drift test in ``tests/test_tune.py`` pins against concrete payloads;
  * the LAUNCH half counts collective launches from the overlap
    bucketer's actual ``plan_buckets`` output (one per bucket), so the
    bucket-size grid trades per-launch alpha against overlap coverage.

Comm cost is the classic ring all-reduce bound over the worker count n:

    t_comm = 2 (n-1) * (n_buckets * alpha  +  (S / n) * beta)

with S the per-worker payload bytes of the mode's wire codec.  The
``ef21``/``efbv`` modes aggregate densely in HLO but their PROTOCOL
payload is the contractive message (see
``repro.comm.collective_payload_scale``) — the predictor charges the
protocol wire, which is the quantity that transfers to a real
bandwidth-limited link; ``benchmarks/autotune_bench.py`` reports the
measured CPU numbers alongside so the gap stays visible.

Overlap modes hide comm under backward compute; the composition charges
only the un-hidden remainder (``OVERLAP_HIDE`` is the model's one free
constant, stated here rather than buried in a weight).

Compressed modes additionally pay a standalone ENCODE stage
(``encode_time_s``: an HBM-bound pass over dense message + payload) —
except the backward-fused ``q8_ring_fused_vjp`` mode, whose encode is
emitted inside the VJP and is therefore charged zero by construction.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm.channel import (
    CHANNEL_MODES,
    FUSED_VJP_MODES,
    OVERLAP_MODES,
)
from repro.comm.overlap import DEFAULT_BUCKET_BYTES, plan_buckets
from repro.comm.transport import (
    WIRE_CODEC_FLAGS,
    aggregation_wire_codec,
    wire_flag_codec,
)
from repro.comm.wire import encode_meta_free, encode_workers
from repro.core.compressors import Identity
from repro.tune.measure import DeviceRates, LinkModel

#: comm modes the tuner searches over — every channel mode except the
#: reference-only parameter server (same derivation as the train CLI)
TUNABLE_MODES: Tuple[str, ...] = tuple(
    m for m in CHANNEL_MODES if m != "sim"
)

#: fraction of compute time the bucketed overlap runtime is modeled to
#: hide comm under (reverse-layer buckets overlap the backward pass; the
#: head of the tree cannot be hidden — it is produced last)
OVERLAP_HIDE = 0.75

_KEY_SDS = jax.ShapeDtypeStruct((2,), jnp.uint32)


@dataclass(frozen=True)
class Candidate:
    """One point of the search grid: a comm mode plus every codec /
    runtime knob the plan can set."""

    comm_mode: str
    bucket_bytes: int = DEFAULT_BUCKET_BYTES
    randk_q: float = 0.05
    q8_block_rows: int = 64
    efbv_eta: float = 1.0
    efbv_nu: float = 1.0
    compressor: str = "natural"
    compressor_kwargs: tuple = ()
    moe_wire: str = "none"
    act_wire: str = "none"
    model_wire: str = "none"

    def __post_init__(self):
        if self.comm_mode not in TUNABLE_MODES:
            raise ValueError(
                f"unknown tunable comm mode {self.comm_mode!r}; "
                f"have {TUNABLE_MODES}"
            )
        for flag in (self.moe_wire, self.act_wire, self.model_wire):
            if flag not in WIRE_CODEC_FLAGS:
                raise ValueError(
                    f"unknown wire codec flag {flag!r}; "
                    f"have {WIRE_CODEC_FLAGS}"
                )

    @property
    def overlap(self) -> bool:
        """Modes that run through the bucketed AsyncChannel (the fused
        mode is overlap-by-construction: each leaf's payload exists the
        moment its cotangent does)."""
        return self.comm_mode in OVERLAP_MODES + FUSED_VJP_MODES

    @property
    def fused(self) -> bool:
        """Backward-fused encode: per-leaf buckets, no standalone
        encode stage (``repro.comm.fused_vjp``)."""
        return self.comm_mode in FUSED_VJP_MODES

    @property
    def label(self) -> str:
        knobs = []
        if self.comm_mode == "randk_shared":
            knobs.append(f"q={self.randk_q:g}")
        if self.comm_mode in ("q8_ring_fused",) + OVERLAP_MODES + \
                FUSED_VJP_MODES:
            knobs.append(f"block={self.q8_block_rows}")
        if self.fused:
            knobs.append("per-leaf")
        elif self.overlap:
            knobs.append(f"bucket={self.bucket_bytes >> 10}KiB")
        if self.comm_mode in ("efbv", "efbv_overlap"):
            knobs.append(f"eta={self.efbv_eta:g},nu={self.efbv_nu:g}")
        if self.moe_wire != "none":
            knobs.append(f"moe={self.moe_wire}")
        if self.act_wire != "none":
            knobs.append(f"act={self.act_wire}")
        if self.model_wire != "none":
            knobs.append(f"model={self.model_wire}")
        return self.comm_mode + (f"[{','.join(knobs)}]" if knobs else "")


def wire_codec(cand: Candidate):
    """The codec whose payload defines this mode's bytes-on-wire —
    delegates to the transport's ONE mode->codec map
    (``repro.comm.transport.aggregation_wire_codec``), so the predictor
    and the live grad wire cannot drift."""
    return aggregation_wire_codec(cand)


def predicted_wire_bits(cand: Candidate, wtree_like) -> float:
    """Total wire bits of one round's worker-stacked messages, AOT.

    ``jax.eval_shape`` over the SAME per-leaf ``encode_workers`` path
    the live uplink runs, summed with the codec's own structural
    ``wire_bits`` — so this number cannot drift from the wire protocol
    without the accounting test catching it.
    """
    codec = wire_codec(cand)
    total = 0.0
    for leaf in jax.tree_util.tree_leaves(wtree_like):
        sds = jax.ShapeDtypeStruct(tuple(leaf.shape), leaf.dtype)
        payload, _ = jax.eval_shape(
            lambda k, l: encode_workers(codec, k, l), _KEY_SDS, sds
        )
        total += float(codec.wire_bits(payload))
    return total


@dataclass(frozen=True)
class StepPrediction:
    """One candidate's predicted timing decomposition."""

    step_s: float
    compute_s: float
    comm_s: float
    wire_bytes: float          # per-worker payload bytes per round
    n_buckets: int
    encode_s: float = 0.0      # standalone encode stage (0 when fused)
    candidate: Candidate = field(repr=False, default=None)


def compute_time_s(analysis: Optional[dict],
                   rates: Optional[DeviceRates]) -> float:
    """Compute half from an ``hlo_cost.analyze`` dict (loop-aware entry
    cost): roofline max of flops-bound and HBM-bound time.  ``None``
    analysis (micro-bench ranking) contributes zero."""
    if analysis is None:
        return 0.0
    rates = rates or DeviceRates.nominal()
    flops_s = float(analysis.get("flops", 0.0)) / rates.flops_per_s
    mem_s = float(analysis.get("bytes", 0.0)) / rates.hbm_bytes_per_s
    return max(flops_s, mem_s)


def encode_time_s(cand: Candidate, wtree_like,
                  rates: Optional[DeviceRates]) -> float:
    """The STANDALONE encode stage: HBM-bound pass reading each dense
    per-worker message and writing its wire payload.

    ``dense`` has no encode; the fused-VJP modes emit payloads as
    cotangents inside the backward pass — the stage does not exist, so
    they are charged ZERO here (the whole point of the mode, and the
    accounting the fused-mode test in ``tests/test_tune.py`` pins).
    Every other compressed mode pays (dense bytes + payload bytes) /
    HBM rate per round.
    """
    if cand.comm_mode in ("dense",) + FUSED_VJP_MODES or rates is None:
        return 0.0
    dense_bytes = sum(
        float(math.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        for leaf in jax.tree_util.tree_leaves(wtree_like)
    )
    payload_bytes = predicted_wire_bits(cand, wtree_like) / 8.0
    return float((dense_bytes + payload_bytes) / rates.hbm_bytes_per_s)


def extra_wire_bits(cand: Candidate, wire_traffic) -> float:
    """Structural per-step bits of every registered NON-grad wire under
    this candidate's per-wire codec flags.

    ``wire_traffic`` is ``Transport.extra_traffic()``: ``{wire name:
    ((sds, count), ...)}``.  A wire whose flag is ``"none"`` still moves
    its payload — uncompressed — so it is charged at identity width; the
    grid can therefore trade a codec's variance against the bytes it
    removes from the wire.  Same meta-free encode path as ``Wire.send``.
    """
    if not wire_traffic:
        return 0.0
    total = 0.0
    for name, traffic in wire_traffic.items():
        flag = getattr(cand, f"{name}_wire", "none")
        codec = (Identity() if flag == "none"
                 else wire_flag_codec(flag, randk_q=cand.randk_q))
        cache = {}
        for sds, count in traffic:
            sig = (tuple(sds.shape), str(jnp.dtype(sds.dtype)))
            if sig not in cache:
                payload = jax.eval_shape(
                    lambda k, l: encode_meta_free(codec, k, l),
                    _KEY_SDS, sds,
                )
                cache[sig] = float(codec.wire_bits(payload))
            total += count * cache[sig]
    return total


def comm_time_s(cand: Candidate, wtree_like, link: LinkModel,
                w: int, *, wire_traffic=None) -> Tuple[float, float, int]:
    """``(comm_s, per_worker_wire_bytes, n_buckets)`` for one candidate
    (the ring all-reduce bound in the module docstring).

    Registered non-grad wires (``wire_traffic``) add their bytes at one
    link traversal each — all-to-all / p2p payloads cross the bisection
    once, not 2(w-1) ring hops — so every wire the transport owns is
    charged, under the codec flags this candidate sets.
    """
    total_bits = predicted_wire_bits(cand, wtree_like)
    s_bytes = total_bits / 8.0 / max(w, 1)
    n_buckets = (
        len(plan_buckets(wtree_like, cand.bucket_bytes,
                         per_leaf=cand.fused))
        if cand.overlap else 1
    )
    hops = 2 * (w - 1)
    comm = hops * (n_buckets * link.alpha_s
                   + (s_bytes / max(w, 1)) * link.beta_s_per_byte)
    extra_bytes = extra_wire_bits(cand, wire_traffic) / 8.0 / max(w, 1)
    comm += extra_bytes * link.beta_s_per_byte
    s_bytes += extra_bytes
    return float(comm), float(s_bytes), int(n_buckets)


def compose_step_s(compute_s: float, comm_s: float, overlap: bool,
                   hide: Optional[float] = None) -> float:
    """Serial modes pay compute + comm; overlap modes pay only the comm
    that does not fit under a ``hide`` fraction of the compute.

    ``hide=None`` charges the nominal ``OVERLAP_HIDE`` constant; a
    MEASURED fraction (``repro.tune.measure.measure_overlap_hide``)
    replaces it when the search has one.
    """
    if overlap:
        h = OVERLAP_HIDE if hide is None else hide
        return compute_s + max(0.0, comm_s - h * compute_s)
    return compute_s + comm_s


def predict_step(cand: Candidate, wtree_like, link: LinkModel, w: int, *,
                 analysis: Optional[dict] = None,
                 rates: Optional[DeviceRates] = None,
                 wire_traffic=None,
                 hide: Optional[float] = None) -> StepPrediction:
    """The full prediction for one candidate (see module docstring).
    ``hide`` overrides the nominal overlap-hide constant (measured)."""
    compute_s = compute_time_s(analysis, rates)
    comm_s, s_bytes, n_buckets = comm_time_s(cand, wtree_like, link, w,
                                             wire_traffic=wire_traffic)
    # The standalone encode stage rides the compute half (it is device
    # work, not wire time); charged only when a compute analysis is in
    # play so codec-only micro-bench rankings stay pure wire orderings.
    encode_s = (encode_time_s(cand, wtree_like, rates)
                if analysis is not None else 0.0)
    return StepPrediction(
        step_s=compose_step_s(compute_s, comm_s, cand.overlap, hide)
        + encode_s,
        compute_s=compute_s,
        comm_s=comm_s,
        wire_bytes=s_bytes,
        n_buckets=n_buckets,
        encode_s=encode_s,
        candidate=cand,
    )
