"""The frozen ``TunePlan`` + its persistence and fingerprint cache.

A plan is the tuner's OUTPUT: the concrete communication configuration
(`comm_mode`, bucket budget, codec parameters) chosen for one
(model x mesh x world-size) workload, together with the evidence
(predicted and measured step times per candidate) that picked it.  Plans
are:

  * strict JSON on disk (``allow_nan=False`` — an artifact a downstream
    RFC 8259 parser rejects is a bug HERE, not there; non-finite values
    become ``null``),
  * cached by FINGERPRINT: a sha256 over the model's leaf signature
    (shape + dtype per parameter leaf — renaming an arch must not fake
    a hit, resizing it must miss), the mesh (axis names + sizes), the
    worker count, and the configured compressor.  Same workload, same
    plan; ``launch/train.py`` reuses a cached plan without re-measuring.

``apply_plan`` folds a plan back into a ``CompressionConfig``: the ONE
place the ``comm_mode="auto"`` sentinel becomes a concrete mode.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax

#: bump when the plan schema or the search semantics change — a cached
#: plan from an older tuner must MISS, not silently misconfigure a run
#: (v2: per-wire codec flags moe_wire/act_wire joined the plan schema;
#:  v3: model_wire — the trainer->serving downlink — joined;
#:  v4: hide_fraction/hide_source — the measured overlap hide replaced
#:      the nominal constant in the search composition;
#:  v5: q8_ring_fused_vjp joined the grid and predictions gained the
#:      standalone-encode term encode_s — zero for the fused mode;
#:  v6: omega/omega_source — a measured compressor variance can replace
#:      the analytic certificate in the EF-BV eta/nu derivation and the
#:      candidate ranking; "none" records that no certificate existed)
PLAN_VERSION = 6


def plan_fingerprint(params_like, mesh, w: int, compressor: str,
                     compressor_kwargs=(), search: Optional[dict] = None
                     ) -> str:
    """Cache key for one tuning workload.

    ``params_like`` is the (unstacked) parameter tree — arrays or
    ``ShapeDtypeStruct`` leaves; only shapes/dtypes enter the hash, so
    the fingerprint is computable AOT and identical across hosts.
    ``search`` captures the SEARCH SPACE (mode restriction, candidate
    grids, verify depth): a plan found by a narrowed CI-style search
    must not satisfy a later full-grid lookup on the same workload.
    """
    leaf_sig = [
        (list(leaf.shape), str(jax.numpy.dtype(leaf.dtype)))
        for leaf in jax.tree_util.tree_leaves(params_like)
    ]
    mesh_sig = {
        "axes": list(mesh.axis_names),
        "shape": [int(s) for s in mesh.devices.shape],
    } if mesh is not None else None
    blob = json.dumps(
        {
            "version": PLAN_VERSION,
            "leaves": leaf_sig,
            "mesh": mesh_sig,
            "workers": int(w),
            "compressor": compressor,
            "compressor_kwargs": sorted(
                (str(k), str(v)) for k, v in dict(compressor_kwargs).items()
            ),
            "search": {str(k): str(v)
                       for k, v in sorted((search or {}).items())},
        },
        sort_keys=True,
    )
    return hashlib.sha256(blob.encode()).hexdigest()


@dataclass(frozen=True)
class TunePlan:
    """The chosen communication plan (see module docstring).

    ``candidates`` keeps the ranked evidence: one dict per candidate
    with its label, predicted step time, measured step time (None if it
    was ranked out before verification), and wire bytes — the
    predicted-vs-measured record ``benchmarks/autotune_bench.py`` and
    the dryrun preview print.
    """

    fingerprint: str
    comm_mode: str
    overlap_bucket_bytes: int
    randk_q: float
    q8_block_rows: int
    efbv_eta: float
    efbv_nu: float
    predicted_step_s: float
    measured_step_s: Optional[float] = None
    moe_wire: str = "none"
    act_wire: str = "none"
    model_wire: str = "none"
    hide_fraction: Optional[float] = None  # overlap hide the search used
    hide_source: str = "nominal"           # "nominal" | "measured"
    omega: Optional[float] = None          # compressor variance the
    #                                        eta/nu derivation used
    omega_source: str = "analytic"         # "measured"|"analytic"|"none"
    candidates: Tuple[dict, ...] = field(default_factory=tuple)
    version: int = PLAN_VERSION

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["candidates"] = list(d["candidates"])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TunePlan":
        if int(d.get("version", -1)) != PLAN_VERSION:
            raise ValueError(
                f"tune plan version {d.get('version')!r} != {PLAN_VERSION} "
                "(re-run the tuner; stale plans must not configure a run)"
            )
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(f"unknown TunePlan fields {sorted(unknown)}")
        d = dict(d)
        d["candidates"] = tuple(d.get("candidates") or ())
        return cls(**d)


def _finite_tree(obj):
    """null-out non-finite floats so the artifact stays strict JSON —
    THE repo-wide sanitizer (``repro.obs.metrics.sanitize_tree``)."""
    from repro.obs.metrics import sanitize_tree

    return sanitize_tree(obj)


def save_plan(plan: TunePlan, path: str) -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(_finite_tree(plan.to_dict()), f, indent=2, sort_keys=True,
                  allow_nan=False)
    return path


def load_plan(path: str) -> TunePlan:
    with open(path) as f:
        return TunePlan.from_dict(json.load(f))


def cache_path(cache_dir: str, fingerprint: str) -> str:
    return os.path.join(cache_dir, f"tuneplan_{fingerprint[:16]}.json")


def load_cached_plan(cache_dir: str, fingerprint: str) -> Optional[TunePlan]:
    """The cached plan for this fingerprint, or None.  A plan whose
    recorded fingerprint disagrees with its filename (hand-edited /
    copied across workloads) is treated as a miss, not an error."""
    path = cache_path(cache_dir, fingerprint)
    if not os.path.exists(path):
        return None
    try:
        plan = load_plan(path)
    except (ValueError, KeyError, TypeError, json.JSONDecodeError):
        return None
    if plan.fingerprint != fingerprint:
        return None
    return plan


def apply_plan(comp, plan: TunePlan):
    """Resolve a ``CompressionConfig`` through a plan: the concrete
    ``comm_mode`` plus every knob the search optimized.  This is the
    only place ``comm_mode="auto"`` becomes a real mode."""
    return dataclasses.replace(
        comp,
        comm_mode=plan.comm_mode,
        overlap_bucket_bytes=plan.overlap_bucket_bytes,
        randk_q=plan.randk_q,
        q8_block_rows=plan.q8_block_rows,
        efbv_eta=plan.efbv_eta,
        efbv_nu=plan.efbv_nu,
        moe_wire=plan.moe_wire,
        act_wire=plan.act_wire,
        model_wire=plan.model_wire,
    )
