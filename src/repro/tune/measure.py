"""Calibration: the alpha-beta link model and device compute rates.

The tuner's comm predictions run on a classic alpha-beta cost model
(latency + inverse-bandwidth, Hockney): one collective launch over a
payload of B bytes costs ``alpha + B * beta`` per hop.  Rather than
quoting datasheet numbers, ``calibrate_link`` FITS alpha and beta from
timed micro-reduces of the REAL leaf shapes on the REAL mesh: a dense
``reduce_mean`` of the smallest leaf (latency-dominated) and of the
whole tree (bandwidth-dominated) give two (bytes, seconds) points; more
subsets give an overdetermined least-squares fit.  On the CPU test
meshes the numbers characterize the host's fake-device transport — the
model's STRUCTURE (rank by payload + launch count) is what transfers to
hardware, and the top candidates are verified by measurement anyway
(``repro.tune.search``).

``calibrate_rates`` times a jitted matmul and a big elementwise pass for
the flops/s and HBM bytes/s the compute half of the predictor divides
by.  ``LinkModel.nominal()`` / ``DeviceRates.nominal()`` provide
TPU-scale constants for AOT-only paths (the dryrun preview) where
nothing can be timed.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

tmap = jax.tree_util.tree_map

#: cap on the worker-stacked bytes any single timed micro-reduce moves —
#: calibration must stay micro (a 151k-vocab embedding stacked over 8
#:  workers is not a micro-reduce on a CPU test mesh)
DEFAULT_MEASURE_BYTES_CAP = 64 << 20


@dataclass(frozen=True)
class LinkModel:
    """alpha (s per collective launch/hop) + beta (s per byte per hop)."""

    alpha_s: float
    beta_s_per_byte: float

    @classmethod
    def nominal(cls) -> "LinkModel":
        # TPU-pod-scale constants for AOT previews: ~10 us launch,
        # ~100 GB/s per-link bandwidth
        return cls(alpha_s=1e-5, beta_s_per_byte=1.0 / 100e9)


@dataclass(frozen=True)
class DeviceRates:
    flops_per_s: float
    hbm_bytes_per_s: float

    @classmethod
    def nominal(cls) -> "DeviceRates":
        # TPU-scale: ~200 TFLOP/s bf16, ~800 GB/s HBM
        return cls(flops_per_s=2e14, hbm_bytes_per_s=8e11)


def time_fn(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-clock seconds of ``fn(*args)`` (blocked until ready).

    ``warmup`` calls absorb compile; the median over ``iters`` resists
    the scheduler jitter that dominates short CPU timings.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _inner_bytes(leaf) -> int:
    """Per-worker message bytes of one worker-stacked leaf."""
    n = 1
    for s in leaf.shape[1:]:
        n *= s
    return n * np.dtype(leaf.dtype).itemsize


def synth_wtree(key: jax.Array, wtree_like, mesh=None):
    """Concrete normal data matching a worker-stacked shape tree,
    device_put with the leading axis over the mesh's data-like axes (the
    layout the real gradient stack arrives in)."""
    leaves, treedef = jax.tree_util.tree_flatten(wtree_like)
    vals = [
        jax.random.normal(jax.random.fold_in(key, i), leaf.shape,
                          jnp.float32).astype(leaf.dtype)
        for i, leaf in enumerate(leaves)
    ]
    tree = jax.tree_util.tree_unflatten(treedef, vals)
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        w = leaves[0].shape[0] if leaves else 0
        nshards = 1
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for a in axes:
            nshards *= sizes[a]
        if axes and w % nshards == 0:
            tree = jax.device_put(tree, NamedSharding(mesh, P(axes)))
    return tree


def measure_subtree(wtree_like, cap_bytes: int = DEFAULT_MEASURE_BYTES_CAP):
    """The leaves a micro-reduce may move: reverse-layer order (the
    bucketer's walk) until the WORKER-STACKED byte cap — real shapes,
    bounded cost.  Always keeps at least one leaf."""
    leaves = jax.tree_util.tree_leaves(wtree_like)
    picked, total = [], 0
    for leaf in reversed(leaves):
        b = _inner_bytes(leaf) * leaf.shape[0]
        if picked and total + b > cap_bytes:
            break
        picked.append(leaf)
        total += b
    return {f"leaf{i:03d}": l for i, l in enumerate(picked)}


def calibrate_link(mesh, wtree_like, *, iters: int = 3,
                   cap_bytes: int = DEFAULT_MEASURE_BYTES_CAP,
                   key: Optional[jax.Array] = None) -> LinkModel:
    """Fit the alpha-beta link model from timed dense micro-reduces of
    the real leaf shapes (see module docstring).

    Subsets: the single smallest leaf, the measure subtree, and (when
    distinct) the single largest leaf within the cap — up to three
    (bytes, seconds) points, least-squares fit, slope clamped >= 0.
    """
    from repro.comm import make_channel

    key = jax.random.PRNGKey(7) if key is None else key
    sub = measure_subtree(wtree_like, cap_bytes)
    leaves = sorted(sub.values(), key=_inner_bytes)
    subsets = [{"s": leaves[0]}]
    if len(leaves) > 1:
        subsets.append({"l": leaves[-1]})
    if len(sub) > 1:
        subsets.append(sub)

    ch = make_channel("dense", mesh)
    fn = jax.jit(ch.reduce_mean)
    points = []
    for subset in subsets:
        tree = synth_wtree(key, subset, mesh)
        t = time_fn(fn, key, tree, iters=iters)
        # per-worker message bytes: the alpha-beta payload unit
        points.append((float(sum(_inner_bytes(l) for l in subset.values())),
                       t))
    return fit_alpha_beta(points)


def fit_alpha_beta(points: Sequence[tuple]) -> LinkModel:
    """Least-squares ``t = alpha + bytes * beta`` over (bytes, seconds)
    points; beta clamped >= 0 (timing noise on small subsets can invert
    the slope) and alpha >= 0."""
    xs = np.array([p[0] for p in points], dtype=np.float64)
    ts = np.array([p[1] for p in points], dtype=np.float64)
    if len(points) < 2 or float(xs.max() - xs.min()) == 0.0:
        return LinkModel(alpha_s=float(ts.mean()), beta_s_per_byte=0.0)
    a = np.stack([np.ones_like(xs), xs], axis=1)
    (alpha, beta), *_ = np.linalg.lstsq(a, ts, rcond=None)
    beta = max(float(beta), 0.0)
    alpha = max(float(alpha), 0.0)
    return LinkModel(alpha_s=alpha, beta_s_per_byte=beta)


@dataclass(frozen=True)
class OverlapMeasurement:
    """A MEASURED compute/comm overlap: the fraction of comm time hidden
    under concurrent compute, derived from three timed phases (compute
    alone, comm alone, both issued together).  ``source`` distinguishes
    this from the nominal ``OVERLAP_HIDE`` constant in records/plans."""

    hide_fraction: float
    compute_s: float
    comm_s: float
    overlapped_s: float
    source: str = "measured"


def measure_overlap_hide(mesh, wtree_like, *, mode: str = "dense",
                         bucket_bytes: int = 1 << 16,
                         cap_bytes: int = DEFAULT_MEASURE_BYTES_CAP,
                         iters: int = 3, n_compute: int = 384,
                         key: Optional[jax.Array] = None,
                         ) -> OverlapMeasurement:
    """Measure the overlap hide fraction on THIS mesh with the REAL
    overlap runtime, replacing the nominal ``OVERLAP_HIDE`` constant.

    Times three phases over the capped measure subtree, using the same
    ``AsyncChannel.reduce_start``/``finish`` handles the trainer
    schedules (an obs ``StampRecorder`` is attached, so the probe reads
    the exact call windows the runtime stamps):

      1. jitted compute alone (a chained matmul standing in for
         backward work),
      2. the bucketed reduction alone (start + finish, drained),
      3. both: ``reduce_start`` issued FIRST, compute next, ``finish``
         last — the trainer's interleave.

    ``hide = (t_compute + t_comm - t_both) / t_comm`` clamped to [0, 1]:
    1 means comm fully disappeared under compute, 0 means full
    serialization (the honest CPU-mesh answer).  ``mode="dense"``
    by default — the probe measures SCHEDULING, not codec cost, and the
    fused-q8 kernels are not built for eager micro-timing.
    """
    from repro.comm.overlap import AsyncChannel
    from repro.obs.trace import StampRecorder

    key = jax.random.PRNGKey(11) if key is None else key
    sub = measure_subtree(wtree_like, cap_bytes)
    tree = synth_wtree(key, sub, mesh)
    ch = AsyncChannel(mode=mode, mesh=mesh, bucket_bytes=bucket_bytes,
                      obs=StampRecorder())

    a = jax.random.normal(key, (n_compute, n_compute), jnp.float32)
    compute = jax.jit(lambda x: (x @ x) @ x)

    def comm_only():
        return ch.finish(ch.reduce_start(key, tree))

    def both():
        inflight = ch.reduce_start(key, tree)
        out = compute(a)
        return out, ch.finish(inflight)

    t_compute = time_fn(compute, a, iters=iters)
    t_comm = time_fn(comm_only, iters=iters)
    t_both = time_fn(both, iters=iters)
    denom = max(t_comm, 1e-12)
    hide = (t_compute + t_comm - t_both) / denom
    return OverlapMeasurement(
        hide_fraction=float(min(1.0, max(0.0, hide))),
        compute_s=float(t_compute),
        comm_s=float(t_comm),
        overlapped_s=float(t_both),
    )


@dataclass(frozen=True)
class OmegaMeasurement:
    """A MEASURED compressor variance: ``omega_hat`` realized on synthetic
    traffic with the real leaf shapes, plus the global NMSE (defined for
    biased codecs too).  ``source`` distinguishes this from the analytic
    ``codec.omega(d)`` certificate in plans/records."""

    omega_hat: float
    nmse: float
    n_leaves: int
    d_total: int
    source: str = "measured"


def measure_omega(codec, wtree_like, *, mesh=None,
                  cap_bytes: int = DEFAULT_MEASURE_BYTES_CAP,
                  iters: int = 3,
                  key: Optional[jax.Array] = None) -> OmegaMeasurement:
    """Measure ``omega_hat = E||Q(v)-v||^2 / ||v||^2`` on THIS codec over
    the real (capped) leaf shapes, replacing the analytic estimate the
    EF-BV ``eta``/``nu`` derivation otherwise trusts.

    Draws ``iters`` independent normal trees (the synthetic stand-in for
    gradient traffic — mean ratio over normal data is the standard
    variance probe) and averages the jitted ``obs.quality`` distortion
    pass; the d-weighting matches ``tune.estimate_omega`` so measured
    and analytic are directly comparable.
    """
    from repro.obs.quality import tree_distortion

    key = jax.random.PRNGKey(13) if key is None else key
    sub = measure_subtree(wtree_like, cap_bytes)
    leaves = jax.tree_util.tree_leaves(sub)
    d_total = sum(
        max(1, int(np.prod(l.shape[1:]))) for l in leaves
    )
    fn = jax.jit(lambda k, t: tree_distortion(codec, k, t))
    omega_acc = 0.0
    nmse_acc = 0.0
    n = max(1, iters)
    for i in range(n):
        tree = synth_wtree(jax.random.fold_in(key, i), sub, mesh)
        out = fn(jax.random.fold_in(key, 1000 + i), tree)
        omega_acc += float(out["omega_hat"])
        nmse_acc += float(out["nmse"])
    return OmegaMeasurement(
        omega_hat=omega_acc / n,
        nmse=nmse_acc / n,
        n_leaves=len(leaves),
        d_total=int(d_total),
    )


def calibrate_rates(*, n: int = 512, iters: int = 3) -> DeviceRates:
    """Device compute/memory rates from a timed matmul and a timed
    elementwise pass (modest sizes — calibration must not dwarf the
    search it serves)."""
    key = jax.random.PRNGKey(3)
    a = jax.random.normal(key, (n, n), jnp.float32)
    mm = jax.jit(lambda x: x @ x)
    t_mm = time_fn(mm, a, iters=iters)
    flops = 2.0 * n**3 / max(t_mm, 1e-9)

    big = jax.random.normal(key, (4 << 20,), jnp.float32)
    add = jax.jit(lambda x: x + 1.0)
    t_add = time_fn(add, big, iters=iters)
    bps = 2.0 * big.size * 4 / max(t_add, 1e-9)  # read + write
    return DeviceRates(flops_per_s=float(flops), hbm_bytes_per_s=float(bps))
