"""Distribution substrate: worker gradients, compressed collectives,
and mesh sharding for the shifted-compression training system.

Mapping onto the paper's operators (Algorithm 1, DCGD-SHIFT):

  ``worker_grads.per_worker_grads``   line 5, "worker i computes
      g_i = grad f_i(x^k)" — one vmapped gradient per batch shard, the
      worker axis sharded over (pod x data).
  ``Q_i`` (the per-worker compressor, Defs. 1-2) is applied by the
      Channel uplink (``repro.comm``): each worker ENCODES the shifted
      difference ``g_i - h_i`` (Def. 3: Q_{h_i}(g_i) = h_i + Q(g_i -
      h_i)) into a wire payload — what travels is the codec's encoded
      message, and wire bits are counted from the payload itself.
  ``collectives.compressed_tree_mean``   lines 9-11, "master averages
      the received m_i" — the uplink aggregation, codec-driven in one of
      three wire formats: exact psum (``dense_mean``), correlated
      Rand-K payload averaging (``randk_shared_mean``: K values per
      message, pattern implied by the shared seed), or the ring/tree
      all-reduce (``q8_ring_tree_mean``) forwarding ``Int8Stochastic``
      payloads — or, for codecs flagged ``fused_ring`` (``FusedQ8``),
      running the Pallas-fused hop pipeline of ``kernels.q8ring``.
      ``repro.comm.MeshChannel`` is the high-level entry point;
      ``repro.comm.AsyncChannel`` pipelines the same collectives bucket
      by bucket (``leaf_indices`` keeps per-leaf keys global, so
      bucketing never changes the math).  The master's aggregated shift
      h^k is tracked incrementally in ``launch.train`` (h^{k+1} = h^k +
      alpha * m^k), so no uncompressed collective ever materializes.
  ``sharding``   not in the paper — the GSPMD layer that places
      parameters, optimizer moments, and worker-stacked shift state on
      the (pod, data, model) mesh.
"""

from repro.dist.collectives import (
    compressed_tree_mean,
    dense_mean,
    q8_ring_tree_mean,
    randk_shared_mean,
)
from repro.dist.sharding import (
    params_pspecs,
    validate_pspecs,
    worker_stacked_pspec,
)
from repro.dist.worker_grads import per_worker_grads, split_batch

__all__ = [
    "compressed_tree_mean",
    "dense_mean",
    "q8_ring_tree_mean",
    "randk_shared_mean",
    "params_pspecs",
    "validate_pspecs",
    "worker_stacked_pspec",
    "per_worker_grads",
    "split_batch",
]
