"""Distribution substrate: worker gradients, compressed collectives,
and mesh sharding for the shifted-compression training system.

Mapping onto the paper's operators (Algorithm 1, DCGD-SHIFT):

  ``worker_grads.per_worker_grads``   line 5, "worker i computes
      g_i = grad f_i(x^k)" — one vmapped gradient per batch shard, the
      worker axis sharded over (pod x data).
  ``Q_i`` (the per-worker unbiased compressor, Def. 2) is applied by
      ``repro.core.shift_rules.worker_compress`` to the SHIFTED
      difference ``g_i - h_i`` (Def. 3: Q_{h_i}(g_i) = h_i + Q(g_i -
      h_i)), so what travels on the wire is the compressed residual.
  ``collectives.compressed_tree_mean``   lines 9-11, "master averages
      the received m_i" — the uplink aggregation in one of three wire
      formats: exact psum (``dense_mean``), correlated Rand-K with a
      shared pattern (``randk_shared_mean``: the aggregated message is
      K-dimensional), or the int8 ring/tree all-reduce
      (``q8_ring_tree_mean``).  The master's aggregated shift h^k is
      tracked incrementally in ``launch.train`` (h^{k+1} = h^k +
      alpha * m^k), so no uncompressed collective ever materializes.
  ``sharding``   not in the paper — the GSPMD layer that places
      parameters, optimizer moments, and worker-stacked shift state on
      the (pod, data, model) mesh.
"""

from repro.dist.collectives import (
    compressed_tree_mean,
    dense_mean,
    q8_ring_tree_mean,
    randk_shared_mean,
)
from repro.dist.sharding import (
    params_pspecs,
    validate_pspecs,
    worker_stacked_pspec,
)
from repro.dist.worker_grads import per_worker_grads, split_batch

__all__ = [
    "compressed_tree_mean",
    "dense_mean",
    "q8_ring_tree_mean",
    "randk_shared_mean",
    "params_pspecs",
    "validate_pspecs",
    "worker_stacked_pspec",
    "per_worker_grads",
    "split_batch",
]
