"""Parameter PartitionSpec derivation for the production mesh.

``params_pspecs`` pattern-matches the stable parameter NAMES produced by
``repro.models.layers`` (and the moe / mla / mamba2 / rwkv6 modules) and
assigns tensor-parallel specs over the "model" axis: column-parallel for
input projections (d, fused_out), row-parallel for output projections
(fused_in, d), expert-sharded for the 3-D MoE weights, vocab-sharded for
the embedding table.  Anything unmatched (norm scales, biases, small
LoRA factors, SSM scalars) stays replicated.

Leaves under a layer-stacked top-level key ("blocks", "dense_blocks",
"moe_blocks", "enc_blocks") carry a leading layer axis that is never
sharded — rules are written against the TRAILING dims and left-padded
with ``None``.

``validate_pspecs`` downgrades any dim whose mesh-axis product does not
divide the dim size (or whose axes are absent from the mesh) to
replicated, so every returned spec is legal on the given mesh by
construction.  ``worker_stacked_pspec`` prepends the worker axes
(pod x data) to a parameter spec for the ``(W, *shape)`` stacked
gradient / shift leaves.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

# Top-level keys whose subtrees are layer-stacked by vmapped init (the
# leading axis is the layer axis — see models.model._stack_init).
_STACKED_KEYS = {"blocks", "dense_blocks", "moe_blocks", "enc_blocks"}

# Column-parallel 2-D weights (d_in, fused_out) -> shard the output dim.
_COL_2D = {
    "wq", "wk", "wv", "wg", "wr", "w_gate", "w_up", "w_in", "w", "w_kr",
}
# Row-parallel 2-D weights (fused_in, d_out) -> shard the input dim.
_ROW_2D = {"wo", "w_down", "w_out"}
# Replicated by name regardless of rank (small / latent / router).
_REPLICATED = {"router", "w_lora_a", "w_lora_b", "w_dkv", "conv_w"}


def _path_names(path) -> list:
    return [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]


def _tail_spec(names, tail_shape) -> Tuple:
    """Spec for the unstacked (trailing) dims of one leaf."""
    name = names[-1]
    nd = len(tail_shape)
    parent = names[-2] if len(names) > 1 else ""

    if name in _REPLICATED or nd <= 1:
        return (None,) * nd
    if name == "table":  # embedding (V, D): vocab-sharded
        return ("model",) + (None,) * (nd - 1)
    if nd == 2:
        # rwkv channel-mix stores its down-projection under "wv" (f, d)
        if parent == "channel" and name == "wv":
            return ("model", None)
        if name in _ROW_2D:
            return ("model", None)
        if name in _COL_2D:
            return (None, "model")
        return (None,) * nd
    if nd == 3:
        if name in ("w_gate", "w_up", "w_down"):
            # MoE expert weights (E, d, f) / (E, f, d): shard experts
            return ("model", None, None)
        if name == "wo":
            # MLA output (H, dv, d): shard heads
            return ("model", None, None)
        if name in ("wq", "w_ukv"):
            # MLA projections (d|r, H, dh'): shard heads
            return (None, "model", None)
        return (None,) * nd
    return (None,) * nd


def params_pspecs(params, *, fsdp: bool = False):
    """PartitionSpecs for a params(-like) pytree, by parameter name.

    With ``fsdp=True`` the first still-replicated trailing dim of every
    >=2-D leaf is additionally sharded over "data" (ZeRO-3 / fully
    sharded storage); ``validate_pspecs`` downgrades whatever does not
    divide the mesh.
    """

    def one(path, leaf):
        names = _path_names(path)
        n_stack = 1 if (names and names[0] in _STACKED_KEYS) else 0
        shape = tuple(leaf.shape)
        tail = _tail_spec(names, shape[n_stack:])
        dims = (None,) * n_stack + tail
        if fsdp and len(shape) - n_stack >= 2:
            dims = list(dims)
            for i in range(n_stack, len(dims)):
                if dims[i] is None:
                    dims[i] = "data"
                    break
            dims = tuple(dims)
        return P(*dims)

    return jax.tree_util.tree_map_with_path(one, params)


def validate_pspecs(shapes, specs, mesh):
    """Downgrade spec dims that are illegal on ``mesh``.

    For every leaf dim: axes not present in the mesh are dropped; if the
    remaining axis-size product does not divide the dim size, the dim
    falls back to ``None`` (replicated).  The returned tree has the same
    structure as ``shapes`` with one legal ``PartitionSpec`` per leaf.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(leaf, sp):
        dims = list(tuple(sp)) + [None] * (len(leaf.shape) - len(tuple(sp)))
        out = []
        for size, ax in zip(leaf.shape, dims):
            if ax is None:
                out.append(None)
                continue
            axs = ax if isinstance(ax, tuple) else (ax,)
            axs = tuple(a for a in axs if a in sizes)
            n = 1
            for a in axs:
                n *= sizes[a]
            if not axs or size % n != 0:
                out.append(None)
            elif len(axs) == 1:
                out.append(axs[0])
            else:
                out.append(axs)
        return P(*out)

    return jax.tree_util.tree_map(
        one,
        shapes,
        specs,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P),
    )


def worker_stacked_pspec(mesh, inner_spec) -> P:
    """Spec for a worker-stacked leaf ``(W, *shape)``: the worker axes
    (pod x data) on the leading dim, ``inner_spec`` on the rest.  Any
    worker axis already appearing in ``inner_spec`` is stripped from it
    (an axis may shard only one dim)."""
    waxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def strip(ax):
        if ax is None:
            return None
        axs = ax if isinstance(ax, tuple) else (ax,)
        axs = tuple(a for a in axs if a not in waxes)
        if not axs:
            return None
        return axs if len(axs) > 1 else axs[0]

    inner = tuple(strip(a) for a in tuple(inner_spec))
    if not waxes:
        return P(None, *inner)
    return P(waxes if len(waxes) > 1 else waxes[0], *inner)
