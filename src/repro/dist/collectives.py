"""Codec-driven tree-mean collectives — the "send m_i to master, average"
line of Algorithm 1, in the wire formats the system supports.

Every payload format here is OWNED by a codec in ``repro.core.compressors``
(``encode``/``decode``/``wire_bits``); this module only moves payloads
around the mesh — it contains no compressor math of its own:

  ``dense_mean``         exact f32 mean (lowers to a plain psum under
                         GSPMD) — the no-compression baseline.
  ``randk_shared_mean``  correlated Rand-K: every worker runs
                         ``RandK(shared_pattern=True).encode`` with the
                         SAME per-step key, so the K-value payloads share
                         one pattern and aggregate by a payload mean; one
                         decode scatters the averaged values back.
                         Exactly K coordinates survive, unbiased over the
                         pattern draw.
  ``q8_ring_tree_mean``  ring all-reduce (reduce-scatter + all-gather)
                         whose hops forward ``Int8Stochastic`` payloads
                         (int8 block + f32 scale) over the mesh's worker
                         axes, with an optional quantized tree (psum)
                         stage across the ``pod`` axis.  The ring is
                         generic over any meta-free codec
                         (``_ring_allreduce_coded``); codecs that set
                         ``fused_ring`` (``kernels.q8ring.FusedQ8``)
                         take ``_ring_allreduce_fused`` instead, where
                         chunk gather + scale + int8 quantize are one
                         Pallas kernel per hop (``q8_ring_fused`` mode).

``compressed_tree_mean`` dispatches between them from an aggregation-mode
string or a ``CompressionConfig``; ``repro.comm.MeshChannel`` is the
higher-level entry point.  Every tree-level entry takes an optional
``leaf_indices`` — the GLOBAL positions of the given leaves in the full
gradient tree, so per-leaf keys stay stable when the overlap runtime
(``repro.comm.overlap``) reduces bucket subtrees independently.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.comm.wire import encode_meta_free, encode_workers
from repro.core.compressors import Compressor, Int8Stochastic, RandK

tmap = jax.tree_util.tree_map


def dense_mean(wtree):
    """Exact mean over the leading worker axis, leaf-wise."""
    return tmap(lambda a: jnp.mean(a, axis=0), wtree)


# ---------------------------------------------------------------------------
# Shared-pattern Rand-K
# ---------------------------------------------------------------------------


def randk_shared_mean(key: jax.Array, wtree, ratio: float, *,
                      leaf_indices: Optional[Sequence[int]] = None):
    """Mean of shared-pattern Rand-K messages (correlated sampling).

    Every worker encodes with the SAME per-leaf key, so
    ``RandK(shared_pattern=True)`` gives all workers one uniformly-random
    K-subset (K = round(ratio * d) per leaf, at least 1).  The per-worker
    payload is just the K kept values (the pattern is implied by the
    shared seed — it lives in ``meta`` and is never charged to the wire);
    the master averages payloads value-wise and decodes ONCE:

        mean_i C_shared(g_i) = decode(mean_i encode(g_i))

    (decode is linear in the values for a fixed pattern).  Unbiased over
    the pattern draw: E[(d/K) * mask] = 1 coordinatewise.
    """
    codec = RandK(q=ratio, shared_pattern=True)
    leaves, treedef = jax.tree_util.tree_flatten(wtree)
    idxs = _leaf_indices(leaves, leaf_indices)
    out = []
    for i, leaf in enumerate(leaves):
        lk = jax.random.fold_in(key, idxs[i])
        sds = jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)
        payload, meta = encode_workers(codec, lk, leaf)
        mean_payload = tmap(lambda v: jnp.mean(v, axis=0), payload)
        meta_one = tmap(lambda v: v[0], meta)  # identical across workers
        out.append(codec.decode(mean_payload, meta_one, sds))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Codec ring / tree all-reduce
# ---------------------------------------------------------------------------


# the meta-free encode guard lives in comm.wire now (shared with the
# Channel layer); kept under its old private name for callers/tests
_encode_meta_free = encode_meta_free


def _leaf_indices(leaves, leaf_indices) -> tuple:
    """Normalize/validate the global leaf positions for per-leaf keys."""
    if leaf_indices is None:
        return tuple(range(len(leaves)))
    if len(leaf_indices) != len(leaves):
        raise ValueError(
            f"leaf_indices has {len(leaf_indices)} entries for "
            f"{len(leaves)} leaves"
        )
    return tuple(int(i) for i in leaf_indices)


def _ring_schedule(key: jax.Array, chunks: jax.Array, axis: str, n: int, *,
                   encode_send, decode_add, decode):
    """THE ring all-reduce schedule, in one place.

    ``chunks`` is (n, ...) with one chunk per device position; both ring
    variants (generic coded, Pallas-fused) drive this same hop/ownership
    arithmetic through three hooks:

      ``encode_send(k, chunks, chunk_id)``  encode the rotating send
            chunk into a forwardable payload pytree.
      ``decode_add(payload, mine)``         dequantize + accumulate into
            the local (1, ...) chunk slice.
      ``decode(payload)``                   dequantize to a (1, ...) slice.

    Phase 1 — reduce-scatter: at hop t each device sends chunk
    ``(idx - t) % n`` (per-hop key ``fold_in(key, t)``) and accumulates
    what it receives into chunk ``(send_id - 1) % n``; after n-1 hops
    device i owns the fully reduced chunk ``(i + 1) % n``.  Phase 2 —
    all-gather: each owner's chunk is encoded ONCE (key ``n + 1``) and
    the payload forwarded verbatim, so every device decodes
    bit-identical values — the output is truly replicated over ``axis``.
    """
    idx = jax.lax.axis_index(axis)
    fwd = [(j, (j + 1) % n) for j in range(n)]

    def hop(payload):
        return tmap(lambda a: jax.lax.ppermute(a, axis, fwd), payload)

    for t in range(n - 1):
        send_id = (idx - t) % n
        payload = hop(encode_send(jax.random.fold_in(key, t), chunks,
                                  send_id))
        recv_id = (send_id - 1) % n
        mine = jax.lax.dynamic_slice_in_dim(chunks, recv_id, 1, axis=0)
        chunks = jax.lax.dynamic_update_slice_in_dim(
            chunks, decode_add(payload, mine), recv_id, axis=0
        )

    own_id = (idx + 1) % n
    payload = encode_send(jax.random.fold_in(key, n + 1), chunks, own_id)
    final = jnp.zeros_like(chunks)
    final = jax.lax.dynamic_update_slice_in_dim(
        final, decode(payload), own_id, axis=0
    )
    for t in range(n - 1):
        payload = hop(payload)
        recv_id = (idx - t) % n  # sender (idx-1) owned (idx - t) at hop t
        final = jax.lax.dynamic_update_slice_in_dim(
            final, decode(payload), recv_id, axis=0
        )
    return final


def _ring_allreduce_coded(key: jax.Array, x: jax.Array, axis: str, n: int,
                          codec: Compressor):
    """Ring all-reduce of ``x`` (sum) over mesh axis ``axis``, forwarding
    the CODEC'S ENCODED PAYLOAD on every hop (schedule in
    ``_ring_schedule``).

    The payload pytree is permuted leaf-wise, so this works for any
    codec whose decoder state travels entirely in the payload (empty
    ``meta`` — shared-seed side information cannot ride the ring).
    """
    if n == 1:
        return x
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    d = flat.shape[0]
    c = -(-d // n)  # chunk length, ceil
    chunks = jnp.pad(flat, (0, n * c - d)).reshape(n, c)
    sds = jax.ShapeDtypeStruct((1, c), jnp.float32)
    encode = functools.partial(_encode_meta_free, codec)

    final = _ring_schedule(
        key, chunks, axis, n,
        encode_send=lambda k, ch, cid: encode(
            k, jax.lax.dynamic_slice_in_dim(ch, cid, 1, axis=0)
        ),
        decode_add=lambda p, mine: mine + codec.decode(p, {}, sds),
        decode=lambda p: codec.decode(p, {}, sds),
    )
    return final.reshape(-1)[:d].reshape(shape)


def _ring_allreduce_fused(key: jax.Array, x: jax.Array, axis: str, n: int,
                          codec):
    """Ring all-reduce with the Pallas-fused q8 hop kernels.

    Same ``_ring_schedule``, but the per-hop pipeline — gather the
    rotating send chunk, compute tile scales, stochastic-round to int8 —
    is ONE kernel (``q8_quantize_chunk_3d``: the chunk id goes in via
    scalar prefetch, so no f32 chunk copy materializes), and the receive
    side is one fused dequant-accumulate pass.  ``codec`` is a
    ``kernels.q8ring.FusedQ8`` (blockwise scales; supplies block_rows /
    interpret).  Chunks are row-aligned to the (rows, 128) lane layout.
    """
    from repro.kernels.q8ring.kernel import (
        LANE,
        q8_dequant_add_2d,
        q8_quantize_chunk_3d,
    )
    from repro.kernels.q8ring.ops import q8_dequant, ring_chunk_layout

    if n == 1:
        return x
    shape = x.shape
    d = int(x.size)
    rows_c, block = ring_chunk_layout(d, n, codec.block_rows)
    flat = x.reshape(-1).astype(jnp.float32)
    chunks = jnp.pad(flat, (0, n * rows_c * LANE - d)).reshape(
        n, rows_c, LANE
    )
    interp = codec.run_interpret

    def encode_send(k, ch, cid):
        u = jax.random.uniform(k, (rows_c, LANE))
        return q8_quantize_chunk_3d(ch, u, cid, block_rows=block,
                                    interpret=interp)

    def decode_add(payload, mine):
        q, s = payload
        return q8_dequant_add_2d(q, s, mine[0], block_rows=block,
                                 interpret=interp)[None]

    def decode(payload):
        q, s = payload
        return q8_dequant(q, s, block=block, interpret=interp)[None]

    final = _ring_schedule(
        key, chunks, axis, n,
        encode_send=encode_send, decode_add=decode_add, decode=decode,
    )
    return final.reshape(-1)[:d].reshape(shape)


def q8_ring_tree_mean(
    key: jax.Array,
    tree,
    mesh,
    *,
    worker_axes: Sequence[str] = ("data",),
    pod_axis: Optional[str] = None,
    wspecs=None,
    codec: Compressor = Int8Stochastic(),
    leaf_indices: Optional[Sequence[int]] = None,
):
    """Quantized ring/tree mean over a worker-stacked tree on a sharded
    mesh, with ``Int8Stochastic`` payloads by default.

    Leaves are ``(W, ...)`` with the leading dim sharded over
    ``worker_axes`` (plus ``pod_axis``); each device sums its local
    worker rows in f32, ring-all-reduces the partial sums over each
    worker axis with encoded hops, then (multi-pod) runs one quantized
    tree (psum) stage across ``pod_axis``.  ``wspecs`` optionally gives
    the worker-stacked PartitionSpecs so inner-dim ("model") sharding is
    preserved through the shard_map — each model shard runs its own
    independent ring.  Codecs with ``fused_ring`` set (``FusedQ8``) run
    the Pallas-fused hop pipeline instead of the generic encoded ring.
    ``leaf_indices`` pins per-leaf keys to global tree positions so a
    bucket subtree reduces bit-identically to the same leaves inside the
    full tree (the overlap runtime's drained-sync contract).
    """
    waxes = tuple(worker_axes)
    all_axes = ((pod_axis,) if pod_axis else ()) + waxes
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    idxs = _leaf_indices(leaves, leaf_indices)
    ring = (_ring_allreduce_fused if getattr(codec, "fused_ring", False)
            else _ring_allreduce_coded)
    w_glob = [leaf.shape[0] for leaf in leaves]

    if wspecs is None:
        spec_leaves = [P(all_axes) for _ in leaves]
    else:
        # pair each value leaf with its spec (specs are tuple subclasses,
        # so flatten against the VALUE tree's structure), then force the
        # leading entry to the worker axes: W always divides their
        # product (n_workers == prod(worker axis sizes))
        spec_leaves = jax.tree_util.tree_leaves(
            tmap(lambda _, sp: sp, tree, wspecs),
            is_leaf=lambda x: isinstance(x, P),
        )
        spec_leaves = [P(all_axes, *tuple(sp)[1:]) for sp in spec_leaves]

    in_specs = tuple(spec_leaves)
    out_specs = tuple(P(*tuple(sp)[1:]) for sp in in_specs)
    pod_n = sizes.get(pod_axis, 1) if pod_axis else 1

    def local_fn(k, *ls):
        outs = []
        for i, x in enumerate(ls):
            lk = jax.random.fold_in(k, idxs[i])
            acc = jnp.sum(x.astype(jnp.float32), axis=0)
            for j, ax in enumerate(waxes):
                acc = ring(
                    jax.random.fold_in(lk, j), acc, ax, sizes[ax], codec
                )
            if pod_axis and pod_n > 1:
                payload = _encode_meta_free(
                    codec, jax.random.fold_in(lk, 101), acc
                )
                dec = codec.decode(
                    payload, {}, jax.ShapeDtypeStruct(acc.shape, jnp.float32)
                )
                acc = jax.lax.psum(dec, pod_axis)
            outs.append((acc / w_glob[i]).astype(x.dtype))
        return tuple(outs)

    out_leaves = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(),) + in_specs,
        out_specs=out_specs,
        check_rep=False,
    )(key, *leaves)
    return jax.tree_util.tree_unflatten(treedef, list(out_leaves))


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def compressed_tree_mean(
    wtree,
    mode,
    key: jax.Array,
    mesh=None,
    *,
    randk_q: float = 0.05,
    wspecs=None,
    leaf_indices: Optional[Sequence[int]] = None,
    q8_block_rows: Optional[int] = None,
):
    """Worker-mean of a stacked tree in the configured wire format.

    ``mode`` is an aggregation-mode string (``dense | randk_shared |
    q8_ring | q8_ring_fused``) or a ``CompressionConfig``, in which case
    its effective aggregation mode and ``randk_q`` fields are used (a
    disabled config and the ``ef21`` comm mode both aggregate densely;
    ``q8_ring_overlap`` aggregates ``q8_ring_fused``).
    ``q8_block_rows`` sets the fused codec's scale-block rows (None =
    the kernel default) — a knob the autotuner searches.  Prefer
    ``repro.comm.make_channel(...).reduce_mean`` in new code.
    """
    from repro.comm.channel import AGGREGATION_MODES, aggregation_mode_of

    given = getattr(mode, "comm_mode", mode)  # pre-normalization, for errors
    if hasattr(mode, "comm_mode"):  # CompressionConfig
        randk_q = mode.randk_q
    mode = aggregation_mode_of(mode)  # ef21/disabled normalize to dense
    if mode == "dense":
        return dense_mean(wtree)
    if mode == "randk_shared":
        return randk_shared_mean(key, wtree, randk_q,
                                 leaf_indices=leaf_indices)
    if mode in ("q8_ring", "q8_ring_fused"):
        if mesh is None:
            raise ValueError(f"{mode} needs a mesh")
        if mode == "q8_ring_fused":
            from repro.kernels.q8ring.ops import FusedQ8

            codec = (FusedQ8() if q8_block_rows is None
                     else FusedQ8(block_rows=q8_block_rows))
        else:
            codec = Int8Stochastic()
        waxes = tuple(a for a in ("data",) if a in mesh.axis_names)
        pod = "pod" if "pod" in mesh.axis_names else None
        return q8_ring_tree_mean(
            key, wtree, mesh, worker_axes=waxes, pod_axis=pod, wspecs=wspecs,
            codec=codec, leaf_indices=leaf_indices,
        )
    raise ValueError(
        f"unknown aggregation mode {mode!r} (given: {given!r}); "
        f"have {AGGREGATION_MODES}"
    )
