"""Codec-driven tree-mean collectives — the "send m_i to master, average"
line of Algorithm 1, in the wire formats the system supports.

Every payload format here is OWNED by a codec in ``repro.core.compressors``
(``encode``/``decode``/``wire_bits``); this module only moves payloads
around the mesh — it contains no compressor math of its own:

  ``dense_mean``         exact f32 mean (lowers to a plain psum under
                         GSPMD) — the no-compression baseline.
  ``randk_shared_mean``  correlated Rand-K: every worker runs
                         ``RandK(shared_pattern=True).encode`` with the
                         SAME per-step key, so the K-value payloads share
                         one pattern and aggregate by a payload mean; one
                         decode scatters the averaged values back.
                         Exactly K coordinates survive, unbiased over the
                         pattern draw.
  ``q8_ring_tree_mean``  ring all-reduce (reduce-scatter + all-gather)
                         whose hops forward ``Int8Stochastic`` payloads
                         (int8 block + f32 scale) over the mesh's worker
                         axes, with an optional quantized tree (psum)
                         stage across the ``pod`` axis.  The ring is
                         generic over any meta-free codec
                         (``_ring_allreduce_coded``).

``compressed_tree_mean`` dispatches between them from an aggregation-mode
string or a ``CompressionConfig``; ``repro.comm.MeshChannel`` is the
higher-level entry point.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.compressors import Compressor, Int8Stochastic, RandK

tmap = jax.tree_util.tree_map


def dense_mean(wtree):
    """Exact mean over the leading worker axis, leaf-wise."""
    return tmap(lambda a: jnp.mean(a, axis=0), wtree)


# ---------------------------------------------------------------------------
# Shared-pattern Rand-K
# ---------------------------------------------------------------------------


def randk_shared_mean(key: jax.Array, wtree, ratio: float):
    """Mean of shared-pattern Rand-K messages (correlated sampling).

    Every worker encodes with the SAME per-leaf key, so
    ``RandK(shared_pattern=True)`` gives all workers one uniformly-random
    K-subset (K = round(ratio * d) per leaf, at least 1).  The per-worker
    payload is just the K kept values (the pattern is implied by the
    shared seed — it lives in ``meta`` and is never charged to the wire);
    the master averages payloads value-wise and decodes ONCE:

        mean_i C_shared(g_i) = decode(mean_i encode(g_i))

    (decode is linear in the values for a fixed pattern).  Unbiased over
    the pattern draw: E[(d/K) * mask] = 1 coordinatewise.
    """
    codec = RandK(q=ratio, shared_pattern=True)
    leaves, treedef = jax.tree_util.tree_flatten(wtree)
    out = []
    for i, leaf in enumerate(leaves):
        lk = jax.random.fold_in(key, i)
        sds = jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)
        payload, meta = jax.vmap(codec.encode, in_axes=(None, 0))(lk, leaf)
        mean_payload = tmap(lambda v: jnp.mean(v, axis=0), payload)
        meta_one = tmap(lambda v: v[0], meta)  # identical across workers
        out.append(codec.decode(mean_payload, meta_one, sds))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Codec ring / tree all-reduce
# ---------------------------------------------------------------------------


def _encode_meta_free(codec: Compressor, key: jax.Array, block: jax.Array):
    """Encode for forwarded-payload transports (ring hops, the pod psum
    stage): the decoder sees ONLY the payload, so shared-seed side
    information in ``meta`` cannot travel — reject codecs that need it.
    """
    payload, meta = codec.encode(key, block)
    if jax.tree_util.tree_leaves(meta):
        raise ValueError(
            f"{type(codec).__name__} carries decoder state in meta; "
            "quantized ring/tree stages forward payloads only "
            "(meta must be empty)"
        )
    return payload


def _ring_allreduce_coded(key: jax.Array, x: jax.Array, axis: str, n: int,
                          codec: Compressor):
    """Ring all-reduce of ``x`` (sum) over mesh axis ``axis``, forwarding
    the CODEC'S ENCODED PAYLOAD on every hop: reduce-scatter then
    all-gather, both with compressed payloads.

    The payload pytree is permuted leaf-wise, so this works for any
    codec whose decoder state travels entirely in the payload (empty
    ``meta`` — shared-seed side information cannot ride the ring).

    In the all-gather phase each finished chunk is encoded ONCE by its
    owner and the payload is forwarded verbatim, so every device decodes
    bit-identical values — the output is truly replicated over ``axis``.
    """
    if n == 1:
        return x
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    d = flat.shape[0]
    c = -(-d // n)  # chunk length, ceil
    flat = jnp.pad(flat, (0, n * c - d))
    chunks = flat.reshape(n, c)
    idx = jax.lax.axis_index(axis)
    fwd = [(j, (j + 1) % n) for j in range(n)]
    sds = jax.ShapeDtypeStruct((1, c), jnp.float32)

    encode = functools.partial(_encode_meta_free, codec)

    def hop(payload):
        return tmap(lambda a: jax.lax.ppermute(a, axis, fwd), payload)

    # Phase 1 — reduce-scatter: after n-1 hops, device i owns the fully
    # reduced chunk (i + 1) % n.
    for t in range(n - 1):
        send_id = (idx - t) % n
        block = jax.lax.dynamic_slice_in_dim(chunks, send_id, 1, axis=0)
        payload = hop(encode(jax.random.fold_in(key, t), block))
        recv_id = (send_id - 1) % n
        mine = jax.lax.dynamic_slice_in_dim(chunks, recv_id, 1, axis=0)
        chunks = jax.lax.dynamic_update_slice_in_dim(
            chunks, mine + codec.decode(payload, {}, sds), recv_id, axis=0
        )

    # Phase 2 — all-gather: circulate each owner's chunk, encoded once.
    own_id = (idx + 1) % n
    own = jax.lax.dynamic_slice_in_dim(chunks, own_id, 1, axis=0)
    payload = encode(jax.random.fold_in(key, n + 1), own)
    final = jnp.zeros_like(chunks)
    final = jax.lax.dynamic_update_slice_in_dim(
        final, codec.decode(payload, {}, sds), own_id, axis=0
    )
    for t in range(n - 1):
        payload = hop(payload)
        recv_id = (idx - t) % n  # sender (idx-1) owned (idx - t) at hop t
        final = jax.lax.dynamic_update_slice_in_dim(
            final, codec.decode(payload, {}, sds), recv_id, axis=0
        )
    return final.reshape(-1)[:d].reshape(shape)


def q8_ring_tree_mean(
    key: jax.Array,
    tree,
    mesh,
    *,
    worker_axes: Sequence[str] = ("data",),
    pod_axis: Optional[str] = None,
    wspecs=None,
    codec: Compressor = Int8Stochastic(),
):
    """Quantized ring/tree mean over a worker-stacked tree on a sharded
    mesh, with ``Int8Stochastic`` payloads by default.

    Leaves are ``(W, ...)`` with the leading dim sharded over
    ``worker_axes`` (plus ``pod_axis``); each device sums its local
    worker rows in f32, ring-all-reduces the partial sums over each
    worker axis with encoded hops, then (multi-pod) runs one quantized
    tree (psum) stage across ``pod_axis``.  ``wspecs`` optionally gives
    the worker-stacked PartitionSpecs so inner-dim ("model") sharding is
    preserved through the shard_map — each model shard runs its own
    independent ring.
    """
    waxes = tuple(worker_axes)
    all_axes = ((pod_axis,) if pod_axis else ()) + waxes
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    w_glob = [leaf.shape[0] for leaf in leaves]

    if wspecs is None:
        spec_leaves = [P(all_axes) for _ in leaves]
    else:
        # pair each value leaf with its spec (specs are tuple subclasses,
        # so flatten against the VALUE tree's structure), then force the
        # leading entry to the worker axes: W always divides their
        # product (n_workers == prod(worker axis sizes))
        spec_leaves = jax.tree_util.tree_leaves(
            tmap(lambda _, sp: sp, tree, wspecs),
            is_leaf=lambda x: isinstance(x, P),
        )
        spec_leaves = [P(all_axes, *tuple(sp)[1:]) for sp in spec_leaves]

    in_specs = tuple(spec_leaves)
    out_specs = tuple(P(*tuple(sp)[1:]) for sp in in_specs)
    pod_n = sizes.get(pod_axis, 1) if pod_axis else 1

    def local_fn(k, *ls):
        outs = []
        for i, x in enumerate(ls):
            lk = jax.random.fold_in(k, i)
            acc = jnp.sum(x.astype(jnp.float32), axis=0)
            for j, ax in enumerate(waxes):
                acc = _ring_allreduce_coded(
                    jax.random.fold_in(lk, j), acc, ax, sizes[ax], codec
                )
            if pod_axis and pod_n > 1:
                payload = _encode_meta_free(
                    codec, jax.random.fold_in(lk, 101), acc
                )
                dec = codec.decode(
                    payload, {}, jax.ShapeDtypeStruct(acc.shape, jnp.float32)
                )
                acc = jax.lax.psum(dec, pod_axis)
            outs.append((acc / w_glob[i]).astype(x.dtype))
        return tuple(outs)

    out_leaves = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(),) + in_specs,
        out_specs=out_specs,
        check_rep=False,
    )(key, *leaves)
    return jax.tree_util.tree_unflatten(treedef, list(out_leaves))


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def compressed_tree_mean(
    wtree,
    mode,
    key: jax.Array,
    mesh=None,
    *,
    randk_q: float = 0.05,
    wspecs=None,
):
    """Worker-mean of a stacked tree in the configured wire format.

    ``mode`` is an aggregation-mode string (``dense | randk_shared |
    q8_ring``) or a ``CompressionConfig``, in which case its effective
    aggregation mode and ``randk_q`` fields are used (a disabled config
    and the ``ef21`` comm mode both aggregate densely).  Prefer
    ``repro.comm.make_channel(...).reduce_mean`` in new code.
    """
    from repro.comm.channel import aggregation_mode_of

    if hasattr(mode, "comm_mode"):  # CompressionConfig
        randk_q = mode.randk_q
    mode = aggregation_mode_of(mode)  # ef21/disabled normalize to dense
    if mode == "dense":
        return dense_mean(wtree)
    if mode == "randk_shared":
        return randk_shared_mean(key, wtree, randk_q)
    if mode == "q8_ring":
        if mesh is None:
            raise ValueError("q8_ring needs a mesh")
        waxes = tuple(a for a in ("data",) if a in mesh.axis_names)
        pod = "pod" if "pod" in mesh.axis_names else None
        return q8_ring_tree_mean(
            key, wtree, mesh, worker_axes=waxes, pod_axis=pod, wspecs=wspecs
        )
    raise ValueError(f"unknown comm mode {mode!r}")
