"""Compressed tree-mean collectives — the "send m_i to master, average"
line of Algorithm 1, in the three wire formats the system supports.

All collectives consume a *worker-stacked* pytree (leaves
``(W, *param.shape)``) and return the mean over the worker axis:

  ``dense_mean``         exact f32 mean (lowers to a plain psum under
                         GSPMD) — the no-compression baseline.
  ``randk_shared_mean``  correlated Rand-K (all workers share one
                         sparsity pattern per step): the aggregated
                         message is K-dimensional, unbiased, and exactly
                         K coordinates survive.  Matches
                         ``RandK(shared_pattern=True)`` applied per
                         worker followed by an exact mean.
  ``q8_ring_tree_mean``  int8-quantized ring all-reduce (reduce-scatter
                         + all-gather with int8 payloads and per-chunk
                         scales, stochastic rounding) over the mesh's
                         worker axes, with an optional quantized tree
                         (psum) stage across the ``pod`` axis.

``compressed_tree_mean`` dispatches between them from a
``CompressionConfig`` (or its ``comm_mode`` string).
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

tmap = jax.tree_util.tree_map


def dense_mean(wtree):
    """Exact mean over the leading worker axis, leaf-wise."""
    return tmap(lambda a: jnp.mean(a, axis=0), wtree)


# ---------------------------------------------------------------------------
# Shared-pattern Rand-K
# ---------------------------------------------------------------------------


def randk_shared_mean(key: jax.Array, wtree, ratio: float):
    """Mean of shared-pattern Rand-K messages (correlated sampling).

    Every worker keeps the SAME uniformly-random K-subset (K =
    round(ratio * d) per leaf, at least 1) scaled by d/K, so the
    aggregated message is supported on exactly K coordinates and the
    masts cancel into one mask applied to the exact mean:

        mean_i C_shared(g_i) = (d/K) * mask * mean_i g_i

    Unbiased over the pattern draw: E[(d/K) * mask] = 1 coordinatewise.
    """
    leaves, treedef = jax.tree_util.tree_flatten(wtree)
    out = []
    for i, leaf in enumerate(leaves):
        lk = jax.random.fold_in(key, i)
        w = leaf.shape[0]
        inner = leaf.shape[1:]
        d = int(math.prod(inner)) if inner else 1
        k = max(1, int(round(ratio * d)))
        idx = jax.random.permutation(lk, d)[:k]
        mask = jnp.zeros((d,), leaf.dtype).at[idx].set(1)
        mean = jnp.mean(leaf.reshape(w, d), axis=0)
        out.append((mean * mask * (d / k)).reshape(inner))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# int8 ring / tree all-reduce
# ---------------------------------------------------------------------------


def _q8(key: jax.Array, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor max-scale int8 with unbiased stochastic rounding.

    Returns ``(payload int8, scale f32)``; ``payload * scale``
    reconstructs x up to quantization noise.  The scale floor keeps
    tiny tensors off the subnormal path (would flush to 0 -> NaN).
    """
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    y = xf / scale
    lo = jnp.floor(y)
    u = jax.random.uniform(key, x.shape)
    q = (lo + (u < (y - lo)).astype(jnp.float32)).astype(jnp.int8)
    return q, scale


def _ring_allreduce_q8(key: jax.Array, x: jax.Array, axis: str, n: int):
    """Ring all-reduce of ``x`` (sum) over mesh axis ``axis`` with int8
    hops: reduce-scatter then all-gather, both with quantized payloads.

    In the all-gather phase each finished chunk is quantized ONCE by its
    owner and the (int8, scale) pair is forwarded verbatim, so every
    device decodes bit-identical values — the output is truly
    replicated over ``axis``.
    """
    if n == 1:
        return x
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    d = flat.shape[0]
    c = -(-d // n)  # chunk length, ceil
    flat = jnp.pad(flat, (0, n * c - d))
    chunks = flat.reshape(n, c)
    idx = jax.lax.axis_index(axis)
    fwd = [(j, (j + 1) % n) for j in range(n)]

    # Phase 1 — reduce-scatter: after n-1 hops, device i owns the fully
    # reduced chunk (i + 1) % n.
    for t in range(n - 1):
        send_id = (idx - t) % n
        payload = jax.lax.dynamic_slice_in_dim(chunks, send_id, 1, axis=0)
        q, s = _q8(jax.random.fold_in(key, t), payload)
        q = jax.lax.ppermute(q, axis, fwd)
        s = jax.lax.ppermute(s, axis, fwd)
        recv_id = (send_id - 1) % n
        mine = jax.lax.dynamic_slice_in_dim(chunks, recv_id, 1, axis=0)
        chunks = jax.lax.dynamic_update_slice_in_dim(
            chunks, mine + q.astype(jnp.float32) * s, recv_id, axis=0
        )

    # Phase 2 — all-gather: circulate each owner's chunk, quantized once.
    own_id = (idx + 1) % n
    own = jax.lax.dynamic_slice_in_dim(chunks, own_id, 1, axis=0)
    q, s = _q8(jax.random.fold_in(key, n + 1), own)
    final = jnp.zeros_like(chunks)
    final = jax.lax.dynamic_update_slice_in_dim(
        final, q.astype(jnp.float32) * s, own_id, axis=0
    )
    for t in range(n - 1):
        q = jax.lax.ppermute(q, axis, fwd)
        s = jax.lax.ppermute(s, axis, fwd)
        recv_id = (idx - t) % n  # sender (idx-1) owned (idx - t) at hop t
        final = jax.lax.dynamic_update_slice_in_dim(
            final, q.astype(jnp.float32) * s, recv_id, axis=0
        )
    return final.reshape(-1)[:d].reshape(shape)


def q8_ring_tree_mean(
    key: jax.Array,
    tree,
    mesh,
    *,
    worker_axes: Sequence[str] = ("data",),
    pod_axis: Optional[str] = None,
    wspecs=None,
):
    """int8 ring/tree mean over a worker-stacked tree on a sharded mesh.

    Leaves are ``(W, ...)`` with the leading dim sharded over
    ``worker_axes`` (plus ``pod_axis``); each device sums its local
    worker rows in f32, ring-all-reduces the partial sums over each
    worker axis with int8 hops, then (multi-pod) runs one quantized
    tree (psum) stage across ``pod_axis``.  ``wspecs`` optionally gives
    the worker-stacked PartitionSpecs so inner-dim ("model") sharding is
    preserved through the shard_map — each model shard runs its own
    independent ring.
    """
    waxes = tuple(worker_axes)
    all_axes = ((pod_axis,) if pod_axis else ()) + waxes
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    w_glob = [leaf.shape[0] for leaf in leaves]

    if wspecs is None:
        spec_leaves = [P(all_axes) for _ in leaves]
    else:
        # pair each value leaf with its spec (specs are tuple subclasses,
        # so flatten against the VALUE tree's structure), then force the
        # leading entry to the worker axes: W always divides their
        # product (n_workers == prod(worker axis sizes))
        spec_leaves = jax.tree_util.tree_leaves(
            tmap(lambda _, sp: sp, tree, wspecs),
            is_leaf=lambda x: isinstance(x, P),
        )
        spec_leaves = [P(all_axes, *tuple(sp)[1:]) for sp in spec_leaves]

    in_specs = tuple(spec_leaves)
    out_specs = tuple(P(*tuple(sp)[1:]) for sp in in_specs)
    pod_n = sizes.get(pod_axis, 1) if pod_axis else 1

    def local_fn(k, *ls):
        outs = []
        for i, x in enumerate(ls):
            lk = jax.random.fold_in(k, i)
            acc = jnp.sum(x.astype(jnp.float32), axis=0)
            for j, ax in enumerate(waxes):
                acc = _ring_allreduce_q8(
                    jax.random.fold_in(lk, j), acc, ax, sizes[ax]
                )
            if pod_axis and pod_n > 1:
                q, s = _q8(jax.random.fold_in(lk, 101), acc)
                acc = jax.lax.psum(q.astype(jnp.float32) * s, pod_axis)
            outs.append((acc / w_glob[i]).astype(x.dtype))
        return tuple(outs)

    out_leaves = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(),) + in_specs,
        out_specs=out_specs,
        check_rep=False,
    )(key, *leaves)
    return jax.tree_util.tree_unflatten(treedef, list(out_leaves))


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def compressed_tree_mean(
    wtree,
    mode,
    key: jax.Array,
    mesh=None,
    *,
    randk_q: float = 0.05,
    wspecs=None,
):
    """Worker-mean of a stacked tree in the configured wire format.

    ``mode`` is a comm-mode string (``dense | randk_shared | q8_ring``)
    or a ``CompressionConfig``, in which case its ``comm_mode`` and
    ``randk_q`` fields are used (a disabled config means dense).
    """
    if hasattr(mode, "comm_mode"):  # CompressionConfig
        cfg = mode
        randk_q = cfg.randk_q
        mode = cfg.comm_mode if cfg.enabled else "dense"
    if mode == "dense":
        return dense_mean(wtree)
    if mode == "randk_shared":
        return randk_shared_mean(key, wtree, randk_q)
    if mode == "q8_ring":
        if mesh is None:
            raise ValueError("q8_ring needs a mesh")
        waxes = tuple(a for a in ("data",) if a in mesh.axis_names)
        pod = "pod" if "pod" in mesh.axis_names else None
        return q8_ring_tree_mean(
            key, wtree, mesh, worker_axes=waxes, pod_axis=pod, wspecs=wspecs
        )
    raise ValueError(f"unknown comm mode {mode!r}")
