"""Per-worker gradient substrate — "worker i computes grad f_i" (Alg. 1 l.5).

The paper's workers are realized as slices of the global batch: worker i
owns rows ``[i*B/W, (i+1)*B/W)``.  ``split_batch`` reshapes the batch to
a leading worker axis and ``per_worker_grads`` vmaps the loss gradient
over it, returning worker-stacked gradient leaves ``(W, *param.shape)``
whose mean over axis 0 equals the full-batch gradient exactly (each
worker's loss is the mean over its own rows, and all shards are equal
size).

On the production mesh the worker axis is sharded ``P(("pod","data"))``,
so the vmap body runs as W parallel per-device gradient computations and
the stacked leaves never materialize unsharded — the compressed
collectives in ``repro.dist.collectives`` consume them in place.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


def split_batch(batch, w: int):
    """Reshape every leaf's leading batch dim ``B`` to ``(W, B/W, ...)``.

    Rows are assigned contiguously, so worker i's shard is exactly
    ``leaf[i*B/W:(i+1)*B/W]`` — the reshape is a pure relabeling and
    round-trips losslessly.
    """

    def one(a):
        b = a.shape[0]
        if b % w:
            raise ValueError(
                f"batch dim {b} not divisible by {w} workers (leaf shape "
                f"{a.shape})"
            )
        return a.reshape(w, b // w, *a.shape[1:])

    return tmap(one, batch)


def per_worker_grads(
    loss_fn: Callable, params, wbatch
) -> Tuple[Any, jax.Array, Any]:
    """Stacked per-worker gradients of ``loss_fn(params, batch_i)``.

    ``loss_fn`` must return ``(loss, metrics)`` (has_aux convention, as
    ``repro.models.model.train_loss`` does).  Returns
    ``(wgrads, loss, metrics)`` where ``wgrads`` leaves are shaped
    ``(W, *param.shape)``, ``loss`` is the mean worker loss (== the
    full-batch loss for mean-reduced losses over equal shards), and
    ``metrics`` leaves are averaged over the worker axis.
    """

    def one(b):
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
        return g, loss, aux

    wgrads, losses, aux = jax.vmap(one)(wbatch)
    loss = jnp.mean(losses)
    metrics = tmap(lambda a: jnp.mean(a, axis=0), aux)
    return wgrads, loss, metrics
