"""Span API: profiler annotations inside jit + host wall-clock spans.

Two kinds of time live in a train step and they need different tools:

  * DEVICE time inside ``jit`` cannot be measured from Python (the host
    returns before the computation runs).  ``span(name)`` therefore
    wraps the region in ``jax.named_scope`` + ``jax.profiler.
    TraceAnnotation`` — both are TRACE-TIME context managers: they tag
    the emitted HLO / profiler timeline and add ZERO runtime ops, so
    annotating a phase can never change the math or force a recompile
    (pinned by the no-extra-compilation test in ``tests/test_obs.py``).
  * HOST time around jit boundaries (encode a delta, drain a reduction,
    apply a publish) is real wall clock.  When a ``SpanRecorder`` is
    active and we are NOT inside a trace, ``span`` also accumulates
    ``perf_counter`` durations into it.  With no recorder active the
    host path is a single ``is None`` check — the obs-off cost contract.

``StampRecorder`` is the raw begin/end-timestamp variant the overlap
channel uses: ``AsyncChannel.reduce_start``/``finish`` stamp their call
windows so ``repro.tune.measure.measure_overlap_hide`` can derive a
MEASURED hide fraction from the same handles the runtime schedules.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict, List, Optional, Tuple

import jax

#: the active host-span recorder (None = host timing off; module-level
#: because spans are annotated at call sites that never see the driver)
_ACTIVE: Optional["SpanRecorder"] = None


def _host_clock_ok() -> bool:
    """True when a perf_counter span is meaningful — i.e. we are not
    inside a jax trace (where Python time measures TRACING, not the
    computation)."""
    try:
        return jax.core.trace_state_clean()
    except Exception:  # noqa: BLE001 — newer jax moved/removed the probe
        return True


class SpanRecorder:
    """Accumulated ``{name: (count, total_seconds)}`` host spans."""

    def __init__(self):
        self.spans: Dict[str, List[float]] = {}

    def add(self, name: str, seconds: float) -> None:
        cur = self.spans.setdefault(name, [0, 0.0])
        cur[0] += 1
        cur[1] += seconds

    def snapshot(self) -> dict:
        """{name: {count, total_s, mean_s}} — drops into a record."""
        return {
            name: {
                "count": int(c),
                "total_s": float(t),
                "mean_s": float(t) / c if c else None,
            }
            for name, (c, t) in self.spans.items()
        }

    def clear(self) -> None:
        self.spans.clear()


@contextmanager
def recording(recorder: SpanRecorder):
    """Activate ``recorder`` for host spans within the block."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, recorder
    try:
        yield recorder
    finally:
        _ACTIVE = prev


def active_recorder() -> Optional[SpanRecorder]:
    return _ACTIVE


@contextmanager
def span(name: str):
    """Annotate one phase (see module docstring).

    Safe anywhere: inside jit it is pure trace metadata; outside jit it
    additionally wall-clocks into the active ``SpanRecorder`` (if any).
    """
    rec = _ACTIVE
    timed = rec is not None and _host_clock_ok()
    t0 = time.perf_counter() if timed else 0.0
    with jax.profiler.TraceAnnotation(name), jax.named_scope(name):
        yield
    if timed:
        rec.add(name, time.perf_counter() - t0)


class StampRecorder:
    """Raw ``(name, t_begin, t_end)`` call-window stamps.

    The overlap channel's ``reduce_start``/``finish`` stamp here (host
    side only — stamping is skipped during tracing, so attaching a
    recorder never perturbs a jitted pipeline).
    """

    def __init__(self):
        self.events: List[Tuple[str, float, float]] = []

    @contextmanager
    def stamp(self, name: str):
        if not _host_clock_ok():
            yield
            return
        t0 = time.perf_counter()
        yield
        self.events.append((name, t0, time.perf_counter()))

    def clear(self) -> None:
        self.events.clear()

    def windows(self, name: str) -> List[Tuple[float, float]]:
        return [(t0, t1) for n, t0, t1 in self.events if n == name]

    def total(self, name: str) -> float:
        return sum(t1 - t0 for t0, t1 in self.windows(name))
