"""Measured distortion probes: the paper's quantities on real traffic.

The whole argument of shifted compression is a *measurable* claim —
``E||Q(v) - v||^2 <= omega ||v||^2`` for the unbiased class U(omega),
and the shifted vector ``g - h`` shrinks while the plain gradient does
not.  Everything before this module trusted the analytic certificates
(``codec.omega(d)`` / ``codec.delta(d)``); here the same quantities are
measured over the traffic a wire actually carries:

* ``omega_hat`` — size-weighted mean of the per-leaf realized variance
  ratio ``||Q(v)-v||^2 / ||v||^2``.  The weighting mirrors
  ``tune.estimate_omega``'s d-weighted analytic mean, so the two
  numbers are directly comparable (and ``omega_hat <= omega`` must hold
  in expectation for any honest U(omega) codec).
* ``nmse`` — global ``sum err^2 / sum norm^2`` over the whole tree.
  Defined for biased (contractive) codecs too, where no omega exists.

All math is pure jnp on concrete trees, so the probes compose under
``jax.jit`` as diagnostics; probe keys are derived with the wire
layer's own ``leaf_key`` fold (never by splitting trainer state), which
is what keeps ``diag=True`` runs bit-exact with ``diag=False``.  Comm
imports stay lazy so ``repro.obs`` remains a leaf package.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

__all__ = [
    "array_distortion",
    "tree_distortion",
    "distortion_floats",
]

#: guard for 0/0 — an all-zero probe tree has zero distortion by fiat
_EPS = 1e-30


def _sq(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.float32)
    return jnp.sum(x * x)


def array_distortion(codec, key: jax.Array, data: jax.Array, *,
                     topology: str = "allreduce") -> Dict[str, jax.Array]:
    """Distortion of ONE wire payload through the codec's real path.

    ``data`` is worker-stacked ``(W, ...)`` for the allreduce uplink
    (each row rides its own ``worker_keys`` row, exactly like
    ``Channel.uplink``); any other topology encodes the block whole,
    the way the forwarded-payload wires (moe / act / model) do.

    Returns f32 scalars ``{"err_sq", "norm_sq"}`` — callers fold them
    into ``omega_hat`` / ``nmse`` (see ``tree_distortion``).
    """
    from repro.comm.wire import encode_decode_workers

    if topology == "allreduce":
        _, decoded = encode_decode_workers(codec, key, data)
    else:
        payload, meta = codec.encode(key, data)
        decoded = codec.decode(
            payload, meta, jax.ShapeDtypeStruct(data.shape, data.dtype)
        )
    err_sq = _sq(decoded.astype(jnp.float32) - data.astype(jnp.float32))
    return {"err_sq": err_sq, "norm_sq": _sq(data)}


def tree_distortion(codec, key: jax.Array, wtree: Any, *,
                    topology: str = "allreduce") -> Dict[str, jax.Array]:
    """Measured ``omega_hat`` / ``nmse`` over a worker-stacked pytree.

    Per-leaf keys come from ``leaf_key(key, i)`` over the global leaf
    position — the same derivation every wire consumer shares — so the
    probe sees the identical encode randomness a real round would.

    Returns f32 scalars:

    * ``omega_hat`` — sum_i d_i * (err_i / norm_i) / sum_i d_i with
      d_i the per-worker leaf size (empty-norm leaves contribute 0);
    * ``nmse``      — sum_i err_i / sum_i norm_i;
    * ``err_sq`` / ``norm_sq`` — the raw global sums.
    """
    from repro.comm.wire import leaf_key

    leaves = jax.tree_util.tree_leaves(wtree)
    if not leaves:
        raise ValueError("tree_distortion of an empty tree")
    ratio_acc = jnp.zeros((), jnp.float32)
    err_acc = jnp.zeros((), jnp.float32)
    norm_acc = jnp.zeros((), jnp.float32)
    d_total = 0
    for i, leaf in enumerate(leaves):
        shape = leaf.shape[1:] if topology == "allreduce" else leaf.shape
        d = int(math.prod(shape)) if shape else 1
        out = array_distortion(codec, leaf_key(key, i), leaf,
                               topology=topology)
        ratio = jnp.where(out["norm_sq"] > 0.0,
                          out["err_sq"] / jnp.maximum(out["norm_sq"], _EPS),
                          0.0)
        ratio_acc = ratio_acc + d * ratio
        err_acc = err_acc + out["err_sq"]
        norm_acc = norm_acc + out["norm_sq"]
        d_total += d
    omega_hat = ratio_acc / d_total
    nmse = jnp.where(norm_acc > 0.0,
                     err_acc / jnp.maximum(norm_acc, _EPS), 0.0)
    return {"omega_hat": omega_hat, "nmse": nmse,
            "err_sq": err_acc, "norm_sq": norm_acc}


def distortion_floats(out: Dict[str, Any]) -> Dict[str, float]:
    """Host-side view of a distortion dict (floats, obs-record ready)."""
    return {k: float(v) for k, v in out.items()}
