"""Record sinks: rotating strict-JSONL on disk, memory, null.

Every emitter in the repo (trainer driver, serving bridge, dryrun,
benches) writes through a sink, and every sink enforces the same
discipline: records are sanitized (``sanitize_tree``) and validated
(``validate_record``) BEFORE they are serialized with
``allow_nan=False`` — an artifact a downstream RFC 8259 parser rejects
is a bug here, not there.

``JsonlSink`` rotates by size: when the live file would exceed
``rotate_bytes`` the existing files shift ``path -> path.1 -> path.2``
up to ``keep`` generations (newest rotation is ``.1``).  ``MemorySink``
retains records in order — the serving bridge's stats and the tests
read from it.  ``NullSink`` swallows everything (the obs-off path).

``write_strict_json`` is the one-shot whole-artifact writer the
``BENCH_*.json`` files share (``benchmarks/common.write_bench_json``
delegates here).
"""

from __future__ import annotations

import json
import os
from typing import List, Optional, Tuple

from repro.obs.metrics import sanitize_tree, validate_record


class NullSink:
    """Swallows every record — the disabled-observability path."""

    def emit(self, rec: dict) -> None:
        pass

    def close(self) -> None:
        pass


class MemorySink:
    """Keeps validated records in order (tests, serving-bridge stats)."""

    def __init__(self):
        self.records: List[dict] = []

    def emit(self, rec: dict) -> None:
        self.records.append(validate_record(sanitize_tree(rec)))

    def close(self) -> None:
        pass

    def by_kind(self, kind: str) -> List[dict]:
        return [r for r in self.records if r.get("kind") == kind]

    def events(self, name: Optional[str] = None) -> List[dict]:
        return [r for r in self.by_kind("event")
                if name is None or r.get("name") == name]


class TeeSink:
    """Fans one emit out to several sinks (the serving bridge keeps a
    MemorySink for its stats AND forwards to the run's JSONL sink)."""

    def __init__(self, *sinks):
        self.sinks = tuple(s for s in sinks if s is not None)

    def emit(self, rec: dict) -> None:
        for s in self.sinks:
            s.emit(rec)

    def close(self) -> None:
        for s in self.sinks:
            s.close()


class JsonlSink:
    """Append-only strict-JSONL file with size rotation (docstring)."""

    def __init__(self, path: str, *, rotate_bytes: int = 64 << 20,
                 keep: int = 3):
        if rotate_bytes <= 0:
            raise ValueError(
                f"rotate_bytes must be positive, got {rotate_bytes}"
            )
        self.path = path
        self.rotate_bytes = rotate_bytes
        self.keep = max(1, keep)
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        self._f = open(path, "a")
        self._nbytes = self._f.tell()

    def _rotate(self) -> None:
        self._f.close()
        oldest = f"{self.path}.{self.keep}"
        if os.path.exists(oldest):
            os.remove(oldest)
        for i in range(self.keep - 1, 0, -1):
            src = f"{self.path}.{i}"
            if os.path.exists(src):
                os.replace(src, f"{self.path}.{i + 1}")
        os.replace(self.path, f"{self.path}.1")
        self._f = open(self.path, "a")
        self._nbytes = 0

    def emit(self, rec: dict) -> None:
        rec = validate_record(sanitize_tree(rec))
        line = json.dumps(rec, sort_keys=True, allow_nan=False) + "\n"
        if self._nbytes and self._nbytes + len(line) > self.rotate_bytes:
            self._rotate()
        self._f.write(line)
        self._f.flush()
        self._nbytes += len(line)

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def read_jsonl(path: str, *, validate: bool = True) -> List[dict]:
    """Load one JSONL file; with ``validate`` every record must pass the
    schema check (the CI ``--check`` path reads through here)."""
    out: List[dict] = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i}: not JSON ({e})") from e
            if validate:
                try:
                    validate_record(rec)
                except ValueError as e:
                    raise ValueError(f"{path}:{i}: {e}") from e
            out.append(rec)
    return out


def check_jsonl(path: str) -> Tuple[int, List[str]]:
    """Schema-check every line: ``(n_valid, errors)``.  Unlike
    ``read_jsonl`` this collects ALL failures (CI prints them in one
    pass instead of dying on the first)."""
    n_valid = 0
    errors: List[str] = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                validate_record(json.loads(line))
                n_valid += 1
            except (json.JSONDecodeError, ValueError) as e:
                errors.append(f"{path}:{i}: {e}")
    return n_valid, errors


def write_strict_json(path: str, obj) -> str:
    """Whole-artifact strict-JSON writer (sanitize, then
    ``allow_nan=False`` as the backstop)."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(sanitize_tree(obj), f, indent=2, sort_keys=True,
                  allow_nan=False)
    return path
