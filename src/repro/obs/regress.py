"""The bench regression gate: current BENCH_*.json vs a recorded baseline.

``python -m repro.obs.regress --baseline experiments/obs/baseline.json
BENCH_*.json`` compares every artifact's flattened metrics
(``repro.obs.history``) against the baseline with PER-CLASS tolerance
bands and exits non-zero on any violation — the CI gate that makes a
silent perf/quality regression impossible:

* **timing** metrics (``*_s``, ``*time*``, ``*elapsed*``) — one-sided:
  only a slowdown beyond ``--timing_rtol`` (default +15%) violates;
  getting faster never does.  The COMMITTED baseline strips timings by
  default (``freeze``): CPU CI runners are too noisy to gate absolute
  times across machines, so CI proves the timing band works by freezing
  a same-run baseline and re-checking with ``--inject`` (which scales
  the current timing metrics — a synthetic regression the gate MUST
  catch).
* **structural** metrics (bits, bytes, counts, steps, tokens...) —
  two-sided ``--structural_rtol`` (default 1%): wire accounting is
  deterministic; any drift is a real behavior change.
* everything else (loss, err_rel, omega_hat, ratios) — two-sided
  ``--rtol`` (default 25%): quality numbers jitter across seeds/BLAS
  builds but an order-of-magnitude move must trip.

Artifacts are compared per **config fingerprint**: when the baseline
and current fingerprints differ (the artifact now measures different
things) only the INTERSECTING metrics are compared and a note is
printed; when they match, a metric that DISAPPEARED is itself a
violation.

Exit codes: 0 clean, 1 regression(s), 2 usage error.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

from repro.obs.history import config_fingerprint, flatten_metrics, git_sha
from repro.obs.sink import write_strict_json

#: baseline artifact schema version — readers must fail loudly on drift
BASELINE_VERSION = 1

_TIMING_MARKS = ("time", "elapsed", "seconds")
_STRUCT_MARKS = ("bits", "bytes", "bucket")
_STRUCT_NAMES = frozenset({
    "count", "steps", "iters", "n", "workers", "replicas", "tokens",
    "publishes", "resyncs", "applied", "entries", "seq", "staleness",
    "rank", "n_buckets", "tokens_served", "requests_done", "d_total",
    "n_leaves",
})


def classify(metric: str) -> str:
    """Tolerance class of one dotted metric path (module docstring)."""
    seg = metric.rsplit(".", 1)[-1]
    if "[" in seg:
        seg = seg.split("[", 1)[0]
    low = seg.lower()
    if low.endswith("_s") or low == "s" or any(m in low
                                               for m in _TIMING_MARKS):
        return "timing"
    if any(m in low for m in _STRUCT_MARKS) or low in _STRUCT_NAMES:
        return "structural"
    return "other"


def freeze(paths, out_path: str, *, keep_timings: bool = False,
           sha: Optional[str] = None) -> dict:
    """Record the given artifacts as the baseline (strict JSON).

    Timing metrics are STRIPPED unless ``keep_timings`` — a committed
    baseline must not gate absolute times across CI machines (the band
    itself is exercised by the ``--inject`` self-test against a
    same-run ``--keep-timings`` freeze).
    """
    artifacts = {}
    for path in paths:
        with open(path) as f:
            payload = json.load(f)
        name = os.path.basename(path)
        metrics = flatten_metrics(payload)
        if not keep_timings:
            metrics = {k: v for k, v in metrics.items()
                       if classify(k) != "timing"}
        artifacts[name] = {
            "fingerprint": config_fingerprint(name, payload),
            "metrics": metrics,
        }
    baseline = {
        "version": BASELINE_VERSION,
        "sha": sha if sha is not None else git_sha(),
        "timings_kept": bool(keep_timings),
        "artifacts": artifacts,
    }
    write_strict_json(out_path, baseline)
    return baseline


def load_baseline(path: str) -> dict:
    with open(path) as f:
        baseline = json.load(f)
    v = baseline.get("version")
    if v != BASELINE_VERSION:
        raise ValueError(
            f"baseline version {v!r} != {BASELINE_VERSION} "
            f"({path}: re-freeze with the current writer)"
        )
    return baseline


def compare_metrics(current: Dict[str, float], base: Dict[str, float], *,
                    timing_rtol: float, structural_rtol: float,
                    other_rtol: float,
                    require_all: bool = True) -> List[dict]:
    """Violations of ``current`` against ``base`` (empty list = clean).

    Each violation dict carries the metric path, its class, both
    values, and the relative excess — machine-checkable evidence, not
    just a log line.
    """
    rtol_by_class = {"timing": timing_rtol, "structural": structural_rtol,
                     "other": other_rtol}
    out: List[dict] = []
    for metric in sorted(base):
        b = base[metric]
        cls = classify(metric)
        rtol = rtol_by_class[cls]
        if metric not in current:
            if require_all:
                out.append({"metric": metric, "class": cls, "base": b,
                            "current": None, "rel": None,
                            "why": "metric disappeared"})
            continue
        c = current[metric]
        if b == 0.0:
            # no relative scale: structural zeros must stay exactly
            # (within float dust) zero; noisy classes get a small slack
            atol = 1e-9 if cls == "structural" else 1e-6
            if abs(c) > atol:
                out.append({"metric": metric, "class": cls, "base": b,
                            "current": c, "rel": None,
                            "why": f"baseline 0, current {c:g}"})
            continue
        rel = (c - b) / abs(b)
        bad = rel > rtol if cls == "timing" else abs(rel) > rtol
        if bad:
            sign = "+" if rel >= 0 else ""
            out.append({"metric": metric, "class": cls, "base": b,
                        "current": c, "rel": rel,
                        "why": f"{sign}{rel * 100:.1f}% vs "
                               f"{'+' if cls == 'timing' else '±'}"
                               f"{rtol * 100:.0f}% band"})
    return out


def run_gate(baseline: dict, paths, *, timing_rtol: float = 0.15,
             structural_rtol: float = 0.01, other_rtol: float = 0.25,
             inject: float = 1.0) -> dict:
    """Gate the given artifacts against a loaded baseline.

    Returns ``{"violations": [...], "compared": n_metrics,
    "skipped": [names], "notes": [...]}`` — ``main`` turns a non-empty
    violations list into exit 1.  ``inject`` scales every CURRENT
    timing metric (the CI self-test that proves the band trips).
    """
    violations: List[dict] = []
    notes: List[str] = []
    skipped: List[str] = []
    compared = 0
    base_artifacts = baseline.get("artifacts", {})
    current_by_name = {}
    for path in paths:
        with open(path) as f:
            payload = json.load(f)
        current_by_name[os.path.basename(path)] = payload

    for name, payload in sorted(current_by_name.items()):
        if name not in base_artifacts:
            skipped.append(name)
            notes.append(f"{name}: not in baseline (new coverage) — "
                         "skipped")
            continue
        entry = base_artifacts[name]
        metrics = flatten_metrics(payload)
        if inject != 1.0:
            metrics = {k: (v * inject if classify(k) == "timing" else v)
                       for k, v in metrics.items()}
        fp = config_fingerprint(name, payload)
        same_config = fp == entry.get("fingerprint")
        if not same_config:
            notes.append(f"{name}: config fingerprint changed — "
                         "comparing intersecting metrics only")
        vs = compare_metrics(
            metrics, entry.get("metrics", {}),
            timing_rtol=timing_rtol, structural_rtol=structural_rtol,
            other_rtol=other_rtol, require_all=same_config,
        )
        for v in vs:
            v["artifact"] = name
        violations.extend(vs)
        compared += len(entry.get("metrics", {}))
    for name in sorted(set(base_artifacts) - set(current_by_name)):
        notes.append(f"{name}: in baseline but not under test — skipped")
    return {"violations": violations, "compared": compared,
            "skipped": skipped, "notes": notes}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="compare BENCH_*.json artifacts against a recorded "
                    "baseline; non-zero exit on regression")
    ap.add_argument("artifacts", nargs="*", help="BENCH_*.json paths")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (see --freeze)")
    ap.add_argument("--freeze", default=None, metavar="OUT",
                    help="record the given artifacts as the baseline at "
                         "OUT and exit (no gating)")
    ap.add_argument("--keep-timings", "--keep_timings",
                    dest="keep_timings", action="store_true",
                    help="keep timing metrics in a frozen baseline "
                         "(same-machine self-tests only)")
    ap.add_argument("--timing_rtol", "--timing-rtol", dest="timing_rtol",
                    type=float, default=0.15,
                    help="one-sided slowdown band for timing metrics")
    ap.add_argument("--structural_rtol", "--structural-rtol",
                    dest="structural_rtol", type=float, default=0.01,
                    help="two-sided band for bits/bytes/count metrics")
    ap.add_argument("--rtol", type=float, default=0.25,
                    help="two-sided band for everything else")
    ap.add_argument("--inject", type=float, default=1.0,
                    help="scale current timing metrics by this factor "
                         "(CI self-test: the gate must catch it)")
    ap.add_argument("--sha", default=None,
                    help="override the recorded git sha when freezing")
    args = ap.parse_args(argv)

    if not args.artifacts:
        ap.error("no artifacts given")
    missing = [p for p in args.artifacts if not os.path.exists(p)]
    if missing:
        print(f"regress: missing artifacts: {missing}", file=sys.stderr)
        return 2

    if args.freeze:
        baseline = freeze(args.artifacts, args.freeze,
                          keep_timings=args.keep_timings, sha=args.sha)
        n = sum(len(a["metrics"]) for a in baseline["artifacts"].values())
        print(f"regress: froze {len(baseline['artifacts'])} artifacts "
              f"({n} metrics, timings_kept={baseline['timings_kept']}) "
              f"-> {args.freeze}")
        return 0

    if not args.baseline:
        ap.error("--baseline is required (or use --freeze)")
    try:
        baseline = load_baseline(args.baseline)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"regress: cannot load baseline: {e}", file=sys.stderr)
        return 2

    result = run_gate(
        baseline, args.artifacts, timing_rtol=args.timing_rtol,
        structural_rtol=args.structural_rtol, other_rtol=args.rtol,
        inject=args.inject,
    )
    for note in result["notes"]:
        print(f"regress: note: {note}")
    violations = result["violations"]
    if violations:
        print(f"regress: {len(violations)} violation(s) over "
              f"{result['compared']} baseline metrics "
              f"(baseline sha {str(baseline.get('sha'))[:12]}):")
        for v in violations:
            cur = "missing" if v["current"] is None else f"{v['current']:g}"
            print(f"  REGRESSION {v['artifact']} :: {v['metric']} "
                  f"[{v['class']}]  base {v['base']:g} -> {cur}  "
                  f"({v['why']})")
        return 1
    print(f"regress: OK — {result['compared']} baseline metrics within "
          f"bands (baseline sha {str(baseline.get('sha'))[:12]})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
