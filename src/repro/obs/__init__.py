"""repro.obs — unified observability: per-wire telemetry, step tracing,
and measured-vs-predicted accounting.

One record schema (``metrics``), one span API (``trace``), one sink
discipline (``sink``), one export surface (``export``):

  ``metrics``  typed counters/gauges/histograms + the versioned
               strict-JSON ``StepRecord`` schema and THE repo-wide
               ``finite_or_none``/``sanitize_tree`` helpers.
  ``trace``    ``span(name)`` — ``jax.named_scope``/``TraceAnnotation``
               inside jit (zero runtime ops, no recompiles) plus
               host wall-clock spans into an active ``SpanRecorder``;
               ``StampRecorder`` for the overlap channel's
               reduce_start/finish call windows.
  ``sink``     rotating strict-JSONL, memory, tee, null sinks; every
               record is sanitized + schema-validated before it is
               serialized.
  ``export``   end-of-run summary table, Prometheus text exposition,
               and the CI ``--check`` schema gate.
  ``quality``  measured distortion: ``omega_hat``/NMSE through the
               codecs' real encode paths (jit-compatible diagnostics).
  ``history``  the bench trajectory ledger: BENCH_*.json flattened into
               ``history.jsonl`` keyed by git sha x config fingerprint.
  ``regress``  the CI regression gate over that ledger's baselines
               (per-metric-class tolerance bands, non-zero exit).

THE CONTRACT (tested): with observability off, the trainer step is
bit-exact with the uninstrumented step and the jit path pays nothing —
spans are trace metadata, sinks are never constructed, and diagnostics
are not computed.
"""

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    Metrics,
    RECORD_KINDS,
    SCHEMA_VERSION,
    event_record,
    finite_or_none,
    make_record,
    run_record,
    sanitize_tree,
    step_record,
    summary_record,
    validate_record,
)
from repro.obs.sink import (
    JsonlSink,
    MemorySink,
    NullSink,
    TeeSink,
    check_jsonl,
    read_jsonl,
    write_strict_json,
)
from repro.obs.trace import (
    SpanRecorder,
    StampRecorder,
    active_recorder,
    recording,
    span,
)
from repro.obs.export import (
    format_table,
    prometheus_text,
    summarize,
    summary_table,
)
from repro.obs.quality import (
    array_distortion,
    distortion_floats,
    tree_distortion,
)

# NOTE: ``history`` and ``regress`` are CLI-first submodules (`python -m
# repro.obs.history` / ``.regress``) — import them explicitly; an eager
# import here would trip runpy's double-import warning under ``-m``.

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "JsonlSink",
    "MemorySink",
    "Metrics",
    "NullSink",
    "RECORD_KINDS",
    "SCHEMA_VERSION",
    "SpanRecorder",
    "StampRecorder",
    "TeeSink",
    "active_recorder",
    "array_distortion",
    "check_jsonl",
    "distortion_floats",
    "event_record",
    "finite_or_none",
    "format_table",
    "make_record",
    "tree_distortion",
    "prometheus_text",
    "read_jsonl",
    "recording",
    "run_record",
    "sanitize_tree",
    "span",
    "step_record",
    "summarize",
    "summary_record",
    "summary_table",
    "validate_record",
    "write_strict_json",
]
