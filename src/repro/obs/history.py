"""The bench trajectory ledger: every BENCH_*.json, keyed and appended.

Every benchmark run ends as a point-in-time ``BENCH_*.json`` snapshot —
and until now that is ALL it was: no artifact knew what the previous run
measured, so a perf or quality regression could only be caught by a
human diffing CI artifacts.  This module turns the snapshots into a
trajectory:

* ``ingest`` flattens each artifact's numeric payload into dotted metric
  paths (``fused.step_s``, ``modes.q8.bytes_per_step``) and appends ONE
  obs ``summary`` record per artifact to ``experiments/obs/history.jsonl``
  — schema-valid JSONL (``repro.obs.metrics``), so the CI ``--check``
  gate and every export consumer read it unchanged.
* Records are keyed by **git sha x config fingerprint**: the sha names
  the code revision, the fingerprint hashes the artifact's metric-name
  set plus its non-numeric config scalars — two runs with the same
  fingerprint measured the same thing and are comparable point-to-point
  (``repro.obs.regress`` refuses to compare across fingerprints).

CLI::

    python -m repro.obs.history BENCH_autotune.json ... [--out PATH]
    python -m repro.obs.history --list [--path PATH]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional

from repro.obs.metrics import summary_record
from repro.obs.sink import JsonlSink, read_jsonl

#: default on-disk home of the ledger (CI uploads it as an artifact)
DEFAULT_HISTORY_PATH = os.path.join("experiments", "obs", "history.jsonl")

#: history files grow forever by design — rotate far later than the
#: per-run metrics JSONL so the trajectory stays in one file
HISTORY_ROTATE_BYTES = 256 << 20


def git_sha(cwd: Optional[str] = None) -> str:
    """The commit the metrics were measured at: ``git rev-parse HEAD``,
    falling back to the CI-provided ``GITHUB_SHA``, then ``"unknown"``
    (a ledger outside a checkout is still a ledger)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=cwd, capture_output=True,
            text=True, timeout=10,
        )
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return os.environ.get("GITHUB_SHA", "unknown")


def flatten_metrics(payload, prefix: str = "") -> Dict[str, float]:
    """Every NUMERIC leaf of a bench payload as a dotted path.

    Lists index as ``name[i]``; bools are config, not metrics, and are
    skipped (they belong to the fingerprint's config half).
    """
    out: Dict[str, float] = {}
    if isinstance(payload, dict):
        for k in sorted(payload):
            p = f"{prefix}.{k}" if prefix else str(k)
            out.update(flatten_metrics(payload[k], p))
    elif isinstance(payload, (list, tuple)):
        for i, v in enumerate(payload):
            out.update(flatten_metrics(v, f"{prefix}[{i}]"))
    elif isinstance(payload, bool):
        pass
    elif isinstance(payload, (int, float)):
        out[prefix] = float(payload)
    return out


def _config_scalars(payload, prefix: str = "") -> Dict[str, str]:
    """The non-numeric scalars (strings, bools) — the artifact's CONFIG
    half, hashed into the fingerprint so a changed arch/mode/flag makes
    runs incomparable instead of silently compared."""
    out: Dict[str, str] = {}
    if isinstance(payload, dict):
        for k in sorted(payload):
            p = f"{prefix}.{k}" if prefix else str(k)
            out.update(_config_scalars(payload[k], p))
    elif isinstance(payload, (list, tuple)):
        for i, v in enumerate(payload):
            out.update(_config_scalars(v, f"{prefix}[{i}]"))
    elif isinstance(payload, (bool, str)):
        out[prefix] = str(payload)
    return out


def config_fingerprint(name: str, payload) -> str:
    """sha256 over the artifact name, its metric-name SET, and its
    config scalars — the 'same experiment' key of the ledger."""
    blob = json.dumps(
        {
            "name": name,
            "metrics": sorted(flatten_metrics(payload)),
            "config": sorted(_config_scalars(payload).items()),
        },
        sort_keys=True,
    )
    return hashlib.sha256(blob.encode()).hexdigest()


def artifact_record(path: str, *, sha: Optional[str] = None) -> dict:
    """One BENCH_*.json -> one obs ``summary`` record (validated)."""
    with open(path) as f:
        payload = json.load(f)
    name = os.path.basename(path)
    return summary_record(
        name,
        sha=sha if sha is not None else git_sha(os.path.dirname(
            os.path.abspath(path)) or None),
        fingerprint=config_fingerprint(name, payload),
        metrics=flatten_metrics(payload),
    )


def ingest(paths, out_path: str = DEFAULT_HISTORY_PATH, *,
           sha: Optional[str] = None) -> List[dict]:
    """Append one record per artifact to the ledger; returns them."""
    records = [artifact_record(p, sha=sha) for p in paths]
    sink = JsonlSink(out_path, rotate_bytes=HISTORY_ROTATE_BYTES)
    try:
        for rec in records:
            sink.emit(rec)
    finally:
        sink.close()
    return records


def load_history(path: str = DEFAULT_HISTORY_PATH) -> List[dict]:
    return read_jsonl(path) if os.path.exists(path) else []


def latest_by_artifact(records) -> Dict[str, dict]:
    """Last ledger entry per artifact name (file order IS time order)."""
    out: Dict[str, dict] = {}
    for rec in records:
        if rec.get("kind") == "summary" and "fingerprint" in rec.get(
                "data", {}):
            out[rec["name"]] = rec
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="ingest BENCH_*.json artifacts into the obs history "
                    "ledger (or --list what it holds)")
    ap.add_argument("artifacts", nargs="*", help="BENCH_*.json paths")
    ap.add_argument("--out", default=DEFAULT_HISTORY_PATH,
                    help="ledger path (append-only strict JSONL)")
    ap.add_argument("--sha", default=None,
                    help="override the recorded git sha")
    ap.add_argument("--list", action="store_true",
                    help="print the ledger's latest entry per artifact")
    args = ap.parse_args(argv)

    if args.list:
        latest = latest_by_artifact(load_history(args.out))
        if not latest:
            print(f"history: {args.out} is empty")
            return 0
        for name, rec in sorted(latest.items()):
            d = rec["data"]
            print(f"{name}  sha={str(d.get('sha'))[:12]}  "
                  f"fp={str(d.get('fingerprint'))[:12]}  "
                  f"{len(d.get('metrics') or {})} metrics")
        return 0
    if not args.artifacts:
        ap.error("no artifacts given (and --list not set)")
    recs = ingest(args.artifacts, args.out, sha=args.sha)
    print(f"history: ingested {len(recs)} artifacts -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
