"""End-of-run exports: summary table, Prometheus text, CI schema check.

    PYTHONPATH=src python -m repro.obs.export --check run.jsonl
    PYTHONPATH=src python -m repro.obs.export --summary run.jsonl
    PYTHONPATH=src python -m repro.obs.export --prom run.jsonl

``summarize`` folds a record stream into one ``summary`` record:
step-time statistics (measured AND predicted, plus their ratio — the
continuously tracked version of the ``BENCH_autotune.json`` predictor
gap), final loss/bits, per-wire byte totals from the run header, the
measured overlap hide fraction, and event counts by name.

``prometheus_text`` renders the same aggregate in the Prometheus text
exposition format (``# TYPE`` + ``name{labels} value`` lines) so a
scrape-based dashboard can ingest a finished run without a custom
parser.  ``--check`` is the CI gate: exit 1 unless every line of the
JSONL validates against the pinned schema.
"""

from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Optional

from repro.obs.metrics import Histogram, finite_or_none, summary_record
from repro.obs.sink import check_jsonl, read_jsonl


def _num(x) -> Optional[float]:
    return None if x is None else finite_or_none(x)


def summarize(records: List[dict], *, name: str = "run") -> dict:
    """Fold a record stream into one ``summary`` record (docstring)."""
    steps = [r for r in records if r.get("kind") == "step"]
    runs = [r for r in records if r.get("kind") == "run"]
    events = [r for r in records if r.get("kind") == "event"]

    h_step = Histogram()
    h_pred = Histogram()
    h_ratio = Histogram()
    h_resid = Histogram()
    h_resid_ratio = Histogram()
    last_loss = None
    last_bits = None
    resid_first = None
    resid_last = None
    for r in steps:
        d = r.get("data", {})
        t = _num(d.get("step_s"))
        p = _num(d.get("predicted_step_s"))
        if t is not None:
            h_step.observe(t)
        if p is not None:
            h_pred.observe(p)
        if t is not None and p is not None and t > 0:
            h_ratio.observe(p / t)
        if d.get("loss") is not None:
            last_loss = _num(d.get("loss"))
        if d.get("bits") is not None:
            last_bits = _num(d.get("bits"))
        # the shift-residual trajectory ||g - h||^2 (vs ||g||^2): the
        # paper's headline effect — shrinking under DIANA/EF-BV, flat
        # (ratio 1) under plain DCGD
        rs = _num(d.get("shift_residual_sq"))
        gs = _num(d.get("grad_sq"))
        if rs is not None:
            h_resid.observe(rs)
            if resid_first is None:
                resid_first = rs
            resid_last = rs
        if rs is not None and gs is not None and gs > 0:
            h_resid_ratio.observe(rs / gs)

    wires = {}
    hide = None
    hide_source = None
    omega = None
    omega_source = None
    for r in runs:
        d = r.get("data", {})
        wires.update(d.get("wires") or {})
        if d.get("hide_fraction") is not None:
            hide = _num(d.get("hide_fraction"))
            hide_source = d.get("hide_source")
        if d.get("omega") is not None:
            omega = _num(d.get("omega"))
        if d.get("omega_source") is not None:
            omega_source = d.get("omega_source")

    by_event: Dict[str, int] = {}
    for r in events:
        by_event[r["name"]] = by_event.get(r["name"], 0) + 1

    return summary_record(
        name,
        n_steps=len(steps),
        step_s=h_step.to_value(),
        predicted_step_s=h_pred.to_value(),
        predicted_over_actual=h_ratio.to_value(),
        final_loss=last_loss,
        final_bits=last_bits,
        wires=wires,
        hide_fraction=hide,
        hide_source=hide_source,
        omega=omega,
        omega_source=omega_source,
        shift_residual_sq=h_resid.to_value(),
        shift_residual_over_grad=h_resid_ratio.to_value(),
        shift_residual_first=resid_first,
        shift_residual_last=resid_last,
        events=by_event,
    )


def _fmt(x) -> str:
    if x is None:
        return "n/a"
    if isinstance(x, float):
        return f"{x:.3e}" if (abs(x) >= 1e4 or 0 < abs(x) < 1e-3) else f"{x:.4g}"
    return str(x)


def format_table(title: str, header: List[str], rows: List[tuple]) -> str:
    """The repo's bench-table look, as a string (benchmarks/common.
    print_table delegates here so the two surfaces cannot drift)."""
    out = [f"\n## {title}"]
    widths = [max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
              for i, h in enumerate(header)]
    out.append("  ".join(str(h).ljust(w) for h, w in zip(header, widths)))
    for r in rows:
        out.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)


def summary_table(records: List[dict], *, name: str = "run") -> str:
    """Human-readable end-of-run table from a record stream."""
    s = summarize(records, name=name)["data"]
    rows = [
        ("steps", s["n_steps"], ""),
        ("step_s (mean)", _fmt((s["step_s"] or {}).get("mean")),
         f"min {_fmt((s['step_s'] or {}).get('min'))} / "
         f"max {_fmt((s['step_s'] or {}).get('max'))}"),
        ("predicted_step_s (mean)",
         _fmt((s["predicted_step_s"] or {}).get("mean")), ""),
        ("predicted/actual (mean)",
         _fmt((s["predicted_over_actual"] or {}).get("mean")),
         "the tracked tuner-predictor gap"),
        ("final loss", _fmt(s["final_loss"]), ""),
        ("final bits", _fmt(s["final_bits"]), ""),
        ("overlap hide fraction", _fmt(s["hide_fraction"]),
         s["hide_source"] or ""),
        ("omega", _fmt(s.get("omega")), s.get("omega_source") or ""),
        ("shift resid/grad (mean)",
         _fmt((s.get("shift_residual_over_grad") or {}).get("mean")),
         f"||g-h||^2: first {_fmt(s.get('shift_residual_first'))} -> "
         f"last {_fmt(s.get('shift_residual_last'))}"),
    ]
    for wname, w in sorted((s["wires"] or {}).items()):
        rows.append((
            f"wire {wname}",
            f"{_fmt((w or {}).get('payload_bytes'))} B/step payload",
            f"enc {_fmt((w or {}).get('encode_s'))}s / "
            f"dec {_fmt((w or {}).get('decode_s'))}s / "
            f"omega_hat {_fmt((w or {}).get('omega_hat'))}",
        ))
    for ev, n in sorted((s["events"] or {}).items()):
        rows.append((f"event {ev}", n, ""))
    return format_table(f"obs summary [{name}]",
                        ["metric", "value", "notes"], rows)


def _prom_escape(s: str) -> str:
    return s.replace("\\", "\\\\").replace('"', '\\"')


def prometheus_text(records: List[dict], *, name: str = "run") -> str:
    """Prometheus text exposition of the run aggregate (docstring)."""
    s = summarize(records, name=name)["data"]
    run = _prom_escape(name)
    lines: List[str] = []

    def gauge(metric: str, value, labels: str = "") -> None:
        if value is None:
            return
        lines.append(f"# TYPE {metric} gauge")
        lab = f'run="{run}"' + (f",{labels}" if labels else "")
        lines.append(f"{metric}{{{lab}}} {value}")

    gauge("repro_steps_total", s["n_steps"])
    gauge("repro_step_seconds_mean", (s["step_s"] or {}).get("mean"))
    gauge("repro_predicted_step_seconds_mean",
          (s["predicted_step_s"] or {}).get("mean"))
    gauge("repro_predicted_over_actual_mean",
          (s["predicted_over_actual"] or {}).get("mean"))
    gauge("repro_final_loss", s["final_loss"])
    gauge("repro_uplink_bits_total", s["final_bits"])
    gauge("repro_overlap_hide_fraction", s["hide_fraction"])
    gauge("repro_omega", s.get("omega"))
    gauge("repro_shift_residual_sq",
          (s.get("shift_residual_sq") or {}).get("mean"))
    gauge("repro_shift_residual_over_grad",
          (s.get("shift_residual_over_grad") or {}).get("mean"))
    for wname, w in sorted((s["wires"] or {}).items()):
        lab = f'wire="{_prom_escape(wname)}"'
        gauge("repro_wire_bits_per_step", (w or {}).get("wire_bits"), lab)
        gauge("repro_wire_payload_bytes_per_step",
              (w or {}).get("payload_bytes"), lab)
        gauge("repro_wire_encode_seconds", (w or {}).get("encode_s"), lab)
        gauge("repro_wire_decode_seconds", (w or {}).get("decode_s"), lab)
        gauge("repro_wire_omega_hat", (w or {}).get("omega_hat"), lab)
        gauge("repro_wire_nmse", (w or {}).get("nmse"), lab)
    for ev, n in sorted((s["events"] or {}).items()):
        lines.append("# TYPE repro_events_total counter")
        lines.append(
            f'repro_events_total{{run="{run}",'
            f'event="{_prom_escape(ev)}"}} {n}'
        )
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="obs JSONL exports: schema check / summary / prometheus"
    )
    ap.add_argument("paths", nargs="+", help="obs JSONL file(s)")
    ap.add_argument("--check", action="store_true",
                    help="schema-validate every line; exit 1 on failure")
    ap.add_argument("--summary", action="store_true",
                    help="print the end-of-run summary table")
    ap.add_argument("--prom", action="store_true",
                    help="print the Prometheus text exposition")
    args = ap.parse_args(argv)
    if not (args.check or args.summary or args.prom):
        args.summary = True

    rc = 0
    for path in args.paths:
        if args.check:
            n, errors = check_jsonl(path)
            if errors:
                rc = 1
                print(f"{path}: {len(errors)} invalid record(s) "
                      f"({n} valid):", file=sys.stderr)
                for e in errors[:20]:
                    print(f"  {e}", file=sys.stderr)
            else:
                print(f"{path}: {n} records, schema v-pinned OK")
        if args.summary or args.prom:
            records = read_jsonl(path, validate=not args.check)
            if args.summary:
                print(summary_table(records, name=path))
            if args.prom:
                print(prometheus_text(records, name=path), end="")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
