"""The obs record schema: typed metrics + versioned strict-JSON records.

Everything the observability layer emits — trainer steps, transport
wire accounting, serving-fleet events, bench summaries — is ONE record
shape: a flat dict with a schema version (``v``), a ``kind`` from
``RECORD_KINDS``, the kind's identity fields (``step`` / ``name`` /
``run``), and a ``data`` dict of JSON scalars and nested dicts/lists.
``validate_record`` enforces the shape STRICTLY (unknown top-level keys,
wrong version, and non-finite floats are all errors), so a JSONL file
that validates here is parseable by any RFC 8259 consumer and by every
future reader that pins ``SCHEMA_VERSION``.

``finite_or_none`` / ``sanitize_tree`` are THE repo-wide strict-JSON
helpers: ``benchmarks/common.py`` and ``repro.tune.plan`` delegate here
(previously each carried its own copy), so there is exactly one place
where inf/nan becomes ``null``.

The typed metric classes (``Counter`` / ``Gauge`` / ``Histogram``) are
host-side aggregation state for the driver loops; ``Metrics`` is a tiny
registry whose ``snapshot()`` drops straight into a record's ``data``.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

#: bump when the record shape changes — old readers must fail loudly,
#: not misparse (v1: initial schema — run/step/event/summary kinds)
SCHEMA_VERSION = 1

#: every record kind the schema admits
RECORD_KINDS = ("run", "step", "event", "summary")

#: top-level keys a record may carry (everything else rides in ``data``)
_ALLOWED_KEYS = frozenset({"v", "kind", "run", "step", "name", "data"})

#: identity fields each kind REQUIRES beyond ``v``/``kind``/``data``
_REQUIRED_BY_KIND = {
    "run": ("run",),
    "step": ("step",),
    "event": ("name", "step"),
    "summary": ("name",),
}


def finite_or_none(x) -> Optional[float]:
    """inf/nan -> None so artifacts stay STRICT JSON (json.dump would
    happily emit a bare ``Infinity`` token, which RFC 8259 parsers —
    jq, JSON.parse — reject); None means 'no finite value'."""
    x = float(x)
    return x if math.isfinite(x) else None


def sanitize_tree(obj):
    """null-out non-finite floats recursively (dicts/lists/tuples), and
    coerce numpy/jax scalars to Python scalars — the one strict-JSON
    normalization pass every writer shares."""
    if isinstance(obj, bool) or obj is None or isinstance(obj, (int, str)):
        return obj
    if isinstance(obj, float):
        return finite_or_none(obj)
    if isinstance(obj, dict):
        return {str(k): sanitize_tree(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize_tree(v) for v in obj]
    # numpy / jax scalar-likes: anything float()-able becomes a float
    try:
        return finite_or_none(float(obj))
    except (TypeError, ValueError):
        return str(obj)


def _check_finite(obj, path: str) -> None:
    if isinstance(obj, bool) or obj is None or isinstance(obj, (int, str)):
        return
    if isinstance(obj, float):
        if not math.isfinite(obj):
            raise ValueError(
                f"record field {path} is non-finite ({obj!r}); run "
                "sanitize_tree before validating"
            )
        return
    if isinstance(obj, dict):
        for k, v in obj.items():
            if not isinstance(k, str):
                raise ValueError(f"record key {path}.{k!r} is not a string")
            _check_finite(v, f"{path}.{k}")
        return
    if isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            _check_finite(v, f"{path}[{i}]")
        return
    raise ValueError(
        f"record field {path} has non-JSON type {type(obj).__name__}; "
        "run sanitize_tree before validating"
    )


def validate_record(rec: dict) -> dict:
    """STRICT schema check; returns ``rec`` unchanged or raises
    ``ValueError`` naming the offending field.

    Pins: ``v == SCHEMA_VERSION`` exactly, ``kind`` in ``RECORD_KINDS``,
    the kind's required identity fields present and typed, no unknown
    top-level keys, and every float finite (records must be sanitized
    before they are validated/written).
    """
    if not isinstance(rec, dict):
        raise ValueError(f"record must be a dict, got {type(rec).__name__}")
    v = rec.get("v")
    if v != SCHEMA_VERSION:
        raise ValueError(
            f"record version {v!r} != {SCHEMA_VERSION} (obs schema is "
            "pinned; re-emit with the current writer)"
        )
    kind = rec.get("kind")
    if kind not in RECORD_KINDS:
        raise ValueError(
            f"unknown record kind {kind!r}; have {RECORD_KINDS}"
        )
    unknown = set(rec) - _ALLOWED_KEYS
    if unknown:
        raise ValueError(
            f"unknown record keys {sorted(unknown)}; "
            f"allowed {sorted(_ALLOWED_KEYS)} (payload belongs in 'data')"
        )
    for field in _REQUIRED_BY_KIND[kind]:
        if field not in rec:
            raise ValueError(f"{kind} record missing required {field!r}")
    if "step" in rec:
        step = rec["step"]
        if not isinstance(step, int) or isinstance(step, bool) or step < 0:
            raise ValueError(
                f"record step must be an int >= 0, got {step!r}"
            )
    for field in ("run", "name"):
        if field in rec and not isinstance(rec[field], str):
            raise ValueError(
                f"record {field} must be a string, got {rec[field]!r}"
            )
    data = rec.get("data", {})
    if not isinstance(data, dict):
        raise ValueError(
            f"record data must be a dict, got {type(data).__name__}"
        )
    _check_finite(data, "data")
    return rec


def make_record(kind: str, *, run: Optional[str] = None,
                step: Optional[int] = None, name: Optional[str] = None,
                data: Optional[dict] = None) -> dict:
    """Build + sanitize + validate one record (the only constructor the
    emitters use, so an invalid record can never reach a sink)."""
    rec: Dict[str, Any] = {"v": SCHEMA_VERSION, "kind": kind}
    if run is not None:
        rec["run"] = str(run)
    if step is not None:
        rec["step"] = int(step)
    if name is not None:
        rec["name"] = str(name)
    rec["data"] = sanitize_tree(data or {})
    return validate_record(rec)


def step_record(step: int, *, run: Optional[str] = None, **data) -> dict:
    """One per-step record (loss, timings, drift norms, wire bytes...)."""
    return make_record("step", run=run, step=step, data=data)


def event_record(name: str, step: int, **data) -> dict:
    """One structured event (resync, publish, unresolved_whiles...)."""
    return make_record("event", name=name, step=step, data=data)


def run_record(run: str, **data) -> dict:
    """The run header: static facts (arch, comm mode, per-wire
    accounting, measured hide fraction) every step record shares."""
    return make_record("run", run=run, data=data)


def summary_record(name: str, **data) -> dict:
    """An end-of-run / bench aggregate."""
    return make_record("summary", name=name, data=data)


# ---------------------------------------------------------------------------
# Typed host-side metrics
# ---------------------------------------------------------------------------


class Counter:
    """Monotone count (events, resyncs, publishes)."""

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"Counter.inc of negative {n} (use a Gauge)")
        self.value += n

    def to_value(self):
        return self.value


class Gauge:
    """Last-write-wins level (staleness, hide fraction, loss)."""

    def __init__(self):
        self.value: Optional[float] = None

    def set(self, x: float) -> None:
        self.value = float(x)

    def to_value(self):
        return None if self.value is None else finite_or_none(self.value)


class Histogram:
    """Streaming summary (count/sum/min/max) of an observed series —
    enough for p50-free step-time accounting without storing samples."""

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, x: float) -> None:
        x = float(x)
        if not math.isfinite(x):
            return
        self.count += 1
        self.total += x
        self.min = x if self.min is None else min(self.min, x)
        self.max = x if self.max is None else max(self.max, x)

    @property
    def mean(self) -> Optional[float]:
        return (self.total / self.count) if self.count else None

    def to_value(self):
        return {
            "count": self.count,
            "sum": finite_or_none(self.total),
            "min": None if self.min is None else finite_or_none(self.min),
            "max": None if self.max is None else finite_or_none(self.max),
            "mean": None if self.mean is None else finite_or_none(self.mean),
        }


class Metrics:
    """A tiny named registry of the typed metrics above.

    ``snapshot()`` returns a plain dict ready for a record's ``data``;
    metric names are created on first touch (``m.counter("resyncs")``).
    """

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls()
        elif not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, requested {cls.__name__}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> dict:
        return {name: m.to_value() for name, m in self._metrics.items()}
