"""Serving layer: slot-based continuous batching over the unified
decode API, plus the trainer->fleet shifted model-delta stream
(``repro.serving.delta`` publisher, ``repro.serving.fleet``
subscribers)."""

from repro.serving.delta import (
    DeltaMsg,
    DeltaPublisher,
    apply_msg,
    dense_tree_bits,
    tree_rel_err,
)
from repro.serving.engine import Engine, Request
from repro.serving.fleet import (
    Replica,
    ServingFleet,
    TrainerFleetBridge,
    run_fleet_demo,
)

__all__ = [
    "DeltaMsg",
    "DeltaPublisher",
    "Engine",
    "Replica",
    "Request",
    "ServingFleet",
    "TrainerFleetBridge",
    "apply_msg",
    "dense_tree_bits",
    "run_fleet_demo",
    "tree_rel_err",
]
