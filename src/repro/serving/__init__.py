"""Serving engine: slot-based continuous batching over the unified
decode API."""

from repro.serving.engine import Engine, Request
