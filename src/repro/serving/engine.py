"""Continuous-batching serving engine.

Slot-based scheduling over the unified ``decode_step`` API: a fixed
batch of B cache slots advances on a SHARED decode clock; requests are
admitted into free slots as others finish, their prompts fed token-by-
token (prefill-as-decode), then generated greedily until EOS/limit.

The shared clock is what keeps the whole engine jit-friendly — one
``decode_step`` per tick for all slots, a single scalar position.
Per-slot correctness comes from two mechanisms:

  * attention caches carry PER-SLOT validity (``kpos`` is (B, C)):
    admitting a request invalidates its slot's stale cache entries, so
    the previous occupant's KV can never leak into the new request;
  * a request admitted at clock t simply lives at absolute positions
    t, t+1, ... — RoPE is relative, so generation is position-coherent
    within the request (verified against offline decode in
    tests/test_serving.py).

Recurrent state (RWKV/Mamba) slots are zeroed on admit.  Slot admission
is host-side pytree surgery between jitted ticks — the tick itself is
one compiled call.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M

tmap = jax.tree_util.tree_map


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    fed: int = 0          # prompt tokens already fed

    @property
    def free(self) -> bool:
        return self.request is None


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 cache_len: int = 256):
        if cfg.is_encoder_decoder:
            raise ValueError("enc-dec serving needs per-request encoder "
                             "outputs; use launch.serve directly")
        self.cfg = cfg
        self.params = params
        self.b = max_batch
        self.cache_len = cache_len
        self.state = M.make_decode_state(cfg, max_batch, cache_len)
        self.slots = [_Slot() for _ in range(max_batch)]
        self.queue: deque[Request] = deque()
        self.clock = 0
        self._step = jax.jit(
            lambda p, s, t, pos: M.decode_step(p, cfg, t, s, pos)
        )

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def update_params(self, params) -> None:
        """Swap the served weights BETWEEN decode ticks.

        Params are an argument of the jitted tick, so swapping values
        never recompiles — this is the delta-application point of the
        serving fleet (``repro.serving.fleet``): a replica applies
        queued model-delta messages here, then keeps decoding.
        """
        self.params = params

    def idle(self) -> bool:
        """No queued requests and every slot free."""
        return all(s.free for s in self.slots) and not self.queue

    def step_tick(self) -> List[Request]:
        """One admission pass + one shared-clock decode tick.

        The externally-driven unit of ``run``: callers that interleave
        work between ticks (delta application, mid-run submission) call
        this directly.  Returns requests finished this tick (empty when
        idle — the clock does not advance on an empty engine).
        """
        self._admit()
        if self.idle():
            return []
        return self._tick()

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        """Drive until queue and slots drain; returns finished requests."""
        finished: List[Request] = []
        for _ in range(max_ticks):
            self._admit()
            if self.idle():
                break
            finished.extend(self._tick())
        return finished

    # -- internals -----------------------------------------------------------

    def _reset_slot_state(self, b: int) -> None:
        """Invalidate slot b's cache/state (host-side tree surgery)."""
        def fix(path, leaf):
            names = [str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path]
            arr = np.asarray(leaf)
            if names and names[-1] == "kpos":        # (L, B, C)
                arr = arr.copy()
                arr[:, b, :] = -1
                return jnp.asarray(arr)
            # recurrent states / conv tails / k / v: zero the slot's row
            if arr.ndim >= 2 and arr.shape[1] == self.b:
                arr = arr.copy()
                arr[:, b] = 0
                return jnp.asarray(arr)
            return leaf
        self.state = jax.tree_util.tree_map_with_path(fix, self.state)

    def _admit(self) -> None:
        for b, slot in enumerate(self.slots):
            if slot.free and self.queue:
                slot.request = self.queue.popleft()
                slot.fed = 0
                self._reset_slot_state(b)

    def _tick(self) -> List[Request]:
        """One shared-clock decode step for all slots."""
        toks = np.zeros((self.b, 1), np.int32)
        for b, slot in enumerate(self.slots):
            r = slot.request
            if r is None:
                continue
            if slot.fed < len(r.prompt):
                toks[b, 0] = r.prompt[slot.fed]
            else:
                toks[b, 0] = r.output[-1]
        logits, self.state = self._step(
            self.params, self.state, jnp.asarray(toks),
            jnp.int32(self.clock),
        )
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        self.clock += 1

        finished = []
        for b, slot in enumerate(self.slots):
            r = slot.request
            if r is None:
                continue
            if slot.fed < len(r.prompt):
                slot.fed += 1
                if slot.fed < len(r.prompt):
                    continue
                # prompt complete: this tick's logits give the first token
            r.output.append(int(nxt[b]))
            if (len(r.output) >= r.max_new_tokens
                    or (r.eos_id is not None and r.output[-1] == r.eos_id)):
                r.done = True
                finished.append(r)
                slot.request = None
        return finished
