"""The subscriber fleet: N continuous-batching replicas on one delta
stream.

Each ``Replica`` wraps a ``repro.serving.Engine`` and applies queued
``DeltaMsg``s BETWEEN decode ticks — the engine's params are a step
argument, so swapping them never recompiles and never tears a tick.
The fleet tracks per-replica staleness (trainer steps behind the last
applied message) and requests a dense ``resync`` when a replica falls
more than ``stale_k`` steps behind or its stream error (the publisher's
``err_rel``, exact for an in-sync replica — see ``repro.serving.delta``)
exceeds ``err_budget``.  A pending resync supersedes everything queued
before it: lagging replicas fast-forward to the snapshot instead of
replaying deltas they can no longer afford.

``TrainerFleetBridge`` is the glue a training loop needs: it owns the
publisher, the publish cadence and the resync policy, and exposes one
``on_step(params, step)`` hook.  ``run_fleet_demo`` co-simulates a real
smoke trainer with a serving fleet — the entrypoint behind
``launch/serve.py --serve_fleet`` and ``benchmarks/serve_delta_bench``.
"""

from __future__ import annotations

from collections import deque
from typing import List, Optional

import jax

from repro.serving.delta import DeltaMsg, DeltaPublisher, apply_msg
from repro.serving.engine import Engine, Request


class Replica:
    """One serving replica subscribed to the delta stream."""

    def __init__(self, rid: int, cfg, params, *, max_batch: int = 2,
                 cache_len: int = 128, obs=None):
        self.rid = rid
        self.engine = Engine(cfg, params, max_batch=max_batch,
                             cache_len=cache_len)
        self.step = 0          # trainer step of the params being served
        self.seq = 0           # last applied stream sequence number
        self.err_rel = 0.0     # stream error of the served params
        self.applied = 0       # delta messages applied
        self.resyncs = 0       # dense resyncs applied
        self.obs = obs         # optional record sink: resync-apply events
        self.pending: deque = deque()

    @property
    def params(self):
        return self.engine.params

    def enqueue(self, msg: DeltaMsg) -> None:
        self.pending.append(msg)

    def _fast_forward(self) -> None:
        """Drop every message queued before the LAST pending resync —
        replacement semantics make replaying them pointless."""
        last = None
        for i, msg in enumerate(self.pending):
            if msg.kind == "resync":
                last = i
        if last:
            for _ in range(last):
                self.pending.popleft()

    def apply_pending(self, limit: Optional[int] = None) -> int:
        """Apply queued messages in stream order (between decode ticks).

        ``limit`` caps messages per call — the knob that makes
        staleness REAL in simulation (an unbounded replica is never
        more than one tick behind).  Returns the number applied.
        """
        self._fast_forward()
        n = 0
        while self.pending and (limit is None or n < limit):
            msg = self.pending.popleft()
            self.engine.update_params(apply_msg(self.engine.params, msg))
            self.step = msg.step
            self.seq = msg.seq
            self.err_rel = msg.err_rel
            if msg.kind == "resync":
                self.resyncs += 1
                if self.obs is not None:
                    from repro.obs import event_record

                    self.obs.emit(event_record(
                        "fleet_resync", max(0, msg.step), replica=self.rid,
                        seq=msg.seq, bytes=msg.bits / 8.0,
                    ))
            else:
                self.applied += 1
            n += 1
        return n

    def staleness(self, trainer_step: int) -> int:
        return trainer_step - self.step

    def load(self) -> int:
        """Admission pressure: occupied slots + queued requests."""
        busy = sum(0 if s.free else 1 for s in self.engine.slots)
        return busy + len(self.engine.queue)


class ServingFleet:
    """N replicas, one stream: deliver -> apply between ticks -> decode.

    Built from the publisher's ``initial_sync`` message so every
    replica starts in bitwise lockstep with the publisher's ``h_bar``.
    """

    def __init__(self, cfg, sync_msg: DeltaMsg, n_replicas: int, *,
                 stale_k: int = 4, err_budget: Optional[float] = None,
                 max_batch: int = 2, cache_len: int = 128,
                 max_apply_per_tick: Optional[int] = None, obs=None):
        if sync_msg.kind != "resync":
            raise ValueError("a fleet bootstraps from a full-model sync "
                             f"message, not {sync_msg.kind!r}")
        self.obs = obs
        self.replicas: List[Replica] = [
            Replica(r, cfg, sync_msg.payload, max_batch=max_batch,
                    cache_len=cache_len, obs=obs)
            for r in range(n_replicas)
        ]
        for rep in self.replicas:
            rep.step = sync_msg.step
            rep.seq = sync_msg.seq
            rep.err_rel = sync_msg.err_rel
        self.trainer_step = sync_msg.step
        self.stale_k = stale_k
        self.err_budget = err_budget
        self.max_apply_per_tick = max_apply_per_tick
        self.max_staleness_seen = 0
        self._rr = 0

    def submit(self, req: Request) -> Replica:
        """Admit to the least-loaded replica (round-robin tie-break)."""
        order = sorted(range(len(self.replicas)),
                       key=lambda i: (self.replicas[i].load(),
                                      (i - self._rr) % len(self.replicas)))
        rep = self.replicas[order[0]]
        self._rr = (rep.rid + 1) % len(self.replicas)
        rep.engine.submit(req)
        return rep

    def deliver(self, msg: DeltaMsg) -> None:
        """Broadcast one stream message to every replica's queue."""
        self.trainer_step = max(self.trainer_step, msg.step)
        for rep in self.replicas:
            rep.enqueue(msg)

    def tick(self) -> List[Request]:
        """One fleet tick: each replica applies pending deltas, then
        runs one shared-clock decode tick.  Returns finished requests."""
        finished: List[Request] = []
        for rep in self.replicas:
            rep.apply_pending(self.max_apply_per_tick)
            stale = rep.staleness(self.trainer_step)
            if stale > self.max_staleness_seen and self.obs is not None:
                from repro.obs import event_record

                self.obs.emit(event_record(
                    "fleet_staleness", max(0, self.trainer_step),
                    replica=rep.rid, staleness=stale,
                ))
            self.max_staleness_seen = max(self.max_staleness_seen, stale)
            finished.extend(rep.engine.step_tick())
        return finished

    def needs_resync(self) -> List[Replica]:
        """Replicas over the staleness bound K or the error budget."""
        out = []
        for rep in self.replicas:
            stale = rep.staleness(self.trainer_step) > self.stale_k
            err = (self.err_budget is not None
                   and rep.err_rel > self.err_budget)
            if stale or err:
                out.append(rep)
        return out

    def idle(self) -> bool:
        return all(rep.engine.idle() for rep in self.replicas)

    def run_drain(self, max_ticks: int = 10_000) -> List[Request]:
        """Tick until every replica's queue and slots drain."""
        finished: List[Request] = []
        for _ in range(max_ticks):
            if self.idle():
                break
            finished.extend(self.tick())
        return finished

    def staleness_by_replica(self):
        return {rep.rid: rep.staleness(self.trainer_step)
                for rep in self.replicas}


class TrainerFleetBridge:
    """Glue between a training loop and a serving fleet.

    Owns the ``DeltaPublisher`` (over the transport's model wire), the
    publish cadence, and the resync policy.  The training loop calls
    ``on_step(params, step)`` after every optimizer step with ``step``
    counting COMPLETED steps from 1; publishes happen every
    ``publish_every`` steps, each followed by one fleet tick (apply +
    decode) and a resync check on the APPLIED state.
    """

    def __init__(self, cfg, params, wire, *, n_replicas: int,
                 publish_every: int = 1, stale_k: int = 4,
                 err_budget: Optional[float] = None, eta: float = 1.0,
                 sync_codec=None, key: Optional[jax.Array] = None,
                 max_batch: int = 2, cache_len: int = 128,
                 max_apply_per_tick: Optional[int] = None, obs=None):
        from repro.core.shift_rules import EFBVShift
        from repro.obs import MemorySink, TeeSink, event_record

        # every structured event lands in the bridge's own MemorySink
        # (``stats`` reads from it) AND fans out to the caller's sink
        # (``--metrics_out`` routes the fleet through the run's JSONL)
        self.events = MemorySink()
        self._obs = TeeSink(self.events, obs)
        self.publisher = DeltaPublisher(wire, rule=EFBVShift(eta=eta),
                                        key=key)
        sync = self.publisher.initial_sync(params, step=0,
                                           sync_codec=sync_codec)
        self.sync_bits = sync.bits
        self._obs.emit(event_record(
            "fleet_bootstrap", 0, replicas=n_replicas,
            bytes=sync.bits / 8.0,
        ))
        self.fleet = ServingFleet(
            cfg, sync, n_replicas, stale_k=stale_k, err_budget=err_budget,
            max_batch=max_batch, cache_len=cache_len,
            max_apply_per_tick=max_apply_per_tick, obs=self._obs,
        )
        self.publish_every = max(1, publish_every)
        self.finished: List[Request] = []

    def on_step(self, params, step: int) -> Optional[DeltaMsg]:
        from repro.obs import event_record

        if step % self.publish_every:
            return None
        msg = self.publisher.publish(params, step=step)
        self._obs.emit(event_record(
            "publish", step, seq=msg.seq, bytes=msg.bits / 8.0,
            err_rel=msg.err_rel,
            # the downlink's quality number in the same NMSE units the
            # per-wire probes report: err_rel is ||Q(d)-d||/||params||
            nmse=msg.err_rel ** 2,
        ))
        self.fleet.deliver(msg)
        self.finished.extend(self.fleet.tick())
        lagging = self.fleet.needs_resync()
        if lagging:
            snap = self.publisher.snapshot(params, step=step)
            for rep in lagging:
                stale = rep.staleness(self.fleet.trainer_step)
                reason = ("staleness" if stale > self.fleet.stale_k
                          else "err_budget")
                self._obs.emit(event_record(
                    "resync_requested", step, replica=rep.rid,
                    reason=reason, staleness=stale, err_rel=rep.err_rel,
                    bytes=snap.bits / 8.0,
                ))
            self.fleet.deliver(snap)
            self.finished.extend(self.fleet.tick())
        return msg

    def drain(self, max_ticks: int = 10_000) -> List[Request]:
        self.finished.extend(self.fleet.run_drain(max_ticks))
        return self.finished

    def stats(self) -> dict:
        """The bridge's ledger.  Event-derived entries (``publishes``,
        ``resyncs``, ``max_staleness``, ``err_rel``) are sourced from the
        obs records the fleet emitted — the same stream ``--metrics_out``
        persists — so the printed table and the JSONL cannot disagree."""
        pub = self.publisher
        dense = pub.dense_bits_per_publish()
        publishes = self.events.events("publish")
        deltas = [e["data"]["bytes"] * 8.0 for e in publishes]
        per_publish = (sum(deltas) / len(deltas)) if deltas else 0.0
        stale_events = self.events.events("fleet_staleness")
        return {
            "publishes": len(publishes),
            "resyncs": len(self.events.events("fleet_resync")),
            "sync_bytes": self.sync_bits / 8.0,
            "delta_bytes": [b / 8.0 for b in deltas],
            "delta_bytes_per_publish": per_publish / 8.0,
            "delta_bytes_per_step": per_publish / 8.0 / self.publish_every,
            "dense_bytes_per_publish": dense / 8.0,
            "dense_bytes_per_step": dense / 8.0 / self.publish_every,
            "bytes_fraction": (per_publish / dense) if dense else 0.0,
            "err_rel": [e["data"]["err_rel"] for e in publishes],
            "max_staleness": max(
                (e["data"]["staleness"] for e in stale_events),
                default=self.fleet.max_staleness_seen,
            ),
            "staleness": self.fleet.staleness_by_replica(),
            "requests_done": len(self.finished),
            "tokens_served": sum(len(r.output) for r in self.finished),
            "obs_events": {
                name: sum(1 for e in self.events.by_kind("event")
                          if e["name"] == name)
                for name in sorted({e["name"]
                                    for e in self.events.by_kind("event")})
            },
        }


def run_fleet_demo(arch: str = "qwen3-0.6b", *, n_replicas: int = 2,
                   model_wire: str = "q8", publish_every: int = 2,
                   stale_k: int = 4, steps: int = 6, batch: int = 4,
                   seq: int = 64, lr: float = 1e-2, n_requests: int = 6,
                   gen_len: int = 8, max_batch: int = 2,
                   cache_len: int = 64, err_budget: Optional[float] = None,
                   max_apply_per_tick: Optional[int] = None,
                   sync_flag: str = "natural", seed: int = 0,
                   obs=None) -> dict:
    """Co-simulate a real smoke trainer with a serving fleet.

    Runs ``steps`` REAL train steps (``launch/train.build_train_step``,
    dense aggregation) on the smoke variant of ``arch`` while ``n_replicas``
    engines serve ``n_requests`` synthetic prompts off the delta stream;
    the returned dict is the ``BENCH_serve_delta.json`` row.  Lazy
    imports keep serving -> launch a runtime edge, not an import-time
    cycle.
    """
    import jax.numpy as jnp

    from repro.comm import SimChannel, build_transport, wire_flag_codec
    from repro.configs import get_smoke_config
    from repro.configs.base import CompressionConfig, TrainConfig
    from repro.data.tokens import TokenStream
    from repro.launch.mesh import make_host_mesh, n_workers
    from repro.launch.train import build_train_step, init_state
    from repro.models import model as M

    cfg = get_smoke_config(arch).with_(dtype="float32")
    mesh = make_host_mesh()
    w = n_workers(mesh)
    comp = CompressionConfig(enabled=False, model_wire=model_wire,
                             publish_every=publish_every)
    tcfg = TrainConfig(learning_rate=lr, total_steps=steps, warmup_steps=1,
                       compression=comp)
    params_shapes = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    transport = build_transport(comp, cfg, SimChannel(), w=w,
                                params_like=params_shapes)

    state = init_state(jax.random.PRNGKey(seed), cfg, tcfg, w)
    step_fn = jax.jit(build_train_step(cfg, tcfg, mesh, w))
    stream = TokenStream(cfg, seq, batch)

    bridge = TrainerFleetBridge(
        cfg, state.params, transport["model"], n_replicas=n_replicas,
        publish_every=publish_every, stale_k=stale_k, err_budget=err_budget,
        key=jax.random.PRNGKey(seed + 1), max_batch=max_batch,
        cache_len=cache_len, max_apply_per_tick=max_apply_per_tick,
        sync_codec=wire_flag_codec(sync_flag), obs=obs,
    )
    rng = jax.random.PRNGKey(seed + 2)
    for i in range(n_requests):
        rng, k = jax.random.split(rng)
        plen = 2 + i % 3
        prompt = [int(t) for t in
                  jax.random.randint(k, (plen,), 0, cfg.vocab_size)]
        bridge.fleet.submit(Request(uid=i, prompt=prompt,
                                    max_new_tokens=gen_len))

    loss = float("nan")
    for i in range(steps):
        state, metrics = step_fn(state, stream.batch(i))
        loss = float(metrics["loss"])
        bridge.on_step(state.params, i + 1)
    bridge.drain()

    stats = bridge.stats()
    stats.update({
        "arch": cfg.name, "model_wire": model_wire,
        "n_replicas": n_replicas, "publish_every": publish_every,
        "stale_k": stale_k, "steps": steps, "final_loss": loss,
        "wire_bytes_per_step": {
            name: bits / 8.0
            for name, bits in transport.per_wire_bits().items()
        },
    })
    return stats
