"""The model-delta publisher: shifted compression of the DOWNLINK.

The paper's framework compresses the difference against a shifting
auxiliary vector; nothing in it says the vector must be a gradient.
Here the published vector is the TRAINER'S PARAMS and the shift is the
serving fleet's current reconstruction: every ``publish_every`` steps
the publisher emits ``Q(params - h_bar)`` through the transport's
``Wire("model", broadcast, ...)`` and integrates the decoded message
into ``h_bar`` with the SAME phased ``EFBVShift`` rule the grad wire
runs — the publisher's shift state is just another rule instance over
params instead of grads (W = 1: the trainer is the only "worker" on
this wire).  As training converges the deltas shrink, so keeping N
replicas fresh costs a vanishing fraction of dense broadcast bytes —
the one regime where compression is free (ROADMAP Open item 5).

Subscriber lockstep is the load-bearing invariant: a replica that has
applied every message holds EXACTLY the publisher's ``h_bar``, because
both sides run the bitwise-identical update expression
``p + eta * m_bar`` (``apply_msg`` mirrors ``EFBVShift.apply``'s
``h_bar`` line).  The publisher therefore KNOWS each in-sync replica's
reconstruction error — it is ``||params - h_bar||``, attached to every
message as ``err_rel`` — and the fleet can trigger a dense ``resync``
on an error budget without ever reading replica state.

Two wire formats:

  * LOSSY flags (q8 / natural / topk / sign / randk): the EF-BV stream
    above.  Error is bounded (the shift recursion contracts it) and
    resets to ZERO at resync.
  * The ``dense`` flag is the LOSSLESS stream — and it is NOT the
    float delta ``p - h`` with an identity codec, because
    ``fl(h + fl(p - h)) != p`` in general (adam-scale updates on
    small-magnitude params break the Sterbenz exactness condition).
    Instead the payload is the INTEGER BIT-PATTERN delta
    ``bitcast_int(p) - bitcast_int(h)`` (wrapping arithmetic), applied
    as ``bitcast_float(bitcast_int(h) + d)`` — exact reconstruction
    for ALL values at identity width, and genuinely delta-shaped (the
    int difference of nearby floats is small, shrinking as training
    converges).  One exact publish makes a replica bit-identical to
    the trainer even after a lossy initial sync.

``resync`` is a full-params REPLACEMENT message (never additive), so a
replica's error after applying it is exactly zero and a lagging
replica can fast-forward to it, discarding older deltas.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.comm.channel import SimChannel
from repro.comm.transport import wire_stream
from repro.core.compressors import Identity, wire_bits
from repro.core.shift_rules import EFBVShift

tmap = jax.tree_util.tree_map

#: bit-pattern integer dtype per float itemsize (the lossless wire)
_INT_OF_ITEMSIZE = {2: jnp.int16, 4: jnp.int32, 8: jnp.int64}


def _int_dtype(leaf):
    itemsize = jnp.dtype(leaf.dtype).itemsize
    if itemsize not in _INT_OF_ITEMSIZE:
        raise ValueError(
            f"no bit-pattern integer dtype for {jnp.dtype(leaf.dtype)} "
            f"(itemsize {itemsize}); have widths "
            f"{sorted(_INT_OF_ITEMSIZE)}"
        )
    return _INT_OF_ITEMSIZE[itemsize]


def _int_delta_leaf(p, h):
    """Wrapping bit-pattern delta: exact for all values, small for
    nearby ones."""
    it = _int_dtype(p)
    return (jax.lax.bitcast_convert_type(p, it)
            - jax.lax.bitcast_convert_type(h, it))


def _int_apply_leaf(h, d):
    """Exact inverse of ``_int_delta_leaf``: recovers ``p`` bitwise."""
    return jax.lax.bitcast_convert_type(
        jax.lax.bitcast_convert_type(h, d.dtype) + d, h.dtype
    )


@jax.jit
def _rel_err(a, b):
    """``||a - b|| / ||a||`` over whole pytrees (f32 accumulation)."""
    num = sum(
        jnp.sum(jnp.square((x - y).astype(jnp.float32)))
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b))
    )
    den = sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(a)
    )
    return jnp.sqrt(num) / (jnp.sqrt(den) + 1e-12)


def tree_rel_err(a, b) -> float:
    return float(_rel_err(a, b))


def dense_tree_bits(tree_like) -> float:
    """Structural bits of one full-width broadcast of ``tree_like`` —
    per-leaf numel at the leaf's TRUE dtype width (the identity payload),
    the baseline every delta publish is measured against."""
    return float(sum(
        wire_bits(jax.ShapeDtypeStruct(leaf.shape, leaf.dtype))
        for leaf in jax.tree_util.tree_leaves(tree_like)
    ))


@dataclasses.dataclass(frozen=True)
class DeltaMsg:
    """One downlink message.  ``payload`` is the DECODED tree (the wire
    would carry the codec payload; ``bits`` charges it structurally,
    the same convention as ``Wire.send``)."""

    kind: str          # "delta" | "resync"
    seq: int           # stream sequence number (applies strictly in order)
    step: int          # trainer step this message brings a subscriber to
    payload: Any       # delta: decoded m_bar (or int bit-delta); resync: params
    scale: float       # delta integration rate (the rule's eta; 1.0 exact)
    exact: bool        # True: integer bit-pattern delta (lossless stream)
    bits: float        # structural wire bits of the payload
    err_rel: float     # publisher-side ||params - h_bar|| / ||params|| AFTER
                       # this message (an in-sync replica's exact error)


def apply_msg(params, msg: DeltaMsg):
    """Subscriber-side apply: the bitwise mirror of the publisher.

    ``resync`` REPLACES (error becomes exactly zero); exact deltas add
    in bit-pattern space; lossy deltas run the same ``p + eta * m_bar``
    expression as ``EFBVShift.apply``'s ``h_bar`` update — identical
    values through identical ops keep replica and publisher in bitwise
    lockstep.
    """
    if msg.kind == "resync":
        return msg.payload
    if msg.exact:
        return tmap(_int_apply_leaf, params, msg.payload)
    return tmap(lambda p, d: p + msg.scale * d, params, msg.payload)


class DeltaPublisher:
    """Trainer-side end of the model wire (see module docstring).

    ``wire`` is the transport's ``Wire("model", broadcast, ...)``; its
    codec defines the stream (``Identity`` selects the exact bit-delta
    path).  ``rule`` must be an ``EFBVShift`` instance — the downlink
    uses its shift integration (``h_bar += eta * m_bar``); the
    estimator knob ``nu`` is a training-side concept and is unused
    here.
    """

    def __init__(self, wire, *, rule: Optional[EFBVShift] = None,
                 key: Optional[jax.Array] = None, track_error: bool = True):
        self.wire = wire
        self.codec = wire.codec
        self.channel = wire.channel if wire.channel is not None else SimChannel()
        self.rule = EFBVShift() if rule is None else rule
        if not isinstance(self.rule, EFBVShift):
            raise ValueError(
                "DeltaPublisher runs the EF-BV shift recursion over "
                f"params; got rule {type(self.rule).__name__} (use "
                "EFBVShift — eta=nu=1 is EF21)"
            )
        self.exact = isinstance(self.codec, Identity)
        self.track_error = track_error
        key = jax.random.PRNGKey(0) if key is None else key
        self._base = wire_stream(key, wire.name)
        self.h_bar = None       # the fleet's reconstruction (= replica params)
        self.seq = 0
        self.step = 0
        self.published_bits = 0.0   # cumulative, deltas + resyncs
        self.delta_bits = []        # per-delta-publish structural bits
        self.err_history = []       # err_rel after each delta publish

    def _emit(self, kind, step, payload, scale, exact, bits, params):
        self.seq += 1
        self.step = int(step)
        self.published_bits += float(bits)
        # err is vs the stream state AFTER this message — exactly 0.0
        # for a snapshot resync (h_bar IS params), the sync-codec error
        # for a lossy initial sync
        err = tree_rel_err(params, self.h_bar) if self.track_error else 0.0
        return DeltaMsg(kind=kind, seq=self.seq, step=int(step),
                        payload=payload, scale=float(scale),
                        exact=bool(exact), bits=float(bits), err_rel=err)

    def initial_sync(self, params, *, step: int = 0,
                     sync_codec=None) -> DeltaMsg:
        """Bootstrap the stream: one full-model broadcast.

        ``sync_codec`` is a ``Compressor`` (default the wire's own
        codec) — Natural Compression makes the bootstrap cheap (~9
        bits/scalar) because the shifted stream corrects its error:
        the publisher's ``h_bar`` is the DECODED sync, so replica and
        publisher start in lockstep regardless of sync fidelity.
        """
        q = self.codec if sync_codec is None else sync_codec
        decoded, bits = self.channel.broadcast(
            q, jax.random.fold_in(self._base, 0), params
        )
        self.h_bar = decoded
        return self._emit("resync", step, decoded, 1.0, False,
                          float(bits), params)

    def publish(self, params, *, step: int) -> DeltaMsg:
        """One shifted-compressed delta publish at trainer ``step``."""
        if self.h_bar is None:
            raise ValueError("publish before initial_sync — the stream "
                             "has no shift state yet")
        if self.exact:
            delta = tmap(_int_delta_leaf, params, self.h_bar)
            self.h_bar = tmap(_int_apply_leaf, self.h_bar, delta)
            bits = dense_tree_bits(delta)
            msg = self._emit("delta", step, delta, 1.0, True, bits, params)
        else:
            # the phased schedule of Channel.shift_round, W = 1: the
            # trainer is the only worker on this wire, h == h_bar
            k = jax.random.fold_in(self._base, self.seq + 1)
            k_msg, _, k_agg = jax.random.split(k, 3)
            wp = tmap(lambda p: p[None], params)
            wh = tmap(lambda hb: hb[None], self.h_bar)
            m, bits = self.rule.message(self.codec, k_msg, wp, wh)
            m_bar = self.channel.reduce_mean(k_agg, m)
            _, _, hb_new = self.rule.apply(wp, m, m_bar, wh, self.h_bar,
                                           None)
            self.h_bar = hb_new
            msg = self._emit("delta", step, m_bar, self.rule.eta, False,
                             float(bits), params)
        self.delta_bits.append(msg.bits)
        self.err_history.append(msg.err_rel)
        return msg

    def snapshot(self, params, *, step: int) -> DeltaMsg:
        """Dense resync: full params at identity width, REPLACEMENT
        semantics.  Resets the stream — ``h_bar`` becomes ``params``
        bitwise, so every subscriber's error returns to exactly zero."""
        self.h_bar = params
        return self._emit("resync", step, params, 1.0, False,
                          dense_tree_bits(params), params)

    def dense_bits_per_publish(self) -> float:
        """The dense-broadcast baseline this stream is measured against."""
        if self.h_bar is None:
            raise ValueError("no shift state yet (initial_sync first)")
        return dense_tree_bits(self.h_bar)
