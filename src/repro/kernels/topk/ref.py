"""Pure-jnp oracle for block Top-K: exact per-block threshold via
lax.top_k, keeping ties like the kernel (|x| >= kth magnitude)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def block_topk_ref(x, *, k: int, block: int):
    """x: (R, 128) viewed as consecutive blocks of ``block`` rows."""
    r, lane = x.shape
    assert r % block == 0
    nb = r // block
    xb = x.reshape(nb, block * lane)
    a = jnp.abs(xb.astype(jnp.float32))
    kth = jax.lax.top_k(a, k)[0][:, -1]          # (nb,) kth magnitude
    mask = a >= kth[:, None]
    out = jnp.where(mask, xb, 0)
    return out.reshape(r, lane).astype(x.dtype)
