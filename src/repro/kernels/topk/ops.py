"""jit'd public wrapper: block Top-K sparsification with keep-fraction q
on arbitrary arrays."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.topk.kernel import (
    DEFAULT_BLOCK_ROWS,
    LANE,
    block_topk_2d,
)


@functools.partial(jax.jit, static_argnames=("q", "block_rows", "interpret"))
def block_topk(x, *, q: float = 0.1, block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: bool = True):
    """Keep ~q of each 8192-element block by magnitude (B(q) operator)."""
    shape, dtype = x.shape, x.dtype
    n = x.size
    rows = -(-n // LANE)
    block = min(block_rows, rows)
    rows_pad = -(-rows // block) * block
    pad = rows_pad * LANE - n
    xf = jnp.pad(jnp.ravel(x), (0, pad)).reshape(rows_pad, LANE)
    k = max(1, int(round(q * block * LANE)))
    out = block_topk_2d(xf, k=k, block_rows=block, interpret=interpret)
    return jnp.ravel(out)[:n].reshape(shape).astype(dtype)
