"""Block Top-K greedy sparsification — Pallas TPU kernel.

TPU adaptation of Top-K (Def. 1, C in B(K/d)): a GLOBAL top-k needs a
full sort — hostile to the TPU memory hierarchy (multiple HBM passes,
no MXU work).  Instead each VMEM-resident block keeps its own top
``k_block = K * block/d`` elements: "block Top-K".  The contraction
property is preserved blockwise with the same delta = K/d (each block
satisfies E||C(x_b)-x_b||^2 <= (1-k_b/n_b)||x_b||^2), and empirically
block Top-K tracks global Top-K closely for i.i.d.-ish gradient noise.

In-block selection uses THRESHOLD BISECTION, not sorting: ~32 VPU-friendly
iterations of "count |x| >= t" narrow t to the k-th magnitude, then a
single masked select keeps everything above the threshold (>= k elements;
ties inflate the kept set, never shrink it — safe for a contraction).

Layout: (rows, 128) lanes; one grid step owns ``block_rows`` rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 64  # block = 64*128 = 8192 elements
BISECT_ITERS = 32


def _block_topk_kernel(x_ref, o_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)
    a = jnp.abs(x)
    hi0 = jnp.max(a)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((a >= mid).astype(jnp.int32))
        keep_raising = cnt >= k
        lo = jnp.where(keep_raising, mid, lo)
        hi = jnp.where(keep_raising, hi, mid)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, BISECT_ITERS, body, (jnp.float32(0.0), hi0))
    o_ref[...] = jnp.where(a >= lo, x, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def block_topk_2d(x, *, k: int, block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = True):
    """x: (R, 128); keeps the top-k magnitudes of each (block_rows, 128)
    block (>= k on exact magnitude ties)."""
    r, lane = x.shape
    assert lane == LANE and r % block_rows == 0
    grid = (r // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_block_topk_kernel, k=k),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
