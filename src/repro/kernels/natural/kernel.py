"""Fused shifted natural-compression estimator — Pallas TPU kernel.

Computes the paper's shifted gradient estimator (eq. 3) in ONE pass over
HBM:

    out = h + C_nat(g - h)

where C_nat is natural compression (stochastic rounding to powers of two,
Horváth et al. 2019a; omega = 1/8).  Unfused, this is 4+ elementwise
passes over two model-sized tensors (diff, abs/log2/exp2 lattice, round,
add-back); fused it is one read of (g, h, u) and one write — the op is
perfectly memory-bound, so the fusion is the entire win.

Randomness enters as a precomputed uniform tensor ``u`` (one f32 per
element) so the kernel is deterministic given inputs and identical under
``interpret=True`` on CPU — in-kernel ``pltpu.prng_random_bits`` would
tie validation to TPU hardware.

Layout: inputs are reshaped to (rows, 128) by ``ops.py``; the grid tiles
rows in blocks of ``block_rows`` (sublane-aligned, default 256 rows →
128 KiB f32 per operand tile in VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 256


def _shifted_natural_kernel(g_ref, h_ref, u_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    u = u_ref[...]
    x = g - h
    a = jnp.abs(x)
    e = jnp.floor(jnp.log2(jnp.maximum(a, 1e-38)))
    lo = jnp.exp2(e)
    p_hi = a / lo - 1.0                       # in [0, 1)
    q = jnp.where(u < p_hi, 2.0 * lo, lo)
    q = jnp.where(a == 0.0, 0.0, q) * jnp.sign(x)
    o_ref[...] = (h + q).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def shifted_natural_2d(g, h, u, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                       interpret: bool = True):
    """g, h: (R, 128) same dtype; u: (R, 128) f32 in [0,1)."""
    r, lane = g.shape
    assert lane == LANE and g.shape == h.shape == u.shape
    assert r % block_rows == 0
    grid = (r // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        _shifted_natural_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=interpret,
    )(g, h, u)
