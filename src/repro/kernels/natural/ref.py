"""Pure-jnp oracle for the fused shifted natural-compression estimator."""

from __future__ import annotations

import jax.numpy as jnp


def shifted_natural_ref(g, h, u):
    """out = h + C_nat(g - h) with the SAME uniforms as the kernel."""
    x = g.astype(jnp.float32) - h.astype(jnp.float32)
    a = jnp.abs(x)
    e = jnp.floor(jnp.log2(jnp.maximum(a, 1e-38)))
    lo = jnp.exp2(e)
    p_hi = a / lo - 1.0
    q = jnp.where(u.astype(jnp.float32) < p_hi, 2.0 * lo, lo)
    q = jnp.where(a == 0.0, 0.0, q) * jnp.sign(x)
    return (h.astype(jnp.float32) + q).astype(g.dtype)
