"""jit'd public wrapper: fused shifted natural compression on arbitrary
arrays (flatten -> pad to (rows,128) -> kernel -> unpad)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.natural.kernel import (
    DEFAULT_BLOCK_ROWS,
    LANE,
    shifted_natural_2d,
)


@functools.partial(jax.jit, static_argnames=("interpret",))
def shifted_natural(key, g, h, *, interpret: bool = True):
    """h + C_nat(g - h) for any-shape g/h (same shape & dtype)."""
    shape, dtype = g.shape, g.dtype
    n = g.size
    rows = -(-n // LANE)
    block = min(DEFAULT_BLOCK_ROWS, rows)
    rows_pad = -(-rows // block) * block
    pad = rows_pad * LANE - n

    gf = jnp.pad(jnp.ravel(g), (0, pad)).reshape(rows_pad, LANE)
    hf = jnp.pad(jnp.ravel(h), (0, pad)).reshape(rows_pad, LANE)
    u = jax.random.uniform(key, (rows_pad, LANE), jnp.float32)
    out = shifted_natural_2d(gf, hf, u, block_rows=block, interpret=interpret)
    return jnp.ravel(out)[:n].reshape(shape).astype(dtype)
