"""Pure-jnp oracle for the WKV6 kernel: the exact sequential recurrence
(independent re-implementation; the model's ``rwkv6.wkv_scan`` is tested
against this too)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u):
    """r,k,w: (BH, T, K); v: (BH, T, V); u: (BH, K).
    Returns (y (BH,T,V) f32, s_final (BH,K,V) f32)."""
    bh, t, dk = r.shape
    dv = v.shape[-1]
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)
    s0 = jnp.zeros((bh, dk, dv), jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs                       # (BH,K),(BH,K),(BH,V),(BH,K)
        kv = kt[:, :, None] * vt[:, None, :]      # (BH,K,V)
        y = jnp.einsum("bk,bkv->bv", rt, s + uf[:, :, None] * kv)
        return wt[:, :, None] * s + kv, y

    xs = (rf.transpose(1, 0, 2), kf.transpose(1, 0, 2),
          vf.transpose(1, 0, 2), wf.transpose(1, 0, 2))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2), s_fin
