"""jit'd public wrapper: WKV6 on model-layout tensors (B, T, H, K)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import DEFAULT_CHUNK, wkv6_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk: int = DEFAULT_CHUNK,
         interpret: bool = True):
    """Model-layout WKV6.  r,k,w: (B,T,H,K); v: (B,T,H,V); u: (H,K).
    Returns (y (B,T,H,V) f32, s_final (B,H,K,V) f32) — drop-in for
    ``repro.models.rwkv6.wkv_scan`` with zero initial state."""
    b, t, h, dk = r.shape
    dv = v.shape[-1]

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, t, x.shape[-1])

    rb, kb, vb, wb = map(to_bh, (r, k, v, w))
    ub = jnp.broadcast_to(u[None], (b, h, dk)).reshape(b * h, dk)
    y, s = wkv6_pallas(rb, kb, vb, wb, ub, chunk=chunk, interpret=interpret)
    y = y.reshape(b, h, t, dv).transpose(0, 2, 1, 3)
    return y, s.reshape(b, h, dk, dv)
