"""RWKV-6 WKV recurrence — Pallas TPU kernel.

Per (batch, head): state S in R^{K x V} (K = V = 64 for Finch);

    y_t = r_t (S + u * k_t v_t^T)
    S  <- diag(w_t) S + k_t v_t^T

The sequence is streamed through VMEM in time-chunks: grid =
(B*H, T/chunk) with the LAST grid dim sequential ("arbitrary"
dimension_semantics on TPU), so the state scratch persists across the
chunk iterations of one (b,h) program while r/k/v/w tiles stream
HBM->VMEM.  All state math is f32 (the recurrence is numerically
delicate under bf16 accumulation); inputs may be bf16.

This is the hardware adaptation of the cuda-style wkv kernel shipped
with RWKV: the GPU version parallelizes over (b,h) thread-blocks with
shared-memory state — here (b,h) maps to the parallel grid dim and the
state lives in VMEM scratch instead.

Within a chunk the time loop is a ``fori_loop`` of rank-1 updates
(K x V outer products): VPU work, deliberately NOT the matmul-chunked
form whose factored decay exponentials overflow for extreme
data-dependent decays.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, s_ref,
                 *, chunk: int, n_chunks: int):
    t_idx = pl.program_id(1)

    @pl.when(t_idx == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[...].astype(jnp.float32)           # (1, K)

    def step(i, s):
        r = r_ref[0, i, :].astype(jnp.float32)   # (K,)
        k = k_ref[0, i, :].astype(jnp.float32)
        v = v_ref[0, i, :].astype(jnp.float32)
        w = w_ref[0, i, :].astype(jnp.float32)
        kv = k[:, None] * v[None, :]             # (K, V)
        y = jnp.sum((s + u[0][:, None] * kv) * r[:, None], axis=0)  # (V,)
        y_ref[0, i, :] = y.astype(y_ref.dtype)
        return w[:, None] * s + kv

    s = jax.lax.fori_loop(0, chunk, step, s_ref[...])
    s_ref[...] = s

    @pl.when(t_idx == n_chunks - 1)
    def _final():
        s_out_ref[0, :, :] = s


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, w, u, *, chunk: int = DEFAULT_CHUNK,
                interpret: bool = True):
    """r,k,w: (BH, T, K); v: (BH, T, V); u: (BH, K).
    Returns (y (BH, T, V) f32, s_final (BH, K, V) f32)."""
    bh, t, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk

    seq_spec = lambda: pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0))
    vseq_spec = pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0))
    u_spec = pl.BlockSpec((1, dk), lambda b, c: (b, 0))
    sfin_spec = pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0))

    y, s_fin = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=(bh, n_chunks),
        in_specs=[seq_spec(), seq_spec(), vseq_spec, seq_spec(), u_spec],
        out_specs=[vseq_spec, sfin_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(r, k, v, w, u)
    return y, s_fin
