"""Pure-jnp oracles for the fused q8 ring kernels: per-tile max-scale
int8 stochastic rounding and dequant-accumulate, tile semantics exactly
as the kernels (one scale per (block, 128) row block)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.q8ring.kernel import LANE, LEVELS, SCALE_FLOOR


def q8_quantize_ref(x, u, *, block: int):
    """x, u: (R, 128); returns (q int8 (R, 128), scales f32 (R//block, 1))."""
    r, lane = x.shape
    assert lane == LANE and r % block == 0
    nb = r // block
    xb = x.astype(jnp.float32).reshape(nb, block * lane)
    scales = jnp.maximum(jnp.max(jnp.abs(xb), axis=1), SCALE_FLOOR) / LEVELS
    y = xb / scales[:, None]
    lo = jnp.floor(y)
    up = (u.reshape(nb, block * lane) < (y - lo)).astype(jnp.float32)
    q = (lo + up).astype(jnp.int8).reshape(r, lane)
    return q, scales[:, None]


def q8_dequant_add_ref(q, scales, acc, *, block: int):
    """acc + q * scale with one scale per (block, 128) row block."""
    r, lane = q.shape
    nb = r // block
    deq = q.astype(jnp.float32).reshape(nb, block * lane) * scales
    return acc + deq.reshape(r, lane)
