"""Fused int8 quantize + ring-hop chunk select — Pallas TPU kernels.

The q8 ring all-reduce (``dist.collectives``) spends its per-hop time in
pure memory traffic: slice the rotating send chunk out of the local
buffer, compute a quantization scale, stochastic-round to int8, and (on
receive) dequantize and accumulate.  Unfused that is 4+ elementwise
passes over the f32 chunk plus a materialized f32 copy for the slice;
fused it is ONE read of the chunk and one s8 write per hop:

  ``_q8_quantize_kernel``      per-tile max-|x| scale + unbiased
        stochastic rounding to int8 in a single pass.  Scales are
        per (block_rows, 128) TILE, not per tensor — strictly tighter
        than ``Int8Stochastic``'s per-tensor scale, and the scale
        reduction never needs a second pass over HBM.
  ``q8_quantize_chunk_3d``     the ring-hop variant: the send chunk
        rotates every hop (send_id = (device - t) mod n), so the chunk
        GATHER is folded into the kernel's block index_map via a
        scalar-prefetch chunk id — the f32 chunk copy that
        ``dynamic_slice`` would materialize never exists.
  ``_q8_dequant_add_kernel``   receive side: dequantize + accumulate
        into the reduction buffer in one pass (acc + q * scale).

Randomness enters as a precomputed uniform tensor (one f32 per element)
so kernels are deterministic given inputs and identical under
``interpret=True`` on CPU — in-kernel ``pltpu.prng_random_bits`` would
tie validation to TPU hardware (same policy as ``kernels.natural``).

Layout: (rows, 128) lanes, tiled in ``block_rows`` row blocks; the 3-d
chunk variant sees the ring buffer as (n_chunks, rows, 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
DEFAULT_BLOCK_ROWS = 64   # 64*128 f32 = 32 KiB per operand tile in VMEM
LEVELS = 127              # int8 quantization lattice [-127, 127]
SCALE_FLOOR = 1e-30       # well above subnormal: tiny/LEVELS must not flush


def _q8_quantize_kernel(x_ref, u_ref, q_ref, s_ref):
    """One tile: scale = max|x|/LEVELS, q = stochastic_round(x/scale)."""
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), SCALE_FLOOR) / LEVELS
    y = x / scale
    lo = jnp.floor(y)
    up = (u_ref[...] < (y - lo)).astype(jnp.float32)
    q_ref[...] = (lo + up).astype(jnp.int8)
    s_ref[0, 0] = scale


def _q8_chunk_kernel(cid_ref, x_ref, u_ref, q_ref, s_ref):
    """Chunk-select variant: x_ref is the (1, block, LANE) tile of the
    chunk picked by the scalar-prefetch id (see index_map below)."""
    x = x_ref[0].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), SCALE_FLOOR) / LEVELS
    y = x / scale
    lo = jnp.floor(y)
    up = (u_ref[...] < (y - lo)).astype(jnp.float32)
    q_ref[...] = (lo + up).astype(jnp.int8)
    s_ref[0, 0] = scale


def _q8_dequant_add_kernel(q_ref, s_ref, acc_ref, o_ref):
    o_ref[...] = acc_ref[...] + q_ref[...].astype(jnp.float32) * s_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def q8_quantize_2d(x, u, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = True):
    """x: (R, 128) f32; u: (R, 128) uniforms.  Returns
    (q: (R, 128) int8, scales: (R//block_rows, 1) f32) — one scale per
    row-block tile."""
    r, lane = x.shape
    assert lane == LANE and u.shape == x.shape and r % block_rows == 0
    grid = (r // block_rows,)
    tile = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        _q8_quantize_kernel,
        grid=grid,
        in_specs=[tile, tile],
        out_specs=[tile, pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, jnp.int8),
            jax.ShapeDtypeStruct((r // block_rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, u)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def q8_quantize_chunk_3d(chunks, u, chunk_id, *,
                         block_rows: int = DEFAULT_BLOCK_ROWS,
                         interpret: bool = True):
    """Fused ring-hop gather + quantize.

    chunks: (n, R, 128) f32 ring buffer; chunk_id: int32 scalar (may be
    traced — it is the rotating send id inside the ring loop); u:
    (R, 128) uniforms.  Quantizes ONLY chunk ``chunk_id``: the block
    index_map reads the scalar-prefetch id, so the gather happens in the
    kernel's DMA and no f32 chunk copy is materialized.  Returns the
    same (q, scales) pair as ``q8_quantize_2d`` on ``chunks[chunk_id]``.
    """
    n, r, lane = chunks.shape
    assert lane == LANE and u.shape == (r, lane) and r % block_rows == 0
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((1, block_rows, LANE), lambda i, cid: (cid[0], i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i, cid: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i, cid: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, cid: (i, 0)),
        ],
    )
    return pl.pallas_call(
        _q8_chunk_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((r, LANE), jnp.int8),
            jax.ShapeDtypeStruct((r // block_rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(chunk_id, jnp.int32).reshape(1), chunks, u)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def q8_dequant_add_2d(q, scales, acc, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                      interpret: bool = True):
    """acc + dequant(q, scales) in one pass.  q: (R, 128) int8, scales:
    (R//block_rows, 1) f32, acc: (R, 128) f32."""
    r, lane = q.shape
    assert lane == LANE and acc.shape == q.shape and r % block_rows == 0
    assert scales.shape == (r // block_rows, 1)
    grid = (r // block_rows,)
    tile = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        _q8_dequant_add_kernel,
        grid=grid,
        in_specs=[tile, pl.BlockSpec((1, 1), lambda i: (i, 0)), tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        interpret=interpret,
    )(q, scales, acc)
