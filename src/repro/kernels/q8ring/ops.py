"""Public wrappers for the fused q8 ring kernels + the ``FusedQ8`` codec.

``FusedQ8`` is a wire codec (``repro.core.compressors`` protocol) whose
encode IS the fused Pallas kernel: int8 stochastic quantization with one
f32 scale per (block_rows, 128) tile.  Blockwise scales are strictly
tighter than ``Int8Stochastic``'s per-tensor scale (each tile's lattice
spans only that tile's max), the scale sidecar costs 32 bits per
``block_rows * 128`` int8 elements (~0.05% of the payload at the
default 64-row tile; ~0.4% at the (8, 128) hardware-floor tile), and —
the point — scale-compute, quantize, and the ring's rotating chunk
gather fuse into a single memory pass on the hop hot path
(``dist.collectives._ring_allreduce_fused``).

``fused_ring = True`` marks the codec so ``q8_ring_tree_mean`` takes the
fused ring (chunk-select folded into the kernel) instead of the generic
encoded-payload ring.  The codec also works standalone anywhere a
meta-free codec does (broadcast downlink, the pod tree stage, the
``q8_block`` registry name).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.compressors import Unbiased
from repro.kernels.q8ring.kernel import (
    DEFAULT_BLOCK_ROWS,
    LANE,
    LEVELS,
    q8_dequant_add_2d,
    q8_quantize_2d,
)


def _tile_rows(rows: int, block_rows: int):
    """THE tile rule, in one place: clamp the block to the row count
    (scalar and sub-tile inputs still get exactly one scale) and pad
    rows to a block multiple.  Interpret mode does not enforce TPU
    sublane tiling — on hardware the (8, 128) f32 tile would set the
    floor HERE, and every layout (codec encode + ring chunks) follows.
    """
    block = min(block_rows, rows)
    return -(-rows // block) * block, block


def q8_layout(d: int, block_rows: int = DEFAULT_BLOCK_ROWS):
    """(rows, block, rows_pad) for a d-element vector laid out (rows, 128)."""
    rows = max(1, -(-d // LANE))
    rows_pad, block = _tile_rows(rows, block_rows)
    return rows, block, rows_pad


def ring_chunk_layout(d: int, n: int, block_rows: int = DEFAULT_BLOCK_ROWS):
    """(rows_c, block) for an n-chunk ring over a d-element vector: the
    lane rows split n ways, each chunk padded to the same tile grid as
    ``q8_layout`` (one rule — see ``_tile_rows``)."""
    rows = max(1, -(-d // LANE))
    rows_c, block = _tile_rows(-(-rows // n), block_rows)
    return rows_c, block


def to_lanes(x, rows_pad: int):
    """Flatten + zero-pad an array to the (rows_pad, 128) kernel layout."""
    flat = jnp.ravel(x).astype(jnp.float32)
    return jnp.pad(flat, (0, rows_pad * LANE - flat.shape[0])).reshape(
        rows_pad, LANE
    )


def q8_dequant(q, scales, *, block: int, interpret: bool = True):
    """Dequantize a (R, 128) int8 block with per-tile scales: fused
    dequant-add against a zero accumulator (same single kernel serves
    both the receive-accumulate and plain-decode paths)."""
    return q8_dequant_add_2d(
        q, scales, jnp.zeros(q.shape, jnp.float32), block_rows=block,
        interpret=interpret,
    )


@dataclass(frozen=True)
class FusedQ8(Unbiased):
    """Blockwise-scale int8 stochastic quantization, Pallas-fused.

    Payload: int8 lanes block (padded to the tile grid) + one f32 scale
    per tile — both travel, so ``wire_bits`` is structural as usual.
    Meta-free: the ring and pod tree stages may forward the payload.
    Unbiased (stochastic rounding): omega <= d / (4 * LEVELS^2), the
    per-tensor-scale bound (blockwise scales only shrink the error).

    ``interpret=None`` (the default) resolves per backend at call time:
    compiled kernels on TPU, the Pallas interpreter everywhere else —
    so the production comm mode never silently interprets on hardware,
    and CPU tests need no flag.
    """

    block_rows: int = DEFAULT_BLOCK_ROWS
    interpret: Optional[bool] = None

    #: q8_ring_tree_mean dispatches to the chunk-fused ring on this flag
    fused_ring = True

    @property
    def run_interpret(self) -> bool:
        if self.interpret is None:
            return jax.default_backend() != "tpu"
        return self.interpret

    def encode(self, key, x):
        d = int(x.size)
        rows, block, rows_pad = q8_layout(d, self.block_rows)
        x2 = to_lanes(x, rows_pad)
        u = jax.random.uniform(key, x2.shape)
        q, scales = q8_quantize_2d(
            x2, u, block_rows=block, interpret=self.run_interpret
        )
        return {"q": q, "scale": scales}, {}

    def decode(self, payload, meta, shape_dtype):
        d = 1
        for s in shape_dtype.shape:
            d *= s
        nb = payload["scale"].shape[0]
        block = payload["q"].shape[0] // nb
        out = q8_dequant(payload["q"], payload["scale"], block=block,
                         interpret=self.run_interpret)
        return (
            jnp.ravel(out)[:d]
            .reshape(shape_dtype.shape)
            .astype(shape_dtype.dtype)
        )

    def omega(self, d):
        return d / (4.0 * LEVELS**2)
