"""Optimizers (pure-pytree, no optax dependency)."""

from repro.optim.optimizers import (
    OptState,
    adamw,
    cosine_schedule,
    make_optimizer,
    sgd,
)
