"""SGD / AdamW with cosine schedule — pure pytree transformations.

State is a NamedTuple of pytrees; moments are kept in f32 regardless of
the (possibly bf16) parameter dtype.  ``update(grads, state, params)``
returns (new_params, new_state) so the training step stays one-liner.
Optimizer-state sharding (ZeRO-1) is applied by the launcher via
``params_pspecs`` on the moment trees.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


class OptState(NamedTuple):
    step: jax.Array
    m: Any          # first moment (or None-like zeros for sgd w/o momentum)
    v: Any          # second moment (adamw only; zeros for sgd)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


@dataclass(frozen=True)
class adamw:
    lr: Callable | float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params) -> OptState:
        zeros = tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), zeros, zeros)

    def update(self, grads, state: OptState, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.beta1, self.beta2
        m = tmap(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
                 state.m, grads)
        v = tmap(lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                 state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, mm, vv):
            mhat = mm / bc1
            vhat = vv / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = tmap(upd, params, m, v)
        return new_params, OptState(step, m, v)


@dataclass(frozen=True)
class sgd:
    lr: Callable | float = 1e-2
    momentum: float = 0.0

    def init(self, params) -> OptState:
        zeros = tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), zeros, tmap(lambda p: jnp.zeros((), jnp.float32), params))

    def update(self, grads, state: OptState, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        if self.momentum > 0:
            m = tmap(lambda mm, g: self.momentum * mm + g.astype(jnp.float32),
                     state.m, grads)
        else:
            m = tmap(lambda g: g.astype(jnp.float32), grads)
        new_params = tmap(
            lambda p, mm: (p.astype(jnp.float32) - lr * mm).astype(p.dtype),
            params, m,
        )
        return new_params, OptState(step, m, state.v)


def make_optimizer(train_cfg) -> adamw | sgd:
    lr = cosine_schedule(train_cfg.learning_rate, train_cfg.warmup_steps,
                         train_cfg.total_steps)
    if train_cfg.optimizer == "adamw":
        return adamw(lr=lr, beta1=train_cfg.beta1, beta2=train_cfg.beta2,
                     eps=train_cfg.eps, weight_decay=train_cfg.weight_decay)
    if train_cfg.optimizer == "sgd":
        return sgd(lr=lr)
    raise ValueError(train_cfg.optimizer)
