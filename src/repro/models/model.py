"""Unified LM assembly: every assigned architecture behind one API.

    init_params(key, cfg)                  -> params pytree
    train_loss(params, cfg, batch)         -> (loss, metrics)
    forward_train(params, cfg, batch)      -> (logits, aux) [= prefill math]
    decode_step(params, cfg, tok, state, pos) -> (logits, new_state)
    make_decode_state(cfg, b, cache_len)   -> zero-initialized state

Prefill is served as forward_train (logits) or token-by-token through
decode_step (the serving engine's prefill-as-decode); a fused
batch-prefill-into-cache path is a possible future addition (the
per-layer attention_prefill/mla_prefill primitives exist in
layers.py/mla.py).

Homogeneous layer stacks are scanned (``lax.scan`` over a leading layer
axis) so the HLO is O(1) in depth — essential for 512-device AOT
compiles of 60-layer models.  Heterogeneous pieces (DeepSeek's leading
dense layer, Zamba2's shared attention block) are separate stacks /
shared params applied at statically-known positions.

``batch`` dict:  tokens (B,S) int32 always; ``prefix`` (B,P,D) for VLM
patch embeddings; ``frames`` (B,S_src,D) for the audio encoder.  The
modality frontends are stubs per the brief — the specs provide embeddings
of the right shape.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import mamba2 as M2
from repro.models import rwkv6 as R6

Params = Dict[str, Any]
tmap = jax.tree_util.tree_map


def _stack_init(fn, key, n: int):
    """vmap an init over n layer keys -> params stacked on axis 0."""
    if n == 0:
        return None
    return jax.vmap(fn)(jax.random.split(key, n))


# --------------------------------------------------------------------------
# Per-family block definitions (init + train-forward + decode)
# --------------------------------------------------------------------------


def _init_dense_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    attn = MLA.init_mla(k1, cfg) if cfg.use_mla else L.init_attention(k1, cfg)
    return {
        "attn_norm": L.init_rmsnorm(cfg.d_model, cfg),
        "attn": attn,
        "mlp_norm": L.init_rmsnorm(cfg.d_model, cfg),
        "mlp": L.init_mlp(k2, cfg),
    }


def _init_moe_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    attn = MLA.init_mla(k1, cfg) if cfg.use_mla else L.init_attention(k1, cfg)
    return {
        "attn_norm": L.init_rmsnorm(cfg.d_model, cfg),
        "attn": attn,
        "mlp_norm": L.init_rmsnorm(cfg.d_model, cfg),
        "moe": MOE.init_moe(k2, cfg),
    }


def _attn_apply(p, x, cfg):
    if cfg.use_mla:
        return MLA.mla_apply(p, x, cfg)
    return L.attention_apply(p, x, cfg)


def _seqshard(x):
    """Sequence parallelism: the (B,S,D) residual stream lives sharded
    over "model" on S — so the remat'd layer-scan carry is S/16 per
    device, not the full sequence."""
    return L.shard_hint(x, None, "model", None)


def _dense_block_fwd(p, x, cfg):
    x = x + _attn_apply(p["attn"], L.rmsnorm(p["attn_norm"], x, cfg.norm_eps), cfg)
    x = x + L.mlp_apply(p["mlp"], L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
    return _seqshard(x), jnp.zeros((), jnp.float32)


def _moe_block_fwd(p, x, cfg, wire=None, key=None):
    x = x + _attn_apply(p["attn"], L.rmsnorm(p["attn_norm"], x, cfg.norm_eps), cfg)
    y, aux = MOE.moe_apply(
        p["moe"], L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps), cfg,
        wire=wire, key=key,
    )
    return _seqshard(x + y), aux


def _init_rwkv_block(key, cfg):
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, cfg),
        "ln2": L.init_rmsnorm(cfg.d_model, cfg),
        **R6.init_rwkv_block(key, cfg),
    }


def _rwkv_block_fwd(p, x, cfg, state=None):
    tm_state = None if state is None else (state["tm_last"], state["wkv"])
    y, (tm_last, wkv) = R6.time_mix_apply(
        p["time"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg, tm_state
    )
    x = x + y
    cm_state = None if state is None else state["cm_last"]
    y, cm_last = R6.channel_mix_apply(
        p["channel"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cm_state
    )
    return x + y, {"tm_last": tm_last, "wkv": wkv, "cm_last": cm_last}


def _init_mamba_block(key, cfg):
    return {"norm": L.init_rmsnorm(cfg.d_model, cfg), "m2": M2.init_mamba2(key, cfg)}


def _mamba_block_fwd(p, x, cfg, state=None):
    y, s = M2.mamba2_apply(p["m2"], L.rmsnorm(p["norm"], x, cfg.norm_eps), cfg, state)
    return x + y, s


# --------------------------------------------------------------------------
# Segmenting (hybrid / leading-dense layouts), statically derived from cfg
# --------------------------------------------------------------------------


def _zamba_segments(cfg: ModelConfig):
    """[(n_mamba_layers, attn_after: bool), ...] covering cfg.n_layers."""
    segs = []
    rest = cfg.n_layers
    period = cfg.attn_every
    while rest > 0:
        n = min(period, rest)
        segs.append((n, n == period))
        rest -= n
    return segs


# --------------------------------------------------------------------------
# Top-level init
# --------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    p: Params = {"embed": L.init_embedding(keys[0], cfg)}

    at = cfg.arch_type
    if at in ("dense", "vlm"):
        p["blocks"] = _stack_init(
            lambda k: _init_dense_block(k, cfg), keys[1], cfg.n_layers
        )
    elif at == "moe":
        nd = cfg.first_dense_layers
        p["dense_blocks"] = _stack_init(
            lambda k: _init_dense_block(k, cfg), keys[1], nd
        )
        p["moe_blocks"] = _stack_init(
            lambda k: _init_moe_block(k, cfg), keys[2], cfg.n_layers - nd
        )
    elif at == "ssm":
        p["blocks"] = _stack_init(
            lambda k: _init_rwkv_block(k, cfg), keys[1], cfg.n_layers
        )
    elif at == "hybrid":
        p["blocks"] = _stack_init(
            lambda k: _init_mamba_block(k, cfg), keys[1], cfg.n_layers
        )
        p["shared_attn"] = _init_dense_block(keys[2], cfg)
    elif at == "audio":
        p["enc_blocks"] = _stack_init(
            lambda k: _init_dense_block(k, cfg), keys[1], cfg.n_enc_layers
        )
        p["blocks"] = _stack_init(
            lambda k: {
                **_init_dense_block(k, cfg),
                "xattn_norm": L.init_rmsnorm(cfg.d_model, cfg),
                "xattn": L.init_cross_attention(
                    jax.random.fold_in(k, 7), cfg
                ),
            },
            keys[2],
            cfg.n_layers,
        )
    else:
        raise ValueError(f"unknown arch_type {at!r}")

    p["final_norm"] = L.init_rmsnorm(cfg.d_model, cfg)
    p["head"] = L.init_lm_head(keys[3], cfg)
    return p


# --------------------------------------------------------------------------
# Training forward
# --------------------------------------------------------------------------


def _scan_blocks(fwd, stacked, x, cfg, remat: bool = True):
    """Scan x through a stacked homogeneous block pytree; sums aux."""
    def body(carry, lp):
        y, aux = fwd(lp, carry, cfg)
        return y, aux
    if remat:
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, stacked)
    return x, jnp.sum(auxs)


def _scan_blocks_wired(fwd, stacked, x, cfg, *, act_wire=None, act_key=None,
                       layer_offset: int = 0, remat: bool = True):
    """``_scan_blocks`` for transport-wired stacks: ``fwd`` also receives
    the global layer index (for per-layer wire keys), and with an
    ``act_wire`` each block boundary rides the activation wire.  The
    act-wire error-feedback shift is part of the scan carry — zeroed at
    step start, threaded across layers; ``layer_offset`` keeps layer
    indices (hence wire keys) globally unique across split stacks.
    """
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]

    def body(carry, inp):
        lp, li = inp
        if act_wire is None:
            y, aux = fwd(lp, carry, cfg, li)
            return y, aux
        h, e = carry
        y, aux = fwd(lp, h, cfg, li)
        y, e = L.wire_boundary(act_wire, jax.random.fold_in(act_key, li), y, e)
        return (y, e), aux

    if remat:
        body = jax.checkpoint(body)
    xs = (stacked, jnp.arange(layer_offset, layer_offset + n))
    if act_wire is None:
        x, auxs = jax.lax.scan(body, x, xs)
    else:
        (x, _), auxs = jax.lax.scan(body, (x, jnp.zeros_like(x)), xs)
    return x, jnp.sum(auxs)


def _embed_inputs(params, cfg: ModelConfig, batch) -> jax.Array:
    x = L.embed(params["embed"], batch["tokens"])
    if cfg.modality == "vision_prefix":
        x = jnp.concatenate([batch["prefix"].astype(x.dtype), x], axis=1)
    if cfg.arch_type in ("dense", "vlm", "moe"):
        x = _seqshard(x)
    return x


def _encoder(params, cfg: ModelConfig, frames) -> jax.Array:
    """Bidirectional encoder over (precomputed) frame embeddings."""
    x = frames.astype(L.pdtype(cfg))

    def fwd(p, h, c):
        h = h + _bidir_attn(p["attn"], L.rmsnorm(p["attn_norm"], h, c.norm_eps), c)
        h = h + L.mlp_apply(p["mlp"], L.rmsnorm(p["mlp_norm"], h, c.norm_eps))
        return h, jnp.zeros((), jnp.float32)

    x, _ = _scan_blocks(fwd, params["enc_blocks"], x, cfg)
    return x


def _bidir_attn(p, x, cfg: ModelConfig):
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q, k, v = L._qkv(p, x, cfg, positions)
    out = L.chunked_attention(
        q, k, v, causal=False, q_offset=jnp.int32(0),
        k_positions=jnp.arange(s, dtype=jnp.int32),
        q_chunk=cfg.attn_q_chunk,
    )
    return L._out_proj(out, p["wo"])


def forward_train(params, cfg: ModelConfig, batch, wires=None,
                  wire_key=None) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits over text positions, aux_loss).

    ``wires`` / ``wire_key``: optional transport (``repro.comm.Transport``
    or any mapping with ``.get``) carrying the non-gradient wires — the
    ``act`` wire compresses each block-boundary residual, the ``moe``
    wire the expert dispatch/combine buffers (see ARCHITECTURE.md,
    Transport layer).  ``wires=None`` (default) is the unwired path,
    bitwise-identical to before the transport existed.
    """
    at = cfg.arch_type
    aux = jnp.zeros((), jnp.float32)
    act_wire = wires.get("act") if wires is not None else None
    moe_wire = wires.get("moe") if wires is not None else None
    if act_wire is not None or moe_wire is not None:
        from repro.comm.transport import wire_stream

        k_act = wire_stream(wire_key, "act")
        k_moe = wire_stream(wire_key, "moe")

    if at == "audio":
        enc_out = _encoder(params, cfg, batch["frames"])
        x = L.embed(params["embed"], batch["tokens"])

        def fwd(p, h, c):
            h = h + _attn_apply(p["attn"], L.rmsnorm(p["attn_norm"], h, c.norm_eps), c)
            kv = L.cross_attention_kv(p["xattn"], enc_out, c)
            h = h + L.cross_attention_apply(
                p["xattn"], L.rmsnorm(p["xattn_norm"], h, c.norm_eps), kv, c
            )
            h = h + L.mlp_apply(p["mlp"], L.rmsnorm(p["mlp_norm"], h, c.norm_eps))
            return h, jnp.zeros((), jnp.float32)

        x, _ = _scan_blocks(fwd, params["blocks"], x, cfg)

    elif at in ("dense", "vlm"):
        x = _embed_inputs(params, cfg, batch)
        if act_wire is None:
            x, _ = _scan_blocks(_dense_block_fwd, params["blocks"], x, cfg)
        else:
            x, _ = _scan_blocks_wired(
                lambda p, h, c, li: _dense_block_fwd(p, h, c),
                params["blocks"], x, cfg,
                act_wire=act_wire, act_key=k_act,
            )
        if at == "vlm":
            x = x[:, batch["prefix"].shape[1]:]

    elif at == "moe":
        x = _embed_inputs(params, cfg, batch)
        if act_wire is None and moe_wire is None:
            if params.get("dense_blocks") is not None:
                x, _ = _scan_blocks(_dense_block_fwd, params["dense_blocks"], x, cfg)
            x, aux = _scan_blocks(_moe_block_fwd, params["moe_blocks"], x, cfg)
        else:
            nd = cfg.first_dense_layers
            if params.get("dense_blocks") is not None:
                x, _ = _scan_blocks_wired(
                    lambda p, h, c, li: _dense_block_fwd(p, h, c),
                    params["dense_blocks"], x, cfg,
                    act_wire=act_wire, act_key=k_act,
                )

            def moe_fwd(p, h, c, li):
                k = None if moe_wire is None else jax.random.fold_in(k_moe, li)
                return _moe_block_fwd(p, h, c, wire=moe_wire, key=k)

            x, aux = _scan_blocks_wired(
                moe_fwd, params["moe_blocks"], x, cfg,
                act_wire=act_wire, act_key=k_act, layer_offset=nd,
            )

    elif at == "ssm":
        x = _embed_inputs(params, cfg, batch)
        def fwd(p, h, c):
            return _rwkv_block_fwd(p, h, c, None)[0], jnp.zeros((), jnp.float32)
        x, _ = _scan_blocks(fwd, params["blocks"], x, cfg)

    elif at == "hybrid":
        x = _embed_inputs(params, cfg, batch)
        def fwd(p, h, c):
            return _mamba_block_fwd(p, h, c, None)[0], jnp.zeros((), jnp.float32)
        off = 0
        for n, attn_after in _zamba_segments(cfg):
            seg = tmap(lambda a: jax.lax.slice_in_dim(a, off, off + n, axis=0),
                       params["blocks"])
            x, _ = _scan_blocks(fwd, seg, x, cfg)
            if attn_after:
                x, _ = _dense_block_fwd(params["shared_attn"], x, cfg)
            off += n
    else:
        raise ValueError(at)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.lm_head(params["head"], x, cfg, params["embed"])
    return logits, aux


def train_loss(params, cfg: ModelConfig, batch, wires=None, wire_key=None,
               param_tap=None):
    """``param_tap``: optional identity-valued wrapper applied to the
    param tree before the forward pass.  The fused-backward encode path
    (``repro.comm.fused_vjp.encode_on_backward``) taps every layer's
    params here, so each leaf's cotangent is intercepted — and its
    shifted-compressed wire message emitted — at the exact point
    backprop produces it, inside the same XLA program as the producing
    layer's matmuls.  ``None`` (default) is the untapped path,
    bitwise-identical to before the hook existed."""
    if param_tap is not None:
        params = param_tap(params)
    logits, aux = forward_train(params, cfg, batch, wires=wires,
                                wire_key=wire_key)
    loss = L.softmax_xent(logits[:, :-1], batch["tokens"][:, 1:])
    metrics = {"xent": loss, "aux": aux}
    return loss + aux, metrics


# --------------------------------------------------------------------------
# Decode path
# --------------------------------------------------------------------------


def _attn_cache_zero(cfg, b, cache_len, dtype):
    if cfg.use_mla:
        return MLA.make_mla_cache(cfg, b, cache_len, dtype)
    return L.make_attention_cache(cfg, b, cache_len, dtype)


def make_decode_state(cfg: ModelConfig, b: int, cache_len: int,
                      enc_len: int = 0) -> Params:
    """Zero decode state; per-layer leaves stacked on axis 0 for scanning."""
    dt = L.pdtype(cfg)
    at = cfg.arch_type

    def rep(make_one, n):
        one = make_one()
        return tmap(lambda a: jnp.broadcast_to(a, (n, *a.shape)).copy(), one)

    if at in ("dense", "vlm"):
        return {"kv": rep(lambda: _attn_cache_zero(cfg, b, cache_len, dt), cfg.n_layers)}
    if at == "moe":
        nd = cfg.first_dense_layers
        return {
            "kv_dense": rep(lambda: _attn_cache_zero(cfg, b, cache_len, dt), nd),
            "kv_moe": rep(lambda: _attn_cache_zero(cfg, b, cache_len, dt),
                          cfg.n_layers - nd),
        }
    if at == "ssm":
        return {"blocks": rep(lambda: R6.make_rwkv_state(cfg, b, dt), cfg.n_layers)}
    if at == "hybrid":
        return {
            "blocks": rep(lambda: M2.make_mamba2_state(cfg, b, dt), cfg.n_layers),
            "shared_kv": rep(
                lambda: _attn_cache_zero(cfg, b, cache_len, dt),
                sum(1 for _, a in _zamba_segments(cfg) if a),
            ),
        }
    if at == "audio":
        kv_heads = cfg.n_kv_heads
        return {
            "kv": rep(lambda: _attn_cache_zero(cfg, b, cache_len, dt), cfg.n_layers),
            "xkv": {
                "k": jnp.zeros((cfg.n_layers, b, enc_len, kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((cfg.n_layers, b, enc_len, kv_heads, cfg.head_dim), dt),
            },
        }
    raise ValueError(at)


def _attn_decode(p, x, cfg, cache, pos):
    if cfg.use_mla:
        return MLA.mla_decode(p, x, cfg, cache, pos, window=cfg.sliding_window)
    return L.attention_decode(p, x, cfg, cache, pos)


def _dense_block_decode(p, x, cfg, cache, pos):
    h = L.rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    y, cache = _attn_decode(p["attn"], h, cfg, cache, pos)
    x = x + y
    x = x + L.mlp_apply(p["mlp"], L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
    return x, cache


def _moe_block_decode(p, x, cfg, cache, pos):
    h = L.rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    y, cache = _attn_decode(p["attn"], h, cfg, cache, pos)
    x = x + y
    y, _ = MOE.moe_apply(p["moe"], L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps), cfg)
    return x + y, cache


def _scan_decode(block_decode, stacked_p, stacked_cache, x, cfg, pos):
    def body(carry, pc):
        lp, lc = pc
        y, nc = block_decode(lp, carry, cfg, lc, pos)
        return y, nc
    x, new_cache = jax.lax.scan(body, x, (stacked_p, stacked_cache))
    return x, new_cache


def decode_step(params, cfg: ModelConfig, tok, state, pos):
    """One token for the whole batch.  tok (B,1) int32; pos () int32 —
    the absolute position being written.  Returns (logits (B,1,V), state)."""
    at = cfg.arch_type
    x = L.embed(params["embed"], tok)

    if at in ("dense", "vlm"):
        x, kv = _scan_decode(_dense_block_decode, params["blocks"],
                             state["kv"], x, cfg, pos)
        state = {**state, "kv": kv}

    elif at == "moe":
        if params.get("dense_blocks") is not None:
            x, kvd = _scan_decode(_dense_block_decode, params["dense_blocks"],
                                  state["kv_dense"], x, cfg, pos)
            state = {**state, "kv_dense": kvd}
        x, kvm = _scan_decode(_moe_block_decode, params["moe_blocks"],
                              state["kv_moe"], x, cfg, pos)
        state = {**state, "kv_moe": kvm}

    elif at == "ssm":
        def body(carry, pc):
            lp, lc = pc
            y, nc = _rwkv_block_fwd(lp, carry, cfg, lc)
            return y, nc
        x, blocks = jax.lax.scan(body, x, (params["blocks"], state["blocks"]))
        state = {**state, "blocks": blocks}

    elif at == "hybrid":
        def body(carry, pc):
            lp, lc = pc
            y, nc = _mamba_block_fwd(lp, carry, cfg, lc)
            return y, nc
        off = 0
        ai = 0
        blocks = state["blocks"]
        shared_kv = state["shared_kv"]
        new_blocks, new_shared = [], []
        for n, attn_after in _zamba_segments(cfg):
            seg_p = tmap(lambda a: jax.lax.slice_in_dim(a, off, off + n, axis=0),
                         params["blocks"])
            seg_c = tmap(lambda a: jax.lax.slice_in_dim(a, off, off + n, axis=0), blocks)
            x, nc = jax.lax.scan(body, x, (seg_p, seg_c))
            new_blocks.append(nc)
            if attn_after:
                kv_i = tmap(lambda a: a[ai], shared_kv)
                x, kv_i = _dense_block_decode(params["shared_attn"], x, cfg, kv_i, pos)
                new_shared.append(kv_i)
                ai += 1
            off += n
        state = {
            **state,
            "blocks": tmap(lambda *xs: jnp.concatenate(xs, 0), *new_blocks),
            "shared_kv": tmap(lambda *xs: jnp.stack(xs, 0), *new_shared),
        }

    elif at == "audio":
        def body(carry, pc):
            lp, lc, lx = pc
            h = L.rmsnorm(lp["attn_norm"], carry, cfg.norm_eps)
            y, lc = _attn_decode(lp["attn"], h, cfg, lc, pos)
            h2 = carry + y
            y2 = L.cross_attention_apply(
                lp["xattn"], L.rmsnorm(lp["xattn_norm"], h2, cfg.norm_eps),
                (lx["k"], lx["v"]), cfg,
            )
            h2 = h2 + y2
            h2 = h2 + L.mlp_apply(lp["mlp"], L.rmsnorm(lp["mlp_norm"], h2, cfg.norm_eps))
            return h2, lc
        x, kv = jax.lax.scan(body, x, (params["blocks"], state["kv"], state["xkv"]))
        state = {**state, "kv": kv}
    else:
        raise ValueError(at)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.lm_head(params["head"], x, cfg, params["embed"])
    return logits, state


# --------------------------------------------------------------------------
# Parameter counting (for 6ND roofline math)
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = 0
    frac = (
        cfg.experts_per_token / cfg.n_experts if (active_only and cfg.is_moe) else 1.0
    )
    for path, leaf in jax.tree_util.tree_leaves_with_path(shapes):
        names = [getattr(k, "key", "") for k in path]
        routed = any(n in ("w_gate", "w_up", "w_down") for n in names) and (
            "moe" in names
        ) and "shared" not in names
        total += int(leaf.size * (frac if routed else 1.0))
    return total
