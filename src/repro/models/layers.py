"""Transformer substrate: norms, RoPE, GQA attention with chunked
(flash-style) softmax and rolling KV caches, SwiGLU MLP, embeddings.

Everything is module-free pure JAX: ``init_*`` builds a nested-dict
param tree, ``*_apply`` consumes it.  Parameter *names* are what the
sharding rules in ``repro.dist.sharding`` match on — keep them stable.

Shape conventions:  x (B, S, D);  q (B, S, H, Dh);  k/v (B, S, KV, Dh);
caches (B, C, KV, Dh) with write cursor ``pos`` (rolling when the config
uses a sliding window).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _normal(key, shape, dtype, scale):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def shard_hint(x, *spec):
    """Best-effort sharding constraint on an activation.

    Per-dim entries:  a mesh axis name (or tuple) pins that dim to the
    axis;  ``None`` leaves the dim UNCONSTRAINED (propagation decides —
    crucial under vmap, where forcing replication would fight the mapped
    worker axis);  the string ``"rep"`` forces the dim replicated (e.g.
    gathering the key sequence once before streamed attention).
    No-op when there is no mesh (CPU smoke tests).
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or "model" not in mesh.axis_names:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        dims = tuple(
            P.UNCONSTRAINED if d is None else (None if d == "rep" else d)
            for d in spec
        )
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*dims))
        )
    except Exception:
        return x


def wire_boundary(wire, key, x, e):
    """Pipeline-boundary activation compression: pass a block output
    through a transport wire (codec round-trip, straight-through on the
    backward pass), threading the per-wire error-feedback shift ``e``.
    Thin indirection so layer code never imports the comm package —
    ``wire`` is a ``repro.comm.transport.Wire`` (anything with ``.send``).
    Returns ``(y, e_new)``.
    """
    return wire.send(key, x, e)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def init_rmsnorm(d: int, cfg: ModelConfig) -> Params:
    return {"scale": jnp.ones((d,), pdtype(cfg))}


def rmsnorm(p: Params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_freqs(dh: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                       # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Chunked (flash-style) attention core
# --------------------------------------------------------------------------


def _grouped_scores(q, k):
    """q (B,Sq,KV,G,Dh) x k (B,Sk,KV,Dh) -> (B,KV,G,Sq,Sk) without
    materializing repeated KV heads."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k)


def _out_proj(out, wo):
    """(B,S,H,dh) x (H*dh, D) — plain matmul against the 2-D weight."""
    b, s, h, dh = out.shape
    return out.reshape(b, s, h * dh) @ wo


def chunked_attention(
    q, k, v, *,
    causal: bool,
    q_offset,                 # int or () int32 array: absolute pos of q[0]
    k_positions,              # (Sk,) absolute positions of keys (for mask)
    k_valid=None,             # (B, Sk) or (Sk,) bool — False = masked out
    window: int = 0,
    q_chunk: int = 512,       # kept for config compat: = key-chunk size
):
    """Grouped-query attention with ONLINE softmax, scanned over KEY
    chunks (flash-attention recurrence): running (max, sum, out)
    accumulators; the live score block is (B, KV, G, Sq, kc) — never the
    full (Sq, Sk) matrix.  The query sequence dim is the one the mesh
    shards ("model"-axis sequence parallelism), so keeping Sq intact and
    streaming keys makes per-shard transients ~Sq_shard * kc.

    Decode (Sq == 1) takes the single-block path so a key-sharded cache
    lowers to one masked softmax with small cross-shard reductions.
    Softmax in f32.
    """
    b, sq, h, dh = q.shape
    dv = v.shape[-1]
    kv = k.shape[2]
    g = h // kv
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, sq, kv, g, dh)
    kpos = k_positions.astype(jnp.int32)
    qpos = q_offset + jnp.arange(sq, dtype=jnp.int32)

    def block(qc, kc_, vc_, kpos_c, kvalid_c):
        """One key block: masked scores -> (scores, mask) in f32."""
        s = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc_,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((sq, kc_.shape[1]), bool)
        if causal:
            mask &= kpos_c[None, :] <= qpos[:, None]
        if window and window > 0:
            mask &= kpos_c[None, :] > (qpos[:, None] - window)
        if kvalid_c is not None:
            kvld = kvalid_c if kvalid_c.ndim == 2 else kvalid_c[None]
            m = mask[None, None, None, :, :] & kvld[:, None, None, None, :]
        else:
            m = mask[None, None, None, :, :]
        return jnp.where(m, s, -1e30)

    kc = min(q_chunk, sk)
    if sq == 1 or sk <= kc:
        # single block: decode path / short sequences
        s = block(qg, k, v, kpos, k_valid)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
        return o.reshape(b, sq, h, dv)

    pad = (-sk) % kc
    n_chunks = (sk + pad) // kc
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=2**30)
        if k_valid is not None:
            kvld2 = k_valid if k_valid.ndim == 2 else k_valid[None]
            k_valid = jnp.pad(kvld2, ((0, 0), (0, pad)))

    kb = k.reshape(b, n_chunks, kc, kv, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_chunks, kc, kv, dv).transpose(1, 0, 2, 3, 4)
    kpb = kpos.reshape(n_chunks, kc)
    kvb = (
        k_valid.reshape(k_valid.shape[0], n_chunks, kc).transpose(1, 0, 2)
        if k_valid is not None else None
    )

    m0 = jnp.full((b, kv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    o0 = jnp.zeros((b, kv, g, sq, dv), jnp.float32)

    def body(carry, xs):
        m, l, o = carry
        if kvb is None:
            kc_, vc_, kp_ = xs
            kvld_c = None
        else:
            kc_, vc_, kp_, kvld_c = xs
        s = block(qg, kc_, vc_, kp_, kvld_c)          # (B,KV,G,Sq,kc)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        # p @ v in the value dtype (bf16): halves the probability-block
        # HBM traffic and puts the contraction on the bf16 MXU path;
        # the (m, l, o) accumulators stay f32 (§Perf-3).
        o = o * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(v.dtype), vc_,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l, o), None

    xs = (kb, vb, kpb) if kvb is None else (kb, vb, kpb, kvb)
    (m, l, o), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, o0), xs)
    o = o / jnp.maximum(l, 1e-30)[..., None]
    # (B,KV,G,Sq,dv) -> (B,Sq,H,dv)
    out = o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)
    return out.astype(v.dtype)


# --------------------------------------------------------------------------
# GQA attention layer
# --------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> Params:
    """Projection weights are stored 2-D (d, H*dh): (a) the fused head
    dim always divides the "model" mesh axis regardless of head COUNT
    (40 heads won't 16-shard; 40*128 will), and (b) the layer-scan body
    sees a plain matmul — no per-iteration transpose of the stacked
    3-D weights (§Perf-3)."""
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    sc = 0.02
    p = {
        "wq": _normal(ks[0], (d, h * dh), pdtype(cfg), sc),
        "wk": _normal(ks[1], (d, kv * dh), pdtype(cfg), sc),
        "wv": _normal(ks[2], (d, kv * dh), pdtype(cfg), sc),
        "wo": _normal(ks[3], (h * dh, d), pdtype(cfg), sc / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), pdtype(cfg))
        p["bk"] = jnp.zeros((kv * dh,), pdtype(cfg))
        p["bv"] = jnp.zeros((kv * dh,), pdtype(cfg))
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(dh, cfg)
        p["k_norm"] = init_rmsnorm(dh, cfg)
    return p


def _qkv(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, kv, dh)
    v = v.reshape(b, s, kv, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_apply(p, x, cfg: ModelConfig):
    """Full-sequence (train/prefill) causal self-attention."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    # sequence parallelism: queries stay sharded over "model" on seq;
    # keys/values must be whole.  Adaptive gather (§Perf-2): for GQA
    # (2*kv*dh < d) gather the small k/v AFTER projection; for MHA-like
    # heads (k+v as big as x) gather x ONCE before the projections —
    # halves the per-layer all-gather volume for kv=40 archs.
    gather_x = 2 * cfg.n_kv_heads * cfg.head_dim >= cfg.d_model
    if gather_x:
        x = shard_hint(x, None, "rep", None)
    q, k, v = _qkv(p, x, cfg, positions)
    q = shard_hint(q, None, "model", None, None)
    if not gather_x:
        k = shard_hint(k, None, "rep", None, None)
        v = shard_hint(v, None, "rep", None, None)
    out = chunked_attention(
        q, k, v,
        causal=True,
        q_offset=jnp.int32(0),
        k_positions=jnp.arange(s, dtype=jnp.int32),
        window=cfg.sliding_window,
        q_chunk=cfg.attn_q_chunk,
    )
    return _out_proj(out, p["wo"])


def attention_prefill(p, x, cfg: ModelConfig, cache_len: int):
    """Prefill: same as apply, but also returns the KV cache laid out for
    decode, plus the next write position."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q, k, v = _qkv(p, x, cfg, positions)
    out = chunked_attention(
        q, k, v,
        causal=True,
        q_offset=jnp.int32(0),
        k_positions=jnp.arange(s, dtype=jnp.int32),
        window=cfg.sliding_window,
        q_chunk=cfg.attn_q_chunk,
    )
    kvd = k.dtype
    kc = jnp.zeros((b, cache_len, *k.shape[2:]), kvd)
    vc = jnp.zeros((b, cache_len, *v.shape[2:]), kvd)
    kpos = jnp.full((b, cache_len), -1, jnp.int32)
    if cache_len >= s:
        kc = kc.at[:, :s].set(k)
        vc = vc.at[:, :s].set(v)
        kpos = kpos.at[:, :s].set(jnp.arange(s, dtype=jnp.int32)[None])
    else:  # rolling window: keep the last cache_len tokens, ring layout
        tail_k = k[:, s - cache_len:]
        tail_v = v[:, s - cache_len:]
        tail_p = jnp.arange(s - cache_len, s, dtype=jnp.int32)
        slot = tail_p % cache_len
        kc = kc.at[:, slot].set(tail_k)
        vc = vc.at[:, slot].set(tail_v)
        kpos = kpos.at[:, slot].set(tail_p[None])
    cache = {"k": kc, "v": vc, "kpos": kpos}
    return _out_proj(out, p["wo"]), cache


def attention_decode(p, x, cfg: ModelConfig, cache, pos):
    """One-token decode. ``pos`` — scalar int32 absolute position; cache is
    a ring buffer of length C (C >= sliding window, or full seq)."""
    b = x.shape[0]
    positions = jnp.broadcast_to(pos.astype(jnp.int32), (b, 1))
    q, k, v = _qkv(p, x, cfg, positions)
    c = cache["k"].shape[1]
    slot = (pos % c).astype(jnp.int32)
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    kpos = jax.lax.dynamic_update_slice_in_dim(
        cache["kpos"],
        jnp.broadcast_to(pos.astype(jnp.int32), (b, 1)), slot, axis=1,
    )
    valid = kpos >= 0                               # (B, C) per-slot
    # shared decode clock: the written position at a slot is identical
    # across batch rows (or -1 where a row was admitted later and the
    # stale entry was invalidated) — max over B recovers it for the
    # causal mask; k_valid handles per-row validity.
    shared_pos = jnp.max(kpos, axis=0)
    out = chunked_attention(
        q, kc, vc,
        causal=True,
        q_offset=pos.astype(jnp.int32),
        k_positions=jnp.where(shared_pos >= 0, shared_pos, jnp.int32(2**30)),
        k_valid=valid,
        window=cfg.sliding_window,
        q_chunk=1,
    )
    y = _out_proj(out, p["wo"])
    return y, {"k": kc, "v": vc, "kpos": kpos}


def make_attention_cache(cfg: ModelConfig, b: int, cache_len: int, dtype):
    return {
        "k": jnp.zeros((b, cache_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((b, cache_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "kpos": jnp.full((b, cache_len), -1, jnp.int32),  # per-slot validity
    }


# --------------------------------------------------------------------------
# Cross attention (encoder-decoder)
# --------------------------------------------------------------------------


def init_cross_attention(key, cfg: ModelConfig) -> Params:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _normal(ks[0], (d, h * dh), pdtype(cfg), 0.02),
        "wk": _normal(ks[1], (d, kv * dh), pdtype(cfg), 0.02),
        "wv": _normal(ks[2], (d, kv * dh), pdtype(cfg), 0.02),
        "wo": _normal(ks[3], (h * dh, d), pdtype(cfg), 0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def cross_attention_kv(p, enc_out, cfg: ModelConfig):
    b, s, _ = enc_out.shape
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"]).reshape(b, s, kv, dh)
    v = (enc_out @ p["wv"]).reshape(b, s, kv, dh)
    return k, v


def cross_attention_apply(p, x, kv_pair, cfg: ModelConfig, enc_valid=None):
    k, v = kv_pair
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    out = chunked_attention(
        q, k, v,
        causal=False,
        q_offset=jnp.int32(0),
        k_positions=jnp.arange(k.shape[1], dtype=jnp.int32),
        k_valid=enc_valid,
        q_chunk=cfg.attn_q_chunk,
    )
    return _out_proj(out, p["wo"])


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _normal(ks[0], (d, f), pdtype(cfg), 0.02),
        "w_up": _normal(ks[1], (d, f), pdtype(cfg), 0.02),
        "w_down": _normal(ks[2], (f, d), pdtype(cfg), 0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def mlp_apply(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard_hint(h, None, None, "model")
    return h @ p["w_down"]


# --------------------------------------------------------------------------
# Embeddings / head
# --------------------------------------------------------------------------


def init_embedding(key, cfg: ModelConfig) -> Params:
    p = {"table": _normal(key, (cfg.vocab_size, cfg.d_model), pdtype(cfg), 0.02)}
    return p


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def init_lm_head(key, cfg: ModelConfig) -> Params:
    if cfg.tie_embeddings:
        return {}
    return {"w": _normal(key, (cfg.d_model, cfg.vocab_size), pdtype(cfg), 0.02)}


def lm_head(p, x, cfg: ModelConfig, emb_params):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, emb_params["table"])
    return jnp.einsum("bsd,dv->bsv", x, p["w"])


def softmax_xent(logits, targets, valid=None):
    """Cross-entropy in f32 over (possibly model-sharded) vocab.  Uses
    take_along_axis for the gold logit — no (B,S,V) one-hot materializes
    (matters at vocab 152k x 1M tokens)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if valid is None:
        return jnp.mean(nll)
    w = valid.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
