"""Mixture-of-Experts FFN: GShard-style capacity routing with dense
dispatch einsums, shared + routed experts (DeepSeek-V2 / Qwen-MoE style).

Routed experts live in one stacked tensor (E, d, f) so they shard over
the ``model`` mesh axis (expert parallelism).  Dispatch is the dense
one-hot form — (tokens, experts, capacity) combine/dispatch tensors —
which lowers to einsums (MXU) rather than gathers, and under GSPMD the
token->expert movement lowers to the expected all-to-all when experts
are sharded.

Router runs in f32; auxiliary load-balance loss per Shazeer et al.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _normal, pdtype, shard_hint

Params = Dict[str, Any]


def init_moe(key, cfg: ModelConfig) -> Params:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    sc = 0.02
    down_sc = sc / math.sqrt(2 * cfg.n_layers)
    p = {
        "router": _normal(ks[0], (d, e), jnp.float32, sc),
        "w_gate": _normal(ks[1], (e, d, f), pdtype(cfg), sc),
        "w_up": _normal(ks[2], (e, d, f), pdtype(cfg), sc),
        "w_down": _normal(ks[3], (e, f, d), pdtype(cfg), down_sc),
    }
    if cfg.n_shared_experts > 0:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _normal(k1, (d, fs), pdtype(cfg), sc),
            "w_up": _normal(k2, (d, fs), pdtype(cfg), sc),
            "w_down": _normal(k3, (fs, d), pdtype(cfg), down_sc),
        }
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(
        math.ceil(
            cfg.capacity_factor * n_tokens * cfg.experts_per_token / cfg.n_experts
        )
    )
    # MXU-friendly: round capacity up to a multiple of 8 (min tile sublane).
    return max(8, -(-c // 8) * 8)


def route(p: Params, x, cfg: ModelConfig):
    """Top-k softmax routing with capacity.  x: (N, D) flat tokens.

    Returns (dispatch (N,E,C) bool-ish, combine (N,E,C) f32, aux_loss).
    """
    n = x.shape[0]
    e, k = cfg.n_experts, cfg.experts_per_token
    c = _capacity(n, cfg)

    logits = x.astype(jnp.float32) @ p["router"]          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)          # (N, k)
    # Normalize the selected gates (DeepSeek-V2 normalizes top-k weights).
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # One-hot expert assignment per routing slot: (k, N, E)
    sel = jax.nn.one_hot(gate_idx.T, e, dtype=jnp.float32)
    # Position of each token in its expert's queue, slot-major so that
    # slot 0 assignments win capacity before slot 1 (standard GShard).
    flat_sel = sel.reshape(k * n, e)
    pos_in_expert = jnp.cumsum(flat_sel, axis=0) * flat_sel - 1.0  # (kN, E)
    within_cap = (pos_in_expert < c) & (flat_sel > 0)
    pos = jnp.sum(pos_in_expert * within_cap, axis=-1)             # (kN,)
    kept = jnp.any(within_cap, axis=-1)                            # (kN,)

    gates_flat = gate_vals.T.reshape(k * n) * kept                 # (kN,)
    onehot_c = jax.nn.one_hot(pos, c, dtype=jnp.float32) * kept[:, None]
    # (kN, E, C) -> sum over k slots -> (N, E, C)
    disp_flat = flat_sel[:, :, None] * onehot_c[:, None, :]
    comb_flat = disp_flat * gates_flat[:, None, None]
    dispatch = disp_flat.reshape(k, n, e, c).sum(0)
    combine = comb_flat.reshape(k, n, e, c).sum(0)

    # Load-balance auxiliary loss:  E * sum_e (frac_tokens_e * mean_prob_e)
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(sel.sum(0), axis=0)                              # (E,) frac routed
    aux = e * jnp.sum(me * ce) * cfg.router_aux_coef
    return dispatch, combine, aux


def _moe_group(p: Params, xf, cfg: ModelConfig, wire=None, key=None,
               shift=None):
    """Route + dispatch + expert FFN + combine for one token group.

    With a ``wire`` (``repro.comm.transport.Wire``), the two expert
    buffers that cross the all-to-all — the dispatched ``xe`` and the
    expert outputs ``ye`` — ride the wire's codec, straight-through on
    the backward pass.  ``shift`` is the per-wire error-feedback pair
    ``(e_dispatch, e_combine)`` threaded along the group scan so
    compression noise on the expert buffers averages out over the step
    instead of biasing expert outputs.  Returns ``(y, aux, shift)``;
    with ``wire=None`` the math is bitwise-identical to before and
    ``shift`` passes through untouched.
    """
    dispatch, combine, aux = route(p, xf, cfg)

    # Dispatch tokens to expert buffers: (E, C, D) — einsum, not gather;
    # with experts sharded over "model" this lowers to the all-to-all.
    xe = jnp.einsum("nec,nd->ecd", dispatch.astype(xf.dtype), xf)
    if wire is not None:
        k_disp, k_comb = jax.random.split(key)
        e_disp, e_comb = shift
        xe, e_disp = wire.send(k_disp, xe, e_disp)
    xe = shard_hint(xe, "model", None, None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    if wire is not None:
        ye, e_comb = wire.send(k_comb, ye, e_comb)
        shift = (e_disp, e_comb)

    y = jnp.einsum("nec,ecd->nd", combine.astype(xf.dtype), ye)
    return y, aux, shift


def _wire_shift_zero(cfg: ModelConfig, g: int, d: int, dtype):
    """Zero EF shift pair for one group's (E, C, D) expert buffers."""
    z = jnp.zeros((cfg.n_experts, _capacity(g, cfg), d), dtype)
    return (z, z)


def moe_wire_traffic(cfg: ModelConfig, n_tokens: int, dtype=None):
    """Declared per-worker ``moe``-wire traffic of ONE MoE layer:
    ``((ShapeDtypeStruct, count), ...)`` for the transport's structural
    accounting.  Two sends (dispatch + combine) of the ``(E, C, D)``
    expert buffer per GShard group — the SAME group/capacity math as
    ``moe_apply``, so the accounting cannot drift from the live path.
    """
    if n_tokens <= 0:
        return ()
    g = min(cfg.moe_group_size, n_tokens)
    n_groups = (n_tokens + ((-n_tokens) % g)) // g
    sds = jax.ShapeDtypeStruct(
        (cfg.n_experts, _capacity(g, cfg), cfg.d_model),
        jnp.dtype(dtype or cfg.dtype),
    )
    return ((sds, 2 * n_groups),)


def moe_apply(p: Params, x, cfg: ModelConfig, wire=None,
              key=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar).

    Tokens are processed in groups of ``cfg.moe_group_size`` (GShard
    "groups"): the dense dispatch tensors are O(G * E * C_G) per group
    instead of O(N * E * C) for the whole shard, which is what keeps the
    1M-token train_4k batch from materializing terabyte dispatch masks.
    Groups run under ``lax.scan`` (sequential, rematerialized).

    ``wire``/``key`` route the dispatch/combine expert buffers through a
    transport Wire (``--moe_wire``): every group shares the ``(E, C, D)``
    buffer shape, so the per-wire error-feedback shift is the scan carry
    — zeroed at step start, threaded across the layer's groups.
    """
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    n = xf.shape[0]
    g = min(cfg.moe_group_size, n)
    pad = (-n) % g
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n_groups = (n + pad) // g

    if n_groups == 1:
        if wire is None:
            y, aux, _ = _moe_group(p, xf, cfg)
        else:
            y, aux, _ = _moe_group(
                p, xf, cfg, wire=wire, key=jax.random.fold_in(key, 0),
                shift=_wire_shift_zero(cfg, xf.shape[0], d, xf.dtype),
            )
    else:
        xg = xf.reshape(n_groups, g, d)

        if wire is None:
            def body(_, xf_g):
                y_g, aux_g, _ = _moe_group(p, xf_g, cfg)
                return None, (y_g, aux_g)

            carry0, xs = None, xg
        else:
            def body(e, inp):
                xf_g, gi = inp
                y_g, aux_g, e = _moe_group(
                    p, xf_g, cfg, wire=wire,
                    key=jax.random.fold_in(key, gi), shift=e,
                )
                return e, (y_g, aux_g)

            carry0 = _wire_shift_zero(cfg, g, d, xf.dtype)
            xs = (xg, jnp.arange(n_groups))

        _, (y, auxs) = jax.lax.scan(jax.checkpoint(body), carry0, xs)
        y = y.reshape(n_groups * g, d)
        aux = jnp.mean(auxs)

    y = y[:n]
    xf = xf[:n]
    if cfg.n_shared_experts > 0:
        sp = p["shared"]
        hs = jax.nn.silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])
        y = y + hs @ sp["w_down"]
    return y.reshape(b, s, d), aux
