"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV activations are compressed into a rank-``kv_lora_rank`` latent c_kv
plus a single shared RoPE key head.  The decode path uses the *absorbed*
formulation: W_uk folds into the query and W_uv into the attention
output, so the KV cache stores only (c_kv, k_rope) — the MLA memory win
— and per-token decode attends directly in latent space.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    _normal,
    apply_rope,
    chunked_attention,
    init_rmsnorm,
    pdtype,
    rmsnorm,
)


def init_mla(key, cfg: ModelConfig) -> Dict[str, Any]:
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    sc = 0.02
    return {
        "wq": _normal(ks[0], (d, h, dn + dr), pdtype(cfg), sc),
        "w_dkv": _normal(ks[1], (d, r), pdtype(cfg), sc),        # down: latent
        "kv_norm": init_rmsnorm(r, cfg),
        "w_ukv": _normal(ks[2], (r, h, dn + dv), pdtype(cfg), sc),  # up: k_nope|v
        "w_kr": _normal(ks[3], (d, dr), pdtype(cfg), sc),        # shared rope key
        "wo": _normal(ks[4], (h, dv, d), pdtype(cfg), sc / math.sqrt(2 * cfg.n_layers)),
    }


def _q_proj(p, x, cfg, positions):
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    qn, qr = q[..., :dn], q[..., dn:]
    qr = apply_rope(qr, positions, cfg.rope_theta)
    return qn, qr


def _latent(p, x, cfg, positions):
    ckv = rmsnorm(p["kv_norm"], x @ p["w_dkv"], cfg.norm_eps)   # (B,S,r)
    kr = apply_rope(
        (x @ p["w_kr"])[:, :, None, :], positions, cfg.rope_theta
    )  # (B,S,1,dr)
    return ckv, kr[:, :, 0, :]


def mla_apply(p, x, cfg: ModelConfig):
    """Full-sequence causal MLA (train/prefill math, expanded form)."""
    b, s, _ = x.shape
    dn, dv = cfg.qk_nope_dim, cfg.v_head_dim
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    qn, qr = _q_proj(p, x, cfg, positions)
    ckv, kr = _latent(p, x, cfg, positions)
    kv = jnp.einsum("bsr,rhe->bshe", ckv, p["w_ukv"])
    kn, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [kn, jnp.broadcast_to(kr[:, :, None, :], (*kn.shape[:3], kr.shape[-1]))],
        axis=-1,
    )
    q = jnp.concatenate([qn, qr], axis=-1)
    from repro.models.layers import shard_hint
    q = shard_hint(q, None, "model", None, None)
    k = shard_hint(k, None, "rep", None, None)
    v = shard_hint(v, None, "rep", None, None)
    out = chunked_attention(
        q, k, v,
        causal=True,
        q_offset=jnp.int32(0),
        k_positions=jnp.arange(s, dtype=jnp.int32),
        q_chunk=cfg.attn_q_chunk,
    )
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])


def mla_prefill(p, x, cfg: ModelConfig, cache_len: int):
    b, s, _ = x.shape
    out = mla_apply(p, x, cfg)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    ckv, kr = _latent(p, x, cfg, positions)
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    ckv_c = jnp.zeros((b, cache_len, r), ckv.dtype).at[:, :s].set(ckv)
    kr_c = jnp.zeros((b, cache_len, dr), kr.dtype).at[:, :s].set(kr)
    kpos = jnp.full((b, cache_len), -1, jnp.int32).at[:, :s].set(
        jnp.arange(s, dtype=jnp.int32)[None]
    )
    return out, {"ckv": ckv_c, "kr": kr_c, "kpos": kpos}


def mla_decode(p, x, cfg: ModelConfig, cache, pos, window: int = 0):
    """Absorbed one-token decode: attends in the latent space.  ``window``
    > 0 adds sliding-window masking (rolling latent cache)."""
    b = x.shape[0]
    dn, dv = cfg.qk_nope_dim, cfg.v_head_dim
    positions = jnp.broadcast_to(pos.astype(jnp.int32), (b, 1))
    qn, qr = _q_proj(p, x, cfg, positions)          # (B,1,H,dn),(B,1,H,dr)
    ckv_t, kr_t = _latent(p, x, cfg, positions)     # (B,1,r),(B,1,dr)

    c = cache["ckv"].shape[1]
    slot = (pos % c).astype(jnp.int32)
    ckv_c = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_t, slot, axis=1)
    kr_c = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr_t, slot, axis=1)
    kpos = jax.lax.dynamic_update_slice_in_dim(
        cache["kpos"],
        jnp.broadcast_to(pos.astype(jnp.int32), (b, 1)), slot, axis=1,
    )
    valid = kpos >= 0                                # (B, C)

    w_uk = p["w_ukv"][..., :dn]                     # (r,H,dn)
    w_uv = p["w_ukv"][..., dn:]                     # (r,H,dv)
    q_abs = jnp.einsum("bshe,rhe->bshr", qn, w_uk)  # (B,1,H,r)
    scores = (
        jnp.einsum("bshr,bcr->bhsc", q_abs.astype(jnp.float32),
                   ckv_c.astype(jnp.float32))
        + jnp.einsum("bshe,bce->bhsc", qr.astype(jnp.float32),
                     kr_c.astype(jnp.float32))
    ) / math.sqrt(dn + cfg.qk_rope_dim)
    mask = valid & (kpos <= pos)                     # (B, C)
    if window and window > 0:
        mask &= kpos > (pos - window)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhsc,bcr->bshr", probs.astype(ckv_c.dtype), ckv_c)
    v = jnp.einsum("bshr,rhe->bshe", ctx, w_uv)     # (B,1,H,dv)
    y = jnp.einsum("bshe,hed->bsd", v, p["wo"])
    return y, {"ckv": ckv_c, "kr": kr_c, "kpos": kpos}


def make_mla_cache(cfg: ModelConfig, b: int, cache_len: int, dtype):
    return {
        "ckv": jnp.zeros((b, cache_len, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((b, cache_len, cfg.qk_rope_dim), dtype),
        "kpos": jnp.full((b, cache_len), -1, jnp.int32),
    }
