"""Mamba-2 (SSD) block — the state-space backbone of Zamba2
(arXiv:2411.15242 uses Mamba2 blocks; SSD per arXiv:2405.21060).

Per head with state S in R^{N x P} (N = ssm_state, P = head dim):

    a_t = exp(-exp(A_log) * dt_t)            # scalar decay per head
    S_t = a_t S_{t-1} + B_t (dt_t x_t)^T     # B_t in R^N, x_t in R^P
    y_t = C_t^T S_t + D * x_t

dt is a softplus of a data-dependent projection (+ bias); B/C are shared
across heads within a group (here: one group).  Short causal conv1d over
the (x, B, C) streams precedes the SSM, as in the reference Mamba2.

The recurrence is an exact ``lax.scan``; O(1) decode state = (conv tail,
S).  Shapes follow the config: d_inner = 2 * d_model, P = rwkv_head_dim.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _normal, pdtype, rmsnorm, init_rmsnorm

Params = Dict[str, Any]


def _dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    p = cfg.rwkv_head_dim            # head dim
    h = d_inner // p                 # heads
    n = cfg.ssm_state
    return d_inner, h, p, n


def init_mamba2(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_inner, h, p, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    ks = jax.random.split(key, 6)
    dt = pdtype(cfg)
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "w_in": _normal(ks[0], (d, 2 * d_inner + 2 * n + h), dt, 0.02),
        "conv_w": _normal(ks[1], (cfg.conv_kernel, conv_dim), dt, 0.02),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.full((h,), -4.6, jnp.float32),   # softplus^-1(0.01)
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": init_rmsnorm(d_inner, cfg),
        "w_out": _normal(ks[2], (d_inner, d), dt, 0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def _ssd_scan(x, b_t, c_t, dt_t, a_log, d_skip, s0):
    """x (B,T,H,P); b_t,c_t (B,T,N); dt_t (B,T,H); s0 (B,H,N,P)."""
    a = -jnp.exp(a_log)                                   # (H,)

    def step(s, inp):
        xt, bt, ct, dtt = inp                             # (B,H,P),(B,N),(B,N),(B,H)
        decay = jnp.exp(a[None] * dtt)                    # (B,H)
        upd = jnp.einsum("bn,bhp->bhnp", bt, xt * dtt[..., None])
        s = decay[..., None, None] * s + upd
        yt = jnp.einsum("bn,bhnp->bhp", ct, s) + d_skip[None, :, None] * xt
        return s, yt

    xs = (
        x.transpose(1, 0, 2, 3),
        b_t.transpose(1, 0, 2),
        c_t.transpose(1, 0, 2),
        dt_t.transpose(1, 0, 2),
    )
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), s_fin


def _ssd_chunked(x, b_t, c_t, dt_t, a_log, d_skip, s0, chunk: int = 128):
    """SSD chunked (matmul) form of the same recurrence — the Mamba-2
    insight mapped to the MXU.  The sequential scan round-trips the
    (B,H,N,P) state through HBM EVERY time step; the chunked form
    materializes it once per chunk and turns intra-chunk work into
    batched matmuls:

      y_t = C_t P_t S_prev + sum_{s<=t} (C_t.B_s) exp(c_t - c_s) dt_s x_s
      S  <- exp(c_L) S_prev + sum_s exp(c_L - c_s) dt_s B_s x_s^T

    with c_t the intra-chunk cumulative log-decay.  All pairwise decay
    factors are exp(non-positive) — no overflow for any decay rate
    (unlike the factored q/k-scaling form).  f32 throughout.

    x (B,T,H,P); b_t,c_t (B,T,N); dt_t (B,T,H); s0 (B,H,N,P).
    """
    bsz, t, h, pdim = x.shape
    n = b_t.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    a = -jnp.exp(a_log)                                    # (H,) negative

    xr = (x * dt_t[..., None]).reshape(bsz, nc, chunk, h, pdim)
    br = b_t.reshape(bsz, nc, chunk, n)
    cr = c_t.reshape(bsz, nc, chunk, n)
    # intra-chunk cumulative log decays (B, nc, L, H), non-positive steps
    la = (a[None, None] * dt_t).reshape(bsz, nc, chunk, h)
    cum = jnp.cumsum(la, axis=2)                           # c_t

    tril = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_body(s, inp):
        """One chunk: exact pairwise decay (L,L,H) built INSIDE the scan
        body (a 67 MB transient at the zamba2 train shape) — computing it
        for all chunks at once would be O(T*L) = 134 GB.  exp(c_t - c_s)
        with s <= t is exp(<=0): exact for arbitrarily strong
        data-dependent decay (no factored u*w cancellation — see
        tests/test_ssm_chunked.py::test_ssd_chunked_extreme_decay)."""
        xr_c, br_c, cr_c, cum_c = inp                      # (B,L,H,P) etc.
        dmat = cum_c[:, :, None, :] - cum_c[:, None, :, :]  # (B,L,L,H)
        dmat = jnp.where(tril[None, :, :, None], jnp.exp(dmat), 0.0)
        g = jnp.einsum("btn,bsn->bts", cr_c, br_c)          # (B,L,L)
        y_intra = jnp.einsum("bts,btsh,bshp->bthp", g, dmat, xr_c)
        # contribution of the incoming state
        u = jnp.exp(cum_c)                                  # (B,L,H) <= 1
        y_inter = jnp.einsum("btn,bth,bhnp->bthp", cr_c, u, s)
        # state update: S <- exp(c_L) S + sum_s exp(c_L - c_s) B_s xr_s
        fac = jnp.exp(cum_c[:, -1:, :] - cum_c)             # (B,L,H) <= 1
        s_in = jnp.einsum("bsn,bsh,bshp->bhnp", br_c, fac, xr_c)
        s = u[:, -1, :, None, None] * s + s_in
        return s, y_intra + y_inter

    xs = (
        xr.transpose(1, 0, 2, 3, 4),
        br.transpose(1, 0, 2, 3),
        cr.transpose(1, 0, 2, 3),
        cum.transpose(1, 0, 2, 3),
    )
    s_fin, ys = jax.lax.scan(chunk_body, s0, xs)            # ys (nc,B,L,H,P)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, t, h, pdim)
    return y + d_skip[None, None, :, None] * x, s_fin


def _causal_conv(u, w, b, tail=None):
    """Depthwise causal conv1d. u (B,T,C); w (K,C); tail (B,K-1,C)."""
    kk = w.shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], kk - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([tail, u], axis=1)
    out = sum(up[:, i : i + u.shape[1]] * w[i][None, None] for i in range(kk))
    return jax.nn.silu(out + b), up[:, -(kk - 1) :]


def mamba2_apply(p: Params, x, cfg: ModelConfig, state=None):
    """x (B,T,D).  state = {'conv': (B,K-1,conv_dim), 'ssm': (B,H,N,P)} or
    None.  Returns (out, new_state)."""
    bsz, t, d = x.shape
    d_inner, h, pdim, n = _dims(cfg)
    proj = x @ p["w_in"]
    z, xs, bs, cs, dts = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xs, bs, cs], axis=-1)
    tail = None if state is None else state["conv"]
    conv_out, new_tail = _causal_conv(conv_in, p["conv_w"], p["conv_b"], tail)
    xs, bs, cs = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)

    dt_t = jax.nn.softplus(dts.astype(jnp.float32) + p["dt_bias"])    # (B,T,H)
    xh = xs.reshape(bsz, t, h, pdim).astype(jnp.float32)
    s0 = (
        jnp.zeros((bsz, h, n, pdim), jnp.float32)
        if state is None
        else state["ssm"]
    )
    # SSD chunked (matmul) path for training/prefill; exact sequential
    # step for decode / ragged tails.  See §Perf-1 in EXPERIMENTS.md.
    chunk = 128
    if t >= chunk and t % chunk == 0 and state is None:
        y, s_fin = _ssd_chunked(
            xh, bs.astype(jnp.float32), cs.astype(jnp.float32), dt_t,
            p["a_log"], p["d_skip"], s0, chunk=chunk,
        )
    else:
        y, s_fin = _ssd_scan(
            xh, bs.astype(jnp.float32), cs.astype(jnp.float32), dt_t,
            p["a_log"], p["d_skip"], s0,
        )
    y = y.reshape(bsz, t, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["w_out"]
    return out, {"conv": new_tail, "ssm": s_fin}


def make_mamba2_state(cfg: ModelConfig, b: int, dtype=jnp.float32):
    d_inner, h, p, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    return {
        "conv": jnp.zeros((b, cfg.conv_kernel - 1, conv_dim), jnp.dtype(cfg.dtype)),
        "ssm": jnp.zeros((b, h, n, p), jnp.float32),
    }
