"""RWKV-6 "Finch" block — attention-free RNN with data-dependent decay
(arXiv:2404.05892).

Time-mixing: token-shift lerps feed r/k/v/g projections; the per-channel
decay w_t = exp(-exp(wb + lora(x))) is *data dependent* (the headline
Finch feature).  The WKV recurrence per head (state S in R^{K x V}):

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Channel-mixing: squared-ReLU MLP gated by a receptance sigmoid.

The recurrence here is an exact ``lax.scan`` (compact HLO; O(1) state —
this is the arch that runs long_500k natively).  The Pallas TPU kernel in
``repro.kernels.wkv6`` implements the same math blocked for VMEM and is
validated against ``wkv_scan`` below.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _normal, pdtype

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# WKV recurrence (exact reference used by the model forward pass)
# --------------------------------------------------------------------------


def wkv_scan(r, k, v, w, u, s0=None):
    """Sequential WKV over time.

    r,k,w: (B, T, H, K);  v: (B, T, H, V);  u: (H, K);  s0: (B, H, K, V).
    Returns (y (B,T,H,V), s_final).  All math in f32.
    """
    b, t, h, dk = r.shape
    dv = v.shape[-1]
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw                     # (B,H,K) / (B,H,V)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,K,V)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, s + uf[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, yt

    xs = (
        rf.transpose(1, 0, 2, 3),
        kf.transpose(1, 0, 2, 3),
        vf.transpose(1, 0, 2, 3),
        wf.transpose(1, 0, 2, 3),
    )
    # unroll: the (B,H,K,V) state round-trips HBM once per UNROLL steps
    # instead of every step (fused register/VMEM chain inside the body) —
    # §Perf-1b.  Exactness unchanged (same op order).
    unroll = 64 if t % 64 == 0 else (16 if t % 16 == 0 else 1)
    s_fin, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs,
                             unroll=unroll)
    return ys.transpose(1, 0, 2, 3), s_fin


def wkv_step(r1, k1, v1, w1, u, s):
    """One decode step: r1,k1,w1 (B,H,K); v1 (B,H,V); s (B,H,K,V)."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r1, k1, v1, w1))
    kv = kf[..., :, None] * vf[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", rf, s + u.astype(jnp.float32)[None, :, :, None] * kv)
    s = wf[..., None] * s + kv
    return y, s


# --------------------------------------------------------------------------
# Layer params
# --------------------------------------------------------------------------

_LORA_RANK = 32


def init_time_mix(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    ks = jax.random.split(key, 10)
    sc = 0.02
    dt = pdtype(cfg)
    return {
        # static token-shift mixes per stream
        "mu_r": jnp.full((d,), 0.5, dt),
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_v": jnp.full((d,), 0.5, dt),
        "mu_w": jnp.full((d,), 0.5, dt),
        "mu_g": jnp.full((d,), 0.5, dt),
        "wr": _normal(ks[0], (d, d), dt, sc),
        "wk": _normal(ks[1], (d, d), dt, sc),
        "wv": _normal(ks[2], (d, d), dt, sc),
        "wg": _normal(ks[3], (d, d), dt, sc),
        "wo": _normal(ks[4], (d, d), dt, sc / math.sqrt(2 * cfg.n_layers)),
        # data-dependent decay: w = exp(-exp(w_base + B A x))
        "w_base": jnp.full((d,), -1.0, dt),
        "w_lora_a": _normal(ks[5], (d, _LORA_RANK), dt, sc),
        "w_lora_b": jnp.zeros((_LORA_RANK, d), dt),
        "u": _normal(ks[6], (h, hd), dt, sc),        # per-head bonus
        "ln_scale": jnp.ones((d,), dt),              # post-WKV group norm
    }


def init_channel_mix(key, cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    dt = pdtype(cfg)
    return {
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_r": jnp.full((d,), 0.5, dt),
        "wk": _normal(ks[0], (d, f), dt, 0.02),
        "wv": _normal(ks[1], (f, d), dt, 0.02 / math.sqrt(2 * cfg.n_layers)),
        "wr": _normal(ks[0], (d, d), dt, 0.02),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / carried last token at t=0)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu


def _decay(p, xw):
    ww = xw @ p["w_lora_a"] @ p["w_lora_b"]
    log_w = -jnp.exp(
        jnp.clip((p["w_base"] + ww).astype(jnp.float32), -20.0, 8.0)
    )
    return jnp.exp(log_w)  # in (0, 1)


def _group_norm(x, scale, h, eps=1e-5):
    """Per-head layer norm on (B, T, D) viewed as (B,T,H,hd)."""
    b, t, d = x.shape
    xh = x.reshape(b, t, h, d // h).astype(jnp.float32)
    m = jnp.mean(xh, axis=-1, keepdims=True)
    v = jnp.mean((xh - m) ** 2, axis=-1, keepdims=True)
    y = (xh - m) * jax.lax.rsqrt(v + eps)
    return (y.reshape(b, t, d) * scale.astype(jnp.float32)).astype(x.dtype)


def time_mix_apply(p: Params, x, cfg: ModelConfig, state=None):
    """state = (last_token (B,1,D), wkv_state (B,H,K,V)) or None (training
    from zeros).  Returns (out, new_state)."""
    b, t, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    last = None if state is None else state[0]
    s0 = None if state is None else state[1]
    xs = _shift(x, last)
    xr, xk, xv, xw, xg = (
        _lerp(x, xs, p[m]) for m in ("mu_r", "mu_k", "mu_v", "mu_w", "mu_g")
    )
    r = (xr @ p["wr"]).reshape(b, t, h, hd)
    k = (xk @ p["wk"]).reshape(b, t, h, hd)
    v = (xv @ p["wv"]).reshape(b, t, h, hd)
    g = jax.nn.silu(xg @ p["wg"])
    w = _decay(p, xw).reshape(b, t, h, hd)

    y, s_fin = wkv_scan(r, k, v, w, p["u"], s0)
    y = y.reshape(b, t, d).astype(x.dtype)
    y = _group_norm(y, p["ln_scale"], h, cfg.norm_eps)
    out = (y * g) @ p["wo"]
    return out, (x[:, -1:], s_fin)


def channel_mix_apply(p: Params, x, state=None):
    last = None if state is None else state
    xs = _shift(x, last)
    xk = _lerp(x, xs, p["mu_k"])
    xr = _lerp(x, xs, p["mu_r"])
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"]), x[:, -1:]


def init_rwkv_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"time": init_time_mix(k1, cfg), "channel": init_channel_mix(k2, cfg)}


def make_rwkv_state(cfg: ModelConfig, b: int, dtype=jnp.float32):
    """Decode state for one block."""
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    return {
        "tm_last": jnp.zeros((b, 1, d), dtype),
        "wkv": jnp.zeros((b, h, hd, hd), jnp.float32),
        "cm_last": jnp.zeros((b, 1, d), dtype),
    }
