"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with shifted-compression gradient exchange, comparing the wire-bit cost
of dense vs DIANA-compressed training at matched loss.

This is the paper's technique doing its actual job on the framework's
actual substrate: per-worker grads -> shifted compression -> compressed
mean -> AdamW, with periodic checkpointing.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(CPU: ~100M params; expect a few minutes.)
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.configs import get_config, get_smoke_config
from repro.configs.base import CompressionConfig, TrainConfig
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_host_mesh, n_workers
from repro.launch.train import build_train_step, init_state
from repro.models import model as M


def make_100m_cfg():
    """A ~100M dense GQA config (qwen3-0.6b family, trimmed)."""
    return get_config("qwen3-0.6b").with_(
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=32768, dtype="float32",
    )


def run(comp: CompressionConfig, steps: int, batch: int, seq: int,
        label: str, cfg=None, metrics_out=None):
    cfg = make_100m_cfg() if cfg is None else cfg
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=steps,
                       warmup_steps=max(1, steps // 20), compression=comp)
    mesh = make_host_mesh()
    w = n_workers(mesh)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg, w)
    step_fn = jax.jit(build_train_step(cfg, tcfg, mesh, w))
    stream = TokenStream(cfg, seq, batch)

    sink = None
    if metrics_out is not None:
        from repro import obs
        sink = obs.JsonlSink(metrics_out)
        sink.emit(obs.run_record(
            label, workers=w, steps=steps, batch=batch, seq=seq,
            shift_rule=comp.shift_rule if comp.enabled else "none",
        ))

    n_params = M.count_params_analytic(cfg)
    print(f"\n[{label}] params={n_params/1e6:.1f}M workers={w} "
          f"rule={comp.shift_rule if comp.enabled else 'none'}")
    t0 = time.time()
    losses = []
    for i in range(steps):
        ts = time.perf_counter()
        state, metrics = step_fn(state, stream.batch(i))
        losses.append(float(metrics["loss"]))
        if sink is not None:
            from repro import obs
            jax.block_until_ready(state.params)
            sink.emit(obs.step_record(
                i, run=label, loss=losses[-1],
                bits=float(metrics["bits"]),
                step_s=time.perf_counter() - ts,
            ))
        if i % max(1, steps // 10) == 0 or i == steps - 1:
            print(f"  step {i:4d} loss {losses[-1]:.4f} "
                  f"bits {float(metrics['bits']):.3e} "
                  f"({time.time()-t0:.0f}s)")
    if sink is not None:
        sink.close()
    save(f"/tmp/repro_{label}.npz", state.params, step=steps)
    return losses, float(state.bits)


def main(argv=None):
    from repro.comm import WIRE_CODEC_FLAGS

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--moe-wire", "--moe_wire", dest="moe_wire",
                    default="none", choices=list(WIRE_CODEC_FLAGS),
                    help="also route the MoE dispatch/combine all-to-all "
                         "through this codec (switches the model to the "
                         "qwen2-moe smoke config, which has experts)")
    ap.add_argument("--act-wire", "--act_wire", dest="act_wire",
                    default="none", choices=list(WIRE_CODEC_FLAGS),
                    help="compress pipeline-boundary activations with "
                         "this codec")
    ap.add_argument("--metrics_out", "--metrics-out", dest="metrics_out",
                    default=None,
                    help="write per-step obs records (schema-valid JSONL) "
                         "for both the dense and compressed runs")
    args = ap.parse_args(argv)

    # the moe wire needs experts to dispatch; everything else runs the
    # ~100M dense config
    cfg = (get_smoke_config("qwen2-moe-a2.7b").with_(dtype="float32")
           if args.moe_wire != "none" else make_100m_cfg())

    dense_losses, _ = run(
        CompressionConfig(enabled=False), args.steps, args.batch, args.seq,
        "dense", cfg=cfg, metrics_out=args.metrics_out,
    )
    diana_losses, diana_bits = run(
        CompressionConfig(enabled=True, compressor="natural",
                          shift_rule="diana", shift_alpha=0.5,
                          moe_wire=args.moe_wire, act_wire=args.act_wire),
        args.steps, args.batch, args.seq, "diana-natural", cfg=cfg,
        metrics_out=args.metrics_out,
    )

    import numpy as np
    k = max(1, args.steps // 10)
    d_tail = float(np.mean(dense_losses[-k:]))
    c_tail = float(np.mean(diana_losses[-k:]))
    dense_bits_step = 32 * M.count_params_analytic(cfg)
    comp_bits_step = diana_bits / args.steps / 2  # w=1 host: per worker
    print(f"\nfinal loss: dense {d_tail:.4f} vs diana {c_tail:.4f} "
          f"(gap {c_tail - d_tail:+.4f})")
    print(f"uplink bits/worker/step: dense(f32) {dense_bits_step:.2e} vs "
          f"compressed {comp_bits_step:.2e} "
          f"({dense_bits_step / max(comp_bits_step,1):.1f}x reduction)")
    if args.metrics_out is not None:
        from repro import obs
        print(obs.summary_table(obs.read_jsonl(args.metrics_out),
                                name="train_lm"))


if __name__ == "__main__":
    main()
