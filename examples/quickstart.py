"""Quickstart: the paper's core objects in ~60 lines.

1. Build a shifted compressor and see its defining property.
2. Run DCGD-SHIFT (Alg. 1) with three shift rules on ridge regression.
3. Train a tiny LM with DIANA-compressed gradients via the launch layer.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    DCGDShift,
    DianaShift,
    FixedShift,
    NaturalCompression,
    RandDianaShift,
    RandK,
    rand_diana_default_p,
    shifted,
    stepsize_diana,
    stepsize_rand_diana,
    stepsize_dcgd_fixed,
)
from repro.core.simulate import run_dcgd_shift
from repro.data.problems import make_ridge

# --- 1. shifted compressors -------------------------------------------------
q = NaturalCompression()
x = jnp.asarray([1.3, -0.7, 4.2, 0.05])
h = jnp.asarray([1.0, -0.5, 4.0, 0.0])
print("Q(x)    =", q(jax.random.PRNGKey(0), x))
print("Q_h(x)  =", shifted(q, h, jax.random.PRNGKey(0), x))
print("Q_h(h)  =", shifted(q, h, jax.random.PRNGKey(0), h),
      "<- exact at the shift: variance vanishes at h, not at 0")

# --- 2. DCGD-SHIFT on the paper's ridge problem ------------------------------
prob = make_ridge(m=100, d=80, n_workers=10)
comp = RandK(0.25)
omega = comp.omega(prob.d)

gamma = stepsize_dcgd_fixed(prob.L, prob.L_max, omega, prob.n_workers)
t1 = run_dcgd_shift(prob, DCGDShift(q=comp, rule=FixedShift()), gamma, 5000)

alpha, gamma = stepsize_diana(prob.L_max, omega, 0.0, prob.n_workers)
t2 = run_dcgd_shift(prob, DCGDShift(q=comp, rule=DianaShift(alpha=alpha)),
                    gamma, 5000)

p = rand_diana_default_p(omega)
_, gamma = stepsize_rand_diana(prob.L_max, omega, prob.n_workers, p)
t3 = run_dcgd_shift(prob, DCGDShift(q=comp, rule=RandDianaShift(p=p)),
                    gamma, 5000)

print("\nrel_err after 5000 steps (ridge, Rand-K q=0.25):")
print(f"  DCGD (h=0):   {t1.rel_err[-1]:.3e}   <- stalls in a neighborhood")
print(f"  DIANA:        {t2.rel_err[-1]:.3e}   <- exact convergence")
print(f"  Rand-DIANA:   {t3.rel_err[-1]:.3e}   <- exact, simpler analysis")

# --- 3. a tiny LM trained with compressed gradients --------------------------
from repro.launch import train as T

print("\ntiny LM with DIANA-compressed gradient exchange:")
T.main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "10",
        "--batch", "4", "--seq", "64"])
