"""Serve a small model with batched requests: prefill-free batched greedy
decode against rolling KV caches / recurrent state, across three arch
families (dense GQA, MLA+MoE, RWKV) through the same serve_step API.

Run:  PYTHONPATH=src python examples/serve_batch.py [--model_wire q8]

``--model_wire`` also prints the trainer->serving downlink accounting:
the structural bytes/step of a ``Wire("model", broadcast, ...)`` that
would keep these replicas fresh (see repro.serving.delta).
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.launch.serve import build_serve_step
from repro.models import model as M


def serve(arch: str, batch: int = 4, gen: int = 48):
    cfg = get_smoke_config(arch).with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    enc_len = 16 if cfg.is_encoder_decoder else 0
    state = M.make_decode_state(cfg, batch, cache_len=64, enc_len=enc_len)
    step = jax.jit(build_serve_step(cfg))

    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, 1), 0,
                              cfg.vocab_size)
    # warmup/compile
    logits, st = step(params, state, toks, jnp.int32(0))
    jax.block_until_ready(logits)

    t0 = time.time()
    state = st
    out = [toks[:, 0]]
    for t in range(1, gen):
        logits, state = step(params, state, toks, jnp.int32(t))
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(toks[:, 0])
    jax.block_until_ready(toks)
    dt = time.time() - t0
    total = batch * (gen - 1)
    print(f"{arch:24s} {total:4d} tokens in {dt:5.2f}s  "
          f"{total/dt:7.1f} tok/s (batched greedy, CPU smoke cfg)")
    return jnp.stack(out, 1)


def continuous_batching_demo():
    """The serving ENGINE: requests of different lengths admitted into
    recycled slots on a shared decode clock (see repro.serving)."""
    from repro.serving import Engine, Request

    cfg = get_smoke_config("qwen3-0.6b").with_(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_batch=3, cache_len=128)
    prompts = [[5, 17, 99], [42, 7], [123, 9, 11, 2], [88, 3], [3, 1, 4],
               [2, 7, 1, 8], [61, 80]]
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=16))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total = sum(len(r.output) for r in done)
    print(f"\ncontinuous batching: {len(done)} requests, {total} tokens "
          f"in {dt:.2f}s over {eng.clock} shared-clock ticks "
          f"(3 slots, {len(prompts)} requests)")
    for r in sorted(done, key=lambda r: r.uid)[:3]:
        print(f"  req {r.uid}: prompt {r.prompt} -> {r.output[:8]}...")


def downlink_accounting(arch: str, model_wire: str, publish_every: int):
    """Structural bytes of the model-delta downlink for this arch —
    read from the transport's shared obs snapshot (the same per-wire
    records ``--metrics_out`` persists and the tune predictor charges),
    so this print, the dryrun table, and the trainer JSONL all report
    identical numbers."""
    from repro.comm import build_transport
    from repro.configs.base import CompressionConfig
    from repro.obs import format_table

    cfg = get_smoke_config(arch).with_(dtype="float32")
    params_shapes = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    comp = CompressionConfig(enabled=False, model_wire=model_wire,
                             publish_every=publish_every)
    transport = build_transport(comp, cfg, None, params_like=params_shapes)
    snap = transport.obs_snapshot()
    rows = [
        (name, rec["topology"], rec["codec"],
         f"{rec['wire_bits'] / 8e6:.3f}",
         f"{rec['payload_bytes'] / 1e6:.3f}")
        for name, rec in sorted(snap.items())
    ]
    print(format_table(
        f"model downlink [{arch}] wire={model_wire} "
        f"publish_every={publish_every} (obs snapshot: protocol bits "
        "vs container payload)",
        ["wire", "topology", "codec", "MB/step (wire)", "MB/step (payload)"],
        rows,
    ))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model_wire", "--model-wire", dest="model_wire",
                    default="none",
                    help="print downlink wire accounting for this codec "
                         "flag (q8/natural/dense/...)")
    ap.add_argument("--publish_every", "--publish-every",
                    dest="publish_every", type=int, default=2)
    args = ap.parse_args(argv)

    print("batched serving across architecture families:")
    for arch in ("qwen3-0.6b", "deepseek-v2-lite-16b", "rwkv6-3b",
                 "zamba2-1.2b"):
        serve(arch)
    continuous_batching_demo()
    if args.model_wire != "none":
        downlink_accounting("qwen3-0.6b", args.model_wire,
                            args.publish_every)


if __name__ == "__main__":
    main()
