"""The paper's Section 4 experiments, runnable end-to-end: reproduces the
qualitative content of Figures 1 and 2 and prints the trajectories as
text sparklines (no matplotlib dependency).

Run:  PYTHONPATH=src python examples/paper_convex.py
"""

import numpy as np

from repro.core import (
    DCGDShift,
    DianaShift,
    FixedShift,
    RandDianaShift,
    RandK,
    rand_diana_default_p,
    stepsize_dcgd_fixed,
    stepsize_diana,
    stepsize_rand_diana,
)
from repro.core.simulate import run_dcgd_shift
from repro.data.problems import make_ridge

BARS = " .:-=+*#%@"


def spark(errs, width=64):
    errs = np.asarray(errs)
    idx = np.linspace(0, len(errs) - 1, width).astype(int)
    lg = np.log10(np.maximum(errs[idx], 1e-16))
    lo, hi = lg.min(), max(lg.max(), lo_ := lg.min() + 1e-9)
    t = (lg - lo) / (hi - lo)
    return "".join(BARS[int(round(v * (len(BARS) - 1)))] for v in t)


def main():
    prob = make_ridge(m=100, d=80, n_workers=10, seed=0)
    q = RandK(0.25)
    omega = q.omega(prob.d)
    steps = 8000

    print(f"ridge d={prob.d} n=10 kappa={prob.kappa:.0f}; "
          f"Rand-K q=0.25 (omega={omega:.1f}); log10 rel_err over "
          f"{steps} steps  (@=start, ' '=converged)\n")

    g = stepsize_dcgd_fixed(prob.L, prob.L_max, omega, prob.n_workers)
    tr = run_dcgd_shift(prob, DCGDShift(q=q, rule=FixedShift()), g, steps)
    print(f"DCGD        |{spark(tr.rel_err)}| final {tr.rel_err[-1]:.1e}")

    a, g = stepsize_diana(prob.L_max, omega, 0.0, prob.n_workers)
    tr = run_dcgd_shift(prob, DCGDShift(q=q, rule=DianaShift(alpha=a)),
                        g, steps)
    print(f"DIANA       |{spark(tr.rel_err)}| final {tr.rel_err[-1]:.1e}")

    p = rand_diana_default_p(omega)
    _, g = stepsize_rand_diana(prob.L_max, omega, prob.n_workers, p)
    tr = run_dcgd_shift(prob, DCGDShift(q=q, rule=RandDianaShift(p=p)),
                        g, steps)
    print(f"Rand-DIANA  |{spark(tr.rel_err)}| final {tr.rel_err[-1]:.1e}")

    print("\nRand-DIANA stability in the M multiplier (Fig 2-left):")
    from repro.core import stepsize_rand_diana as ssrd
    for b in (0.25, 1.0, 1.5):
        _, g = ssrd(prob.L_max, omega, prob.n_workers, p, M_mult=b)
        tr = run_dcgd_shift(prob, DCGDShift(q=q, rule=RandDianaShift(p=p)),
                            g, steps)
        status = "diverged" if (not np.isfinite(tr.rel_err[-1])
                                or tr.rel_err[-1] > 1) else "ok"
        print(f"  M = {b:4.2f} * M'  |{spark(tr.rel_err)}| "
              f"final {tr.rel_err[-1]:.1e} [{status}]")


if __name__ == "__main__":
    main()
